"""Fault corpus + scored detector harness tests.

Three layers:

* scoreboard unit tests — classification, window scoring, floors, bench
  diffs (pure functions, no processes);
* daemon plumbing — fault-marker ingestion, attach backoff/give-up,
  poisoned verdict callbacks, detector recovery transitions, straggler and
  phase-segmentation edge cases;
* one end-to-end smoke (marked slow) — the injected_spin scenario through
  real child + agent + daemon processes.
"""

from __future__ import annotations

import json
import os
import time

import pytest

from repro.core.calltree import CallTree
from repro.core.detector import (
    LIVELOCK,
    LIVELOCK_CLEARED,
    DominanceDetector,
    Rule,
    StragglerDetector,
    TrendDetector,
    TrendRule,
    WatchdogLoop,
    segment_phases,
)
from repro.faults.scoreboard import (
    DETECTOR_COLUMNS,
    build_bench,
    detector_of,
    diff_bench,
    floor_report,
    score_runs,
)
from repro.profilerd.daemon import FAULT_MARKERS_FILENAME, DaemonConfig, ProfilerDaemon
from repro.profilerd.daemon import rule_from_spec, rule_to_spec
from repro.profilerd.spool import SpoolWriter
from repro.profilerd.wire import Encoder, RawFrame, RawSample


def wait_until(pred, timeout_s=10.0, interval_s=0.01, desc="condition"):
    deadline = time.monotonic() + timeout_s
    while True:
        value = pred()
        if value:
            return value
        if time.monotonic() >= deadline:
            raise AssertionError(f"timed out after {timeout_s:g}s waiting for {desc}")
        time.sleep(interval_s)


class FakeTarget:
    """Deterministic spool publisher (same shape as test_profilerd's)."""

    def __init__(self, path, leaf: str = "leaf_fn", pid: int = 0):
        self.path = str(path)
        self.leaf = leaf
        self.writer = SpoolWriter(self.path, capacity=1 << 20)
        self.enc = Encoder()
        self.n = 0
        self.writer.write(self.enc.encode_hello(pid or os.getpid(), 0.01))

    def emit(self, k: int = 1, leaf=None):
        frames = [
            RawFrame("/fake/app.py", "main", 1),
            RawFrame("/fake/app.py", leaf or self.leaf, 2),
        ]
        for _ in range(k):
            payload, fresh = self.enc.encode_tick(
                [RawSample(self.n * 0.01, 1, "w", frames)]
            )
            if self.writer.write(payload):
                self.n += 1
            else:
                self.enc.rollback(fresh)
        return self

    def bye(self):
        self.writer.write_bye(self.enc.encode_bye(self.n))
        self.writer.close()


# ---------------------------------------------------------------------------
# scoreboard


def _ev(kind, detector=None, wall=100.0, **extra):
    ev = {"kind": kind, "wall_time": wall}
    if detector is not None:
        ev["detector"] = detector
    ev.update(extra)
    return ev


class TestDetectorClassification:
    def test_scored_columns(self):
        assert detector_of(_ev("LIVELOCK_SUSPECT", "dominance")) == "dominance"
        assert detector_of(_ev("LIVELOCK", "trend")) == "trend_livelock"
        assert detector_of(_ev("SHARE_DRIFT", "trend")) == "trend_drift"
        assert detector_of(_ev("TARGET_STALLED", "stall")) == "stall"
        assert detector_of(_ev("STRAGGLER", "straggler")) == "straggler"

    def test_informational_and_lifecycle_unscored(self):
        assert detector_of(_ev("DOMINANT", "trend")) is None  # hot != anomalous
        for kind in ("TARGET_ATTACHED", "FAULT_INJECT", "FAULT_CLEAR",
                     "CALLBACK_FAILED", "SOURCE_GAVE_UP", "FAULT_MARKER_INVALID"):
            assert detector_of(_ev(kind)) is None

    def test_recovery_kinds_unscored(self):
        assert detector_of(_ev("LIVELOCK_CLEARED", "trend")) is None
        assert detector_of(_ev("TARGET_RESUMED", "stall")) is None


class TestScoreRuns:
    T_INJECT, T_CLEAR, EPOCH = 100.0, 104.0, 0.5

    def _score(self, fault_events, control_events=()):
        return score_runs(
            list(fault_events),
            list(control_events),
            t_inject=self.T_INJECT,
            t_clear=self.T_CLEAR,
            epoch_s=self.EPOCH,
            grace_epochs=2,
        )

    def test_in_window_verdict_is_detection_with_ttd(self):
        cells = self._score([_ev("LIVELOCK_SUSPECT", "dominance", wall=101.0)])
        dom = cells["dominance"]
        assert dom.detected and dom.true_positives == 1
        assert dom.ttd_s == pytest.approx(1.0)
        assert dom.ttd_epochs == pytest.approx(2.0)  # 1.0s / 0.5s epochs

    def test_pre_inject_verdict_is_fault_run_fp(self):
        cells = self._score([_ev("LIVELOCK_SUSPECT", "dominance", wall=99.0)])
        dom = cells["dominance"]
        assert not dom.detected and dom.fault_run_fps == 1

    def test_grace_window_bounds(self):
        inside = _ev("SHARE_DRIFT", "trend", wall=self.T_CLEAR + 0.9)  # within 2*0.5
        outside = _ev("SHARE_DRIFT", "trend", wall=self.T_CLEAR + 1.1)
        cells = self._score([inside, outside])
        drift = cells["trend_drift"]
        assert drift.detected and drift.true_positives == 1 and drift.fault_run_fps == 1

    def test_control_events_are_fps(self):
        cells = self._score([], [_ev("STRAGGLER", "straggler", wall=50.0)])
        assert cells["straggler"].control_fps == 1
        assert not cells["straggler"].detected

    def test_recovery_observed(self):
        cells = self._score([
            _ev("LIVELOCK", "trend", wall=101.0),
            _ev("LIVELOCK_CLEARED", "trend", wall=104.5),
            _ev("TARGET_RESUMED", "stall", wall=104.5),
        ])
        assert cells["trend_livelock"].detected
        assert cells["trend_livelock"].recovery_observed
        assert cells["stall"].recovery_observed

    def test_all_columns_present(self):
        assert set(self._score([])) == set(DETECTOR_COLUMNS)


class TestFloorsAndDiff:
    def _cells(self, detected=True, ttd=1.5, control_fps=0):
        cells = score_runs([], [], t_inject=0.0, t_clear=1.0, epoch_s=0.5)
        cell = cells["dominance"]
        cell.detected = detected
        cell.ttd_epochs = ttd if detected else None
        cell.control_fps = control_fps
        return cells

    def test_floor_passes_when_detected_fast_and_clean(self):
        rep = floor_report({"spin": self._cells()})
        assert rep["pass"] and rep["problems"] == []
        assert rep["per_scenario"]["spin"]["best_ttd_epochs"] == 1.5

    def test_floor_fails_on_missed_scenario(self):
        rep = floor_report({"spin": self._cells(detected=False)})
        assert not rep["pass"] and "no detector fired" in rep["problems"][0]

    def test_floor_fails_on_slow_detection(self):
        rep = floor_report({"spin": self._cells(ttd=11.0)}, ttd_floor_epochs=10.0)
        assert not rep["pass"] and "time-to-detect" in rep["problems"][0]

    def test_floor_fails_on_control_fp(self):
        rep = floor_report({"spin": self._cells(control_fps=2)})
        assert not rep["pass"] and "false positive" in rep["problems"][-1]

    def _bench(self, cells):
        return build_bench({"spin": cells}, config={})

    def test_diff_flags_detected_to_missed(self):
        problems = diff_bench(self._bench(self._cells()), self._bench(self._cells(detected=False)))
        assert any("detected -> missed" in p for p in problems)

    def test_diff_flags_new_control_fp(self):
        problems = diff_bench(self._bench(self._cells()), self._bench(self._cells(control_fps=1)))
        assert any("false positive" in p for p in problems)

    def test_diff_tolerates_skipped_scenario(self):
        base = self._bench(self._cells())
        new = build_bench({}, config={}, skipped={"spin": "missing dependency: jax"})
        assert diff_bench(base, new) == []

    def test_diff_flags_vanished_scenario(self):
        base = self._bench(self._cells())
        new = build_bench({}, config={})
        assert any("missing from new run" in p for p in diff_bench(base, new))

    def test_diff_ignores_latency_changes(self):
        problems = diff_bench(self._bench(self._cells(ttd=1.0)), self._bench(self._cells(ttd=9.0)))
        assert problems == []


# ---------------------------------------------------------------------------
# trend recovery + phase/straggler edges (satellite: only onset was covered)


def _window(leaf: str, n: int = 50) -> CallTree:
    t = CallTree()
    for _ in range(n):
        t.add_stack(["main", "loop", leaf])
    return t


def _diverse_window(n: int = 50) -> CallTree:
    t = CallTree()
    for i in range(n):
        t.add_stack(["main", "loop", f"op{i % 5}"])
    return t


class TestTrendRecovery:
    def test_livelock_clears_when_dominance_breaks(self):
        det = TrendDetector(TrendRule(epochs=2, min_baseline_epochs=99))
        det.observe_epoch(_diverse_window(), progress=10)
        for _ in range(3):  # dominance + stalled progress -> LIVELOCK
            det.observe_epoch(_window("spin"), progress=10)
        assert det.livelock_active
        assert det.detection_latency(LIVELOCK) == 1  # began epoch 1, fired epoch 2
        out = det.observe_epoch(_diverse_window(), progress=11)
        cleared = [v for v in out if v.kind == LIVELOCK_CLEARED]
        assert len(cleared) == 1
        assert not det.livelock_active
        # stamped with the onset epoch, so wedged-time is reconstructable
        assert cleared[0].began_epoch == det.first_detection(LIVELOCK).began_epoch
        assert cleared[0].epoch > cleared[0].began_epoch

    def test_livelock_clears_when_progress_resumes(self):
        det = TrendDetector(TrendRule(epochs=2, min_baseline_epochs=99))
        for _ in range(3):
            det.observe_epoch(_window("spin"), progress=5)
        assert det.livelock_active
        # same dominant frame, but the target is minting new stacks again
        out = det.observe_epoch(_window("spin"), progress=6)
        assert [v.kind for v in out] == [LIVELOCK_CLEARED]
        assert not det.livelock_active

    def test_cleared_emitted_once_per_onset(self):
        det = TrendDetector(TrendRule(epochs=2, min_baseline_epochs=99))
        for _ in range(3):
            det.observe_epoch(_window("spin"), progress=5)
        det.observe_epoch(_diverse_window(), progress=6)
        out = det.observe_epoch(_diverse_window(), progress=7)
        assert [v.kind for v in out] == []


class TestSegmentPhasesEdges:
    def test_empty_sequence(self):
        assert segment_phases([]) == []

    def test_single_epoch_is_one_phase(self):
        assert segment_phases([{"a": 1.0}]) == [(0, 0)]

    def test_identical_vectors_are_one_phase(self):
        vecs = [{"a": 0.5, "b": 0.5}] * 6
        assert segment_phases(vecs) == [(0, 5)]

    def test_empty_share_vectors(self):
        # all-empty vectors have zero TV distance: one phase, no crash
        assert segment_phases([{}, {}, {}]) == [(0, 2)]

    def test_jump_splits_phases(self):
        vecs = [{"a": 1.0}] * 3 + [{"b": 1.0}] * 2
        assert segment_phases(vecs) == [(0, 2), (3, 4)]


class TestStragglerEdges:
    def test_empty_and_single_host(self):
        det = StragglerDetector()
        assert det.observe({}) == []
        assert det.observe({"h0": _window("x")}) == []

    def test_identical_hosts_silent(self):
        det = StragglerDetector(threshold=0.2)
        hosts = {f"h{i}": _diverse_window() for i in range(4)}
        assert det.observe(hosts) == []

    def test_empty_tree_host(self):
        # a host with no samples at all must not crash the fleet comparison
        det = StragglerDetector(threshold=0.4)
        hosts = {"h0": CallTree(), "h1": _diverse_window(), "h2": _diverse_window()}
        flagged = det.observe(hosts)
        assert all(h != "h1" and h != "h2" for h, _ in flagged)

    def test_divergent_host_flagged_despite_deep_shared_prefix(self):
        # self-share comparison: a deep common prefix must not dilute the
        # divergence (inclusive shares would)
        deep = ["bootstrap", "runtime", "main", "train", "step"]
        healthy = CallTree()
        for i in range(100):
            healthy.add_stack(deep + [f"op{i % 5}"])
        parked = CallTree()
        for _ in range(100):
            parked.add_stack(deep + ["collective_wait"])
        hosts = {"h0": healthy.copy(), "h1": healthy.copy(), "h2": parked}
        flagged = StragglerDetector(threshold=0.5).observe(hosts)
        assert [h for h, _ in flagged] == ["h2"]


# ---------------------------------------------------------------------------
# callback hardening (satellite: a poison callback must not kill sampling)


class TestCallbackHardening:
    def _firing_detector(self, *callbacks):
        det = DominanceDetector([Rule(threshold=0.5, consecutive=1, min_window_total=1)])
        for cb in callbacks:
            det.add_callback(cb)
        return det

    def test_poison_callback_does_not_break_later_callbacks(self):
        seen = []
        det = self._firing_detector(
            lambda ev: (_ for _ in ()).throw(RuntimeError("poison")),
            seen.append,
        )
        fired = det.observe(_window("hot"))
        assert fired and seen == fired
        assert len(det.callback_failures) == 1
        ev, tb = det.callback_failures[0]
        assert ev is fired[0] and "poison" in tb

    def test_on_callback_error_hook_receives_traceback(self):
        hook_calls = []
        det = self._firing_detector(lambda ev: 1 / 0)
        det.on_callback_error = lambda ev, tb: hook_calls.append((ev, tb))
        det.observe(_window("hot"))
        assert len(hook_calls) == 1 and "ZeroDivisionError" in hook_calls[0][1]

    def test_failing_error_hook_is_swallowed(self):
        det = self._firing_detector(lambda ev: 1 / 0)
        det.on_callback_error = lambda ev, tb: (_ for _ in ()).throw(ValueError("sink"))
        assert det.observe(_window("hot"))  # must not raise

    def test_detector_keeps_firing_after_poison(self):
        det = self._firing_detector(lambda ev: 1 / 0)
        cum = _window("hot")
        det.observe(cum.copy())
        for _ in range(50):  # snapshots are cumulative; grow the window
            cum.add_stack(["main", "loop", "hot"])
        fired = det.observe(cum.copy())
        assert fired and len(det.callback_failures) == 2

    def test_watchdog_records_observe_errors_and_keeps_running(self):
        class BrokenSampler:
            calls = 0

            def snapshot(self):
                BrokenSampler.calls += 1
                raise RuntimeError("sampler exploded")

        det = DominanceDetector([Rule()])
        wd = WatchdogLoop(BrokenSampler(), det, interval_s=0.01)
        wd.start()
        try:
            wait_until(lambda: len(wd.errors) >= 2, desc="watchdog surviving errors")
            assert wd._thread.name == "repro-prof-watchdog"
        finally:
            wd.stop()
        assert any("sampler exploded" in tb for tb in wd.errors)


# ---------------------------------------------------------------------------
# daemon plumbing: markers, backoff, give-up


class TestFaultMarkerIngestion:
    def _daemon(self, tmp_path, **cfg_kw):
        spool = str(tmp_path / "t.spool")
        target = FakeTarget(spool, leaf="work_fn")
        target.emit(5)
        cfg = DaemonConfig(
            spool_paths=(spool,),
            out_dir=str(tmp_path / "out"),
            epoch_s=0.05,
            **cfg_kw,
        )
        daemon = ProfilerDaemon(cfg)
        daemon.attach()
        daemon.drain()
        return daemon, target

    def _write_marker(self, daemon, line: str):
        path = os.path.join(daemon.out_dir, FAULT_MARKERS_FILENAME)
        with open(path, "a") as f:
            f.write(line)

    def test_markers_become_events_with_epoch_stamp(self, tmp_path):
        daemon, target = self._daemon(tmp_path)
        self._write_marker(
            daemon,
            json.dumps({"op": "inject", "scenario": "spin", "wall_time": 123.0}) + "\n",
        )
        daemon.drain()
        self._write_marker(
            daemon,
            json.dumps({"op": "clear", "scenario": "spin", "wall_time": 125.0}) + "\n",
        )
        daemon.drain()
        kinds = [e["kind"] for e in daemon.events]
        assert "FAULT_INJECT" in kinds and "FAULT_CLEAR" in kinds
        inject = next(e for e in daemon.events if e["kind"] == "FAULT_INJECT")
        assert inject["scenario"] == "spin"
        assert inject["detector"] == "harness"
        assert inject["marker_wall_time"] == 123.0
        assert "epoch" in inject and "target_epochs" in inject
        target.bye()

    def test_partial_marker_line_buffers_until_complete(self, tmp_path):
        daemon, target = self._daemon(tmp_path)
        full = json.dumps({"op": "inject", "scenario": "spin", "wall_time": 1.0}) + "\n"
        self._write_marker(daemon, full[:10])
        daemon.drain()
        assert not [e for e in daemon.events if e["kind"].startswith("FAULT_")]
        self._write_marker(daemon, full[10:])
        daemon.drain()
        assert [e for e in daemon.events if e["kind"] == "FAULT_INJECT"]
        target.bye()

    def test_invalid_marker_line_is_loud_not_fatal(self, tmp_path):
        daemon, target = self._daemon(tmp_path)
        self._write_marker(daemon, "not json at all\n")
        daemon.drain()
        assert [e for e in daemon.events if e["kind"] == "FAULT_MARKER_INVALID"]
        # subsequent valid markers still ingest
        self._write_marker(
            daemon, json.dumps({"op": "inject", "scenario": "s", "wall_time": 1.0}) + "\n"
        )
        daemon.drain()
        assert [e for e in daemon.events if e["kind"] == "FAULT_INJECT"]
        target.bye()


class TestAttachBackoff:
    def test_garbage_target_gives_up_after_budget(self, tmp_path):
        good = str(tmp_path / "good.spool")
        bad = str(tmp_path / "bad.spool")
        target = FakeTarget(good, leaf="work_fn")
        target.emit(3)
        with open(bad, "wb") as f:
            f.write(b"this is not a spool file at all, padded " * 4)
        cfg = DaemonConfig(
            spool_paths=(good, bad),
            out_dir=str(tmp_path / "out"),
            attach_retry_base_s=0.01,
            attach_retry_cap_s=0.02,
            attach_max_attempts=3,
        )
        daemon = ProfilerDaemon(cfg)
        daemon.attach()

        def gave_up():
            daemon.drain()
            return [e for e in daemon.events if e["kind"] == "SOURCE_GAVE_UP"]

        events = wait_until(gave_up, desc="SOURCE_GAVE_UP after retry budget")
        assert events[0]["path"] == bad
        assert events[0]["attempts"] == 3
        assert events[0]["error"]
        # terminal state is visible in status() for /targets + top
        rows = daemon.status()["attach_failures"]
        assert [r for r in rows if r["path"] == bad and r["gave_up"]]
        # and SOURCE_ATTACH_FAILED was logged when the failure first appeared
        assert [e for e in daemon.events if e["kind"] == "SOURCE_ATTACH_FAILED"]
        target.bye()

    def test_rewritten_file_gets_fresh_budget(self, tmp_path):
        calls = []

        def make_source(name, path):
            calls.append(path)
            return None

        from repro.profilerd.sources import SpoolSet

        bad = str(tmp_path / "bad.spool")
        with open(bad, "wb") as f:
            f.write(b"garbage-v1")
        ss = SpoolSet(
            paths=(bad,),
            make_source=make_source,
            attach_retry_base_s=0.001,
            attach_retry_cap_s=0.002,
            attach_max_attempts=2,
        )
        wait_until(
            lambda: (ss.discover(), bad in ss._given_up)[1],
            desc="give-up on garbage path",
        )
        n_before = len(calls)
        ss.discover()
        assert len(calls) == n_before  # parked: no further attach attempts
        time.sleep(0.005)
        with open(bad, "wb") as f:
            f.write(b"garbage-v2-different-length")
        wait_until(
            lambda: (ss.discover(), len(calls) > n_before)[1],
            desc="revival after rewrite",
        )

    def test_backoff_rows_expose_retry_countdown(self, tmp_path):
        from repro.profilerd.sources import SpoolSet

        bad = str(tmp_path / "bad.spool")
        with open(bad, "wb") as f:
            f.write(b"junk")
        ss = SpoolSet(
            paths=(bad,),
            make_source=lambda name, path: None,
            attach_retry_base_s=5.0,
            attach_max_attempts=4,
        )
        ss.discover()
        rows = ss.attach_failure_rows()
        assert rows[0]["attempts"] == 1 and not rows[0]["gave_up"]
        assert rows[0]["retry_in_s"] > 0


class TestRuleSpecRoundtrip:
    def test_roundtrip(self):
        rule = Rule(pattern="allreduce", threshold=0.6, consecutive=3,
                    kind="COLLECTIVE_STALL", self_only=False, min_window_total=8.0)
        spec = rule_to_spec(rule)
        back = rule_from_spec(spec)
        assert (back.pattern, back.threshold, back.consecutive, back.kind,
                back.self_only, back.min_window_total) == (
            rule.pattern, rule.threshold, rule.consecutive, rule.kind,
            rule.self_only, rule.min_window_total)

    def test_unknown_key_rejected(self):
        with pytest.raises(ValueError):
            rule_from_spec("pattern=x,bogus=1")


# ---------------------------------------------------------------------------
# end-to-end smoke: one real scenario through child + agent + daemon


@pytest.mark.slow
class TestHarnessEndToEnd:
    def test_injected_spin_detected_with_ground_truth(self):
        from repro.faults import HarnessConfig, SCENARIOS, run_scenario, score_runs

        cfg = HarnessConfig(clean_s=1.6, fault_s=2.4, recovery_s=1.2)
        res = run_scenario(SCENARIOS["injected_spin"], cfg, control=False)
        kinds = {e["kind"] for e in res.events}
        assert "FAULT_INJECT" in kinds and "FAULT_CLEAR" in kinds
        cells = score_runs(
            res.events, [],
            t_inject=res.t_inject, t_clear=res.t_clear,
            epoch_s=cfg.epoch_s, grace_epochs=cfg.grace_epochs,
        )
        dom = cells["dominance"]
        assert dom.detected, f"no dominance verdict; kinds={sorted(kinds)}"
        assert dom.ttd_epochs is not None and dom.ttd_epochs <= 10
        assert dom.fault_run_fps == 0
