"""repro.profilerd tests: wire codec, spool, daemon lifecycle, backend parity.

The invariants the ISSUE pins down:

* codec roundtrip — raw frames -> codec -> resolver yields symbols identical
  to the in-process backend's ``frame_symbol``/``collapse_stack`` path;
* spool — SPSC ring with wraparound, and a full spool drops whole batches
  with exact accounting (nothing is half-written, nothing is lost silently);
* daemon lifecycle — attach -> sample -> drain -> stop; every stack the agent
  committed to the spool reaches the daemon's tree;
* parity — thread and daemon backends build equivalent trees for the same
  deterministic workload (a worker parked in a stable deep stack);
* out-of-process — `python -m repro.profilerd attach` drains a live target
  from a separate process, and a silent-but-alive target is flagged
  ``TARGET_STALLED`` (the wedged-interpreter case).
"""

import json
import os
import subprocess
import sys
import threading
import time

import pytest

from repro.core import CallTree, SamplerConfig, StackSampler, collapse_stack, frame_symbol, make_sampler
from repro.profilerd.agent import Agent, DaemonBackend
from repro.profilerd.daemon import STALLED, DaemonConfig, ProfilerDaemon
from repro.profilerd.resolver import SymbolResolver
from repro.profilerd.spool import SpoolReader, SpoolWriter
from repro.profilerd.wire import Bye, Decoder, Encoder, Hello, RawFrame, RawSample

SRC_ROOT = os.path.join(os.path.dirname(__file__), "..", "src")


def parked_worker(depth_a_evt):
    """Park a thread in a recognizable, stable 3-deep stack."""

    def parked_level_one():
        parked_level_two()

    def parked_level_two():
        parked_level_three()

    def parked_level_three():
        depth_a_evt.wait()

    parked_level_one()


@pytest.fixture
def parked():
    evt = threading.Event()
    t = threading.Thread(target=parked_worker, args=(evt,), name="parked-worker", daemon=True)
    t.start()
    time.sleep(0.05)  # let it reach the wait
    yield t
    evt.set()
    t.join(timeout=5)


class TestWireCodec:
    def frames(self):
        return [
            RawFrame("/usr/lib/python3/threading.py", "run", 10),
            RawFrame("/site-packages/jax/api.py", "jit", 20),
            RawFrame("/root/repo/src/repro/models/model.py", "forward", 30),
        ]

    def test_roundtrip_single_tick(self):
        enc, dec = Encoder(), Decoder()
        samples = [RawSample(1.5, 42, "MainThread", self.frames())]
        payload, fresh = enc.encode_tick(samples)
        assert fresh  # first tick defines new strings
        events = list(dec.feed(payload))
        assert len(events) == 1
        got = events[0]
        assert isinstance(got, RawSample)
        assert got.t == 1.5 and got.tid == 42 and got.thread_name == "MainThread"
        assert got.frames == self.frames()

    def test_string_interning_across_ticks(self):
        enc, dec = Encoder(), Decoder()
        p1, _ = enc.encode_tick([RawSample(0.0, 1, "t", self.frames())])
        p2, fresh2 = enc.encode_tick([RawSample(0.1, 1, "t", self.frames())])
        assert fresh2 == []  # steady state: no new strings
        assert len(p2) < len(p1) / 2
        evs = list(dec.feed(p1 + p2))
        assert [e.frames for e in evs] == [self.frames(), self.frames()]

    def test_chunked_feed_reassembles_partial_records(self):
        enc, dec = Encoder(), Decoder()
        payload, _ = enc.encode_tick([RawSample(0.0, 1, "t", self.frames())])
        events = []
        for i in range(0, len(payload), 3):  # drip-feed 3 bytes at a time
            events.extend(dec.feed(payload[i : i + 3]))
        assert len(events) == 1 and events[0].frames == self.frames()

    def test_rollback_keeps_stream_decodable(self):
        """A dropped batch must not leave dangling string ids."""
        enc, dec = Encoder(), Decoder()
        dropped, fresh = enc.encode_tick([RawSample(0.0, 1, "t", self.frames())])
        enc.rollback(fresh)  # transport rejected the batch; it is never fed
        kept, _ = enc.encode_tick([RawSample(0.1, 1, "t", self.frames())])
        evs = list(dec.feed(kept))
        assert len(evs) == 1 and evs[0].frames == self.frames()

    def test_hello_bye_roundtrip(self):
        enc, dec = Encoder(), Decoder()
        evs = list(dec.feed(enc.encode_hello(1234, 0.5) + enc.encode_bye(77)))
        assert isinstance(evs[0], Hello) and evs[0].pid == 1234 and evs[0].period_s == 0.5
        assert isinstance(evs[1], Bye) and evs[1].n_ticks == 77

    def test_resolver_matches_thread_backend_symbols(self, parked):
        """Raw capture -> codec -> resolver == frame_symbol on the same frame."""
        frame = sys._current_frames()[parked.ident]
        # thread-backend path
        expected = StackSampler(SamplerConfig(period_s=10))._stack_of(frame)
        # daemon path: raw walk (as the agent does) -> encode -> decode -> resolve
        raw, f = [], frame
        while f is not None:
            raw.append(RawFrame(f.f_code.co_filename, f.f_code.co_name, f.f_lineno))
            f = f.f_back
        raw.reverse()
        payload, _ = Encoder().encode_tick([RawSample(0.0, parked.ident, "w", raw)])
        (sample,) = list(Decoder().feed(payload))
        assert SymbolResolver().resolve_stack(sample.frames) == expected

    def test_resolver_collapse_matches_thread_backend(self, parked):
        frame = sys._current_frames()[parked.ident]
        expected = StackSampler(
            SamplerConfig(period_s=10, collapse_origins=("py",))
        )._stack_of(frame)
        raw, f = [], frame
        while f is not None:
            raw.append(RawFrame(f.f_code.co_filename, f.f_code.co_name, f.f_lineno))
            f = f.f_back
        raw.reverse()
        got = SymbolResolver(("py",)).resolve_stack(raw)
        assert got == expected
        assert "py::*" in got


class TestSpool:
    def test_write_read_roundtrip(self, tmp_path):
        p = str(tmp_path / "s.spool")
        w = SpoolWriter(p, capacity=1024)
        r = SpoolReader(p)
        assert w.write(b"hello") and w.write(b"world")
        assert r.read() == b"helloworld"
        assert r.read() == b""

    def test_wraparound(self, tmp_path):
        p = str(tmp_path / "s.spool")
        w = SpoolWriter(p, capacity=64)
        r = SpoolReader(p)
        blob = bytes(range(48))
        for _ in range(10):  # 480 bytes through a 64-byte ring
            assert w.write(blob)
            assert r.read() == blob
        assert w.dropped == 0

    def test_full_spool_drops_whole_batches_with_accounting(self, tmp_path):
        p = str(tmp_path / "s.spool")
        w = SpoolWriter(p, capacity=100)
        committed = []
        for i in range(10):
            payload = bytes([i]) * 40
            if w.write(payload):
                committed.append(payload)
        assert len(committed) == 2  # 2*40 fit, the rest dropped
        assert w.dropped == 8
        r = SpoolReader(p)
        assert r.dropped == 8
        assert r.read() == b"".join(committed)  # no partial writes

    def test_reader_waits_for_writer(self, tmp_path):
        p = str(tmp_path / "late.spool")

        def create_late():
            time.sleep(0.2)
            SpoolWriter(p, capacity=256).write(b"x")

        threading.Thread(target=create_late, daemon=True).start()
        r = SpoolReader.wait_for(p, timeout_s=5)
        deadline = time.monotonic() + 5
        data = b""
        while not data and time.monotonic() < deadline:
            data = r.read()
            time.sleep(0.01)
        assert data == b"x"


class TestDaemonLifecycle:
    def test_attach_sample_drain_stop_no_loss(self, tmp_path, parked):
        """Every stack the agent committed reaches the daemon's tree."""
        spool = str(tmp_path / "t.spool")
        agent = Agent(spool, period_s=10, spool_bytes=1 << 20)
        committed = 0
        for _ in range(25):
            committed += agent.tick()
        agent.stop()
        assert agent.n_dropped_batches == 0

        daemon = ProfilerDaemon(
            DaemonConfig(spool_path=spool, out_dir=str(tmp_path / "out"), max_seconds=10)
        )
        tree = daemon.run()
        assert daemon.bye_seen
        assert daemon.n_ticks_reported == 25
        assert daemon.n_stacks == committed
        assert tree.total() == committed
        # the parked worker's stable stack must be a hot path
        flat = tree.flatten()
        assert any("parked_level_three" in k for k in flat)

    def test_full_spool_loses_batches_but_not_correctness(self, tmp_path, parked):
        """Tiny spool, no reader: batches drop; the ingested count matches
        exactly what was committed (drop accounting, no corruption)."""
        spool = str(tmp_path / "t.spool")
        agent = Agent(spool, period_s=10, spool_bytes=4096)
        committed = 0
        for _ in range(400):
            committed += agent.tick()
        agent.stop()
        assert agent.n_dropped_batches > 0  # the spool did fill

        daemon = ProfilerDaemon(
            DaemonConfig(spool_path=spool, out_dir=str(tmp_path / "out"), max_seconds=10)
        )
        tree = daemon.run()
        assert tree.total() == committed > 0
        # With no reader draining, the BYE *record* may itself have been
        # dropped (one extra drop beyond the agent's tick-drop count), but the
        # spool-header flag still marks the shutdown as clean.
        assert daemon.bye_seen
        assert daemon.dropped_batches in (
            agent.n_dropped_batches,
            agent.n_dropped_batches + 1,
        )

    def test_stall_verdict_for_silent_live_target(self, tmp_path):
        """Agent goes quiet without BYE while its pid is alive -> TARGET_STALLED.

        The declared period matters: silence only counts as a stall once it
        clearly exceeds the publisher's own cadence (3x), so a slow-ticking
        healthy target is never flagged."""
        spool = str(tmp_path / "t.spool")
        agent = Agent(spool, period_s=0.02)
        agent.tick()
        # no agent.stop(): the 'target' (this test process) wedges silently
        daemon = ProfilerDaemon(
            DaemonConfig(
                spool_path=spool,
                out_dir=str(tmp_path / "out"),
                publish_interval_s=0.05,
                stall_timeout_s=0.2,
                max_seconds=3.0,
            )
        )
        daemon.run()
        kinds = [e["kind"] for e in daemon.events]
        assert STALLED in kinds

    def test_artifacts_published(self, tmp_path, parked):
        spool = str(tmp_path / "t.spool")
        agent = Agent(spool, period_s=10)
        for _ in range(5):
            agent.tick()
        agent.stop()
        out = str(tmp_path / "out")
        ProfilerDaemon(DaemonConfig(spool_path=spool, out_dir=out, max_seconds=10)).run()
        assert sorted(os.listdir(out)) == ["report.html", "status.json", "tree.json"]
        status = json.load(open(os.path.join(out, "status.json")))
        assert status["done"] and status["n_stacks"] > 0 and status["hot_paths"]
        tree = CallTree.from_json(open(os.path.join(out, "tree.json")).read())
        assert tree.total() == status["n_stacks"]


class TestBackendParity:
    def _worker_subtree(self, tree, name="thread::parked-worker"):
        node = tree.root.children.get(name)
        assert node is not None, f"{name} missing; saw {list(tree.root.children)}"
        return node.to_dict()

    def test_thread_and_daemon_trees_equivalent(self, tmp_path, parked):
        """Same parked stack sampled N times by both backends -> identical
        subtrees (structure and counts)."""
        n = 12
        cfg = SamplerConfig(period_s=10, collapse_origins=("py",))

        thread_backend = StackSampler(cfg)
        for _ in range(n):
            thread_backend.sample_now()
        thread_tree = thread_backend.snapshot()

        spool = str(tmp_path / "t.spool")
        agent = Agent(spool, period_s=10)
        for _ in range(n):
            agent.tick()
        agent.stop()
        daemon = ProfilerDaemon(
            DaemonConfig(
                spool_path=spool,
                out_dir=str(tmp_path / "out"),
                collapse_origins=cfg.collapse_origins,
                max_seconds=10,
            )
        )
        daemon_tree = daemon.run()

        assert self._worker_subtree(thread_tree) == self._worker_subtree(daemon_tree)

    def test_make_sampler_backend_selection(self):
        assert isinstance(make_sampler(SamplerConfig(backend="thread")), StackSampler)
        s = make_sampler(SamplerConfig(backend="daemon", spool_path="/tmp/x.spool"))
        assert isinstance(s, DaemonBackend)
        assert s.spawn_daemon is False  # explicit spool => external daemon
        with pytest.raises(ValueError):
            make_sampler(SamplerConfig(backend="perf"))

    def test_env_override_routes_to_external_daemon(self, tmp_path, monkeypatch):
        spool = str(tmp_path / "env.spool")
        monkeypatch.setenv("REPRO_PROFILERD_SPOOL", spool)
        monkeypatch.setenv("REPRO_PROFILERD_PERIOD", "0.123")
        s = make_sampler(SamplerConfig(backend="thread"))
        assert isinstance(s, DaemonBackend)
        assert s.spool_path == spool and s.spawn_daemon is False
        assert s.config.period_s == 0.123


_TARGET = """
import sys, time
sys.path.insert(0, {src!r})
from repro.core import SamplerConfig, make_sampler
s = make_sampler(SamplerConfig(backend="daemon", spool_path={spool!r},
                               spawn_daemon=False, period_s=0.02))
s.start()
def busy_loop_for_profilerd():
    t0 = time.monotonic(); x = 0
    while time.monotonic() - t0 < 1.5:
        x += 1
busy_loop_for_profilerd()
s.stop()
"""


@pytest.mark.slow
class TestEndToEndCLI:
    def test_attach_streams_live_target(self, tmp_path):
        """`python -m repro.profilerd attach` in a separate process drains a
        live publisher and emits a tree whose hot path is the busy loop."""
        spool = str(tmp_path / "e2e.spool")
        out = str(tmp_path / "e2e.out")
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC_ROOT + os.pathsep + env.get("PYTHONPATH", "")
        env.setdefault("JAX_PLATFORMS", "cpu")
        target = subprocess.Popen(
            [sys.executable, "-c", _TARGET.format(src=SRC_ROOT, spool=spool)], env=env
        )
        daemon = subprocess.run(
            [
                sys.executable, "-m", "repro.profilerd", "attach",
                "--spool", spool, "--out", out,
                "--interval", "0.2", "--max-seconds", "30",
            ],
            env=env, capture_output=True, text=True, timeout=60,
        )
        assert target.wait(timeout=30) == 0
        assert daemon.returncode == 0, daemon.stderr
        tree = CallTree.from_json(open(os.path.join(out, "tree.json")).read())
        assert tree.total() > 0
        assert any("busy_loop_for_profilerd" in k for k in tree.flatten())
        assert os.path.exists(os.path.join(out, "report.html"))
