"""repro.profilerd tests: wire codec, spool, daemon lifecycle, backend parity.

The invariants the ISSUE pins down:

* codec roundtrip — raw frames -> codec -> resolver yields symbols identical
  to the in-process backend's ``frame_symbol``/``collapse_stack`` path;
* spool — SPSC ring with wraparound, and a full spool drops whole batches
  with exact accounting (nothing is half-written, nothing is lost silently);
* daemon lifecycle — attach -> sample -> drain -> stop; every stack the agent
  committed to the spool reaches the daemon's tree;
* parity — thread and daemon backends build equivalent trees for the same
  deterministic workload (a worker parked in a stable deep stack);
* out-of-process — `python -m repro.profilerd attach` drains a live target
  from a separate process, and a silent-but-alive target is flagged
  ``TARGET_STALLED`` (the wedged-interpreter case).
"""

import json
import os
import struct
import subprocess
import sys
import threading
import time
import urllib.error
import urllib.request

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # keep property tests running where hypothesis is absent
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.core import CallTree, SamplerConfig, StackSampler, make_sampler
from repro.profilerd.agent import Agent, DaemonBackend
from repro.profilerd.daemon import STALLED, DaemonConfig, ProfilerDaemon
from repro.profilerd.ingest import TreeIngestor
from repro.profilerd.resolver import SymbolResolver
from repro.profilerd.spool import HEADER_SIZE, SpoolError, SpoolReader, SpoolWriter
from repro.profilerd.wire import (
    WIRE_VERSION,
    Bye,
    Decoder,
    Encoder,
    Hello,
    RawFrame,
    RawSample,
    numpy_available,
)

SRC_ROOT = os.path.join(os.path.dirname(__file__), "..", "src")


def wait_until(pred, timeout_s=10.0, interval_s=0.01, desc="condition"):
    """Deadline-poll ``pred`` instead of sleeping a guessed duration.

    The CI matrix runs on noisy shared runners where a fixed sleep is either
    wastefully long or flakily short; every lifecycle test waits on the
    actual state transition and fails with a description on timeout.
    """
    deadline = time.monotonic() + timeout_s
    while True:
        value = pred()
        if value:
            return value
        if time.monotonic() >= deadline:
            raise AssertionError(f"timed out after {timeout_s:g}s waiting for {desc}")
        time.sleep(interval_s)


def _thread_stack_funcs(thread) -> list:
    frame = sys._current_frames().get(thread.ident)
    out = []
    while frame is not None:
        out.append(frame.f_code.co_name)
        frame = frame.f_back
    return out


def _http_get(url: str) -> tuple[int, str]:
    try:
        with urllib.request.urlopen(url, timeout=5) as resp:
            return resp.status, resp.read().decode()
    except urllib.error.HTTPError as e:
        return e.code, e.read().decode()


class FakeTarget:
    """Deterministic spool publisher with full control over bye/crash/restart.

    Unlike :class:`Agent` (which samples this test process's real threads),
    every stack is chosen by the test, so two fake targets are genuinely
    distinct and re-attach/fleet-merge assertions can be exact.
    """

    def __init__(self, path, leaf: str, pid: int = 0, capacity: int = 1 << 20):
        self.path = str(path)
        self.leaf = leaf
        self.writer = SpoolWriter(self.path, capacity=capacity)
        self.enc = Encoder()
        self.n = 0
        self.writer.write(self.enc.encode_hello(pid or os.getpid(), 0.01))

    def emit(self, k: int = 1, leaf=None):
        frames = [
            RawFrame("/fake/app.py", "main", 1),
            RawFrame("/fake/app.py", leaf or self.leaf, 2),
        ]
        for _ in range(k):
            payload, fresh = self.enc.encode_tick(
                [RawSample(self.n * 0.01, 1, "w", frames)]
            )
            if self.writer.write(payload):
                self.n += 1
            else:
                self.enc.rollback(fresh)
        return self

    def bye(self):
        self.writer.write_bye(self.enc.encode_bye(self.n))
        self.writer.close()

    def crash(self):
        """Disappear without a BYE (the writer process died)."""
        self.writer.close()


def parked_worker(depth_a_evt):
    """Park a thread in a recognizable, stable 3-deep stack."""

    def parked_level_one():
        parked_level_two()

    def parked_level_two():
        parked_level_three()

    def parked_level_three():
        depth_a_evt.wait()

    parked_level_one()


@pytest.fixture
def parked():
    evt = threading.Event()
    t = threading.Thread(target=parked_worker, args=(evt,), name="parked-worker", daemon=True)
    t.start()
    wait_until(
        lambda: "parked_level_three" in _thread_stack_funcs(t),
        desc="parked worker reaching its wait()",
    )
    yield t
    evt.set()
    t.join(timeout=5)


class TestWireCodec:
    def frames(self):
        return [
            RawFrame("/usr/lib/python3/threading.py", "run", 10),
            RawFrame("/site-packages/jax/api.py", "jit", 20),
            RawFrame("/root/repo/src/repro/models/model.py", "forward", 30),
        ]

    def test_roundtrip_single_tick(self):
        enc, dec = Encoder(), Decoder()
        samples = [RawSample(1.5, 42, "MainThread", self.frames())]
        payload, fresh = enc.encode_tick(samples)
        assert fresh  # first tick defines new strings
        events = list(dec.feed(payload))
        assert len(events) == 1
        got = events[0]
        assert isinstance(got, RawSample)
        assert got.t == 1.5 and got.tid == 42 and got.thread_name == "MainThread"
        assert got.frames == self.frames()

    def test_string_interning_across_ticks(self):
        enc, dec = Encoder(), Decoder()
        p1, _ = enc.encode_tick([RawSample(0.0, 1, "t", self.frames())])
        p2, fresh2 = enc.encode_tick([RawSample(0.1, 1, "t", self.frames())])
        assert fresh2 == []  # steady state: no new strings
        assert len(p2) < len(p1) / 2
        evs = list(dec.feed(p1 + p2))
        assert [e.frames for e in evs] == [self.frames(), self.frames()]

    def test_chunked_feed_reassembles_partial_records(self):
        enc, dec = Encoder(), Decoder()
        payload, _ = enc.encode_tick([RawSample(0.0, 1, "t", self.frames())])
        events = []
        for i in range(0, len(payload), 3):  # drip-feed 3 bytes at a time
            events.extend(dec.feed(payload[i : i + 3]))
        assert len(events) == 1 and events[0].frames == self.frames()

    def test_rollback_keeps_stream_decodable(self):
        """A dropped batch must not leave dangling string ids."""
        enc, dec = Encoder(), Decoder()
        dropped, fresh = enc.encode_tick([RawSample(0.0, 1, "t", self.frames())])
        enc.rollback(fresh)  # transport rejected the batch; it is never fed
        kept, _ = enc.encode_tick([RawSample(0.1, 1, "t", self.frames())])
        evs = list(dec.feed(kept))
        assert len(evs) == 1 and evs[0].frames == self.frames()

    def test_hello_bye_roundtrip(self):
        enc, dec = Encoder(), Decoder()
        evs = list(dec.feed(enc.encode_hello(1234, 0.5) + enc.encode_bye(77)))
        assert isinstance(evs[0], Hello) and evs[0].pid == 1234 and evs[0].period_s == 0.5
        assert isinstance(evs[1], Bye) and evs[1].n_ticks == 77

    def test_resolver_matches_thread_backend_symbols(self, parked):
        """Raw capture -> codec -> resolver == frame_symbol on the same frame."""
        frame = sys._current_frames()[parked.ident]
        # thread-backend path
        expected = StackSampler(SamplerConfig(period_s=10))._stack_of(frame)
        # daemon path: raw walk (as the agent does) -> encode -> decode -> resolve
        raw, f = [], frame
        while f is not None:
            raw.append(RawFrame(f.f_code.co_filename, f.f_code.co_name, f.f_lineno))
            f = f.f_back
        raw.reverse()
        payload, _ = Encoder().encode_tick([RawSample(0.0, parked.ident, "w", raw)])
        (sample,) = list(Decoder().feed(payload))
        assert SymbolResolver().resolve_stack(sample.frames) == expected

    def test_resolver_collapse_matches_thread_backend(self, parked):
        frame = sys._current_frames()[parked.ident]
        expected = StackSampler(
            SamplerConfig(period_s=10, collapse_origins=("py",))
        )._stack_of(frame)
        raw, f = [], frame
        while f is not None:
            raw.append(RawFrame(f.f_code.co_filename, f.f_code.co_name, f.f_lineno))
            f = f.f_back
        raw.reverse()
        got = SymbolResolver(("py",)).resolve_stack(raw)
        assert got == expected
        assert "py::*" in got


class TestWireV2:
    """Stack interning (STACKDEF/SAMPLE2): the perf core of wire v2."""

    def frames(self, leaf="leaf_fn"):
        return [
            RawFrame("/usr/lib/python3/threading.py", "run", 10),
            RawFrame("/site-packages/jax/api.py", "jit", 20),
            RawFrame("/root/repo/src/repro/models/model.py", leaf, 30),
        ]

    def test_steady_state_sample_is_fixed_size(self):
        enc, dec = Encoder(), Decoder()
        p1, fresh1 = enc.encode_tick([RawSample(0.0, 1, "t", self.frames())])
        p2, fresh2 = enc.encode_tick([RawSample(0.1, 1, "t", self.frames())])
        assert fresh2 == []  # no new strings *and* no new stacks
        # SAMPLE2 record: 5-byte framing + 24-byte payload.
        assert len(p2) == 29
        evs = list(dec.feed(p1 + p2))
        assert [e.frames for e in evs] == [self.frames(), self.frames()]
        assert evs[0].stack_id == evs[1].stack_id == 0
        # the decoder shares one frames list per interned stack (fast lane)
        assert evs[0].frames is evs[1].frames

    def test_prefix_delta_against_previous_stackdef(self):
        """Two stacks sharing a root prefix: the second STACKDEF encodes only
        the divergent tail (prefix-delta), and both decode to full stacks."""
        enc, dec = Encoder(), Decoder()
        a = self.frames("leaf_a")
        b = self.frames("leaf_b")  # same first two frames, new leaf
        pa, _ = enc.encode_tick([RawSample(0.0, 1, "t", a)])
        pb, _ = enc.encode_tick([RawSample(0.1, 1, "t", b)])
        # delta STACKDEF: only the leaf frame + its one new string crosses
        assert len(pb) < len(pa) / 2
        evs = list(dec.feed(pa + pb))
        assert evs[0].frames == a and evs[1].frames == b
        assert evs[0].stack_id != evs[1].stack_id

    def test_stackdef_rollback_keeps_stream_decodable(self):
        """A dropped batch with a fresh STACKDEF must not poison later ticks:
        ids are never reused and the delta context resets."""
        enc, dec = Encoder(), Decoder()
        committed, _ = enc.encode_tick([RawSample(0.0, 1, "t", self.frames("leaf_a"))])
        dropped, fresh = enc.encode_tick([RawSample(0.1, 1, "t", self.frames("leaf_b"))])
        enc.rollback(fresh)  # transport rejected; decoder never sees `dropped`
        retry, _ = enc.encode_tick([RawSample(0.2, 1, "t", self.frames("leaf_b"))])
        evs = list(dec.feed(committed + retry))
        assert [e.frames for e in evs] == [self.frames("leaf_a"), self.frames("leaf_b")]
        assert len({e.stack_id for e in evs}) == 2

    def test_hello_announces_negotiated_version(self):
        for version in (1, 2):
            (hello,) = Decoder().feed(Encoder(version=version).encode_hello(1, 0.5))
            assert isinstance(hello, Hello) and hello.version == version
        assert WIRE_VERSION == 2

    def test_v1_encoder_still_produces_v1_stream(self):
        """Backward compat: Encoder(version=1) emits per-frame SAMPLE records
        (stack_id is None) and old spools keep decoding."""
        enc, dec = Encoder(version=1), Decoder()
        p, _ = enc.encode_tick([RawSample(0.0, 1, "t", self.frames())])
        (ev,) = list(dec.feed(p))
        assert ev.frames == self.frames() and ev.stack_id is None

    def test_utf8_truncation_lands_on_codepoint_boundary(self):
        """A >64 KiB multi-byte name truncates on a codepoint boundary, never
        leaving a mangled trailing sequence (the old byte-slice bug)."""
        enc, dec = Encoder(), Decoder()
        long_name = "é" * 40_000  # 80,000 UTF-8 bytes > 0xFFFF
        p, _ = enc.encode_tick([RawSample(0.0, 1, "t", [RawFrame("/f.py", long_name, 1)])])
        (ev,) = list(dec.feed(p))
        got = ev.frames[0].func
        assert "�" not in got  # no replacement char from a split sequence
        assert got == "é" * (0xFFFF // 2)

    def test_same_stack_different_threads_shares_stackdef(self):
        enc, dec = Encoder(), Decoder()
        p, _ = enc.encode_tick(
            [RawSample(0.0, 1, "a", self.frames()), RawSample(0.0, 2, "b", self.frames())]
        )
        evs = list(dec.feed(p))
        assert evs[0].stack_id == evs[1].stack_id
        assert {e.thread_name for e in evs} == {"a", "b"}

    def test_leaf_lineno_jitter_does_not_defeat_interning(self):
        """An actively-executing leaf frame changes f_lineno nearly every
        tick; resolution is line-agnostic, so those must intern as ONE stack
        (else a busy thread would mint a STACKDEF per sample and grow the
        intern tables without bound)."""
        enc, dec = Encoder(), Decoder()
        first, _ = enc.encode_tick(
            [RawSample(0.0, 1, "t", self.frames()[:-1] + [RawFrame("/w.py", "busy", 100)])]
        )
        for i in range(1, 6):
            jittered = self.frames()[:-1] + [RawFrame("/w.py", "busy", 100 + i)]
            p, fresh = enc.encode_tick([RawSample(i * 0.1, 1, "t", jittered)])
            assert fresh == []  # no new STACKDEF despite the moving lineno
            assert len(p) == 29  # steady-state fixed-size SAMPLE2
            first += p
        evs = list(dec.feed(first))
        assert len({e.stack_id for e in evs}) == 1
        # decoded linenos are the first occurrence's representative values
        assert all(e.frames[-1].lineno == 100 for e in evs)

    def test_unknown_stack_id_degrades_to_counted_placeholder(self):
        """Re-attaching after a previous reader consumed the STACKDEFs must
        not silently drop stack structure: samples decode to a "?" frame
        (v1-style degradation) and the loss is counted."""
        enc = Encoder()
        p1, _ = enc.encode_tick([RawSample(0.0, 1, "t", self.frames())])
        p2, _ = enc.encode_tick([RawSample(0.1, 1, "t", self.frames())])
        dec = Decoder()  # fresh decoder: never saw p1's STRDEF/STACKDEF
        (ev,) = list(dec.feed(p2))
        assert ev.frames == [RawFrame("?", "?", 0)]
        assert ev.thread_name == "?"  # name STRDEF was consumed too
        assert dec.unknown_stack_refs == 1
        ing = TreeIngestor()
        ing.ingest(ev)
        assert ing.tree.total() == 1  # counted, visible as thread::?/py::?

    def test_delta_stackdef_against_unseen_context_degrades_not_misroots(self):
        """A mid-stream attach may first see a STACKDEF that delta-encodes
        against a definition the dead reader consumed.  Applying it would
        silently mis-root the stack; it must degrade to the counted
        placeholder, and stay degraded until a full (n_prefix=0) definition
        restores the context."""
        enc = Encoder()
        p1, _ = enc.encode_tick([RawSample(0.0, 1, "t", self.frames("leaf_a"))])
        p2, _ = enc.encode_tick([RawSample(0.1, 1, "t", self.frames("leaf_b"))])
        # leaf_b's STACKDEF shares a 2-frame prefix with leaf_a's -> delta
        dec = Decoder()
        evs = list(dec.feed(p2))  # p1 was consumed by a previous reader
        assert dec.degraded_stackdefs == 1
        assert [e.frames for e in evs] == [[RawFrame("?", "?", 0)]]
        # every sample referencing the degraded def is counted, not just the def
        assert dec.unknown_stack_refs == 1
        # a later definition with a fresh root (n_prefix=0) recovers fully
        fresh_stack = [RawFrame("/other/root.py", "main", 1), RawFrame("/w.py", "busy", 2)]
        p3, _ = enc.encode_tick([RawSample(0.2, 1, "t", fresh_stack)])
        (ev3,) = list(dec.feed(p3))
        assert [(f.filename, f.func) for f in ev3.frames] == [
            ("/other/root.py", "main"), ("/w.py", "busy")
        ]
        assert dec.degraded_stackdefs == 1  # no further degradation

    def test_stack_table_cap_falls_back_to_v1_records(self):
        """A full stack-intern table must not grow target memory: new stacks
        encode as v1 per-frame SAMPLE records in the same stream."""
        enc, dec = Encoder(max_stacks=1), Decoder()
        interned = self.frames("leaf_a")
        overflow = [RawFrame("/x.py", "other_root", 1)]
        p, _ = enc.encode_tick(
            [RawSample(0.0, 1, "t", interned), RawSample(0.0, 2, "t", overflow)]
        )
        evs = list(dec.feed(p))
        assert evs[0].stack_id == 0 and evs[0].frames == interned
        assert evs[1].stack_id is None and evs[1].frames == overflow  # v1 fallback
        # the interned stack keeps its fixed-size fast path
        p2, fresh = enc.encode_tick([RawSample(0.1, 1, "t", interned)])
        assert fresh == [] and len(p2) == 29

    def test_keyframe_defs_bound_degraded_window_after_reattach(self):
        """Real stacks always share root frames, so organic n_prefix=0 defs
        never happen after warm-up; periodic keyframe definitions must bound
        how long a mid-stream attacher stays degraded."""
        from repro.profilerd.wire import FULL_DEF_INTERVAL

        enc = Encoder()
        base = self.frames()[:-1]
        consumed, _ = enc.encode_tick([RawSample(0.0, 1, "t", base + [RawFrame("/w.py", "f0", 1)])])
        dec = Decoder()  # attaches after `consumed` is gone
        recovered_at = None
        for i in range(1, FULL_DEF_INTERVAL + 2):
            stack = base + [RawFrame("/w.py", f"f{i}", 1)]  # shares the root prefix
            p, _ = enc.encode_tick([RawSample(i * 0.1, 1, "t", stack)])
            (ev,) = list(dec.feed(p))
            if ev.frames != [RawFrame("?", "?", 0)]:
                recovered_at = i
                break
        assert recovered_at is not None and recovered_at <= FULL_DEF_INTERVAL
        assert dec.degraded_stackdefs == recovered_at - 1
        # Once recovered, subsequent deltas decode with full structure again.
        # Strings defined before the attach stay "?" (v1-parity degradation);
        # strings defined after decode normally.
        p, _ = enc.encode_tick([RawSample(9.9, 1, "t", base + [RawFrame("/w.py", "tail", 2)])])
        (ev,) = list(dec.feed(p))
        assert [f.func for f in ev.frames] == ["?"] * len(base) + ["tail"]

    def test_corrupt_record_raises_instead_of_desyncing(self):
        """A declared frame count exceeding the record's length prefix must
        raise loudly, never silently read the next record's bytes."""
        import struct

        enc = Encoder(version=1)
        good, _ = enc.encode_tick([RawSample(0.0, 1, "t", self.frames())])
        # Find the SAMPLE record and inflate its nframes field without
        # growing the payload: length prefix u32, kind u8, then the header
        # <dQIH> whose final u16 is nframes.
        buf = bytearray(good)
        off = 0
        while True:
            (n,) = struct.unpack_from("<I", buf, off)
            kind = buf[off + 4]
            if kind == 3:  # K_SAMPLE
                hdr_off = off + 5
                struct.pack_into("<H", buf, hdr_off + 8 + 8 + 4, 999)
                break
            off += 4 + n
        with pytest.raises(ValueError):
            list(Decoder().feed(bytes(buf)))


_WIRE_FILES = ["/a/repro/x.py", "/b/jax/y.py", "/c/numpy/z.py", "/d/app.py"]
_WIRE_FUNCS = ["fa", "fb", "fc", "fd", "fe"]
_frame_st = st.sampled_from(
    [RawFrame(f, q, ln) for f in _WIRE_FILES for q in _WIRE_FUNCS for ln in (1, 7)]
)
_stack_st = st.lists(_frame_st, min_size=0, max_size=8)
_stacks_st = st.lists(_stack_st, min_size=1, max_size=24)


@settings(max_examples=60, deadline=None)
@given(_stacks_st)
def test_prop_v1_v2_decode_parity(stacks):
    """The same samples encoded with v1 and v2 decode to the same symbol
    sequences and build identical trees through the ingestor.

    v1 round-trips frames exactly; v2 interns stacks on the (filename, func)
    sequence, so decoded linenos are the first occurrence's — everything
    symbol resolution consumes is preserved bit-for-bit.
    """
    samples = [RawSample(i * 0.1, 100 + (i % 3), f"w{i % 3}", s) for i, s in enumerate(stacks)]
    trees = {}
    for version in (1, 2):
        enc, dec = Encoder(version=version), Decoder()
        payload = b"".join(enc.encode_tick(samples[i : i + 4])[0] for i in range(0, len(samples), 4))
        ing = TreeIngestor()
        decoded = []
        for ev in dec.feed(payload):
            decoded.append(ev.frames)
            ing.ingest(ev)
        if version == 1:
            assert decoded == [s.frames for s in samples]
        assert [[(f.filename, f.func) for f in fs] for fs in decoded] == [
            [(f.filename, f.func) for f in s.frames] for s in samples
        ]
        trees[version] = ing.tree
    assert trees[1].to_json() == trees[2].to_json()


@settings(max_examples=40, deadline=None)
@given(_stacks_st)
def test_prop_v2_steady_state_bytes_are_depth_independent(stacks):
    """Once stacks are interned, a repeated tick costs exactly 29 bytes per
    v2 sample regardless of depth, while v1 re-pays 12 bytes per frame."""
    samples = [RawSample(i * 0.1, 7, "w", s) for i, s in enumerate(stacks)]
    steady = {}
    for version in (1, 2):
        enc = Encoder(version=version)
        enc.encode_tick(samples)  # warm the intern tables
        steady[version], fresh = enc.encode_tick(samples)
        assert fresh == []
    assert len(steady[1]) == sum(27 + 12 * len(s.frames) for s in samples)
    assert len(steady[2]) == 29 * len(samples)


class TestIngestFastPath:
    def _mixed_samples(self):
        stack_a = [RawFrame("/d/app.py", "main", 1), RawFrame("/a/repro/x.py", "step", 2)]
        stack_b = [RawFrame("/d/app.py", "main", 1), RawFrame("/b/jax/y.py", "jit", 3)]
        return [
            RawSample(0.0, 1, "w", stack_a),
            RawSample(0.1, 1, "w", stack_a),
            RawSample(0.2, 1, "w", stack_b),
            RawSample(0.3, 1, "w", stack_a),
        ]

    def test_repeated_samples_hit_cached_chain(self):
        enc, dec, ing = Encoder(), Decoder(), TreeIngestor()
        for s in self._mixed_samples():
            payload, _ = enc.encode_tick([s])
            for ev in dec.feed(payload):
                ing.ingest(ev)
        assert ing.fast_hits == 2  # both stack_a repeats
        assert ing.slow_ingests == 2  # first sight of stack_a and stack_b
        assert ing.tree.total() == 4
        flat = ing.tree.flatten()
        assert flat["repro::step"] == 3 and flat["jax::jit"] == 1

    def test_fast_path_tree_equals_generic_add_stack(self):
        """Cached-chain ingestion and the generic per-frame path must agree."""
        enc, dec, ing = Encoder(), Decoder(), TreeIngestor()
        reference = CallTree()
        ref_resolver = SymbolResolver()
        for s in self._mixed_samples():
            reference.add_stack([f"thread::{s.thread_name}"] + ref_resolver.resolve_stack(s.frames))
            payload, _ = enc.encode_tick([s])
            for ev in dec.feed(payload):
                ing.ingest(ev)
        assert ing.tree.to_json() == reference.to_json()

    def test_daemon_reports_v2_and_fast_hits(self, tmp_path, parked):
        spool = str(tmp_path / "t.spool")
        agent = Agent(spool, period_s=10)
        for _ in range(20):
            agent.tick()
        agent.stop()
        daemon = ProfilerDaemon(
            DaemonConfig(spool_path=spool, out_dir=str(tmp_path / "out"), max_seconds=10)
        )
        daemon.run()
        status = daemon.status()
        assert status["wire_version"] == 2
        # The parked worker repeats the same stack: the fast lane dominates.
        assert status["ingest"]["fast_hits"] > status["ingest"]["slow_ingests"]
        assert status["ingest"]["cached_paths"] >= 1

    def test_v1_agent_spool_still_ingests(self, tmp_path, parked):
        """Old spools (v1 agents) decode and build the same tree as v2."""
        trees = {}
        for version in (1, 2):
            spool = str(tmp_path / f"v{version}.spool")
            agent = Agent(spool, period_s=10, wire_version=version)
            for _ in range(8):
                agent.tick()
            agent.stop()
            daemon = ProfilerDaemon(
                DaemonConfig(
                    spool_path=spool, out_dir=str(tmp_path / f"out{version}"), max_seconds=10
                )
            )
            daemon.run()
            assert daemon.wire_version == version
            sub = daemon.tree.root.children.get("thread::parked-worker")
            assert sub is not None
            trees[version] = json.dumps(sub.to_dict())
        assert trees[1] == trees[2]


class TestSpool:
    def test_write_read_roundtrip(self, tmp_path):
        p = str(tmp_path / "s.spool")
        w = SpoolWriter(p, capacity=1024)
        r = SpoolReader(p)
        assert w.write(b"hello") and w.write(b"world")
        assert r.read() == b"helloworld"
        assert r.read() == b""

    def test_wraparound(self, tmp_path):
        p = str(tmp_path / "s.spool")
        w = SpoolWriter(p, capacity=64)
        r = SpoolReader(p)
        blob = bytes(range(48))
        for _ in range(10):  # 480 bytes through a 64-byte ring
            assert w.write(blob)
            assert r.read() == blob
        assert w.dropped == 0

    def test_full_spool_drops_whole_batches_with_accounting(self, tmp_path):
        p = str(tmp_path / "s.spool")
        w = SpoolWriter(p, capacity=100)
        committed = []
        for i in range(10):
            payload = bytes([i]) * 40
            if w.write(payload):
                committed.append(payload)
        assert len(committed) == 2  # 2*40 fit, the rest dropped
        assert w.dropped == 8
        r = SpoolReader(p)
        assert r.dropped == 8
        assert r.read() == b"".join(committed)  # no partial writes

    def test_reader_waits_for_writer(self, tmp_path):
        p = str(tmp_path / "late.spool")
        created = threading.Event()

        def create_late():
            created.wait()
            SpoolWriter(p, capacity=256).write(b"x")

        threading.Thread(target=create_late, daemon=True).start()
        created.set()
        r = SpoolReader.wait_for(p, timeout_s=5)
        assert wait_until(r.read, desc="late writer's bytes") == b"x"


class TestSpoolAttachHardening:
    """Every corrupt-attach mode must raise SpoolError with a clean message,
    never a raw struct.error/ValueError/OSError (multi-target --watch races
    half-created and foreign files as a matter of course)."""

    def _header(self, magic=b"RPSP", version=1, capacity=64):
        hdr = bytearray(HEADER_SIZE)
        hdr[0:4] = magic
        struct.pack_into("<I", hdr, 4, version)
        struct.pack_into("<Q", hdr, 8, capacity)
        return bytes(hdr)

    def _attach(self, path):
        return SpoolReader(str(path), header_retry_s=0.01)

    def test_zero_length_file(self, tmp_path):
        p = tmp_path / "z.spool"
        p.write_bytes(b"")
        with pytest.raises(SpoolError, match="truncated spool header"):
            self._attach(p)

    def test_truncated_header(self, tmp_path):
        p = tmp_path / "t.spool"
        p.write_bytes(b"RPSP\x01")
        with pytest.raises(SpoolError, match="truncated spool header"):
            self._attach(p)

    def test_garbage_file(self, tmp_path):
        p = tmp_path / "g.spool"
        p.write_bytes(b"\xde\xad\xbe\xef" * 64)
        with pytest.raises(SpoolError, match="bad spool magic"):
            self._attach(p)

    def test_version_skew(self, tmp_path):
        p = tmp_path / "v.spool"
        p.write_bytes(self._header(version=99) + b"\x00" * 64)
        with pytest.raises(SpoolError, match="version 99"):
            self._attach(p)

    def test_capacity_beyond_file_size(self, tmp_path):
        """A spool truncated mid-copy declares more capacity than it holds."""
        p = tmp_path / "c.spool"
        p.write_bytes(self._header(capacity=1 << 20) + b"\x00" * 16)
        with pytest.raises(SpoolError, match="smaller than declared capacity"):
            self._attach(p)

    def test_zero_capacity(self, tmp_path):
        """capacity=0 used to survive the header checks and die later with a
        ZeroDivisionError in read(); it must be rejected at attach."""
        p = tmp_path / "0.spool"
        p.write_bytes(self._header(capacity=0))
        with pytest.raises(SpoolError, match="capacity 0 is not positive"):
            self._attach(p)

    def test_short_header_retries_once_and_wins(self, tmp_path):
        """The --watch race: a short file that becomes a real spool between
        the first and second open attaches cleanly."""
        p = tmp_path / "race.spool"
        p.write_bytes(b"RP")  # half-created
        grown = threading.Event()

        def grow():
            w = SpoolWriter(str(p), capacity=128)  # temp+rename over the stub
            w.write(b"ok")
            w.close()
            grown.set()

        threading.Thread(target=grow, daemon=True).start()
        grown.wait(timeout=5)
        r = SpoolReader(str(p), header_retry_s=0.5)
        assert r.read() == b"ok"

    def test_replaced_detects_new_incarnation(self, tmp_path):
        p = tmp_path / "r.spool"
        w1 = SpoolWriter(str(p), capacity=128)
        w1.write(b"first")
        r = SpoolReader(str(p))
        assert not r.replaced()
        w1.close()
        w2 = SpoolWriter(str(p), capacity=128)  # restart: temp+rename
        w2.write(b"second")
        assert r.replaced()
        assert r.read() == b"first"  # the unlinked mmap drains dry
        r2 = SpoolReader(str(p))
        assert r2.read() == b"second"
        w2.close()


class TestDaemonLifecycle:
    def test_attach_sample_drain_stop_no_loss(self, tmp_path, parked):
        """Every stack the agent committed reaches the daemon's tree."""
        spool = str(tmp_path / "t.spool")
        agent = Agent(spool, period_s=10, spool_bytes=1 << 20)
        committed = 0
        for _ in range(25):
            committed += agent.tick()
        agent.stop()
        assert agent.n_dropped_batches == 0

        daemon = ProfilerDaemon(
            DaemonConfig(spool_path=spool, out_dir=str(tmp_path / "out"), max_seconds=10)
        )
        tree = daemon.run()
        assert daemon.bye_seen
        assert daemon.n_ticks_reported == 25
        assert daemon.n_stacks == committed
        assert tree.total() == committed
        # the parked worker's stable stack must be a hot path
        flat = tree.flatten()
        assert any("parked_level_three" in k for k in flat)

    def test_full_spool_loses_batches_but_not_correctness(self, tmp_path, parked):
        """Tiny spool, no reader: batches drop; the ingested count matches
        exactly what was committed (drop accounting, no corruption)."""
        spool = str(tmp_path / "t.spool")
        agent = Agent(spool, period_s=10, spool_bytes=4096)
        committed = 0
        for _ in range(400):
            committed += agent.tick()
        agent.stop()
        assert agent.n_dropped_batches > 0  # the spool did fill

        daemon = ProfilerDaemon(
            DaemonConfig(spool_path=spool, out_dir=str(tmp_path / "out"), max_seconds=10)
        )
        tree = daemon.run()
        assert tree.total() == committed > 0
        # With no reader draining, the BYE *record* may itself have been
        # dropped (one extra drop beyond the agent's tick-drop count), but the
        # spool-header flag still marks the shutdown as clean.
        assert daemon.bye_seen
        assert daemon.dropped_batches in (
            agent.n_dropped_batches,
            agent.n_dropped_batches + 1,
        )

    def test_stall_verdict_for_silent_live_target(self, tmp_path):
        """Agent goes quiet without BYE while its pid is alive -> TARGET_STALLED.

        The declared period matters: silence only counts as a stall once it
        clearly exceeds the publisher's own cadence (3x), so a slow-ticking
        healthy target is never flagged."""
        spool = str(tmp_path / "t.spool")
        agent = Agent(spool, period_s=0.02)
        agent.tick()
        # no agent.stop(): the 'target' (this test process) wedges silently
        daemon = ProfilerDaemon(
            DaemonConfig(
                spool_path=spool,
                out_dir=str(tmp_path / "out"),
                publish_interval_s=0.05,
                stall_timeout_s=0.2,
                max_seconds=3.0,
            )
        )
        daemon.run()
        kinds = [e["kind"] for e in daemon.events]
        assert STALLED in kinds

    def test_artifacts_published(self, tmp_path, parked):
        spool = str(tmp_path / "t.spool")
        agent = Agent(spool, period_s=10)
        for _ in range(5):
            agent.tick()
        agent.stop()
        out = str(tmp_path / "out")
        ProfilerDaemon(DaemonConfig(spool_path=spool, out_dir=out, max_seconds=10)).run()
        expected = ["report.html", "status.json", "timeline", "tree.json"]
        if not numpy_available():
            # Scalar fallback logs one INGEST_SCALAR_FALLBACK event on attach.
            expected = ["events.jsonl"] + expected
        assert sorted(os.listdir(out)) == expected
        status = json.load(open(os.path.join(out, "status.json")))
        assert status["done"] and status["n_stacks"] > 0 and status["hot_paths"]
        tree = CallTree.from_json(open(os.path.join(out, "tree.json")).read())
        assert tree.total() == status["n_stacks"]
        # The sealed timeline reconstructs the exact merged tree.
        from repro.core.snapshot import TimelineReader

        last = TimelineReader(os.path.join(out, "timeline")).last()
        assert last is not None and last[1].root == tree.root
        assert status["timeline"]["epochs"] >= 1


class TestBackendParity:
    def _worker_subtree(self, tree, name="thread::parked-worker"):
        node = tree.root.children.get(name)
        assert node is not None, f"{name} missing; saw {list(tree.root.children)}"
        return node.to_dict()

    def test_thread_and_daemon_trees_equivalent(self, tmp_path, parked):
        """Same parked stack sampled N times by both backends -> identical
        subtrees (structure and counts)."""
        n = 12
        cfg = SamplerConfig(period_s=10, collapse_origins=("py",))

        thread_backend = StackSampler(cfg)
        for _ in range(n):
            thread_backend.sample_now()
        thread_tree = thread_backend.snapshot()

        spool = str(tmp_path / "t.spool")
        agent = Agent(spool, period_s=10)
        for _ in range(n):
            agent.tick()
        agent.stop()
        daemon = ProfilerDaemon(
            DaemonConfig(
                spool_path=spool,
                out_dir=str(tmp_path / "out"),
                collapse_origins=cfg.collapse_origins,
                max_seconds=10,
            )
        )
        daemon_tree = daemon.run()

        assert self._worker_subtree(thread_tree) == self._worker_subtree(daemon_tree)

    def test_make_sampler_backend_selection(self):
        assert isinstance(make_sampler(SamplerConfig(backend="thread")), StackSampler)
        s = make_sampler(SamplerConfig(backend="daemon", spool_path="/tmp/x.spool"))
        assert isinstance(s, DaemonBackend)
        assert s.spawn_daemon is False  # explicit spool => external daemon
        with pytest.raises(ValueError):
            make_sampler(SamplerConfig(backend="perf"))

    def test_env_override_routes_to_external_daemon(self, tmp_path, monkeypatch):
        spool = str(tmp_path / "env.spool")
        monkeypatch.setenv("REPRO_PROFILERD_SPOOL", spool)
        monkeypatch.setenv("REPRO_PROFILERD_PERIOD", "0.123")
        s = make_sampler(SamplerConfig(backend="thread"))
        assert isinstance(s, DaemonBackend)
        assert s.spool_path == spool and s.spawn_daemon is False
        assert s.config.period_s == 0.123


class TestIngestorOverflowSealing:
    """ISSUE 5 satellite: the chain-cache overflow fallback mutates the tree
    outside the cache, so it must flip the `untracked` epoch flag exactly
    like the v1 path — otherwise sealed K_COUNTS records silently drop that
    mass from the timeline."""

    def _feed(self, enc, dec, ing, frames):
        payload, _ = enc.encode_tick([RawSample(0.0, 1, "t", frames)])
        for ev in dec.feed(payload):
            ing.ingest(ev)

    def test_overflow_mid_epoch_forces_sealer_keyframe(self, tmp_path):
        from repro.core.snapshot import K_FULL, CountSealer, TimelineReader, TimelineWriter

        enc, dec = Encoder(), Decoder()
        ing = TreeIngestor(max_paths=1)
        writer = TimelineWriter(str(tmp_path / "tl"))
        sealer = CountSealer(ing.tree, writer)
        stack_a = [RawFrame("/a.py", "root", 1), RawFrame("/a.py", "hot", 2)]
        stack_b = [RawFrame("/a.py", "root", 1), RawFrame("/b.py", "cold", 3)]

        # Epoch 0: one stack, fits the 1-entry cache; normal counts path.
        self._feed(enc, dec, ing, stack_a)
        entries, untracked = ing.drain_epoch()
        assert not untracked
        sealer.seal(entries, wall_time=0.0, untracked=untracked)

        # Epoch 1: repeats ride the cache, then a second unique stack
        # overflows it mid-epoch -> the epoch must be untracked and the
        # sealer must keyframe (a counts record cannot carry stack_b).
        self._feed(enc, dec, ing, stack_a)
        self._feed(enc, dec, ing, stack_b)
        self._feed(enc, dec, ing, stack_b)
        entries, untracked = ing.drain_epoch()
        assert untracked, "cache overflow must mark the epoch untracked"
        meta = sealer.seal(entries, wall_time=1.0, untracked=untracked)
        assert meta.kind == K_FULL

        # Overflowed stacks can never be counted, so later epochs that touch
        # them keyframe too — the mass keeps reaching the ring.
        self._feed(enc, dec, ing, stack_b)
        entries, untracked = ing.drain_epoch()
        assert untracked
        sealer.seal(entries, wall_time=2.0, untracked=untracked)
        writer.close()

        last = TimelineReader(str(tmp_path / "tl")).last()
        assert last is not None
        assert last[1].root == ing.tree.root  # nothing silently dropped
        assert last[1].total() == 5.0


class TestWriterRestartReattach:
    """ISSUE 5 satellite: a crashed-and-restarted target recreates its spool
    (same path, new inode, fresh stack-id space, possibly stale bye=1 or a
    reused pid).  The daemon must re-attach instead of reporting a phantom
    TARGET_STALLED, and both incarnations' samples must land in the tree."""

    def _daemon(self, tmp_path, **kw):
        kw.setdefault("out_dir", str(tmp_path / "out"))
        kw.setdefault("publish_interval_s", 0.05)
        kw.setdefault("drain_interval_s", 0.01)
        kw.setdefault("epoch_s", 0.2)
        kw.setdefault("stall_timeout_s", 60.0)  # a restart must beat a stall
        kw.setdefault("max_seconds", 30.0)
        return ProfilerDaemon(DaemonConfig(**kw))

    def test_kill_and_respawn_reattaches_without_phantom_stall(self, tmp_path):
        spool = tmp_path / "job.spool"
        # Incarnation 1 crashes: samples, no BYE, and the recorded pid (this
        # test process) stays alive — the pid-reuse shape that used to read
        # as a stall.
        FakeTarget(spool, "first_incarnation").emit(4).crash()
        daemon = self._daemon(tmp_path, spool_paths=(str(spool),))
        th = threading.Thread(target=daemon.run, daemon=True)
        th.start()
        wait_until(lambda: daemon.n_stacks >= 4, desc="first incarnation drained")
        # Respawn under the same path; clean BYE ends the run.
        FakeTarget(spool, "second_incarnation").emit(3).bye()
        th.join(timeout=20)
        assert not th.is_alive()
        assert daemon.n_stacks == 7
        (src,) = daemon.sources
        assert src.restarts == 1
        kinds = [e["kind"] for e in daemon.events]
        assert "TARGET_RESTARTED" in kinds
        assert STALLED not in kinds, "restart must not read as a stall"
        flat = daemon.tree.flatten()
        assert any("first_incarnation" in k for k in flat)
        assert any("second_incarnation" in k for k in flat)

    def test_stale_bye_clears_on_restart(self, tmp_path):
        """A cleanly-stopped target (bye=1) that restarts must flip back to
        live: the stale header flag belongs to the dead incarnation."""
        watch = tmp_path / "spools"
        watch.mkdir()
        FakeTarget(watch / "job.spool", "gen_one").emit(2).bye()
        daemon = self._daemon(tmp_path, watch_dir=str(watch))
        th = threading.Thread(target=daemon.run, daemon=True)
        th.start()
        wait_until(
            lambda: daemon.sources and daemon.sources[0].bye_seen,
            desc="first incarnation drained to BYE",
        )
        FakeTarget(watch / "job.spool", "gen_two").emit(5)  # restart, no bye
        wait_until(lambda: daemon.n_stacks == 7, desc="second incarnation drained")
        (src,) = daemon.sources
        assert src.bye_seen is False and src.restarts == 1
        daemon.request_stop()
        th.join(timeout=20)
        assert not th.is_alive()
        assert STALLED not in [e["kind"] for e in daemon.events]
        assert daemon.tree.total() == 7


class TestMultiTargetDaemon:
    """The tentpole: one daemon, N spools -> per-target trees + merged fleet."""

    def _cfg(self, tmp_path, **kw):
        kw.setdefault("out_dir", str(tmp_path / "fleet.out"))
        kw.setdefault("publish_interval_s", 0.05)
        kw.setdefault("drain_interval_s", 0.01)
        kw.setdefault("epoch_s", 0.2)
        kw.setdefault("max_seconds", 30.0)
        return DaemonConfig(**kw)

    def test_two_live_targets_served_and_merged(self, tmp_path):
        """Acceptance: one daemon over >= 2 concurrently-running targets
        serves distinct /tree?target= views plus a fleet tree whose inclusive
        mass equals the sum of the per-target trees."""
        alpha = FakeTarget(tmp_path / "alpha.spool", "alpha_leaf").emit(6)
        beta = FakeTarget(tmp_path / "beta.spool", "beta_leaf").emit(4)
        cfg = self._cfg(
            tmp_path,
            spool_paths=(str(tmp_path / "alpha.spool"), str(tmp_path / "beta.spool")),
            serve_port=0,
        )
        daemon = ProfilerDaemon(cfg)
        th = threading.Thread(target=daemon.run, daemon=True)
        th.start()
        try:
            wait_until(lambda: daemon.server is not None, desc="query plane up")
            url = daemon.server.url

            def targets_published():
                _code, body = _http_get(url + "/targets")
                rows = {r["name"]: r for r in json.loads(body)["targets"]}
                return rows if {"alpha", "beta"} <= set(rows) else None

            rows = wait_until(targets_published, desc="both targets published")
            assert rows["alpha"]["n_stacks"] == 6 and rows["beta"]["n_stacks"] == 4
            assert rows["alpha"]["done"] is False and rows["alpha"]["alive"] is True

            from repro.core.export import from_folded

            _c, alpha_folded = _http_get(url + "/tree?target=alpha&fmt=folded")
            assert "alpha_leaf" in alpha_folded and "beta_leaf" not in alpha_folded
            _c, beta_folded = _http_get(url + "/tree?target=beta&fmt=folded")
            assert "beta_leaf" in beta_folded and "alpha_leaf" not in beta_folded
            _c, fleet_folded = _http_get(url + "/tree?fmt=folded")
            fleet = from_folded(fleet_folded)
            per_target_sum = from_folded(alpha_folded).total() + from_folded(beta_folded).total()
            assert fleet.total() == pytest.approx(per_target_sum) == pytest.approx(10.0)
            code, body = _http_get(url + "/tree?target=nope&fmt=folded")
            assert code == 404 and "unknown target" in body
        finally:
            alpha.bye()
            beta.bye()
            th.join(timeout=20)
        assert not th.is_alive()
        assert daemon.bye_seen

        # On-disk layout: fleet tree + per-target artifacts + sealed rings.
        from repro.core.snapshot import TimelineReader
        from repro.profilerd.profiles import list_profile_targets, load_profile

        out = cfg.resolved_out_dir()
        assert load_profile(out).total() == 10.0
        assert list_profile_targets(out) == ["alpha", "beta"]
        assert load_profile(os.path.join(out, "targets", "alpha")).total() == 6.0
        fleet_last = TimelineReader(os.path.join(out, "timeline")).last()
        assert fleet_last is not None and fleet_last[1].total() == 10.0
        alpha_last = TimelineReader(
            os.path.join(out, "targets", "alpha", "timeline")
        ).last()
        assert alpha_last is not None and alpha_last[1].total() == 6.0
        status = json.load(open(os.path.join(out, "status.json")))
        assert status["n_targets"] == 2 and set(status["targets"]) == {"alpha", "beta"}

    def test_offline_fleet_dir_serves_targets(self, tmp_path):
        from repro.profilerd.server import OfflineSource, ProfileServer

        FakeTarget(tmp_path / "alpha.spool", "alpha_leaf").emit(6).bye()
        FakeTarget(tmp_path / "beta.spool", "beta_leaf").emit(4).bye()
        cfg = self._cfg(
            tmp_path,
            spool_paths=(str(tmp_path / "alpha.spool"), str(tmp_path / "beta.spool")),
        )
        ProfilerDaemon(cfg).run()  # both targets already said BYE: returns fast
        src = OfflineSource(cfg.resolved_out_dir())
        assert {r["name"] for r in src.targets()} == {"alpha", "beta"}
        assert src.tree("alpha").total() == 6.0
        assert src.tree().total() == 10.0
        server = ProfileServer(src).start()
        try:
            _c, body = _http_get(server.url + "/targets")
            assert {r["name"] for r in json.loads(body)["targets"]} == {"alpha", "beta"}
            _c, folded = _http_get(server.url + "/tree?target=beta&fmt=folded")
            assert "beta_leaf" in folded and "alpha_leaf" not in folded
            code, _b = _http_get(server.url + "/tree?target=missing")
            assert code == 404
            _c, status_body = _http_get(server.url + "/status")
            assert json.loads(status_body)["n_targets"] == 2
        finally:
            server.stop()

    def test_watch_discovers_spool_created_after_start(self, tmp_path):
        """Acceptance: --watch picks up a spool created after daemon start
        within one drain interval."""
        watch = tmp_path / "spools"
        watch.mkdir()
        cfg = self._cfg(tmp_path, watch_dir=str(watch), attach_timeout_s=10.0)
        daemon = ProfilerDaemon(cfg)
        th = threading.Thread(target=daemon.run, daemon=True)
        th.start()
        early = FakeTarget(watch / "early.spool", "early_leaf").emit(3)
        wait_until(lambda: daemon.n_stacks == 3, desc="first spool attached+drained")
        t0 = time.monotonic()
        late = FakeTarget(watch / "late.spool", "late_leaf").emit(2)
        wait_until(lambda: daemon.n_stacks == 5, desc="late spool discovered")
        # "within one drain interval" (0.01s) plus scheduler noise; 2s is the
        # generous CI bound that still proves discovery is loop-driven.
        assert time.monotonic() - t0 < 2.0
        assert set(daemon.spools.sources) == {"early", "late"}
        early.bye()
        late.bye()
        # A --watch daemon outlives done targets (new ones may appear): it
        # exits on request_stop (the launcher sends SIGTERM).
        wait_until(lambda: daemon.bye_seen, desc="both targets drained to BYE")
        assert th.is_alive()
        daemon.request_stop()
        th.join(timeout=20)
        assert not th.is_alive()
        kinds = [e["kind"] for e in daemon.events]
        assert kinds.count("TARGET_ATTACHED") == 2
        assert daemon.tree.total() == 5.0

    def test_watch_skips_garbage_spool_with_one_event(self, tmp_path):
        watch = tmp_path / "spools"
        watch.mkdir()
        (watch / "junk.spool").write_bytes(b"\xde\xad\xbe\xef" * 64)
        FakeTarget(watch / "good.spool", "good_leaf").emit(3).bye()
        cfg = self._cfg(tmp_path, watch_dir=str(watch))
        daemon = ProfilerDaemon(cfg)
        th = threading.Thread(target=daemon.run, daemon=True)
        th.start()
        wait_until(lambda: daemon.n_stacks == 3, desc="good spool drained")
        daemon.request_stop()
        th.join(timeout=20)
        assert not th.is_alive()
        fails = [e for e in daemon.events if e["kind"] == "SOURCE_ATTACH_FAILED"]
        assert len(fails) == 1  # logged once, not once per drain pass
        assert "junk" in fails[0]["path"] and "magic" in fails[0]["error"]
        assert list(daemon.spools.sources) == ["good"]

    def test_config_requires_a_source(self):
        with pytest.raises(ValueError):
            ProfilerDaemon(DaemonConfig())

    def test_live_quiet_target_serves_empty_tree_not_404(self):
        """A target that attached but has no published window yet is listed
        by /targets, so /tree?target= must answer with an empty tree, not
        contradict the listing with a 404."""
        from repro.profilerd.profiles import ProfileLoadError
        from repro.profilerd.server import LiveSource, SharedProfileState

        shared = SharedProfileState()
        shared.update({"targets": {"quiet": {"n_stacks": 0}}}, None, targets={})
        src = LiveSource(shared)
        assert src.tree("quiet").total() == 0.0
        with pytest.raises(ProfileLoadError, match="unknown target"):
            src.tree("missing")

    def test_never_appearing_explicit_target_is_abandoned(self, tmp_path):
        """A typo'd --targets path must not pin the run open forever: after
        the attach window it is abandoned with a loud event and the daemon
        exits once the real targets finish."""
        FakeTarget(tmp_path / "real.spool", "real_leaf").emit(3).bye()
        daemon = ProfilerDaemon(
            self._cfg(
                tmp_path,
                spool_paths=(str(tmp_path / "real.spool"), str(tmp_path / "typo.spool")),
                attach_timeout_s=0.3,
            )
        )
        th = threading.Thread(target=daemon.run, daemon=True)
        th.start()
        th.join(timeout=20)
        assert not th.is_alive(), "daemon hung on the never-appearing target"
        never = [e for e in daemon.events if e["kind"] == "TARGET_NEVER_APPEARED"]
        assert len(never) == 1 and never[0]["target"] == "typo"
        assert daemon.tree.total() == 3.0

    def test_exit_with_dead_pid_stops_watch_daemon(self, tmp_path):
        """--exit-with: a watch daemon whose supervisor died finishes cleanly
        instead of leaking forever."""
        watch = tmp_path / "spools"
        watch.mkdir()
        FakeTarget(watch / "job.spool", "leaf").emit(2).bye()
        dead_pid = 2**22 + 12345  # beyond any live pid on this box
        daemon = ProfilerDaemon(
            self._cfg(tmp_path, watch_dir=str(watch), exit_with_pid=dead_pid)
        )
        th = threading.Thread(target=daemon.run, daemon=True)
        th.start()
        th.join(timeout=20)
        assert not th.is_alive()
        assert "SUPERVISOR_GONE" in [e["kind"] for e in daemon.events]
        assert daemon.tree.total() == 2.0


_TARGET = """
import sys, time
sys.path.insert(0, {src!r})
from repro.core import SamplerConfig, make_sampler
s = make_sampler(SamplerConfig(backend="daemon", spool_path={spool!r},
                               spawn_daemon=False, period_s=0.02))
s.start()
def busy_loop_for_profilerd():
    t0 = time.monotonic(); x = 0
    while time.monotonic() - t0 < 1.5:
        x += 1
busy_loop_for_profilerd()
s.stop()
"""


@pytest.mark.slow
class TestEndToEndCLI:
    def test_attach_streams_live_target(self, tmp_path):
        """`python -m repro.profilerd attach` in a separate process drains a
        live publisher and emits a tree whose hot path is the busy loop."""
        spool = str(tmp_path / "e2e.spool")
        out = str(tmp_path / "e2e.out")
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC_ROOT + os.pathsep + env.get("PYTHONPATH", "")
        env.setdefault("JAX_PLATFORMS", "cpu")
        target = subprocess.Popen(
            [sys.executable, "-c", _TARGET.format(src=SRC_ROOT, spool=spool)], env=env
        )
        daemon = subprocess.run(
            [
                sys.executable, "-m", "repro.profilerd", "attach",
                "--spool", spool, "--out", out,
                "--interval", "0.2", "--max-seconds", "30",
            ],
            env=env, capture_output=True, text=True, timeout=60,
        )
        assert target.wait(timeout=30) == 0
        assert daemon.returncode == 0, daemon.stderr
        tree = CallTree.from_json(open(os.path.join(out, "tree.json")).read())
        assert tree.total() > 0
        assert any("busy_loop_for_profilerd" in k for k in tree.flatten())
        assert os.path.exists(os.path.join(out, "report.html"))
