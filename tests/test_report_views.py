"""Report/views tests: HTML export, ViewConfig semantics, views library."""

from repro.core import NO_MATCH_MARKER, CallTree, ViewConfig, render_html, write_report
from repro.core.report import EMPTY_VIEW_MARKER
from repro.core.views_library import list_views, render_view


def sample_tree():
    t = CallTree()
    for _ in range(6):
        t.add_stack(["train_step", "model", "attention", "scores"], {"samples": 1, "flops": 100})
    for _ in range(3):
        t.add_stack(["train_step", "model", "mlp", "up_proj"], {"samples": 1, "flops": 300})
    t.add_stack(["train_step", "optimizer", "adamw"], {"samples": 1, "flops": 10})
    return t


class TestHtmlReport:
    def test_render_html_is_standalone(self):
        html = render_html(sample_tree(), title="t", metric="flops")
        assert html.startswith("<!DOCTYPE html>")
        assert "attention" in html and "calltree-json" in html
        # embedded JSON round-trips
        blob = html.split('id="calltree-json">')[1].split("</script>")[0]
        assert CallTree.from_json(blob).total("flops") == sample_tree().total("flops")

    def test_tag_shaped_names_are_escaped_not_swallowed(self):
        # Regression: a frame named "<module>" must land in the page as
        # visible text, not as a (vanishing) HTML tag.
        t = CallTree()
        t.add_stack(["<module>", "run"])
        page = render_html(t, title="t")
        assert "&lt;module&gt;" in page

    def test_script_closing_name_cannot_break_the_json_island(self):
        # Regression: the embedded JSON blob used to be interpolated raw, so
        # a frame named "</script>..." terminated the data island early and
        # spilled the rest of the tree into the page as markup.
        t = CallTree()
        t.add_stack(["<module>", "</script><script>alert(1)</script>", "leaf"])
        page = render_html(t, title="t")
        blob = page.split('id="calltree-json">')[1].split("</script>")[0]
        roundtripped = CallTree.from_json(blob)  # "<\/" decodes to "</"
        assert roundtripped.root == t.root
        body = page.split('id="calltree-json">')[0]
        assert "<script>alert(1)" not in body  # never as live markup

    def test_write_report_files(self, tmp_path):
        paths = write_report(sample_tree(), str(tmp_path), "r", metric="samples")
        assert (tmp_path / "r.html").exists() and (tmp_path / "r.json").exists()
        loaded = CallTree.from_json((tmp_path / "r.json").read_text())
        assert loaded.total() == 10


class TestViewConfig:
    def test_zoom_and_level(self):
        v = ViewConfig(name="attn", root="attention", level=1)
        t = v.apply(sample_tree())
        assert t.total() == 6
        assert "attention" in t.root.children
        assert not t.root.children["attention"].children  # folded at level 1

    def test_csv_shares_sum_leq_one_per_level(self):
        v = ViewConfig(name="x", level=1)
        csv = v.to_csv(sample_tree())
        rows = [l for l in csv.splitlines() if l and not l.startswith(("#", "path"))]
        shares = [float(r.rsplit(",", 1)[1]) for r in rows]
        assert all(0 <= s <= 1 for s in shares)
        assert abs(sum(shares) - 1.0) < 1e-6  # level-1 partitions the total

    def test_blacklist(self):
        v = ViewConfig(name="x", blacklist=["optimizer"])
        t = v.apply(sample_tree())
        assert t.total() == 10  # root metrics kept
        assert "optimizer" not in t.root.children["train_step"].children

    def test_no_match_root_emits_marker_not_vacuous_empty_csv(self):
        # Regression: root= matching nothing used to render a headers-only
        # CSV indistinguishable from "this component genuinely costs 0".
        v = ViewConfig(name="x", root="does_not_exist")
        csv = v.to_csv(sample_tree())
        assert f"{NO_MATCH_MARKER}does_not_exist" in csv
        assert not v.matches(sample_tree())
        assert ViewConfig(name="y", root="attention").matches(sample_tree())
        # a rootless view is never "no match"
        assert ViewConfig(name="z").matches(CallTree())

    def test_matched_root_with_empty_filters_is_not_reported_as_no_match(self):
        # root matched, but the whitelist removed every row: a *different*
        # marker — "no match for root=" here would point at the wrong knob.
        v = ViewConfig(name="x", root="attention", whitelist=["nonexistent_leaf"])
        csv = v.to_csv(sample_tree())
        assert NO_MATCH_MARKER not in csv
        assert EMPTY_VIEW_MARKER in csv
        assert v.matches(sample_tree())  # the root selector itself is fine

    def test_level_zero_fold_is_not_marked_empty(self):
        v = ViewConfig(name="x", root="attention", level=0)
        csv = v.to_csv(sample_tree())
        assert EMPTY_VIEW_MARKER not in csv and NO_MATCH_MARKER not in csv
        assert "total=6" in csv  # the fold keeps the total in the header

    def test_matching_whitelist_with_level_zero_is_not_marked_empty(self):
        # The filters matched; only the level fold emptied the children —
        # judging filters *after* the fold would falsely blame them.
        v = ViewConfig(name="x", root="attention", whitelist=["scores"], level=0)
        csv = v.to_csv(sample_tree())
        assert EMPTY_VIEW_MARKER not in csv and NO_MATCH_MARKER not in csv
        assert v.empty_marker(sample_tree()) is None


class TestViewsLibrary:
    def test_all_views_render_without_error(self):
        t = sample_tree()
        for name in list_views():
            csv = render_view(t, name)
            assert csv.startswith("# view=")

    def test_attention_view_isolates_component(self):
        csv = render_view(sample_tree(), "attention_internals")
        assert "scores" in csv and "mlp" not in csv

    def test_metric_override(self):
        csv = render_view(sample_tree(), "model_components", metric="flops")
        assert "metric=flops" in csv

    def test_library_covers_both_planes(self):
        names = list_views()
        assert any(n.startswith("host_") for n in names)
        assert any("collectives" in n for n in names)
        assert len(names) >= 20
