"""Report/views tests: HTML export, ViewConfig semantics, views library."""



from repro.core import CallTree, ViewConfig, render_html, write_report
from repro.core.views_library import list_views, render_view


def sample_tree():
    t = CallTree()
    for _ in range(6):
        t.add_stack(["train_step", "model", "attention", "scores"], {"samples": 1, "flops": 100})
    for _ in range(3):
        t.add_stack(["train_step", "model", "mlp", "up_proj"], {"samples": 1, "flops": 300})
    t.add_stack(["train_step", "optimizer", "adamw"], {"samples": 1, "flops": 10})
    return t


class TestHtmlReport:
    def test_render_html_is_standalone(self):
        html = render_html(sample_tree(), title="t", metric="flops")
        assert html.startswith("<!DOCTYPE html>")
        assert "attention" in html and "calltree-json" in html
        # embedded JSON round-trips
        blob = html.split('id="calltree-json">')[1].split("</script>")[0]
        assert CallTree.from_json(blob).total("flops") == sample_tree().total("flops")

    def test_write_report_files(self, tmp_path):
        paths = write_report(sample_tree(), str(tmp_path), "r", metric="samples")
        assert (tmp_path / "r.html").exists() and (tmp_path / "r.json").exists()
        loaded = CallTree.from_json((tmp_path / "r.json").read_text())
        assert loaded.total() == 10


class TestViewConfig:
    def test_zoom_and_level(self):
        v = ViewConfig(name="attn", root="attention", level=1)
        t = v.apply(sample_tree())
        assert t.total() == 6
        assert "attention" in t.root.children
        assert not t.root.children["attention"].children  # folded at level 1

    def test_csv_shares_sum_leq_one_per_level(self):
        v = ViewConfig(name="x", level=1)
        csv = v.to_csv(sample_tree())
        rows = [l for l in csv.splitlines() if l and not l.startswith(("#", "path"))]
        shares = [float(r.rsplit(",", 1)[1]) for r in rows]
        assert all(0 <= s <= 1 for s in shares)
        assert abs(sum(shares) - 1.0) < 1e-6  # level-1 partitions the total

    def test_blacklist(self):
        v = ViewConfig(name="x", blacklist=["optimizer"])
        t = v.apply(sample_tree())
        assert t.total() == 10  # root metrics kept
        assert "optimizer" not in t.root.children["train_step"].children


class TestViewsLibrary:
    def test_all_views_render_without_error(self):
        t = sample_tree()
        for name in list_views():
            csv = render_view(t, name)
            assert csv.startswith("# view=")

    def test_attention_view_isolates_component(self):
        csv = render_view(sample_tree(), "attention_internals")
        assert "scores" in csv and "mlp" not in csv

    def test_metric_override(self):
        csv = render_view(sample_tree(), "model_components", metric="flops")
        assert "metric=flops" in csv

    def test_library_covers_both_planes(self):
        names = list_views()
        assert any(n.startswith("host_") for n in names)
        assert any("collectives" in n for n in names)
        assert len(names) >= 20
