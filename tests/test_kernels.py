"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle, swept over
shapes / dtypes / masks (assignment item c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def allclose(got, want, dtype):
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,T,Hq,Hkv,D",
    [
        (1, 128, 128, 2, 2, 64),   # MHA, single block
        (2, 256, 256, 4, 1, 64),   # MQA, multi-block
        (1, 384, 384, 4, 2, 128),  # GQA, non-square block count
        (1, 100, 100, 2, 2, 64),   # ragged (padding path)
        (1, 128, 256, 2, 2, 64),   # cross: kv longer than q
    ],
)
def test_flash_vs_ref_causal(B, S, T, Hq, Hkv, D, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), dtype)
    got = ops.flash_attention(q, k, v, causal=True, interpret=True, block_q=128, block_k=128)
    want = jnp.swapaxes(
        ref.attention_ref(
            jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2), causal=True
        ),
        1,
        2,
    )
    allclose(got, want, dtype)


@pytest.mark.parametrize("window", [16, 64, 1024])
def test_flash_sliding_window(window):
    B, S, H, D = 1, 256, 2, 64
    ks = jax.random.split(jax.random.key(1), 3)
    q, k, v = (jax.random.normal(kk, (B, S, H, D), jnp.float32) for kk in ks)
    got = ops.flash_attention(q, k, v, causal=True, window=window, interpret=True)
    want = jnp.swapaxes(
        ref.attention_ref(
            jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
            causal=True, window=window,
        ),
        1,
        2,
    )
    allclose(got, want, jnp.float32)


def test_flash_noncausal():
    B, S, H, D = 1, 128, 2, 64
    ks = jax.random.split(jax.random.key(2), 3)
    q, k, v = (jax.random.normal(kk, (B, S, H, D), jnp.float32) for kk in ks)
    got = ops.flash_attention(q, k, v, causal=False, interpret=True)
    want = jnp.swapaxes(
        ref.attention_ref(
            jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2), causal=False
        ),
        1,
        2,
    )
    allclose(got, want, jnp.float32)


@pytest.mark.parametrize("bq,bk", [(64, 64), (128, 64), (64, 128)])
def test_flash_block_shape_invariance(bq, bk):
    """Output must not depend on the BlockSpec tiling."""
    B, S, H, D = 1, 256, 2, 64
    ks = jax.random.split(jax.random.key(3), 3)
    q, k, v = (jax.random.normal(kk, (B, S, H, D), jnp.float32) for kk in ks)
    base = ops.flash_attention(q, k, v, interpret=True, block_q=128, block_k=128)
    got = ops.flash_attention(q, k, v, interpret=True, block_q=bq, block_k=bk)
    allclose(got, base, jnp.float32)


def test_flash_matches_model_xla_path():
    """The model's chunked-XLA attention and the kernel agree."""
    from repro.configs import get_config
    from repro.models.attention import _attend_chunked, _attend_full

    cfg = get_config("qwen3-4b", smoke=True)
    B, S, Hq, Hkv, D = 1, 128, 4, 2, 16
    ks = jax.random.split(jax.random.key(4), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    kern = ops.flash_attention(q, k, v, causal=True, interpret=True, block_q=64, block_k=64)
    full = _attend_full(q, k, v, cfg)
    chunked = _attend_chunked(q, k, v, cfg)
    allclose(kern, full, jnp.float32)
    allclose(chunked, full, jnp.float32)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,W,bs,bw",
    [
        (1, 128, 512, 128, 512),  # single block
        (2, 256, 512, 128, 256),  # multi block both axes
        (1, 200, 300, 128, 256),  # ragged padding
        (1, 512, 128, 64, 128),   # long sequence, short width
    ],
)
def test_rglru_vs_ref(B, S, W, bs, bw, dtype):
    ks = jax.random.split(jax.random.key(5), 2)
    # decays in (0,1): realistic RG-LRU regime
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W))).astype(dtype)
    b = jax.random.normal(ks[1], (B, S, W)).astype(dtype)
    got = ops.rglru_scan(a, b, block_s=bs, block_w=bw, interpret=True)
    want = ref.rglru_ref(a, b)
    allclose(got, want, dtype)


def test_rglru_state_carries_across_seq_blocks():
    """With a=1, b=1 the output is a running count — any state loss between
    sequence blocks would show as a reset."""
    B, S, W = 1, 256, 128
    a = jnp.ones((B, S, W), jnp.float32)
    b = jnp.ones((B, S, W), jnp.float32)
    got = ops.rglru_scan(a, b, block_s=64, block_w=128, interpret=True)
    want = jnp.broadcast_to(jnp.arange(1, S + 1, dtype=jnp.float32)[None, :, None], (B, S, W))
    allclose(got, want, jnp.float32)


def test_rglru_matches_model_assoc_scan():
    from repro.models.rglru import rglru, rglru_spec
    from repro.models.modules import init_params

    B, S, W = 2, 64, 128
    params = init_params(rglru_spec(W), jax.random.key(6))
    x = jax.random.normal(jax.random.key(7), (B, S, W), jnp.float32)
    y_xla, _ = rglru(params, x, impl="xla")
    y_pallas, _ = rglru(params, x, impl="pallas_interpret")
    allclose(y_pallas, y_xla, jnp.float32)


# ---------------------------------------------------------------------------
# fused rmsnorm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 128), (2, 7, 256), (1, 1000, 512)])
def test_rmsnorm_vs_ref(shape, dtype):
    ks = jax.random.split(jax.random.key(8), 2)
    x = jax.random.normal(ks[0], shape, dtype)
    scale = jax.random.normal(ks[1], (shape[-1],), jnp.float32) * 0.1
    got = ops.fused_rmsnorm(x, scale, interpret=True)
    want = ref.rmsnorm_ref(x, scale)
    allclose(got, want, dtype)


def test_rmsnorm_matches_model_impl():
    from repro.models.modules import rms_norm

    x = jax.random.normal(jax.random.key(9), (4, 64, 256), jnp.bfloat16)
    scale = jax.random.normal(jax.random.key(10), (256,), jnp.float32) * 0.1
    got = ops.fused_rmsnorm(x, scale, interpret=True)
    want = rms_norm({"scale": scale}, x)
    allclose(got, want, jnp.bfloat16)
