"""Substrate tests: optimizer, data pipeline, checkpointing, sharding rules."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # keep property tests running where hypothesis is absent
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, Pipeline, SyntheticLM
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule


class TestAdamW:
    def params(self):
        return {"a": jnp.array([1.0, 2.0]), "b": {"w": jnp.ones((2, 2))}}

    def test_matches_reference_math(self):
        p = {"w": jnp.array([1.0])}
        g = {"w": jnp.array([0.5])}
        st_ = adamw_init(p)
        cfg = AdamWConfig(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0, clip_norm=1e9)
        new_p, st2, _ = adamw_update(g, st_, p, lr=0.1, cfg=cfg)
        # bias-corrected first step: update = lr * g/|g| = lr (adam property)
        np.testing.assert_allclose(float(new_p["w"][0]), 1.0 - 0.1, rtol=1e-5)
        assert int(st2["step"]) == 1

    def test_weight_decay_pulls_to_zero(self):
        p = {"w": jnp.array([10.0])}
        g = {"w": jnp.array([0.0])}
        st_ = adamw_init(p)
        new_p, _, _ = adamw_update(g, st_, p, lr=0.1, cfg=AdamWConfig(weight_decay=0.1))
        assert float(new_p["w"][0]) < 10.0

    def test_clipping_bounds_update(self):
        p = {"w": jnp.array([0.0])}
        g = {"w": jnp.array([1e6])}
        st_ = adamw_init(p)
        _, _, m = adamw_update(g, st_, p, lr=0.1, cfg=AdamWConfig(clip_norm=1.0))
        assert float(m["clip_scale"]) == pytest.approx(1e-6, rel=1e-3)

    def test_state_mirrors_param_tree(self):
        p = self.params()
        st_ = adamw_init(p)
        assert jax.tree.structure(st_["m"]) == jax.tree.structure(p)

    def test_schedule_warmup_and_decay(self):
        lr = cosine_schedule(1.0, warmup_steps=10, total_steps=100)
        assert float(lr(0)) == 0.0
        assert float(lr(10)) == pytest.approx(1.0, rel=1e-3)
        assert float(lr(100)) == pytest.approx(0.1, rel=1e-2)
        assert float(lr(5)) == pytest.approx(0.5, rel=1e-3)


class TestData:
    def cfg(self, **kw):
        return DataConfig(vocab=97, seq_len=32, global_batch=8, **kw)

    def test_deterministic_and_resumable(self):
        ds = SyntheticLM(self.cfg())
        b1, b2 = ds.batch(7), ds.batch(7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert not np.array_equal(ds.batch(8)["tokens"], b1["tokens"])

    def test_labels_are_shifted_tokens(self):
        b = SyntheticLM(self.cfg()).batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_host_sharding_partitions_batch(self):
        full = SyntheticLM(self.cfg()).batch(3)
        shards = [
            SyntheticLM(self.cfg(n_hosts=4, host_id=h)).batch(3)["tokens"] for h in range(4)
        ]
        assert all(s.shape[0] == 2 for s in shards)
        # different hosts generate different rows
        assert not np.array_equal(shards[0], shards[1])

    def test_tokens_in_vocab(self):
        b = SyntheticLM(self.cfg()).batch(1)
        assert b["tokens"].min() >= 0 and b["tokens"].max() < 97

    def test_pipeline_prefetch_and_state(self):
        pipe = Pipeline(SyntheticLM(self.cfg()), prefetch=2)
        a = next(pipe)
        b = next(pipe)
        assert pipe.state_dict()["next_step"] == 2
        pipe.load_state_dict({"next_step": 1})
        b_again = next(pipe)
        np.testing.assert_array_equal(b["tokens"], b_again["tokens"])
        pipe.close()

    @settings(max_examples=20, deadline=None)
    @given(st.integers(min_value=0, max_value=1000), st.integers(min_value=0, max_value=1000))
    def test_prop_distinct_steps_distinct_batches(self, s1, s2):
        ds = SyntheticLM(self.cfg())
        t1, t2 = ds.batch(s1)["tokens"], ds.batch(s2)["tokens"]
        assert np.array_equal(t1, t2) == (s1 == s2)


class TestCheckpoint:
    def tree(self, scale=1.0):
        return {
            "params": {"w": np.full((4, 4), scale, np.float32), "b": np.arange(3, dtype=np.int32)},
            "opt": {"step": np.asarray(7)},
        }

    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(5, self.tree(), blocking=True)
        step, tree, manifest = mgr.restore_latest()
        assert step == 5 and manifest["tag"] == "periodic"
        np.testing.assert_array_equal(tree["params"]["w"], self.tree()["params"]["w"])
        np.testing.assert_array_equal(tree["params"]["b"], self.tree()["params"]["b"])

    def test_async_save_then_wait(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, self.tree(1.0))
        mgr.wait()
        assert mgr.list_steps() == [1]

    def test_keep_policy_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, self.tree(s), blocking=True)
        assert mgr.list_steps() == [3, 4]

    def test_crash_safe_tmp_never_restored(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, self.tree(), blocking=True)
        os.makedirs(tmp_path / "step_0000000002.tmp")  # simulated crashed save
        step, _, _ = mgr.restore_latest()
        assert step == 1

    def test_emergency_tagging(self, tmp_path):
        from repro.core.detector import AnomalyEvent, Rule

        mgr = CheckpointManager(str(tmp_path))
        ev = AnomalyEvent("LIVELOCK_SUSPECT", ("a", "b"), 0.97, Rule(), 3)
        mgr.save_emergency(lambda: (9, self.tree()), ev)
        _, _, manifest = mgr.restore_latest()
        assert manifest["tag"] == "emergency"
        assert manifest["extra"]["anomaly"]["share"] == pytest.approx(0.97)


class _StubMesh:
    """spec_for only reads mesh.shape; a stub lets rule tests use any size."""

    def __init__(self, **shape):
        self.shape = shape


class TestShardingRules:
    def test_spec_resolution_and_fallback(self):
        from repro.models.modules import ArraySpec
        from repro.sharding import make_strategy, spec_for

        strat = make_strategy("tp_fsdp")
        mesh = _StubMesh(data=2, model=4)
        # divisible: vocab 64 over model=4
        s = spec_for(ArraySpec((64, 32), ("vocab", "embed")), strat, mesh)
        assert s[0] == "model" and s[1] in (("data",), "data")
        # non-divisible kv_heads=3 over model=4 -> replicated
        s2 = spec_for(ArraySpec((32, 3, 8), ("embed", "kv_heads", "head")), strat, mesh)
        assert s2[1] is None

    def test_production_mesh_divisibility_fallbacks(self):
        """GQA kv=8 < model=16 replicates KV; experts 128 shard 16-way."""
        from repro.models.modules import ArraySpec
        from repro.sharding import make_strategy, spec_for

        strat = make_strategy("tp_fsdp", multi_pod=True)
        mesh = _StubMesh(pod=2, data=16, model=16)
        kv = spec_for(ArraySpec((4096, 8, 128), ("embed", "kv_heads", "head")), strat, mesh)
        assert kv[1] is None  # 8 kv heads cannot shard 16 ways
        assert kv[0] == ("pod", "data")  # FSDP over pod x data
        ex = spec_for(ArraySpec((128, 4096, 1536), ("expert", "embed", "mlp")), strat, mesh)
        assert ex[0] == "model" and ex[1] == ("pod", "data")

    def test_mesh_axis_never_reused(self):
        from repro.models.modules import ArraySpec
        from repro.sharding import make_strategy, spec_for

        strat = make_strategy("tp_only")
        mesh = _StubMesh(data=1, model=4)
        # both logical axes map to 'model'; only the first may take it
        s = spec_for(ArraySpec((8, 8), ("vocab", "mlp")), strat, mesh)
        taken = [x for x in s if x is not None]
        assert taken == ["model"]

    def test_activation_ctx_identity_outside(self):
        from repro.sharding import shard_activation

        x = jnp.ones((4, 4))
        assert shard_activation(x, ("batch", None)) is x
