"""End-to-end integration: trainer loop (+resume, +watchdog), server, launcher."""

import json
import os
import sys

import numpy as np
import pytest

from repro.launch.train import Trainer, TrainJobConfig


def job(tmp_path, **kw):
    base = dict(
        arch="xlstm-125m",
        smoke=True,
        steps=6,
        global_batch=4,
        seq_len=32,
        lr=1e-2,
        out_dir=str(tmp_path),
        ckpt_every=3,
        profile=True,
        sample_period_s=0.05,
        resume=True,
    )
    base.update(kw)
    return TrainJobConfig(**base)


class TestTrainer:
    def test_loss_decreases_and_artifacts_written(self, tmp_path):
        summary = Trainer(job(tmp_path, steps=8)).run()
        assert summary["steps"] == 8
        assert summary["final_loss"] < summary["first_loss"]
        assert os.path.exists(tmp_path / "metrics.json")
        assert os.path.exists(tmp_path / "heartbeat")
        # host-plane profile written (the always-on paper toolchain)
        assert os.path.exists(tmp_path / "host_profile.html")

    def test_checkpoint_resume_exact(self, tmp_path):
        t1 = Trainer(job(tmp_path, steps=6))
        t1.run()
        # second run continues from step 6 checkpoint, runs to 9
        t2 = Trainer(job(tmp_path, steps=9))
        t2.run()
        assert t2.step == 9
        with open(tmp_path / "metrics.json") as f:
            log = json.load(f)
        steps = [m["step"] for m in log["steps"]]
        assert steps == [7, 8, 9]

    def test_resume_reproduces_uninterrupted_run(self, tmp_path):
        """train(6)+resume(4) == train(10) bit-for-bit on the loss curve."""
        a = tmp_path / "a"
        b = tmp_path / "b"
        Trainer(job(a, steps=5, ckpt_every=5, profile=False)).run()
        Trainer(job(a, steps=10, ckpt_every=5, profile=False)).run()
        Trainer(job(b, steps=10, ckpt_every=10, profile=False)).run()
        with open(a / "metrics.json") as f:
            la = json.load(f)["steps"]
        with open(b / "metrics.json") as f:
            lb = json.load(f)["steps"]
        la = {m["step"]: m["loss"] for m in la}
        lb = {m["step"]: m["loss"] for m in lb}
        for s in (6, 8, 10):
            assert la[s] == pytest.approx(lb[s], rel=1e-4), f"divergence at step {s}"


class TestServer:
    def test_batched_serving_completes_requests(self):
        from repro.configs import get_config
        from repro.launch.serve import BatchedServer, Request
        from repro.models import Model

        cfg = get_config("gemma-2b", smoke=True)
        model = Model(cfg)
        rng = np.random.default_rng(0)
        reqs = [
            Request(rid=i, prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32), max_new=4)
            for i in range(6)
        ]
        server = BatchedServer(model, batch=3, max_len=64)
        stats = server.run(reqs)
        assert stats["requests_done"] == 6
        assert all(len(r.out) == 4 for r in reqs)
        assert all(0 <= t < cfg.vocab for r in reqs for t in r.out)

    def test_continuous_batching_reuses_slots(self):
        from repro.configs import get_config
        from repro.launch.serve import BatchedServer, Request
        from repro.models import Model

        cfg = get_config("xlstm-125m", smoke=True)
        model = Model(cfg)
        reqs = [Request(rid=i, prompt=np.array([1, 2, 3], np.int32), max_new=2) for i in range(5)]
        server = BatchedServer(model, batch=2, max_len=64)
        stats = server.run(reqs)
        assert stats["requests_done"] == 5  # 5 requests through 2 slots


class TestLauncher:
    def _script(self, tmp_path, hang: bool):
        """A child that heartbeats, then either finishes or hangs forever."""
        p = tmp_path / "child.py"
        hb = tmp_path / "heartbeat"
        marker = tmp_path / "attempts.txt"
        p.write_text(
            f"""
import os, sys, time
hb = {str(hb)!r}
marker = {str(marker)!r}
with open(marker, 'a') as f:
    f.write('x')
attempts = os.path.getsize(marker)
for i in range(3):
    open(hb, 'w').write(str(i))
    time.sleep(0.05)
if {hang!r} and attempts == 1:
    time.sleep(3600)   # first attempt hangs after heartbeats stop
open(hb, 'w').write('done')
"""
        )
        return p, hb, marker

    def test_restart_on_hang_then_success(self, tmp_path):
        from repro.launch.launcher import LaunchConfig, Launcher

        script, hb, marker = self._script(tmp_path, hang=True)
        cfg = LaunchConfig(
            cmd=[sys.executable, str(script)],
            workdir=str(tmp_path),
            heartbeat_path=str(hb),
            heartbeat_timeout_s=1.0,
            poll_s=0.1,
            max_restarts=2,
            backoff_s=0.1,
        )
        rep = Launcher(cfg).run()
        assert rep.exit_code == 0
        assert rep.restarts == 1  # hung once, restarted, completed
        assert marker.read_text() == "xx"

    def test_clean_job_no_restarts(self, tmp_path):
        from repro.launch.launcher import LaunchConfig, Launcher

        script, hb, _ = self._script(tmp_path, hang=False)
        cfg = LaunchConfig(
            cmd=[sys.executable, str(script)],
            workdir=str(tmp_path),
            heartbeat_path=str(hb),
            heartbeat_timeout_s=5.0,
            poll_s=0.1,
        )
        rep = Launcher(cfg).run()
        assert rep.exit_code == 0 and rep.restarts == 0

    def test_shared_profilerd_daemon_per_node(self, tmp_path):
        """profile_dir starts ONE watch daemon for the whole job; it attaches
        the child's spool as it appears and publishes the merged fleet tree
        that rendezvous then just collects."""
        from repro.launch.launcher import LaunchConfig, Launcher

        src_root = os.path.join(os.path.dirname(__file__), "..", "src")
        p = tmp_path / "child.py"
        hb = tmp_path / "heartbeat"
        p.write_text(
            f"""
import os, sys, time
sys.path.insert(0, {os.path.abspath(src_root)!r})
from repro.core import SamplerConfig, make_sampler
s = make_sampler(SamplerConfig(backend="thread"))  # env routes to the daemon
s.start()
def launcher_child_busy_loop():
    t0 = time.monotonic(); x = 0
    while time.monotonic() - t0 < 1.0:
        x += 1
        if x % 100000 == 0:
            open({str(hb)!r}, 'w').write(str(x))
launcher_child_busy_loop()
s.stop()
"""
        )
        cfg = LaunchConfig(
            cmd=[sys.executable, str(p)],
            workdir=str(tmp_path),
            heartbeat_path=str(hb),
            heartbeat_timeout_s=20.0,
            poll_s=0.1,
            profile_dir=str(tmp_path / "prof"),
            profile_period_s=0.05,
            env={"JAX_PLATFORMS": "cpu",
                 "PYTHONPATH": os.path.abspath(src_root)},
        )
        launcher = Launcher(cfg)
        rep = launcher.run()
        assert rep.exit_code == 0
        assert len(launcher._daemons) == 1  # one shared daemon, not one per spool
        fleet_tree = os.path.join(cfg.profile_dir, "fleet.d", "tree.json")
        assert os.path.exists(fleet_tree)
        # The child's DaemonBackend reads its artifacts where the shared
        # daemon publishes them (REPRO_PROFILERD_OUT -> per-target dir).
        target_dir = os.path.join(cfg.profile_dir, "fleet.d", "targets", "attempt0")
        assert os.path.exists(os.path.join(target_dir, "tree.json"))
        tstatus = json.load(open(os.path.join(target_dir, "status.json")))
        assert tstatus["done"] and tstatus["n_stacks"] > 0
        merged = os.path.join(cfg.profile_dir, "merged_tree.json")
        assert os.path.exists(merged)
        tree = json.load(open(merged))
        names = json.dumps(tree)
        assert "launcher_child_busy_loop" in names
        assert any("merged 1 host tree" in e for e in rep.events)

    def test_gives_up_after_budget(self, tmp_path):
        from repro.launch.launcher import LaunchConfig, Launcher

        p = tmp_path / "bad.py"
        p.write_text("import sys; sys.exit(3)")
        cfg = LaunchConfig(
            cmd=[sys.executable, str(p)],
            workdir=str(tmp_path),
            heartbeat_path=str(tmp_path / "hb"),
            heartbeat_timeout_s=5.0,
            poll_s=0.05,
            max_restarts=2,
            backoff_s=0.01,
        )
        rep = Launcher(cfg).run()
        assert rep.exit_code == 3
        assert rep.restarts == 3  # budget exhausted
