"""Export-layer + query-plane tests.

The invariants the ISSUE pins down:

* folded roundtrip — fold -> re-ingest yields a tree with identical inclusive
  metrics at every node (and therefore identical shares);
* speedscope — frame/event invariants of the file-format schema shape;
* diff export — sign conventions: positive share delta == candidate grew;
* HTML — one self-contained file: no external (http/https) references, names
  escaped, the embedded data island survives hostile frame names;
* server — /status /tree /timeline /diff answer against both a live daemon
  and an offline artifact dir, with bounded responses and sane error codes;
* CLI — export/no-match exit codes, top --once, diff --html.
"""

import json
import os
import threading
import time
import urllib.error
import urllib.request

import pytest

from repro.core import CallTree
from repro.core.export import (
    DIFF_SHARE_DELTA,
    EXPORT_FORMATS,
    build_diff_tree,
    diff_flamegraph_html,
    export_tree,
    flamegraph_html,
    from_folded,
    iter_folded,
    to_folded,
    to_speedscope,
)
from repro.core.report import ViewConfig, save_views
from repro.core.snapshot import EpochSealer, TimelineWriter, save_snapshot
from repro.core.views_library import export_view
from repro.profilerd.__main__ import EXIT_NO_MATCH, EXIT_UNREADABLE, main
from repro.profilerd.server import OfflineSource, ProfileServer, render_top


def sample_tree():
    t = CallTree()
    for _ in range(6):
        t.add_stack(["serve_step", "model", "attention", "scores"])
    for _ in range(3):
        t.add_stack(["serve_step", "model", "mlp", "gate_proj"])
    t.add_stack(["serve_step", "sampler", "top_p"])
    for _ in range(2):
        t.add_stack(["data", "pipeline", "next_batch"])
    return t


def device_tree():
    """Metrics-dict plane: inclusive values not expressible as leaf counts."""
    t = CallTree()
    t.add_stack(["model", "attention", "scores"], {"flops": 100.0, "bytes": 7.0})
    t.add_stack(["model", "attention"], {"flops": 20.0})
    t.add_stack(["model", "mlp"], {"flops": 300.0})
    return t


def profile_dir(tmp_path, tree=None, epochs=3):
    """A daemon-out-dir-shaped artifact: tree.json + sealed timeline ring."""
    d = str(tmp_path)
    t = CallTree()
    writer = TimelineWriter(os.path.join(d, "timeline"), epochs_per_segment=4)
    sealer = EpochSealer(t, writer)
    for epoch in range(epochs):
        for _ in range(10):
            for stack, n in [
                (["thread::Main", "serve_step", "model", "attention"], 3.0),
                (["thread::Main", "serve_step", "sampler"], 1.0),
            ]:
                chain = t.path_nodes(stack)
                CallTree.add_stack_nodes(chain, n)
        sealer.seal(wall_time=float(epoch))
    writer.close()
    with open(os.path.join(d, "tree.json"), "w") as f:
        f.write(t.to_json())
    return d, t


class TestFoldedRoundtrip:
    def test_fold_reingest_is_exact(self):
        t = sample_tree()
        t2 = from_folded(to_folded(t))
        assert t2.root == t.root  # identical inclusive + self metrics everywhere
        assert t2.shares() == t.shares()

    def test_device_plane_residuals_roundtrip_inclusive_metrics(self):
        t = device_tree()
        t2 = from_folded(to_folded(t, metric="flops"), metric="flops")
        for path, node in t.root.walk():
            n2 = t2.root
            for name in path[1:]:
                n2 = n2.children[name]
            assert n2.metrics.get("flops", 0.0) == pytest.approx(node.metrics.get("flops", 0.0))

    def test_folded_lines_are_sorted_and_parseable(self):
        lines = to_folded(sample_tree()).splitlines()
        assert lines == sorted(lines)
        for line in lines:
            stack, _, v = line.rpartition(" ")
            float(v)
            assert stack

    def test_windowed_delta_trees_fold_with_negatives(self):
        a = sample_tree()
        b = a.copy()
        b.add_stack(["serve_step", "sampler", "top_p"])
        delta = a.diff(b)  # a minus b => the extra sample shows as -1
        folded = to_folded(delta)
        assert any(v < 0 for _p, v in iter_folded(delta))
        t2 = from_folded(folded)
        assert t2.root.children["serve_step"].metrics["samples"] == -1.0

    def test_comment_and_blank_lines_ignored(self):
        t = from_folded("# header\n\na;b 2\n")
        assert t.total() == 2.0

    def test_hostile_frame_names_roundtrip(self):
        # ';' is the folded separator, '#' starts a comment line, '\n' ends a
        # record: frame names containing any of them must survive the fold.
        t = CallTree()
        t.add_stack(["a;b", "with\nnewline"], {"samples": 2.0})
        t.add_stack(["#looks_like_comment", "leaf"], {"samples": 1.0})
        t.add_stack(["back\\slash"], {"samples": 1.0})
        t.add_stack([" leading_space", "x"], {"samples": 1.0})
        t.add_stack(["<root>"], {"samples": 1.0})  # collides with the root token
        t.add_stack(["cr\rlf", "v\x0bt sep"], {"samples": 1.0})  # splitlines() bait
        t.add_stack([""], {"samples": 5.0})  # empty frame name
        t2 = from_folded(to_folded(t))
        assert t2.root == t.root
        assert "a;b" in t2.root.children and "#looks_like_comment" in t2.root.children
        assert " leading_space" in t2.root.children and "<root>" in t2.root.children
        assert "cr\rlf" in t2.root.children

    def test_root_residual_mass_is_not_dropped(self):
        # Samples ingested with an empty stack land on the synthetic root;
        # the fold must carry that mass or totals silently shrink.
        t = CallTree()
        t.add_stack([], {"flops": 5.0})
        t.add_stack(["a"], {"flops": 1.0})
        t2 = from_folded(to_folded(t, metric="flops"), metric="flops")
        assert t2.total("flops") == 6.0
        assert t2.root == t.root

    def test_full_float_precision_roundtrips(self):
        # Values needing >12 significant digits (the old %.12g formatting
        # truncated these) and classic non-representable sums must survive
        # the text roundtrip bit-for-bit.
        t = CallTree()
        t.add_stack(["model", "mlp"], {"flops": 123456789.0123456})
        t.add_stack(["data", "pipeline"], {"flops": 0.1 + 0.2})
        t2 = from_folded(to_folded(t, metric="flops"), metric="flops")
        assert t2.root == t.root  # bit-exact, not N-significant-digits


class TestSpeedscope:
    def test_schema_shape_invariants(self):
        ss = to_speedscope(sample_tree(), name="unit")
        assert ss["$schema"].endswith("file-format-schema.json")
        assert ss["activeProfileIndex"] == 0
        frames = ss["shared"]["frames"]
        assert frames and all(isinstance(f["name"], str) for f in frames)
        (prof,) = ss["profiles"]
        assert prof["type"] == "sampled" and prof["name"] == "unit"
        assert len(prof["samples"]) == len(prof["weights"])
        assert all(w > 0 for w in prof["weights"])
        assert prof["startValue"] == 0.0
        assert sum(prof["weights"]) == pytest.approx(prof["endValue"])
        nf = len(frames)
        assert all(0 <= i < nf for stack in prof["samples"] for i in stack)

    def test_weights_total_matches_tree_total(self):
        t = sample_tree()
        ss = to_speedscope(t)
        assert ss["profiles"][0]["endValue"] == pytest.approx(t.total())

    def test_json_serializable(self):
        json.dumps(to_speedscope(sample_tree()))


class TestDiffExport:
    def baseline_and_candidate(self):
        base = sample_tree()
        cand = sample_tree()
        for _ in range(6):
            cand.add_stack(["serve_step", "spin_retry_loop"])
        return base, cand

    def test_sign_convention_positive_means_candidate_grew(self):
        base, cand = self.baseline_and_candidate()
        diff = build_diff_tree(base, cand)
        spin = diff.root.children["serve_step"].children["spin_retry_loop"]
        assert spin.metrics[DIFF_SHARE_DELTA] > 0  # regression: red
        model = diff.root.children["serve_step"].children["model"]
        assert model.metrics[DIFF_SHARE_DELTA] < 0  # relative improvement: blue

    def test_share_deltas_are_run_length_invariant(self):
        base, cand = self.baseline_and_candidate()
        twice = CallTree().merge(cand).merge(cand)  # same shape, double the mass
        d1 = build_diff_tree(base, cand)
        d2 = build_diff_tree(base, twice)
        n1 = d1.root.children["serve_step"].children["spin_retry_loop"]
        n2 = d2.root.children["serve_step"].children["spin_retry_loop"]
        assert n1.metrics[DIFF_SHARE_DELTA] == pytest.approx(n2.metrics[DIFF_SHARE_DELTA])

    def test_baseline_only_nodes_survive_in_the_union(self):
        base, cand = self.baseline_and_candidate()
        base.add_stack(["serve_step", "legacy_path"])
        diff = build_diff_tree(base, cand)
        legacy = diff.root.children["serve_step"].children["legacy_path"]
        assert legacy.metrics["baseline"] == 1.0 and legacy.metrics["samples"] == 0.0
        assert legacy.metrics[DIFF_SHARE_DELTA] < 0

    def test_diff_flamegraph_html_is_self_contained_and_marked(self):
        base, cand = self.baseline_and_candidate()
        html = diff_flamegraph_html(base, cand)
        assert "http://" not in html and "https://" not in html
        data = json.loads(html.split('id="fgdata" type="application/json">')[1].split("</script>")[0])
        assert data["diff"] is True
        step = next(c for c in data["c"] if c["n"] == "serve_step")
        spin = next(c for c in step["c"] if c["n"] == "spin_retry_loop")
        assert spin["d"] > 0 and spin["b"] == 0


class TestFlamegraphHtml:
    def test_single_self_contained_file(self):
        html = flamegraph_html(sample_tree(), title="t")
        assert html.startswith("<!DOCTYPE html>")
        assert "http://" not in html and "https://" not in html
        assert "function" in html and "zoom" in html  # interactive, not static

    def test_hostile_frame_names_cannot_break_the_data_island(self):
        t = CallTree()
        t.add_stack(["<module>", "</script><script>alert(1)</script>"])
        html = flamegraph_html(t)
        blob = html.split('id="fgdata" type="application/json">')[1].split("</script>")[0]
        data = json.loads(blob)  # the first real </script> is the island's own close tag
        assert data["c"][0]["n"] == "<module>"
        assert data["c"][0]["c"][0]["n"] == "</script><script>alert(1)</script>"

    def test_title_and_metric_escaped(self):
        html = flamegraph_html(sample_tree(), title="<b>x</b>", metric="samples")
        assert "<b>x</b>" not in html and "&lt;b&gt;x&lt;/b&gt;" in html


class TestExportRouter:
    def test_every_format_renders(self):
        t = sample_tree()
        for fmt in EXPORT_FORMATS:
            out = export_tree(t, fmt)
            assert isinstance(out, str) and out

    def test_view_routing_applies_zoom(self):
        folded = export_tree(sample_tree(), "folded", view=ViewConfig(name="v", root="model"))
        assert folded and all(line.startswith("model") for line in folded.splitlines())

    def test_min_share_honored_by_non_csv_formats(self):
        # min_share is the advertised way to shrink an oversized response;
        # it must prune folded/speedscope/html too, not only to_csv rows.
        view = ViewConfig(name="v", min_share=0.5)
        folded = export_tree(sample_tree(), "folded", view=view)
        assert "scores" in folded  # 6/12 of total keeps the hot stack
        assert "top_p" not in folded and "next_batch" not in folded
        ss = json.loads(export_tree(sample_tree(), "speedscope", view=view))
        names = {f["name"] for f in ss["shared"]["frames"]}
        assert "top_p" not in names

    def test_library_views_export_uniformly(self):
        t = sample_tree()
        for fmt in ("folded", "speedscope", "html"):
            assert export_view(t, "top_level", fmt)

    def test_unknown_format_and_view_raise(self):
        with pytest.raises(ValueError):
            export_tree(sample_tree(), "gif")
        with pytest.raises(KeyError):
            export_tree(sample_tree(), "csv", view="not_a_view")

    def test_save_views_multi_format(self, tmp_path):
        written = save_views(
            sample_tree(), [ViewConfig(name="all")], str(tmp_path), formats=("csv", "folded", "html")
        )
        assert {os.path.basename(p) for p in written} == {"all.csv", "all.folded", "all.html"}
        for p in written:
            assert os.path.getsize(p) > 0
        html = open([p for p in written if p.endswith(".html")][0]).read()
        assert "all [all]" not in html  # view name not duplicated in the title

    def test_save_views_empty_view_writes_marker_not_empty_file(self, tmp_path):
        written = save_views(
            sample_tree(), [ViewConfig(name="ghost", root="typo")], str(tmp_path),
            formats=("csv", "folded"),
        )
        for p in written:
            body = open(p).read()
            assert "# no match for root=typo" in body, p  # never a vacuous empty file


@pytest.fixture
def offline_server(tmp_path):
    d, tree = profile_dir(tmp_path)
    server = ProfileServer(OfflineSource(d), port=0).start()
    yield server, d, tree
    server.stop()


def _get(url):
    with urllib.request.urlopen(url, timeout=10) as resp:
        return resp.status, resp.read().decode()


class TestServerOffline:
    def test_status_tree_timeline_diff(self, offline_server, tmp_path):
        server, d, tree = offline_server
        code, body = _get(server.url + "/status")
        status = json.loads(body)
        assert code == 200 and status["offline"] and status["hot_paths"]

        for fmt in EXPORT_FORMATS:
            code, body = _get(server.url + f"/tree?fmt={fmt}")
            assert code == 200 and body, fmt
        code, folded = _get(server.url + "/tree?fmt=folded")
        assert from_folded(folded).total() == pytest.approx(tree.total())
        code, ss = _get(server.url + "/tree?fmt=speedscope")
        prof = json.loads(ss)["profiles"][0]
        assert len(prof["samples"]) == len(prof["weights"]) > 0

        code, body = _get(server.url + "/timeline")
        assert code == 200 and "epoch" in body
        code, body = _get(server.url + "/timeline?fmt=json")
        epochs = json.loads(body)
        assert epochs[0]["epoch"] == 0 and epochs[0]["window_total"] > 0

        snap = str(tmp_path / "base.snap")
        save_snapshot(tree, snap)
        code, body = _get(server.url + f"/diff?baseline={snap}")
        assert code == 200 and body.startswith("# diff")
        code, body = _get(server.url + f"/diff?baseline={snap}&fmt=html")
        assert code == 200 and "fgdata" in body

    def test_view_and_adhoc_params(self, offline_server):
        server, _d, _t = offline_server
        code, body = _get(server.url + "/tree?view=host_threads")
        assert code == 200 and body.startswith("# view=host_threads")
        code, body = _get(server.url + "/tree?root=attention&fmt=folded")
        assert code == 200 and body.startswith("attention")

    def test_adhoc_params_refine_a_named_view(self, offline_server):
        # level=/min_share= are the advertised 413 remedies; they must
        # compose with view= instead of being silently dropped.
        server, _d, _t = offline_server
        _code, folded1 = _get(server.url + "/tree?view=host_threads&fmt=folded")
        _code, deep = _get(server.url + "/tree?view=host_threads&fmt=folded&level=-1")
        assert len(deep) > len(folded1)  # level=1 fold replaced by full stacks
        _code, pruned = _get(
            server.url + "/tree?view=host_threads&fmt=folded&level=-1&min_share=0.5"
        )
        assert "sampler" in deep and "sampler" not in pruned  # 25% share pruned
        assert len(pruned) < len(deep)

    def test_no_match_view_is_404_for_stack_formats_not_empty_200(self, offline_server):
        server, _d, _t = offline_server
        for q in ("/tree?root=typo&fmt=folded", "/tree?root=typo&fmt=speedscope",
                  "/tree?root=typo&fmt=html", "/tree?level=0&fmt=folded",
                  "/tree?min_share=1.5&fmt=folded"):  # min_share prunes everything
            with pytest.raises(urllib.error.HTTPError) as e:
                _get(server.url + q)
            assert e.value.code == 404, q
        # csv still answers 200 with its own marker rows
        code, body = _get(server.url + "/tree?root=typo&fmt=csv")
        assert code == 200 and "# no match for root=typo" in body
        code, body = _get(server.url + "/tree?min_share=1.5&fmt=csv")
        assert code == 200 and "min_share" in body and "pruned every row" in body

    def test_error_codes(self, offline_server):
        server, _d, _t = offline_server
        for path, want in [
            ("/nope", 404),
            ("/tree?fmt=bogus", 400),
            ("/tree?view=bogus", 404),
            ("/tree?level=abc", 400),
            ("/timeline?fmt=jsn", 400),
            ("/diff", 400),
            ("/diff?fmt=bogus&baseline=tests/data/ci_baseline.snap", 400),
            ("/diff?baseline=/does/not/exist", 404),
        ]:
            with pytest.raises(urllib.error.HTTPError) as e:
                _get(server.url + path)
            assert e.value.code == want, path

    def test_timeline_cache_refreshes_when_ring_grows(self, tmp_path):
        d = str(tmp_path)
        t = CallTree()
        writer = TimelineWriter(os.path.join(d, "timeline"), epochs_per_segment=2)
        sealer = EpochSealer(t, writer)
        chain = t.path_nodes(["thread::Main", "step"])
        CallTree.add_stack_nodes(chain, 5.0)
        sealer.seal(wall_time=0.0)
        server = ProfileServer(OfflineSource(d), port=0).start()
        try:
            first = json.loads(_get(server.url + "/timeline?fmt=json")[1])
            assert len(first) == 1
            cached = json.loads(_get(server.url + "/timeline?fmt=json")[1])
            assert cached == first  # served from the segment-mtime cache
            CallTree.add_stack_nodes(chain, 3.0)
            sealer.seal(wall_time=1.0)
            seg = os.path.join(d, "timeline")
            newest = max(os.path.join(seg, p) for p in os.listdir(seg))
            os.utime(newest, (time.time() + 2, time.time() + 2))
            grown = json.loads(_get(server.url + "/timeline?fmt=json")[1])
            assert len(grown) == 2  # cache invalidated by the mtime change
        finally:
            server.stop()
            writer.close()

    def test_diff_baseline_query_param_rejected_off_loopback(self, tmp_path):
        # ?baseline= is a server-side file read: on a non-loopback bind only
        # the operator-configured --baseline may be diffed (403 otherwise).
        d, tree = profile_dir(tmp_path)
        snap = str(tmp_path / "base.snap")
        save_snapshot(tree, snap)
        server = ProfileServer(OfflineSource(d), host="0.0.0.0", port=0, baseline=snap).start()
        url = f"http://127.0.0.1:{server.port}"
        try:
            with pytest.raises(urllib.error.HTTPError) as e:
                _get(url + "/diff?baseline=/etc/hostname")
            assert e.value.code == 403
            code, body = _get(url + "/diff")  # configured default: allowed
            assert code == 200 and body.startswith("# diff")
            code, body = _get(url + f"/diff?baseline={snap}")  # == configured
            assert code == 200
        finally:
            server.stop()

    def test_response_size_cap(self, tmp_path):
        d, _t = profile_dir(tmp_path)
        server = ProfileServer(OfflineSource(d), port=0, max_bytes=64).start()
        try:
            with pytest.raises(urllib.error.HTTPError) as e:
                _get(server.url + "/tree?fmt=html")
            assert e.value.code == 413
        finally:
            server.stop()

    def test_mtime_cache_picks_up_new_writes(self, tmp_path):
        # tree.json-only profile (no ring): rewrites must be re-served.
        tree = sample_tree()
        path = str(tmp_path / "tree.json")
        with open(path, "w") as f:
            f.write(tree.to_json())
        server = ProfileServer(OfflineSource(path), port=0).start()
        try:
            _code, before = _get(server.url + "/tree?fmt=folded")
            assert "fresh_path" not in before
            tree.add_stack(["fresh_path"])
            with open(path, "w") as f:
                f.write(tree.to_json())
            os.utime(path, (time.time() + 2, time.time() + 2))  # force mtime forward
            _code, after = _get(server.url + "/tree?fmt=folded")
            assert "fresh_path" in after
        finally:
            server.stop()


class TestServerLive:
    def test_live_daemon_answers_all_endpoints(self, tmp_path):
        from repro.profilerd.agent import Agent
        from repro.profilerd.daemon import DaemonConfig, ProfilerDaemon

        evt = threading.Event()

        def parked():
            evt.wait()

        worker = threading.Thread(target=parked, name="served-worker", daemon=True)
        worker.start()
        time.sleep(0.05)
        spool = str(tmp_path / "t.spool")
        agent = Agent(spool, period_s=10)
        for _ in range(12):
            agent.tick()

        cfg = DaemonConfig(
            spool_path=spool,
            out_dir=str(tmp_path / "out"),
            publish_interval_s=0.05,
            epoch_s=0.2,
            max_seconds=30,
            serve_port=0,
        )
        daemon = ProfilerDaemon(cfg)
        daemon.attach()
        server = daemon.enable_serving()
        runner = threading.Thread(target=daemon.run, daemon=True)
        runner.start()
        try:
            deadline = time.time() + 15
            status = {}
            while time.time() < deadline:
                status = json.loads(_get(server.url + "/status")[1])
                if status.get("n_stacks", 0) >= 12:
                    break
                time.sleep(0.05)
            assert status.get("n_stacks", 0) >= 12, status
            assert not status.get("offline")

            _code, folded = _get(server.url + "/tree?fmt=folded")
            assert "thread::served-worker" in folded
            _code, html = _get(server.url + "/tree?fmt=html")
            assert "http://" not in html and "https://" not in html

            deadline = time.time() + 10  # wait for the first sealed epoch
            while time.time() < deadline:
                try:
                    _code, tl = _get(server.url + "/timeline")
                    break
                except urllib.error.HTTPError:
                    time.sleep(0.1)
            assert "epoch" in tl
        finally:
            agent.stop()
            evt.set()
            runner.join(timeout=20)
        assert not runner.is_alive()
        # run() stops the server: the port must be closed afterwards.
        with pytest.raises((urllib.error.URLError, ConnectionError, OSError)):
            urllib.request.urlopen(server.url + "/status", timeout=1)

    def test_serving_reads_do_not_touch_live_tree(self, tmp_path):
        """Handlers only see published copies: mutating the live tree between
        publishes must not change what /tree serves."""
        from repro.profilerd.server import LiveSource, SharedProfileState

        shared = SharedProfileState()
        live = CallTree()
        live.add_stack(["a", "b"])
        shared.update({"n_stacks": 1}, live.copy())
        source = LiveSource(shared)
        live.add_stack(["a", "c"])  # ingest happens after the publish
        assert source.tree().total() == 1.0  # the snapshot, not the live tree


class TestLauncherServe:
    def host_dir(self, tmp_path, name, leaf):
        out = tmp_path / f"{name}.spool.d"
        out.mkdir()
        t = CallTree()
        for _ in range(4):
            t.add_stack(["thread::m", "serve_step", leaf])
        (out / "tree.json").write_text(t.to_json())
        return t

    def test_fleet_merge_is_served(self, tmp_path):
        from repro.launch.launcher import LaunchConfig, Launcher

        a = self.host_dir(tmp_path, "attempt0", "attention")
        b = self.host_dir(tmp_path, "attempt1", "mlp")
        launcher = Launcher(
            LaunchConfig(
                cmd=["true"],
                workdir=str(tmp_path),
                heartbeat_path=str(tmp_path / "hb"),
                profile_dir=str(tmp_path),
                serve_port=0,
            )
        )
        merged_path = launcher._rendezvous_merge()
        assert merged_path is not None and launcher.server is not None
        try:
            _code, body = _get(launcher.server.url + "/status")
            assert json.loads(body)["offline"]
            _code, folded = _get(launcher.server.url + "/tree?fmt=folded")
            merged = from_folded(folded)
            assert merged.total() == pytest.approx(a.total() + b.total())
            assert "attention" in folded and "mlp" in folded
        finally:
            launcher.server.stop()

    def test_no_serving_without_port(self, tmp_path):
        from repro.launch.launcher import LaunchConfig, Launcher

        self.host_dir(tmp_path, "attempt0", "attention")
        launcher = Launcher(
            LaunchConfig(
                cmd=["true"],
                workdir=str(tmp_path),
                heartbeat_path=str(tmp_path / "hb"),
                profile_dir=str(tmp_path),
            )
        )
        assert launcher._rendezvous_merge() is not None
        assert launcher.server is None


class TestCli:
    def test_export_folded_and_html(self, tmp_path, capsys):
        d, tree = profile_dir(tmp_path)
        assert main(["export", d, "--fmt", "folded"]) == 0
        out = capsys.readouterr().out
        assert from_folded(out).total() == pytest.approx(tree.total())
        html_path = str(tmp_path / "f.html")
        assert main(["export", d, "--fmt", "html", "--out", html_path]) == 0
        html = open(html_path).read()
        assert "http://" not in html and "https://" not in html

    def test_export_no_match_exits_4_with_marker(self, tmp_path, capsys):
        d, _tree = profile_dir(tmp_path)
        rc = main(["export", d, "--fmt", "csv", "--root", "does_not_exist"])
        captured = capsys.readouterr()
        assert rc == EXIT_NO_MATCH
        assert "# no match for root=does_not_exist" in captured.out + captured.err

    def test_export_filter_emptied_view_exits_4_not_silently_empty(self, tmp_path, capsys):
        # attention_scores_only: root="attention" matches nothing here, but
        # craft a profile where the root *does* match and only the whitelist
        # empties the view — the no-match exit must still fire, with the
        # empty-view marker (not a misleading "no match for root=").
        d = str(tmp_path / "p")
        os.makedirs(d)
        t = CallTree()
        t.add_stack(["thread::Main", "model", "attention", "context"])  # no "scores"
        with open(os.path.join(d, "tree.json"), "w") as f:
            f.write(t.to_json())
        rc = main(["export", d, "--fmt", "folded", "--view", "attention_scores_only"])
        captured = capsys.readouterr()
        assert rc == EXIT_NO_MATCH
        assert "# empty view" in captured.err
        assert "no match for root=" not in captured.err

    def test_export_unreadable_profile_exits_3(self, tmp_path):
        assert main(["export", str(tmp_path / "nope")]) == EXIT_UNREADABLE

    def test_export_level0_folded_exits_4_not_empty_file(self, tmp_path, capsys):
        # levels(0) folds everything into the root: no stacks exist for the
        # stack-shaped formats, which must fail loudly instead of writing an
        # empty artifact with exit 0 (csv keeps its header total and passes).
        d, _tree = profile_dir(tmp_path)
        out = str(tmp_path / "empty.folded")
        rc = main(["export", d, "--fmt", "folded", "--level", "0", "--out", out])
        captured = capsys.readouterr()
        assert rc == EXIT_NO_MATCH
        assert "empty export" in captured.err
        assert not os.path.exists(out)
        assert main(["export", d, "--fmt", "csv", "--level", "0"]) == 0
        capsys.readouterr()
        # min_share pruning everything must also fail loudly, not write ""
        rc = main(["export", d, "--fmt", "folded", "--min-share", "1.5", "--out", out])
        captured = capsys.readouterr()
        assert rc == EXIT_NO_MATCH and "min_share" in captured.err
        assert not os.path.exists(out)

    def test_export_baseline_defaults_to_html(self, tmp_path, capsys):
        d, tree = profile_dir(tmp_path)
        snap = str(tmp_path / "base.snap")
        save_snapshot(tree, snap)
        out = str(tmp_path / "d.html")
        # no --fmt: --baseline implies html
        assert main(["export", d, "--baseline", snap, "--out", out]) == 0
        assert "fgdata" in open(out).read()
        # an explicit conflicting fmt is a usage error (2), not "unreadable" (3)
        assert main(["export", d, "--baseline", snap, "--fmt", "folded"]) == 2

    def test_export_view_composes_with_min_share(self, tmp_path, capsys):
        d, _tree = profile_dir(tmp_path)
        assert main(["export", d, "--fmt", "folded", "--view", "host_threads",
                     "--level", "-1"]) == 0
        full = capsys.readouterr().out
        assert main(["export", d, "--fmt", "folded", "--view", "host_threads",
                     "--level", "-1", "--min-share", "0.5"]) == 0
        pruned = capsys.readouterr().out
        assert "sampler" in full and "sampler" not in pruned
        assert len(pruned) < len(full)

    def test_diff_html_writes_flamegraph(self, tmp_path, capsys):
        d, tree = profile_dir(tmp_path)
        snap = str(tmp_path / "base.snap")
        save_snapshot(tree, snap)
        html_path = str(tmp_path / "diff.html")
        assert main(["diff", snap, d, "--html", html_path]) == 0
        assert "fgdata" in open(html_path).read()

    def test_top_once_against_offline_server(self, tmp_path, capsys):
        d, _tree = profile_dir(tmp_path)
        server = ProfileServer(OfflineSource(d), port=0).start()
        try:
            assert main(["top", "--url", server.url, "--once"]) == 0
            out = capsys.readouterr().out
            assert "profilerd top" in out and "serve_step" in out
        finally:
            server.stop()

    def test_top_unreachable_exits_1(self):
        assert main(["top", "--url", "http://127.0.0.1:9", "--once"]) == 1

    def test_render_top_live_shape(self):
        out = render_top(
            {
                "pid": 7,
                "stalled": True,
                "n_stacks": 5,
                "wire_version": 2,
                "hot_paths": [{"path": ["a", "b"], "share": 0.5}],
                "events": [{"kind": "TARGET_STALLED", "path": [], "share": 1.0}],
            },
            "http://x",
        )
        assert "STALLED" in out and "a/b" in out and "TARGET_STALLED" in out
