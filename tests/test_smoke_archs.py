"""Per-architecture smoke tests: reduced config, one forward + one train step
+ one decode step on CPU, asserting shapes and no NaNs (assignment item f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.models import Model

ARCHS = [
    "recurrentgemma-9b",
    "qwen3-4b",
    "llama3.2-3b",
    "gemma-2b",
    "granite-3-8b",
    "qwen2-vl-2b",
    "xlstm-125m",
    "deepseek-moe-16b",
    "qwen3-moe-235b-a22b",
    "musicgen-medium",
]

B, S = 2, 32


def make_batch(cfg, key, batch=B, seq=S):
    ks = jax.random.split(key, 3)
    out = {}
    if cfg.input_mode == "tokens":
        out["tokens"] = jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab)
    else:
        out["embeds"] = jax.random.normal(ks[0], (batch, seq, cfg.d_model), jnp.bfloat16)
    if cfg.mrope:
        pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None, :, None], (batch, seq, 3))
        out["positions"] = pos
    out["labels"] = jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab)
    out["loss_mask"] = jnp.ones((batch, seq), jnp.float32)
    return out


def test_all_assigned_archs_registered():
    assert set(ARCHS) <= set(list_archs())
    assert len(list_archs()) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, jax.random.key(1))
    logits, lb = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), "non-finite logits"
    assert np.isfinite(float(lb))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_loss(arch):
    """One SGD step on a repeated batch must reduce loss (end-to-end grad flow)."""
    cfg = get_config(arch, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, jax.random.key(1))

    @jax.jit
    def step(p):
        (loss, aux), g = jax.value_and_grad(model.loss, has_aux=True)(p, batch)
        # normalized SGD: robust to per-arch gradient scale differences
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(g)))
        new_p = jax.tree.map(lambda w, gw: w - 0.05 * gw / (gnorm + 1e-6), p, g)
        return loss, new_p

    loss0, params = step(params)
    assert np.isfinite(float(loss0)), "loss not finite"
    for _ in range(5):
        loss1, params = step(params)
    assert float(loss1) < float(loss0), f"loss did not decrease: {loss0} -> {loss1}"


@pytest.mark.parametrize("arch", ARCHS)
def test_grads_finite_and_nonzero(arch):
    cfg = get_config(arch, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, jax.random.key(1))
    (_, _), grads = jax.jit(jax.value_and_grad(model.loss, has_aux=True))(params, batch)
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in leaves)
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves), "all-zero gradients"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    state = model.init_decode_state(batch=B, max_len=64)
    if cfg.input_mode == "tokens":
        batch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    else:
        batch = {"embeds": jnp.zeros((B, 1, cfg.d_model), jnp.bfloat16)}
    step = jax.jit(model.decode_step)
    logits, state = step(params, batch, state, jnp.int32(0))
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    logits2, state = step(params, batch, state, jnp.int32(1))
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ["qwen3-4b", "gemma-2b", "xlstm-125m", "recurrentgemma-9b"])
def test_decode_matches_prefill(arch):
    """Greedy decode logits must match teacher-forced forward (causality +
    cache correctness), for representative families."""
    cfg = get_config(arch, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    T = 8
    tokens = jax.random.randint(jax.random.key(2), (1, T), 0, cfg.vocab)
    fwd_logits, _ = jax.jit(model.forward)(params, {"tokens": tokens})
    state = model.init_decode_state(batch=1, max_len=32)
    step = jax.jit(model.decode_step)
    errs = []
    for t in range(T):
        logits, state = step(params, {"tokens": tokens[:, t : t + 1]}, state, jnp.int32(t))
        errs.append(float(jnp.abs(logits[0] - fwd_logits[0, t]).max()))
    assert max(errs) < 0.05, f"decode/prefill divergence: {errs}"


def test_full_configs_match_assignment():
    """Exact architecture numbers from the assignment table."""
    expect = {
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == h, arch
        assert cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab == v, arch
    moe = get_config("deepseek-moe-16b")
    assert (moe.n_experts, moe.top_k, moe.n_shared_experts) == (64, 6, 2)
    q3 = get_config("qwen3-moe-235b-a22b")
    assert (q3.n_experts, q3.top_k) == (128, 8)


def test_param_counts_in_expected_range():
    """Full-config parameter counts should be near the advertised sizes."""
    expect_range = {
        "qwen3-4b": (3.0e9, 5.5e9),
        "llama3.2-3b": (2.5e9, 4.0e9),
        "gemma-2b": (2.0e9, 3.2e9),
        "granite-3-8b": (7.0e9, 9.5e9),
        "recurrentgemma-9b": (7.5e9, 11e9),
        "deepseek-moe-16b": (14e9, 20e9),
        "qwen3-moe-235b-a22b": (200e9, 260e9),
        "xlstm-125m": (0.08e9, 0.2e9),
        "musicgen-medium": (1.2e9, 2.2e9),
        "qwen2-vl-2b": (1.2e9, 2.2e9),
    }
    for arch, (lo, hi) in expect_range.items():
        n = Model(get_config(arch)).n_params
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]B"


def test_long_500k_applicability():
    """Sub-quadratic archs run long_500k; full-attention archs skip (by rule)."""
    runs = {a for a in ARCHS if shape_applicable(get_config(a), SHAPES["long_500k"])[0]}
    assert runs == {"recurrentgemma-9b", "xlstm-125m"}
