"""Fleet tier tests: push wire format, aggregator replay, liveness, retention.

The invariants the ISSUE pins down:

* push bodies are self-contained snapshot-codec segments — torn or
  multi-record bodies are rejected, never half-applied;
* replay is idempotent (client retries after a lost 200 don't double-count)
  and order-tolerant (deltas commute within a keyframe era; a stale keyframe
  can't erase later-applied mass);
* node churn folds dead incarnations into a retained base — a crash-looping
  node keeps contributing everything it ever reported;
* retention is two-ring: recent epochs exact in a bounded ring, old epochs
  at coarser grain (one keyframe every N fleet epochs), both bounded by
  whole-segment drops;
* a dead aggregator never blocks the client and never loses epoch *mass*:
  spill + bounded backoff + keyframe resync (PUSH_FAILED/PUSH_RECOVERED);
* the aggregator restarts crash-safe from its own rings and sidecars.
"""

import json
import os
import urllib.error
import urllib.request

import pytest

from repro.core.calltree import CallTree
from repro.core.snapshot import (
    K_DELTA,
    K_FULL,
    EpochMeta,
    SnapshotCorrupt,
    TimelineReader,
    list_segments,
)
from repro.profilerd.aggregator import (
    NODE_RECOVERED,
    NODE_STALLED,
    Aggregator,
    AggregatorConfig,
)
from repro.profilerd.push import (
    H_BOOT,
    H_DONE,
    H_EPOCH,
    H_INTERVAL,
    H_NODE,
    H_TARGETS,
    PushClient,
    decode_push_body,
    encode_push_body,
    push_url_for,
)


def tree_of(*stacks, w=1.0):
    t = CallTree()
    for s in stacks:
        t.add_stack(list(s), {"samples": float(w)})
    return t


def headers_for(node, boot="boot0", epoch=0, **extra):
    h = {H_NODE: node, H_BOOT: boot, H_EPOCH: str(epoch), H_INTERVAL: "5"}
    h.update(extra)
    return h


def mkagg(tmp_path, **kw):
    kw.setdefault("out_dir", str(tmp_path / "region.d"))
    return Aggregator(AggregatorConfig(**kw))


class TestPushWire:
    def test_body_roundtrip_full_and_delta(self):
        t = tree_of(("main", "step", "loss"), ("main", "io"))
        for kind in (K_FULL, K_DELTA):
            meta, got = decode_push_body(
                encode_push_body(kind, EpochMeta(7, 123.0, 3.0), t)
            )
            assert meta.epoch == 7 and meta.kind == kind
            assert got.total() == t.total()

    def test_torn_body_rejected(self):
        body = encode_push_body(K_FULL, EpochMeta(0), tree_of(("a", "b")))
        with pytest.raises(SnapshotCorrupt):
            decode_push_body(body[:-3])

    def test_garbage_and_empty_bodies_rejected(self):
        for bad in (b"", b"not a segment at all", b"RTL1\x00\x00"):
            with pytest.raises(SnapshotCorrupt):
                decode_push_body(bad)

    def test_multi_record_body_rejected(self):
        one = encode_push_body(K_FULL, EpochMeta(0), tree_of(("a",)))
        two = one + one[6:]  # second framed record appended after the header
        with pytest.raises(SnapshotCorrupt):
            decode_push_body(two)

    def test_push_url_normalization(self):
        assert push_url_for("localhost:9000") == "http://localhost:9000/push"
        assert push_url_for("http://h:1/") == "http://h:1/push"
        assert push_url_for("http://h:1/push") == "http://h:1/push"


class TestReplay:
    def test_duplicate_epoch_not_double_counted(self, tmp_path):
        agg = mkagg(tmp_path)
        body = encode_push_body(K_DELTA, EpochMeta(0), tree_of(("main", "f")))
        code, resp = agg.handle_push(headers_for("n1", epoch=0), body)
        assert code == 200 and resp["applied"]
        # The client retries the identical POST (it never saw the 200).
        code, resp = agg.handle_push(headers_for("n1", epoch=0), body)
        assert code == 200 and not resp["applied"] and resp["duplicate"]
        agg.seal_fleet_epoch(force=True)
        assert agg.fleet_tree().total() == 1.0
        agg.close()

    def test_out_of_order_deltas_converge(self, tmp_path):
        agg = mkagg(tmp_path)
        bodies = {
            e: encode_push_body(K_DELTA, EpochMeta(e), tree_of(("main", f"f{e}")))
            for e in range(4)
        }
        for e in (2, 0, 3, 1):  # arbitrary arrival order
            code, resp = agg.handle_push(headers_for("n1", epoch=e), bodies[e])
            assert code == 200 and resp["applied"]
        agg.seal_fleet_epoch(force=True)
        assert agg.fleet_tree().total() == 4.0
        assert agg.nodes["n1"].floor == 3  # contiguous floor caught up
        assert not agg.nodes["n1"].applied  # sparse set fully absorbed
        agg.close()

    def test_stale_keyframe_cannot_erase_later_mass(self, tmp_path):
        agg = mkagg(tmp_path)
        agg.handle_push(
            headers_for("n1", epoch=0),
            encode_push_body(K_FULL, EpochMeta(0), tree_of(("main", "a"))),
        )
        agg.handle_push(
            headers_for("n1", epoch=2),
            encode_push_body(K_DELTA, EpochMeta(2), tree_of(("main", "b"))),
        )
        # A delayed keyframe for epoch 1 arrives after epoch 2 was applied:
        # replacement would erase epoch 2's mass, so it must be refused.
        code, resp = agg.handle_push(
            headers_for("n1", epoch=1),
            encode_push_body(K_FULL, EpochMeta(1), tree_of(("main", "a"), w=2.0)),
        )
        assert code == 200 and not resp["applied"]
        assert agg.nodes["n1"].stale == 1
        agg.seal_fleet_epoch(force=True)
        assert agg.fleet_tree().total() == 2.0  # a + b, untouched
        agg.close()

    def test_keyframe_replacement_resyncs_exactly(self, tmp_path):
        agg = mkagg(tmp_path)
        agg.handle_push(
            headers_for("n1", epoch=0),
            encode_push_body(K_DELTA, EpochMeta(0), tree_of(("main", "a"))),
        )
        # Epochs 1..3 were lost client-side (spill overflow); the resync
        # keyframe carries the exact cumulative and supersedes everything.
        cum = tree_of(("main", "a"), ("main", "b"), w=5.0)
        code, resp = agg.handle_push(
            headers_for("n1", epoch=4), encode_push_body(K_FULL, EpochMeta(4), cum)
        )
        assert code == 200 and resp["applied"]
        agg.seal_fleet_epoch(force=True)
        assert agg.fleet_tree().total() == cum.total()
        agg.close()

    def test_fleet_mass_is_sum_of_node_masses(self, tmp_path):
        agg = mkagg(tmp_path)
        for name, w in (("n1", 2.0), ("n2", 3.0), ("n3", 5.0)):
            agg.handle_push(
                headers_for(name, epoch=0),
                encode_push_body(K_FULL, EpochMeta(0), tree_of(("main", name), w=w)),
            )
        agg.seal_fleet_epoch(force=True)
        status = agg.status()
        node_mass = sum(r["mass"] for r in status["nodes"].values())
        assert status["fleet"]["mass"] == node_mass == 10.0
        agg.close()


class TestNodeChurn:
    def test_reboot_folds_incarnation_into_base(self, tmp_path):
        agg = mkagg(tmp_path)
        agg.handle_push(
            headers_for("n1", boot="boot-a", epoch=0),
            encode_push_body(K_FULL, EpochMeta(0), tree_of(("main", "a"), w=4.0)),
        )
        # Crash + restart: fresh boot id, epoch numbering restarts at 0.
        agg.handle_push(
            headers_for("n1", boot="boot-b", epoch=0),
            encode_push_body(K_FULL, EpochMeta(0), tree_of(("main", "b"), w=6.0)),
        )
        node = agg.nodes["n1"]
        assert node.incarnations == 1 and node.boot == "boot-b"
        assert node.effective().total() == 10.0  # nothing lost across the reboot
        assert any(e["kind"] == "NODE_REBOOTED" for e in agg.events)
        assert os.path.exists(
            os.path.join(agg.out_dir, "targets", "n1", "base.json")
        )
        agg.close()

    def test_invalid_node_names_rejected(self, tmp_path):
        agg = mkagg(tmp_path)
        body = encode_push_body(K_FULL, EpochMeta(0), tree_of(("a",)))
        for bad in ("", "../escape", "a/b", ".hidden", "x" * 80):
            code, resp = agg.handle_push(headers_for(bad), body)
            assert code == 400, bad
        assert not agg.nodes
        agg.close()


class TestRetention:
    def test_recent_and_coarse_rings_are_bounded(self, tmp_path):
        agg = mkagg(
            tmp_path,
            epochs_per_segment=2,
            max_segments=3,
            coarse_every=2,
            coarse_segments=4,
        )
        for e in range(40):
            agg.handle_push(
                headers_for("n1", epoch=e),
                encode_push_body(
                    K_DELTA, EpochMeta(e, float(e)), tree_of(("main", "f"))
                ),
            )
            agg.seal_fleet_epoch(force=True)
        recent = list_segments(agg.cfg.timeline_dir())
        coarse = list_segments(agg.cfg.coarse_dir())
        assert 0 < len(recent) <= 3
        assert 0 < len(coarse) <= 4
        # Recent ring: exact consecutive epochs at the tail of history.
        epochs = [m.epoch for m, _w, _c in TimelineReader(agg.cfg.timeline_dir()).epochs()]
        assert epochs == list(range(epochs[0], 40))
        # Coarse ring: one keyframe every coarse_every fleet epochs, each
        # decodable standalone, spanning an older horizon than the exact ring.
        coarse_epochs = [
            m.epoch for m, _w, _c in TimelineReader(agg.cfg.coarse_dir()).epochs()
        ]
        assert all(e % 2 == 0 for e in coarse_epochs)
        assert coarse_epochs[0] <= epochs[0]
        # Dropped coarse epochs are whole-segment drops; retained tail is
        # still the cumulative truth.
        last = TimelineReader(agg.cfg.coarse_dir()).last()
        assert last[1].total() == pytest.approx(agg.fleet_tree().total(), abs=1.0)
        agg.close()


class TestHTTPIngest:
    def test_oversized_body_413_and_torn_body_400_over_http(self, tmp_path):
        agg = mkagg(tmp_path, max_body_bytes=4096)
        url = agg.enable_serving().url
        good = encode_push_body(K_FULL, EpochMeta(0), tree_of(("main", "f")))

        def post(body, headers):
            req = urllib.request.Request(
                url + "/push", data=body, headers=headers, method="POST"
            )
            try:
                with urllib.request.urlopen(req, timeout=5) as resp:
                    return resp.status, json.loads(resp.read())
            except urllib.error.HTTPError as e:
                return e.code, e.read().decode("utf-8", "replace")

        code, resp = post(good, headers_for("n1"))
        assert code == 200 and resp["applied"]
        assert post(good[:-5], headers_for("n1", epoch=1))[0] == 400
        assert post(b"\x00" * 8192, headers_for("n1", epoch=2))[0] == 413
        assert post(good, {})[0] == 400  # missing node header
        # Clean rejects leave the applied state untouched.
        agg.seal_fleet_epoch(force=True)
        assert agg.fleet_tree().total() == 1.0
        agg.close()

    def test_get_surfaces_fleet_hierarchy_and_node_trees(self, tmp_path):
        agg = mkagg(tmp_path, region="eu-west")
        url = agg.enable_serving().url
        for name in ("n1", "n2"):
            agg.handle_push(
                headers_for(name, **{H_TARGETS: f"{name}-t0,{name}-t1"}),
                encode_push_body(K_FULL, EpochMeta(0), tree_of(("main", name))),
            )
        agg.seal_fleet_epoch(force=True)
        h = json.loads(urllib.request.urlopen(url + "/targets", timeout=5).read())
        assert h["region"] == "eu-west"
        assert [n["name"] for n in h["nodes"]] == ["n1", "n2"]
        assert [t["name"] for t in h["nodes"][0]["targets"]] == ["n1-t0", "n1-t1"]
        # Flat rows stay for pre-fleet consumers.
        assert {r["node"] for r in h["targets"]} == {"n1", "n2"}
        per_node = urllib.request.urlopen(
            url + "/tree?fmt=folded&target=n2", timeout=5
        ).read().decode()
        assert "n2" in per_node and "n1" not in per_node
        status = json.loads(urllib.request.urlopen(url + "/status", timeout=5).read())
        assert status["aggregator"] and status["fleet"]["mass"] == 2.0
        agg.close()

    def test_offline_targets_hierarchy_from_published_region_map(self, tmp_path):
        from repro.profilerd.server import OfflineSource, ProfileServer

        agg = mkagg(tmp_path, region="eu-west")
        agg.handle_push(
            headers_for("n1", **{H_TARGETS: "t0"}),
            encode_push_body(K_FULL, EpochMeta(0), tree_of(("main", "f"))),
        )
        agg.seal_fleet_epoch(force=True)
        agg.publish()
        agg.close()
        srv = ProfileServer(OfflineSource(agg.out_dir), port=0).start()
        try:
            h = json.loads(
                urllib.request.urlopen(srv.url + "/targets", timeout=5).read()
            )
            assert h["region"] == "eu-west"
            assert [n["name"] for n in h["nodes"]] == ["n1"]
        finally:
            srv.stop()


class TestLiveness:
    def test_stall_and_recovery_events(self, tmp_path):
        agg = mkagg(tmp_path, stall_floor_s=0.0, stall_factor=0.0)
        agg.handle_push(
            headers_for("n1"), encode_push_body(K_FULL, EpochMeta(0), tree_of(("a",)))
        )
        agg.nodes["n1"].last_push_mono -= 10.0  # silence without sleeping
        agg.check_liveness()
        assert any(e["kind"] == NODE_STALLED for e in agg.events)
        assert agg.nodes["n1"].stalled
        agg.handle_push(
            headers_for("n1", epoch=1),
            encode_push_body(K_DELTA, EpochMeta(1), tree_of(("a",))),
        )
        assert not agg.nodes["n1"].stalled
        assert any(e["kind"] == NODE_RECOVERED for e in agg.events)
        agg.close()

    def test_done_nodes_never_stall(self, tmp_path):
        agg = mkagg(tmp_path, stall_floor_s=0.0, stall_factor=0.0)
        agg.handle_push(
            headers_for("n1", **{H_DONE: "1"}),
            encode_push_body(K_FULL, EpochMeta(0), tree_of(("a",))),
        )
        agg.nodes["n1"].last_push_mono -= 10.0
        agg.check_liveness()
        assert not any(e["kind"] == NODE_STALLED for e in agg.events)
        assert agg.status()["nodes"]["n1"]["state"] == "done"
        agg.close()


class TestPushClient:
    def _direct_post(self, agg, fail=None):
        """In-process delivery: the aggregator IS the endpoint (no sockets)."""

        def post(url, body, headers, timeout_s):
            if fail is not None and fail["on"]:
                raise OSError("connection refused")
            return agg.handle_push(headers, body)[0]

        return post

    def test_outage_spills_then_recovers_losslessly(self, tmp_path):
        agg = mkagg(tmp_path)
        fail = {"on": False}
        events = []
        client = PushClient(
            "127.0.0.1:1", "n1",
            post=self._direct_post(agg, fail), on_event=events.append,
            retry_base_s=0.0, retry_cap_s=0.0,
        )
        cum = CallTree()
        for e in range(3):
            cum.merge(tree_of(("main", f"f{e}")))
            client.push_epoch(cum.copy(), wall_time=float(e))
        fail["on"] = True
        for e in range(3, 6):
            cum.merge(tree_of(("main", f"f{e}")))
            client.push_epoch(cum.copy(), wall_time=float(e))
        assert [ev["kind"] for ev in events] == ["PUSH_FAILED"]  # one edge, not 3
        assert client.stats()["queue_epochs"] == 3
        fail["on"] = False
        cum.merge(tree_of(("main", "f6")))
        client.push_epoch(cum.copy(), wall_time=6.0)
        assert [ev["kind"] for ev in events] == ["PUSH_FAILED", "PUSH_RECOVERED"]
        assert client.stats()["queue_epochs"] == 0
        agg.seal_fleet_epoch(force=True)
        assert agg.fleet_tree().total() == cum.total()  # zero lost mass
        agg.close()

    def test_spill_overflow_drops_oldest_and_resyncs_by_keyframe(self, tmp_path):
        agg = mkagg(tmp_path)
        fail = {"on": True}
        client = PushClient(
            "127.0.0.1:1", "n1",
            post=self._direct_post(agg, fail),
            max_spill_bytes=256,  # tiny: a couple of bodies at most
            retry_base_s=0.0, retry_cap_s=0.0,
        )
        cum = CallTree()
        for e in range(20):
            cum.merge(tree_of(("main", f"fn_{e}")))
            client.push_epoch(cum.copy(), wall_time=float(e))
        stats = client.stats()
        # Bounded: drops happened and at most one body (the forced resync
        # keyframe, which may alone exceed the budget) rides over the limit.
        assert stats["dropped"] > 0
        assert stats["queue_epochs"] <= 2 or stats["queue_bytes"] <= 256
        fail["on"] = False
        cum.merge(tree_of(("main", "final")))
        client.push_epoch(cum.copy(), wall_time=99.0)  # forced K_FULL resync
        agg.seal_fleet_epoch(force=True)
        # Dropped deltas are subsumed by the replacement keyframe: the fleet
        # converges to the exact cumulative despite the losses.
        assert agg.fleet_tree().total() == cum.total()
        agg.close()

    def test_rejected_body_dropped_not_retried_forever(self, tmp_path):
        agg = mkagg(tmp_path, max_body_bytes=1)  # everything is oversized
        events = []
        client = PushClient(
            "127.0.0.1:1", "n1",
            post=self._direct_post(agg), on_event=events.append,
            retry_base_s=0.0, retry_cap_s=0.0,
        )
        client.push_epoch(tree_of(("main", "f")), wall_time=0.0)
        stats = client.stats()
        assert stats["rejected"] == 1 and stats["queue_epochs"] == 0
        assert [ev["kind"] for ev in events] == ["PUSH_REJECTED"]
        agg.close()

    def test_done_push_forces_flush_through_backoff(self, tmp_path):
        agg = mkagg(tmp_path)
        fail = {"on": True}
        client = PushClient(
            "127.0.0.1:1", "n1",
            post=self._direct_post(agg, fail),
            retry_base_s=3600.0, retry_cap_s=3600.0,  # backoff parks the queue
        )
        client.push_epoch(tree_of(("a",)), wall_time=0.0)
        fail["on"] = False
        client.push_epoch(tree_of(("a",), ("b",)), wall_time=1.0, done=True)
        assert client.stats()["queue_epochs"] == 0  # force bypassed the window
        assert agg.nodes["n1"].done
        agg.close()


class TestRestart:
    def test_restart_restores_mass_floor_and_ring_numbering(self, tmp_path):
        out = str(tmp_path / "region.d")
        agg = Aggregator(AggregatorConfig(out_dir=out))
        boot = "boot-a"
        cum = CallTree()
        for e in range(5):
            w = tree_of(("main", f"f{e}"))
            cum.merge(w)
            kind = K_FULL if e == 0 else K_DELTA
            body = encode_push_body(kind, EpochMeta(e), cum if e == 0 else w)
            assert agg.handle_push(headers_for("n1", boot=boot, epoch=e), body)[0] == 200
        agg.seal_fleet_epoch(force=True)
        mass = agg.fleet_tree().total()
        ring_epoch = agg.nodes["n1"].ring_epoch
        agg.close()  # simulated crash: no extra finalization beyond the 200s

        agg2 = Aggregator(AggregatorConfig(out_dir=out))
        assert any(e["kind"] == "AGGREGATOR_RESTORED" for e in agg2.events)
        node = agg2.nodes["n1"]
        assert node.boot == boot and node.floor == 4
        assert node.effective().total() == mass
        assert node.ring_epoch == ring_epoch  # monotonic, no reuse
        # The client (same boot) re-delivers an unacked epoch + a fresh one.
        dup = encode_push_body(K_DELTA, EpochMeta(4), tree_of(("main", "f4")))
        code, resp = agg2.handle_push(headers_for("n1", boot=boot, epoch=4), dup)
        assert code == 200 and resp["duplicate"]
        nxt = encode_push_body(K_DELTA, EpochMeta(5), tree_of(("main", "f5")))
        assert agg2.handle_push(headers_for("n1", boot=boot, epoch=5), nxt)[0] == 200
        agg2.seal_fleet_epoch(force=True)
        assert agg2.fleet_tree().total() == mass + 1.0
        # Fleet ring numbering also continued across the restart.
        epochs = [m.epoch for m, _w, _c in TimelineReader(agg2.cfg.timeline_dir()).epochs()]
        assert epochs == sorted(set(epochs)) and len(epochs) == 2
        agg2.close()

    def test_restart_without_sidecar_treats_history_as_base(self, tmp_path):
        out = str(tmp_path / "region.d")
        agg = Aggregator(AggregatorConfig(out_dir=out))
        agg.handle_push(
            headers_for("n1", boot="boot-a"),
            encode_push_body(K_FULL, EpochMeta(0), tree_of(("main", "a"), w=3.0)),
        )
        agg.close()
        os.remove(os.path.join(out, "targets", "n1", "node.json"))
        agg2 = Aggregator(AggregatorConfig(out_dir=out))
        node = agg2.nodes["n1"]
        assert node.boot is None and node.effective().total() == 3.0
        # A known-boot client pushing now is a new incarnation on top.
        agg2.handle_push(
            headers_for("n1", boot="boot-a", epoch=0),
            encode_push_body(K_FULL, EpochMeta(0), tree_of(("main", "b"), w=2.0)),
        )
        assert agg2.nodes["n1"].effective().total() == 5.0
        agg2.seal_fleet_epoch(force=True)
        assert agg2.fleet_tree().total() == 5.0
        agg2.close()


@pytest.mark.slow
class TestFleetSoak:
    def test_three_nodes_thirty_epochs_with_mid_run_restart(self, tmp_path):
        """Nightly gate: zero lost epoch mass across a node restart.

        Three nodes push 30 epochs each through the real HTTP plane; node
        ``n1`` is "killed" at epoch 15 (its client vanishes, un-acked queue
        and all) and replaced by a fresh incarnation that re-reports its
        recovered local history as a keyframe — exactly what a restarted
        daemon's first push is.  The fleet total must equal the sum of every
        node's final cumulative: nothing lost, nothing double-counted.
        """
        agg = mkagg(tmp_path, epoch_s=0.05)
        url = agg.enable_serving().url
        cums = {f"n{i}": CallTree() for i in range(3)}
        clients = {name: PushClient(url, name, interval_hint_s=0.05) for name in cums}
        expected = {}
        for e in range(30):
            if e == 15:
                # n1 dies and restarts: new boot, epoch numbering from 0.
                # Its recovered state re-ships as the new client's first
                # keyframe; the dead incarnation's mass is already folded.
                expected["n1-inc0"] = cums["n1"].total()
                clients["n1"] = PushClient(url, "n1", interval_hint_s=0.05)
                cums["n1"] = CallTree()
            for name, cum in cums.items():
                cum.merge(tree_of(("main", name, f"e{e % 7}")))
                clients[name].push_epoch(
                    cum.copy(), wall_time=float(e), targets=[f"{name}-t0"],
                    done=(e == 29),
                )
            agg.seal_fleet_epoch(force=True)
        for name, cum in cums.items():
            expected[name] = cum.total()
        agg.seal_fleet_epoch(force=True)
        agg.publish()
        status = agg.status()
        assert status["fleet"]["mass"] == sum(expected.values())
        assert status["nodes"]["n1"]["incarnations"] == 1
        assert status["done"]  # every node's last push was done=1
        assert status["fleet"]["duplicates"] == 0
        # The published artifact agrees with the live status.
        disk = json.load(open(os.path.join(agg.out_dir, "status.json")))
        assert disk["fleet"]["mass"] == status["fleet"]["mass"]
        agg.close()
