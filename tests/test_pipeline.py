"""IngestPipeline batch/scalar parity and the unified ingest_stats schema.

The vectorized lane (ISSUE 8) is only allowed to be faster, never different:
every test here runs the same byte stream through a scalar pipeline and a
vectorized one and asserts identical trees, identical depth timelines,
identical stats, and — where a sealer is attached — byte-identical sealed
timeline segments.  The adversarial stream shapes from the issue are all
covered: mixed v1/v2 records, torn chunk boundaries mid-record, unknown
stack ids, chain-cache overflow, and writer re-attach mid-stream.

Everything degrades to the scalar path without numpy, so the parity tests
that *need* the vectorized lane skip when it is unavailable; the fallback
tests run everywhere (they monkeypatch numpy away).
"""

import json
import os
import random

import pytest

import repro.profilerd.wire as wire
from repro.core.snapshot import TimelineWriter
from repro.profilerd.daemon import DaemonConfig, ProfilerDaemon
from repro.profilerd.ingest import TreeIngestor
from repro.profilerd.pipeline import (
    INGEST_STATS_KEYS,
    IngestPipeline,
    format_ingest_stats,
    merge_ingest_stats,
)
from repro.profilerd.spool import SpoolWriter
from repro.profilerd.wire import (
    Encoder,
    RawFrame,
    RawSample,
    Rusage,
    numpy_available,
)

needs_numpy = pytest.mark.skipif(
    not numpy_available(), reason="vectorized lane requires numpy"
)

TICK = 4


def make_samples(n=240, n_stacks=10, depth=6, threads=3, seed=7):
    """Steady-state-shaped samples: shared root prefix, jittered leaf lines."""
    rng = random.Random(seed)
    shared = [RawFrame("/site-packages/jax/core.py", f"bind_{i}", i + 1) for i in range(depth // 2)]
    stacks = [
        shared
        + [
            RawFrame(f"/root/repo/src/repro/m{u % 3}.py", f"fn{u}_{j}", j + 1)
            for j in range(depth - len(shared))
        ]
        for u in range(n_stacks)
    ]
    out = []
    for i in range(n):
        u = rng.randrange(n_stacks)
        frames = stacks[u]
        leaf = frames[-1]
        frames = frames[:-1] + [RawFrame(leaf.filename, leaf.func, rng.randrange(1, 99))]
        out.append(RawSample(i * 0.01, 100 + u % threads, f"w{u % threads}", frames))
    return out


def encode_stream(samples, version=2, max_stacks=1 << 16, rusage_every=0):
    """hello + ticks (+ periodic rusage) + bye, as one byte string."""
    enc = Encoder(version=version, max_stacks=max_stacks)
    parts = [enc.encode_hello(77, 0.01)]
    for tick_i, i in enumerate(range(0, len(samples), TICK)):
        ru = Rusage(i * 0.01, i * 0.001, 1 << 20) if rusage_every and tick_i % rusage_every == 0 else None
        payload, _ = enc.encode_tick(samples[i : i + TICK], rusage=ru)
        parts.append(payload)
    parts.append(enc.encode_bye(len(samples)))
    return b"".join(parts)


def run_lane(payload, vectorized, tmp_path=None, *, chunk=997, seal_every=0,
             max_paths=1 << 18, reset_at=None):
    """Feed ``payload`` in ``chunk``-byte pieces; returns (pipeline, events, dir)."""
    tl_dir = None
    writer = None
    if tmp_path is not None:
        tl_dir = str(tmp_path / f"tl_{'vec' if vectorized else 'scalar'}")
        writer = TimelineWriter(tl_dir, epochs_per_segment=4)
    pipe = IngestPipeline(
        ingestor=TreeIngestor(max_paths=max_paths),
        timeline_writer=writer,
        vectorized=vectorized,
    )
    events = []
    chunks = [payload[i : i + chunk] for i in range(0, len(payload), chunk)]
    for ci, c in enumerate(chunks):
        if reset_at is not None and ci == reset_at:
            pipe.reset_stream()
        events.extend(pipe.feed(c))
        if seal_every and (ci + 1) % seal_every == 0:
            pipe.seal_epoch(wall_time=float(ci))
    if seal_every:
        pipe.seal_epoch(wall_time=1e6)
    return pipe, events, tl_dir


def _dir_bytes(d):
    out = {}
    for name in sorted(os.listdir(d)):
        with open(os.path.join(d, name), "rb") as f:
            out[name] = f.read()
    return out


def assert_lane_parity(payload, tmp_path, **kw):
    """The workhorse: scalar vs vectorized on the same bytes, everything equal."""
    scalar, s_events, s_dir = run_lane(payload, False, tmp_path, **kw)
    vec, v_events, v_dir = run_lane(payload, True, tmp_path, **kw)
    assert vec.vectorized, "vectorized lane did not engage"
    assert vec.tree.to_json() == scalar.tree.to_json()
    assert list(vec.depth_timeline) == list(scalar.depth_timeline)
    assert vec.samples == scalar.samples
    assert vec.unknown_stack_refs == scalar.unknown_stack_refs
    assert vec.degraded_stackdefs == scalar.degraded_stackdefs
    # Non-sample events come out in stream order in both lanes.
    assert [type(e).__name__ for e in v_events] == [type(e).__name__ for e in s_events]
    s_stats, v_stats = scalar.ingest_stats(), vec.ingest_stats()
    for k in ("samples", "fast_hits", "slow_ingests", "cached_paths",
              "unknown_stack_refs", "degraded_stackdefs"):
        assert v_stats[k] == s_stats[k], k
    if s_dir is not None:
        assert _dir_bytes(v_dir) == _dir_bytes(s_dir), "sealed segments differ"
    return scalar, vec


@needs_numpy
class TestBatchScalarParity:
    def test_tree_timeline_and_stats_parity(self, tmp_path):
        payload = encode_stream(make_samples(), rusage_every=5)
        scalar, vec = assert_lane_parity(payload, tmp_path, seal_every=3)
        assert vec.samples == 240
        assert vec.ingest_stats()["batch_samples"] > 0  # the fast lane ran
        assert scalar.ingest_stats()["batch_samples"] == 0

    def test_single_byte_chunks_torn_mid_record(self, tmp_path):
        """Every record torn across chunk boundaries: the probe must never
        fire on a partial record and the tail buffering must match feed()."""
        payload = encode_stream(make_samples(n=60))
        assert_lane_parity(payload, tmp_path, chunk=3, seal_every=40)

    def test_mixed_v1_v2_stream(self, tmp_path):
        """Encoder stack-table overflow interleaves v1 SAMPLE records with
        SAMPLE2 runs; v1 records take the scalar core inside the batch lane
        and force keyframes (untracked) identically in both lanes."""
        payload = encode_stream(make_samples(n_stacks=12), max_stacks=3)
        scalar, _vec = assert_lane_parity(payload, tmp_path, seal_every=3)
        assert scalar.ingestor.stats()["slow_ingests"] > 12  # v1 fall-through

    def test_pure_v1_stream(self, tmp_path):
        payload = encode_stream(make_samples(n=80), version=1)
        scalar, vec = assert_lane_parity(payload, tmp_path, seal_every=3)
        assert vec.ingest_stats()["batch_samples"] == 0  # nothing to batch

    def test_unknown_stack_ids_count_as_placeholders(self, tmp_path):
        """A reader that missed the STACKDEFs (late re-attach): every sample
        degrades to the counted '?' placeholder, identically per lane."""
        samples = make_samples(n=100)
        enc = Encoder(version=2)
        for i in range(0, len(samples), TICK):  # defs consumed elsewhere
            enc.encode_tick(samples[i : i + TICK])
        parts = [enc.encode_hello(77, 0.01)]
        for i in range(0, len(samples), TICK):  # pure SAMPLE2, ids unseen
            parts.append(enc.encode_tick(samples[i : i + TICK])[0])
        payload = b"".join(parts)
        scalar, vec = assert_lane_parity(payload, tmp_path, seal_every=3)
        assert vec.unknown_stack_refs == 100
        flat = vec.tree.flatten()
        assert flat.get("py::?") == 100 and flat.get("thread::?") == 100

    def test_chain_cache_overflow_forces_keyframes(self, tmp_path):
        scalar, vec = assert_lane_parity(
            encode_stream(make_samples()), tmp_path, seal_every=2, max_paths=1
        )
        assert scalar.ingestor.stats()["cached_paths"] == 1

    def test_reset_stream_mid_batch(self, tmp_path):
        """Writer re-attach mid-stream (at a record boundary, as the real
        reader re-attach does): stack_id caches die, loss counters fold into
        the pipeline, and both lanes agree on all of it."""
        samples = make_samples()
        enc = Encoder(version=2)
        parts = [enc.encode_hello(77, 0.01)]
        for i in range(0, len(samples), TICK):
            parts.append(enc.encode_tick(samples[i : i + TICK])[0])
        half = len(parts) // 2
        pre, post = b"".join(parts[:half]), b"".join(parts[half:])
        lanes = {}
        for vec in (False, True):
            d = str(tmp_path / f"tl_{'vec' if vec else 'scalar'}")
            pipe = IngestPipeline(
                timeline_writer=TimelineWriter(d, epochs_per_segment=4), vectorized=vec
            )
            for i in range(0, len(pre), 997):
                pipe.feed(pre[i : i + 997])
            pipe.seal_epoch(1.0)
            pipe.reset_stream()
            for i in range(0, len(post), 997):
                pipe.feed(post[i : i + 997])
            pipe.seal_epoch(2.0)
            lanes[vec] = (pipe, d)
        scalar, s_dir = lanes[False]
        vec_pipe, v_dir = lanes[True]
        assert vec_pipe.tree.to_json() == scalar.tree.to_json()
        # Post-reset SAMPLE2 ids were defined pre-reset: the fresh decoder
        # counts every reference as unknown, identically per lane.
        assert vec_pipe.unknown_stack_refs == scalar.unknown_stack_refs > 0
        assert vec_pipe.degraded_stackdefs == scalar.degraded_stackdefs
        assert _dir_bytes(v_dir) == _dir_bytes(s_dir)

    def test_one_shot_vs_chunked_batch(self, tmp_path):
        """Chunking must not change anything: one giant feed vs tiny feeds."""
        payload = encode_stream(make_samples())
        one, _, _ = run_lane(payload, True, chunk=len(payload))
        many, _, _ = run_lane(payload, True, chunk=311)
        assert one.tree.to_json() == many.tree.to_json()
        assert list(one.depth_timeline) == list(many.depth_timeline)
        assert one.ingest_stats()["fast_hits"] == many.ingest_stats()["fast_hits"]


class TestScalarFallback:
    def _no_numpy(self, monkeypatch):
        monkeypatch.setattr(wire, "_np_probed", True)
        monkeypatch.setattr(wire, "_np", None)
        monkeypatch.setattr(wire, "_sample2_dtype", None)

    def test_pipeline_selects_scalar_without_numpy(self, monkeypatch, tmp_path):
        payload = encode_stream(make_samples(n=60))
        with_numpy = numpy_available()
        ref, _, _ = run_lane(payload, with_numpy)
        self._no_numpy(monkeypatch)
        assert not numpy_available()
        pipe = IngestPipeline()  # auto-detect: must pick scalar, not crash
        assert pipe.vectorized is False
        forced = IngestPipeline(vectorized=True)  # the flag reports reality
        assert forced.vectorized is False
        for i in range(0, len(payload), 101):
            pipe.feed(payload[i : i + 101])
        assert pipe.tree.to_json() == ref.tree.to_json()
        assert pipe.ingest_stats()["vectorized"] is False

    def test_feed_batch_degrades_to_scalar_without_numpy(self, monkeypatch):
        self._no_numpy(monkeypatch)
        dec = wire.Decoder()
        events = list(dec.feed_batch(encode_stream(make_samples(n=20))))
        kinds = {type(e).__name__ for e in events}
        assert "SampleBatch" not in kinds
        assert sum(1 for e in events if type(e) is RawSample) == 20

    def test_daemon_logs_scalar_fallback_once(self, monkeypatch, tmp_path):
        self._no_numpy(monkeypatch)
        spool = str(tmp_path / "t.spool")
        w = SpoolWriter(spool, capacity=1 << 20)
        enc = Encoder()
        w.write(enc.encode_hello(os.getpid(), 0.01))
        for s in make_samples(n=40):
            w.write(enc.encode_tick([s])[0])
        w.write_bye(enc.encode_bye(40))
        daemon = ProfilerDaemon(
            DaemonConfig(spool_path=spool, out_dir=str(tmp_path / "out"), max_seconds=10)
        )
        daemon.run()
        falls = [e for e in daemon.events if e["kind"] == "INGEST_SCALAR_FALLBACK"]
        assert len(falls) == 1
        assert "numpy" in falls[0]["reason"]
        assert daemon.status()["ingest"]["vectorized"] is False

    @needs_numpy
    def test_daemon_does_not_log_fallback_with_numpy(self, tmp_path):
        spool = str(tmp_path / "t.spool")
        w = SpoolWriter(spool, capacity=1 << 20)
        enc = Encoder()
        w.write(enc.encode_hello(os.getpid(), 0.01))
        for s in make_samples(n=40):
            w.write(enc.encode_tick([s])[0])
        w.write_bye(enc.encode_bye(40))
        daemon = ProfilerDaemon(
            DaemonConfig(spool_path=spool, out_dir=str(tmp_path / "out"), max_seconds=10)
        )
        daemon.run()
        assert not [e for e in daemon.events if e["kind"] == "INGEST_SCALAR_FALLBACK"]
        status = daemon.status()
        assert status["ingest"]["vectorized"] is True
        assert status["ingest"]["batch_samples"] == 40


class TestIngestStatsSchema:
    def test_pipeline_emits_full_schema(self):
        pipe, _, _ = run_lane(encode_stream(make_samples(n=40)), numpy_available())
        stats = pipe.ingest_stats()
        assert set(stats) == set(INGEST_STATS_KEYS)
        assert stats["samples"] == 40

    def test_daemon_status_merges_schema(self, tmp_path):
        spool = str(tmp_path / "t.spool")
        w = SpoolWriter(spool, capacity=1 << 20)
        enc = Encoder()
        w.write(enc.encode_hello(os.getpid(), 0.01))
        for s in make_samples(n=24):
            w.write(enc.encode_tick([s])[0])
        w.write_bye(enc.encode_bye(24))
        daemon = ProfilerDaemon(
            DaemonConfig(spool_path=spool, out_dir=str(tmp_path / "out"), max_seconds=10)
        )
        daemon.run()
        status = daemon.status()
        assert set(status["ingest"]) == set(INGEST_STATS_KEYS)
        assert status["ingest"]["samples"] == 24
        # the per-source row carries the same schema
        row_stats = json.load(open(os.path.join(str(tmp_path / "out"), "status.json")))
        assert set(row_stats["ingest"]) == set(INGEST_STATS_KEYS)

    def test_merge_sums_and_ands(self):
        a = dict.fromkeys(INGEST_STATS_KEYS, 3)
        a["vectorized"] = True
        b = dict.fromkeys(INGEST_STATS_KEYS, 4)
        b["vectorized"] = False
        merged = merge_ingest_stats([a, b])
        assert merged["samples"] == 7 and merged["fast_hits"] == 7
        assert merged["vectorized"] is False  # one scalar source degrades the fleet
        assert merge_ingest_stats([a, a])["vectorized"] is True
        assert merge_ingest_stats([])["vectorized"] == numpy_available()

    def test_format_renders_lane_and_losses(self):
        stats = dict.fromkeys(INGEST_STATS_KEYS, 0)
        stats.update(vectorized=True, samples=10, fast_hits=8)
        line = format_ingest_stats(stats)
        assert "ingest[vectorized]" in line and "samples=10" in line
        assert "unknown=" not in line  # loss counters only shown when nonzero
        stats.update(vectorized=False, unknown_stack_refs=2)
        line = format_ingest_stats(stats)
        assert "ingest[scalar]" in line and "unknown=2" in line
