"""Static-analysis plane tests: extractor, envelope, repro-lint, coverage,
baseline gating, and ``plane=static`` through the server and CLI.

Pure stdlib by design — the analysis package is what CI runs on a bare
interpreter, so nothing here may import jax or numpy.
"""

import json
import os
import subprocess
import sys
import urllib.error
import urllib.request

import pytest

from repro.analysis.baseline import (
    BASELINE_SCHEMA,
    EXIT_PASS,
    EXIT_REGRESSION,
    EXIT_UNREADABLE,
    BaselineError,
    check,
    load_baseline,
    save_baseline,
)
from repro.analysis.coverage import (
    COVERAGE_SCHEMA,
    coverage_report,
    coverage_tree,
    render_coverage,
)
from repro.analysis.extract import (
    CALLS,
    DEFS,
    EXT_CALLS,
    default_package_root,
    extract_static_graph,
    extract_to_file,
    module_name,
)
from repro.analysis.lint import PASS_IDS, PASSES, Finding, RepoIndex, run_passes
from repro.analysis.score import score_fixtures
from repro.analysis.static_tree import (
    STATIC_TREE_FILENAME,
    STATIC_TREE_SCHEMA,
    load_static_tree,
    save_static_tree,
    static_meta,
)
from repro.core.calltree import CallTree
from repro.core.export import export_tree, to_folded
from repro.core.planes import PLANES, PlaneError, default_metric, select_plane
from repro.profilerd.profiles import (
    ProfileLoadError,
    load_static_plane,
    static_tree_path,
)

TESTS_DIR = os.path.dirname(__file__)
SRC_ROOT = os.path.abspath(os.path.join(TESTS_DIR, "..", "src"))
REPRO_ROOT = os.path.join(SRC_ROOT, "repro")
FIXTURES_DIR = os.path.join(TESTS_DIR, "data", "analysis_fixtures")
BASELINE_PATH = os.path.join(TESTS_DIR, "data", "analysis_baseline.json")


def write_pkg(root, files):
    for rel, src in files.items():
        p = os.path.join(root, rel)
        os.makedirs(os.path.dirname(p), exist_ok=True)
        with open(p, "w") as f:
            f.write(src)
    return root


SYNTH_PKG = {
    "alpha.py": (
        "def outer():\n"
        "    inner()\n"
        "    inner()\n"
        "    print('x')\n"
        "\n"
        "def inner():\n"
        "    return 1\n"
    ),
    "sub/beta.py": (
        "class Widget:\n"
        "    def render(self):\n"
        "        return self.helper()\n"
        "    def helper(self):\n"
        "        return 0\n"
    ),
}


class TestExtractor:
    def test_synthetic_tree_shape(self, tmp_path):
        root = write_pkg(str(tmp_path / "pkg"), SYNTH_PKG)
        g = extract_static_graph(root, package="pkg")
        assert g.n_modules == 2
        assert {d.qualname for d in g.defs} == {
            "pkg.alpha.outer",
            "pkg.alpha.inner",
            "pkg.sub.beta.Widget.render",
            "pkg.sub.beta.Widget.helper",
        }
        flat = g.tree.flatten(DEFS)
        assert flat["repro::outer"] == 1.0
        assert flat["mod::pkg.alpha"] == 2.0  # module carries its def count
        # outer -> inner resolved twice (calls metric), print is external
        mod = g.tree.root.children["mod::pkg.alpha"]
        outer = mod.children["repro::outer"]
        assert outer.children["repro::inner"].metrics[CALLS] == 2.0
        assert outer.metrics[EXT_CALLS] == 1.0
        # methods nest under the cls:: frame
        cls = g.tree.root.children["mod::pkg.sub.beta"].children["cls::Widget"]
        assert set(cls.children) == {"repro::render", "repro::helper"}
        assert g.def_names == frozenset({"outer", "inner", "render", "helper"})

    def test_extraction_is_deterministic(self, tmp_path):
        root = write_pkg(str(tmp_path / "pkg"), SYNTH_PKG)
        a = extract_static_graph(root, package="pkg").tree.to_json()
        b = extract_static_graph(root, package="pkg").tree.to_json()
        assert a == b

    def test_unparsable_module_raises(self, tmp_path):
        root = write_pkg(str(tmp_path / "pkg"), {"bad.py": "def broken(:\n"})
        with pytest.raises(SyntaxError, match="bad.py"):
            extract_static_graph(root, package="pkg")

    def test_module_name(self):
        assert module_name("alpha.py", "pkg") == "pkg.alpha"
        assert module_name(os.path.join("sub", "__init__.py"), "pkg") == "pkg.sub"

    def test_real_repo_extracts(self):
        g = extract_static_graph(default_package_root())
        assert g.n_modules > 50
        assert len(g.defs) > 500
        assert g.n_edges > 500
        flat = g.tree.flatten(DEFS)
        # the resolver's symbols for the agent hot path are present
        assert flat["repro::tick"] >= 1.0
        assert flat["repro::_raw_stack"] >= 1.0


class TestEnvelope:
    def _tree(self):
        t = CallTree()
        t.add_stack(["mod::pkg.alpha", "repro::outer"], {DEFS: 1.0, "samples": 1.0})
        return t

    def test_round_trip_with_meta(self, tmp_path):
        p = str(tmp_path / STATIC_TREE_FILENAME)
        save_static_tree(self._tree(), p, meta={"modules": 1})
        loaded = load_static_tree(p)
        assert loaded.flatten(DEFS)["repro::outer"] == 1.0
        assert static_meta(p) == {"modules": 1}
        doc = json.load(open(p))
        assert doc["schema"] == STATIC_TREE_SCHEMA

    def test_legacy_bare_root_accepted(self, tmp_path):
        p = str(tmp_path / "legacy.json")
        with open(p, "w") as f:
            f.write(self._tree().to_json())
        assert load_static_tree(p).flatten(DEFS)["repro::outer"] == 1.0
        assert static_meta(p) == {}

    def test_bad_documents_raise(self, tmp_path):
        cases = {
            "schema.json": json.dumps({"schema": "bogus/v9", "root": {"name": "<root>"}}),
            "list.json": "[1, 2]",
            "rootless.json": json.dumps({"schema": STATIC_TREE_SCHEMA, "root": {}}),
        }
        for name, body in cases.items():
            p = str(tmp_path / name)
            with open(p, "w") as f:
                f.write(body)
            with pytest.raises(ValueError):
                load_static_tree(p)


class TestLint:
    def test_clean_repo_zero_findings(self):
        index = RepoIndex.load(REPRO_ROOT)
        assert run_passes(index) == []

    def test_every_pass_has_fixture_with_recall_one(self):
        score = score_fixtures(FIXTURES_DIR, REPRO_ROOT)
        assert score["ok"], json.dumps(score, indent=2)
        for pid in PASS_IDS:
            row = score["passes"][pid]
            assert row["recall"] == 1.0, (pid, row)
            assert row["precision"] == 1.0, (pid, row)
            assert row["seeded_found"] >= 1

    def test_fixture_controls_not_flagged(self):
        # each fixture's "control" sites must stay invisible to its pass
        index = RepoIndex.load(os.path.join(FIXTURES_DIR, "wire-slots"))
        symbols = {f.symbol for f in run_passes(index, only="wire-slots")}
        assert symbols == {"Sample"}
        index = RepoIndex.load(os.path.join(FIXTURES_DIR, "scope-coverage"))
        symbols = {f.symbol for f in run_passes(index, only="scope-coverage")}
        assert symbols == {"flash_attention", "forward"}

    def test_unknown_pass_rejected(self):
        index = RepoIndex(".", {})
        with pytest.raises(ValueError, match="unknown pass"):
            run_passes(index, only="bogus-pass")

    def test_finding_key_is_line_stable(self):
        a = Finding("wire-slots", "profilerd/wire.py", 10, "Sample", "m")
        b = Finding("wire-slots", "profilerd/wire.py", 99, "Sample", "m")
        assert a.key() == b.key()
        assert "10" in a.render() and "[wire-slots]" in a.render()

    def test_pass_registry_ids_unique(self):
        assert len(PASS_IDS) == len(set(PASS_IDS)) == len(PASSES) == 7


class TestBaselineGate:
    def test_committed_baseline_passes_on_repo(self):
        code, report = check(REPRO_ROOT, BASELINE_PATH)
        assert code == EXIT_PASS, report
        assert "PASS" in report
        assert load_baseline(BASELINE_PATH) == frozenset()

    def test_new_findings_exit_regression(self, tmp_path):
        bl = str(tmp_path / "bl.json")
        save_baseline([], bl)
        code, report = check(os.path.join(FIXTURES_DIR, "wire-slots"), bl)
        assert code == EXIT_REGRESSION
        assert "NEW:" in report and "FAIL" in report

    def test_baselined_findings_pass_and_fixed_reported(self, tmp_path):
        root = os.path.join(FIXTURES_DIR, "wire-slots")
        bl = str(tmp_path / "bl.json")
        code, _ = check(root, bl, update=True)
        assert code == EXIT_PASS
        code, report = check(root, bl)
        assert code == EXIT_PASS, report
        # a baseline carrying debt that no longer exists reports it as fixed
        keys = sorted(load_baseline(bl) | {"wire-slots:profilerd/wire.py:Gone"})
        with open(bl, "w") as f:
            json.dump({"schema": BASELINE_SCHEMA, "root": "x", "keys": keys}, f)
        code, report = check(root, bl)
        assert code == EXIT_PASS
        assert "FIXED" in report and "Gone" in report

    def test_unreadable_paths_exit_3(self, tmp_path):
        empty = str(tmp_path / "empty")
        os.mkdir(empty)
        code, report = check(empty, BASELINE_PATH)
        assert code == EXIT_UNREADABLE and "no python files" in report
        code, report = check(REPRO_ROOT, str(tmp_path / "missing.json"))
        assert code == EXIT_UNREADABLE
        bad = str(tmp_path / "bad.json")
        with open(bad, "w") as f:
            f.write("{not json")
        code, _ = check(REPRO_ROOT, bad)
        assert code == EXIT_UNREADABLE
        with pytest.raises(BaselineError):
            load_baseline(bad)
        broken = write_pkg(str(tmp_path / "broken"), {"x.py": "def (:\n"})
        code, _ = check(broken, BASELINE_PATH)
        assert code == EXIT_UNREADABLE


class COVPKG:
    FILES = {
        "mod.py": (
            "def hot_fn():\n"
            "    return cold_fn\n"
            "\n"
            "def cold_fn():\n"
            "    return 0\n"
        ),
    }


class TestCoverage:
    def _graph(self, tmp_path, files=None):
        root = write_pkg(str(tmp_path / "covpkg"), files or COVPKG.FILES)
        return extract_static_graph(root, package="covpkg")

    def _dynamic(self):
        t = CallTree()
        for _ in range(5):
            t.add_stack(["thread::MainThread", "repro::hot_fn"])
        t.add_stack(["thread::MainThread", "repro::<lambda>"])
        t.add_stack(["thread::MainThread", "repro::*"])
        return t

    def test_cold_covered_drift_classification(self, tmp_path):
        report = coverage_report(self._graph(tmp_path), self._dynamic())
        assert report["schema"] == COVERAGE_SCHEMA
        assert [e["name"] for e in report["cold"]] == ["cold_fn"]
        assert report["cold"][0]["path"] == "mod.py"  # StaticGraph enriches sites
        assert [e["name"] for e in report["hot"]] == ["hot_fn"]
        assert report["hot"][0]["mass"] == 5.0
        assert report["covered"] == 1 and report["defs"] == 2
        assert report["coverage"] == pytest.approx(0.5)
        # synthetic frames and origin-collapse stars never count as drift
        assert report["drift"] == []

    def test_symbolization_drift_surfaces_renamed_def(self, tmp_path):
        # profile taken against the old source: samples land on hot_fn
        dynamic = self._dynamic()
        # then the def is renamed out from under the profile
        renamed = {"mod.py": COVPKG.FILES["mod.py"].replace("hot_fn", "warm_fn")}
        report = coverage_report(self._graph(tmp_path, renamed), dynamic)
        drift = {e["name"]: e["mass"] for e in report["drift"]}
        # the sampled mass did NOT vanish — it surfaces as drift, and the
        # renamed def shows up cold (deleted defs behave identically)
        assert drift == {"hot_fn": 5.0}
        assert {e["name"] for e in report["cold"]} == {"warm_fn", "cold_fn"}
        assert report["covered"] == 0
        text = render_coverage(report)
        assert "repro::hot_fn" in text and "drift" in text

    def test_bare_tree_input_and_exports_round_trip(self, tmp_path):
        g = self._graph(tmp_path)
        p = str(tmp_path / STATIC_TREE_FILENAME)
        save_static_tree(g.tree, p)
        report = coverage_report(load_static_tree(p), self._dynamic())
        assert "qualname" not in report["cold"][0]  # bare tree: no def sites
        ctree = coverage_tree(report)
        folded = to_folded(ctree)
        assert "coverage::cold;repro::cold_fn" in folded
        assert "coverage::covered;repro::hot_fn" in folded
        html = export_tree(ctree, "html", metric="samples", title="cov")
        assert "coverage::cold" in html


class TestStaticPlane:
    def test_planes_registry(self):
        assert "static" in PLANES
        assert default_metric("static", None) == DEFS
        assert default_metric("static", "calls") == "calls"

    def test_select_plane_static(self):
        host, static = CallTree(), CallTree()
        assert select_plane(host, None, "static", static=static) is static
        with pytest.raises(PlaneError, match="static_tree.json"):
            select_plane(host, None, "static", profile="/p/prof")
        with pytest.raises(PlaneError, match="repro.analysis extract"):
            select_plane(host, None, "static")

    def test_profiles_loaders(self, tmp_path):
        prof = tmp_path / "prof"
        (prof / "targets" / "t0").mkdir(parents=True)
        (prof / "tree.json").write_text(CallTree().to_json())
        assert static_tree_path(str(prof)) is None
        assert load_static_plane(str(prof)) is None
        t = CallTree()
        t.add_stack(["mod::m", "repro::f"], {DEFS: 1.0, "samples": 1.0})
        save_static_tree(t, str(prof / STATIC_TREE_FILENAME))
        assert static_tree_path(str(prof)) == str(prof / STATIC_TREE_FILENAME)
        # per-target resolution falls back to the fleet-level artifact
        assert static_tree_path(str(prof), "t0") == str(prof / STATIC_TREE_FILENAME)
        save_static_tree(t, str(prof / "targets" / "t0" / STATIC_TREE_FILENAME))
        assert "targets" in static_tree_path(str(prof), "t0")
        loaded = load_static_plane(str(prof))
        assert loaded.flatten(DEFS)["repro::f"] == 1.0
        # a tree.json file path resolves the artifact as a sibling
        assert static_tree_path(str(prof / "tree.json")) == str(prof / STATIC_TREE_FILENAME)
        (prof / STATIC_TREE_FILENAME).write_text("{broken")
        with pytest.raises(ProfileLoadError, match="unreadable static tree"):
            load_static_plane(str(prof))

    def test_shared_state_and_live_source(self):
        from repro.profilerd.server import LiveSource, SharedProfileState

        shared = SharedProfileState()
        src = LiveSource(shared)
        assert src.static_tree() is None
        t = CallTree()
        shared.set_static_tree(t)
        assert src.static_tree() is t
        assert src.static_tree("any-target") is t  # one artifact per fleet


def _http_get(url):
    try:
        with urllib.request.urlopen(url, timeout=5) as resp:
            return resp.status, resp.read().decode()
    except urllib.error.HTTPError as e:
        return e.code, e.read().decode()


class TestServerStaticPlane:
    @pytest.fixture
    def profile_dir(self, tmp_path):
        d = tmp_path / "prof"
        d.mkdir()
        host = CallTree()
        host.add_stack(["thread::MainThread", "repro::tick"])
        (d / "tree.json").write_text(host.to_json())
        return d

    def _serve(self, path):
        from repro.profilerd.server import OfflineSource, ProfileServer

        return ProfileServer(OfflineSource(str(path))).start()

    def test_tree_plane_static(self, profile_dir, tmp_path):
        root = write_pkg(str(tmp_path / "pkg"), SYNTH_PKG)
        g = extract_static_graph(root, package="pkg")
        save_static_tree(g.tree, str(profile_dir / STATIC_TREE_FILENAME), meta=g.meta())
        server = self._serve(profile_dir)
        try:
            code, folded = _http_get(server.url + "/tree?plane=static&fmt=folded")
            assert code == 200, folded
            assert "mod::pkg.alpha;repro::outer" in folded
            code, body = _http_get(server.url + "/tree?plane=static&fmt=json")
            assert code == 200 and json.loads(body)["name"] == "<root>"
            code, html = _http_get(server.url + "/tree?plane=static&fmt=html")
            assert code == 200 and "static plane" in html
            code, body = _http_get(server.url + "/")
            assert "plane=host|device|merged|static" in body
        finally:
            server.stop()

    def test_missing_artifact_404_with_hint(self, profile_dir):
        server = self._serve(profile_dir)
        try:
            code, body = _http_get(server.url + "/tree?plane=static")
            assert code == 404
            assert "static_tree.json" in body and "repro.analysis extract" in body
        finally:
            server.stop()

    def test_diff_plane_static(self, profile_dir, tmp_path):
        root = write_pkg(str(tmp_path / "pkg"), SYNTH_PKG)
        g = extract_static_graph(root, package="pkg")
        save_static_tree(g.tree, str(profile_dir / STATIC_TREE_FILENAME))
        server = self._serve(profile_dir)
        try:
            code, body = _http_get(
                server.url + f"/diff?plane=static&baseline={profile_dir}&metric=defs"
            )
            assert code == 200, body
        finally:
            server.stop()


class TestCLI:
    def _run(self, module, *argv, cwd=None):
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC_ROOT + os.pathsep + env.get("PYTHONPATH", "")
        return subprocess.run(
            [sys.executable, "-m", module, *argv],
            env=env, capture_output=True, text=True, timeout=120, cwd=cwd,
        )

    @pytest.fixture
    def profile_with_static(self, tmp_path):
        d = tmp_path / "prof"
        d.mkdir()
        host = CallTree()
        host.add_stack(["thread::MainThread", "repro::outer"])
        (d / "tree.json").write_text(host.to_json())
        root = write_pkg(str(tmp_path / "pkg"), SYNTH_PKG)
        r = self._run(
            "repro.analysis", "extract", "--root", root, "--package", "pkg",
            "--out", str(d),
        )
        assert r.returncode == 0, (r.stdout, r.stderr)
        assert "2 modules" in r.stdout
        return d

    def test_export_static_plane_round_trips(self, profile_with_static, tmp_path):
        out = str(tmp_path / "static.folded")
        r = self._run(
            "repro.profilerd", "export", str(profile_with_static),
            "--plane", "static", "--fmt", "folded", "--out", out,
        )
        assert r.returncode == 0, (r.stdout, r.stderr)
        folded = open(out).read()
        assert "mod::pkg.alpha;repro::outer" in folded
        r = self._run(
            "repro.profilerd", "export", str(profile_with_static),
            "--plane", "static", "--fmt", "html", "--out", str(tmp_path / "s.html"),
        )
        assert r.returncode == 0, (r.stdout, r.stderr)

    def test_export_static_without_artifact_exits_4(self, tmp_path):
        d = tmp_path / "hostonly"
        d.mkdir()
        (d / "tree.json").write_text(CallTree().to_json())
        r = self._run(
            "repro.profilerd", "export", str(d), "--plane", "static",
            "--fmt", "folded", "--out", str(tmp_path / "o.folded"),
        )
        assert r.returncode == 4, (r.stdout, r.stderr)
        assert "static_tree.json" in (r.stdout + r.stderr)

    def test_analysis_check_cli(self, tmp_path):
        r = self._run(
            "repro.analysis", "check", "--root", REPRO_ROOT,
            "--baseline", BASELINE_PATH,
        )
        assert r.returncode == 0, (r.stdout, r.stderr)
        r = self._run(
            "repro.analysis", "check",
            "--root", os.path.join(FIXTURES_DIR, "agent-hot-path"),
            "--baseline", BASELINE_PATH,
        )
        assert r.returncode == 2, (r.stdout, r.stderr)
        r = self._run(
            "repro.analysis", "check", "--root", REPRO_ROOT,
            "--baseline", str(tmp_path / "missing.json"),
        )
        assert r.returncode == 3, (r.stdout, r.stderr)

    def test_analysis_fixtures_cli(self):
        r = self._run("repro.analysis", "fixtures", "--dir", FIXTURES_DIR, "--json")
        assert r.returncode == 0, (r.stdout, r.stderr)
        score = json.loads(r.stdout)
        assert score["ok"] is True

    def test_analysis_coverage_cli(self, profile_with_static, tmp_path):
        r = self._run(
            "repro.analysis", "coverage", "--profile", str(profile_with_static),
            "--json",
        )
        assert r.returncode == 0, (r.stdout, r.stderr)
        report = json.loads(r.stdout)
        assert report["schema"] == COVERAGE_SCHEMA
        assert {e["name"] for e in report["hot"]} == {"outer"}
        tree_out = str(tmp_path / "covtree.json")
        r = self._run(
            "repro.analysis", "coverage", "--profile", str(profile_with_static),
            "--tree", tree_out,
        )
        assert r.returncode == 0, (r.stdout, r.stderr)
        assert os.path.exists(tree_out)


class TestWireRecordsSlots:
    def test_wire_dataclasses_have_slots(self):
        from repro.profilerd import wire

        for name in ("Hello", "Rusage", "Bye"):
            cls = getattr(wire, name)
            assert hasattr(cls, "__slots__"), name
            assert "__dict__" not in cls.__slots__


class TestEventRegistry:
    def test_event_kinds_canonical(self):
        from repro.profilerd import events

        assert len(events.EVENT_KINDS) >= 40
        names = [n for n in events.__all__ if n != "EVENT_KINDS"]
        # each constant names itself and is registered
        for n in names:
            assert getattr(events, n) == n
            assert n in events.EVENT_KINDS
        assert len(names) == len(events.EVENT_KINDS)

    def test_daemon_extract_to_file_meta(self, tmp_path):
        out = str(tmp_path / STATIC_TREE_FILENAME)
        g = extract_to_file(out)
        meta = static_meta(out)
        assert meta["modules"] == g.n_modules
        assert meta["defs"] == len(g.defs)
        assert meta["generator"] == "repro.analysis.extract"
