"""Call-tree semantics tests, including the paper's Figure 7 example verbatim."""

import math


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # keep property tests running where hypothesis is absent
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.core import CallTree


def make_fig7_tree():
    """Paper Fig. 7: samples a1->b1->c->e1 and a2->b2->d->f->e2.

    Shared prefix a->b merges (counters a1+a2, b1+b2); after the split the
    same callee e reached from c vs f stays a distinct call-site.
    """
    t = CallTree()
    t.add_stack(["a", "b", "c", "e"])  # a1->b1->c->e1
    t.add_stack(["a", "b", "d", "f", "e"])  # a2->b2->d->f->e2
    return t


class TestFigure7:
    def test_prefix_merge_counters(self):
        t = make_fig7_tree()
        a = t.root.children["a"]
        b = a.children["b"]
        assert a.metrics["samples"] == 2  # a1+a2
        assert b.metrics["samples"] == 2  # b1+b2
        assert set(b.children) == {"c", "d"}

    def test_distinct_call_sites_for_same_callee(self):
        t = make_fig7_tree()
        b = t.root.children["a"].children["b"]
        e_via_c = b.children["c"].children["e"]
        e_via_f = b.children["d"].children["f"].children["e"]
        assert e_via_c is not e_via_f
        assert e_via_c.metrics["samples"] == 1
        assert e_via_f.metrics["samples"] == 1

    def test_flattened_view_merges_identical_names(self):
        t = make_fig7_tree()
        flat = t.flatten()
        assert flat["a"] == 2 and flat["b"] == 2
        assert flat["e"] == 2  # e1+e2 merged in the flattened view
        assert flat["c"] == 1 and flat["d"] == 1 and flat["f"] == 1

    def test_three_level_view_folds_deep_nodes(self):
        """Paper: in the 3-level view, e1 folds into c; f and e2 fold into d."""
        t = make_fig7_tree()
        v = t.levels(3)
        b = v.root.children["a"].children["b"]
        c, d = b.children["c"], b.children["d"]
        assert not c.children and not d.children
        # Folding preserves inclusive counters.
        assert c.metrics["samples"] == 1 and d.metrics["samples"] == 1
        assert c.self_metrics["samples"] == 1  # e1 aggregated into c
        assert d.self_metrics["samples"] == 1  # f+e2 aggregated into d

    def test_zoom_reroots_and_merges(self):
        t = make_fig7_tree()
        z = t.zoom("e")
        assert z.total() == 2  # both e call-sites merged under the new root
        assert set(z.root.children) == {"e"}

    def test_level_minus_one_is_full_tree(self):
        t = make_fig7_tree()
        assert t.levels(-1).to_json() == t.to_json()


class TestViews:
    def test_blacklist_removes_subtree(self):
        t = make_fig7_tree()
        f = t.filtered(blacklist=["d"])
        b = f.root.children["a"].children["b"]
        assert "d" not in b.children and "c" in b.children

    def test_whitelist_keeps_matching_paths(self):
        t = make_fig7_tree()
        f = t.filtered(whitelist=["f"])
        b = f.root.children["a"].children["b"]
        assert "c" not in b.children
        assert "f" in b.children["d"].children

    def test_shares_and_hot_paths(self):
        t = make_fig7_tree()
        shares = t.shares()
        assert shares[("a",)] == 1.0
        hot = t.hot_paths(k=2)
        assert all(0 < s <= 1 for _, s in hot)

    def test_render_and_depth(self):
        t = make_fig7_tree()
        assert t.depth() == 5
        out = t.render()
        assert "a" in out and "%" in out


class TestMergeDiff:
    def test_cross_host_merge(self):
        t1, t2 = make_fig7_tree(), make_fig7_tree()
        t1.merge(t2)
        assert t1.root.children["a"].metrics["samples"] == 4

    def test_diff_isolates_window(self):
        t = make_fig7_tree()
        snap = t.copy()
        t.add_stack(["a", "b", "c", "e"])
        t.add_stack(["x", "spin"])
        d = t.diff(snap)
        assert d.total() == 2
        assert d.root.children["x"].metrics["samples"] == 1
        assert "d" not in d.root.children["a"].children["b"].children

    def test_json_roundtrip(self):
        t = make_fig7_tree()
        t2 = CallTree.from_json(t.to_json())
        assert t2.to_json() == t.to_json()


class TestEdgeCases:
    def test_levels_zero_folds_everything_into_root(self):
        t = make_fig7_tree()
        v = t.levels(0)
        assert not v.root.children
        assert v.total() == t.total() == 2
        assert v.root.self_metrics == v.root.metrics  # all mass folded to root

    def test_levels_zero_on_root_only_tree(self):
        t = CallTree()
        t.add_stack([])  # a zero-depth sample lands on the root itself
        v = t.levels(0)
        assert not v.root.children
        assert v.total() == 1 and v.root.self_metrics["samples"] == 1

    def test_levels_on_empty_tree(self):
        t = CallTree()
        for n in (0, 1, 3):
            v = t.levels(n)
            assert v.total() == 0 and not v.root.children

    def test_diff_against_empty_snapshot_is_identity(self):
        t = make_fig7_tree()
        d = t.diff(CallTree())
        assert d.to_json() == t.to_json()

    def test_diff_of_empty_tree_is_root_only(self):
        d = CallTree().diff(CallTree())
        assert d.total() == 0 and not d.root.children
        assert d.root.name == CallTree.ROOT

    def test_diff_drops_metrics_that_cancel_to_exactly_zero(self):
        """A metric that nets to 0.0 over the window disappears, and nodes
        left with no metrics and no changed descendants are pruned."""
        t = CallTree()
        t.add_stack(["a", "b"], {"credit": 2.0})
        snap = t.copy()
        t.add_stack(["a", "b"], {"credit": -2.0})  # cancels within the window?
        d = t.diff(snap)
        # window delta is -2.0 (changed), so nodes survive with the delta...
        assert d.root.children["a"].metrics["credit"] == -2.0
        # ...but diffing a tree against itself cancels everything to 0.0
        self_diff = t.diff(t.copy())
        assert self_diff.total() == 0 and not self_diff.root.children

    def test_diff_unchanged_subtree_pruned_even_with_zero_valued_metric(self):
        t = CallTree()
        t.add_stack(["a", "b"], {"samples": 0.0})  # explicitly zero-valued
        d = t.diff(CallTree())
        assert not d.root.children  # 0.0 deltas never materialize nodes


class TestFastLane:
    """The samples/self_samples hot counters must be invisible to readers."""

    def test_fast_lane_flushes_into_metrics_on_read(self):
        t = CallTree()
        t.add_stack(["a", "b"])  # default-metrics path rides the fast lane
        a = t.root.children["a"]
        assert a.samples == 1.0  # pending, not yet in the dict
        assert a.metrics["samples"] == 1.0  # reading flushes
        assert a.samples == 0.0

    def test_path_nodes_plus_add_stack_nodes_equals_add_stack(self):
        stacks = [["a", "b", "c"], ["a", "b"], ["a", "x"], ["a", "b", "c"]]
        generic, fast = CallTree(), CallTree()
        cache = {}
        for s in stacks:
            generic.add_stack(s)
            key = tuple(s)
            chain = cache.get(key)
            if chain is None:
                chain = cache[key] = fast.path_nodes(s)
            CallTree.add_stack_nodes(chain)
        assert fast.to_json() == generic.to_json()

    def test_fast_lane_mixes_with_generic_metrics(self):
        t = CallTree()
        t.add_stack(["a"], {"samples": 2.0, "flops": 5.0})  # generic dict path
        t.add_stack(["a"])  # fast lane
        a = t.root.children["a"]
        assert a.metrics == {"samples": 3.0, "flops": 5.0}
        assert a.self_metrics["samples"] == 3.0

    def test_views_and_merge_see_flushed_counts(self):
        t = CallTree()
        chain = t.path_nodes(["a", "b"])
        for _ in range(5):
            CallTree.add_stack_nodes(chain)
        assert t.flatten()["b"] == 5
        assert t.copy().total() == 5
        other = CallTree()
        other.add_stack(["a", "b"])
        t.merge(other)
        assert t.root.children["a"].children["b"].metrics["samples"] == 6
        assert t.levels(1).root.children["a"].self_metrics["samples"] == 6


# ---------------------------------------------------------------------------
# Property-based invariants
# ---------------------------------------------------------------------------

frames = st.lists(st.sampled_from(["a", "b", "c", "d", "e", "f", "g"]), min_size=1, max_size=8)
stacks = st.lists(frames, min_size=1, max_size=40)


@settings(max_examples=100, deadline=None)
@given(stacks)
def test_prop_root_total_equals_sample_count(ss):
    t = CallTree()
    for s in ss:
        t.add_stack(s)
    assert t.total() == len(ss)


@settings(max_examples=100, deadline=None)
@given(stacks)
def test_prop_children_never_exceed_parent(ss):
    t = CallTree()
    for s in ss:
        t.add_stack(s)
    for _, node in t.root.walk():
        child_sum = sum(c.metrics.get("samples", 0) for c in node.children.values())
        assert child_sum <= node.metrics.get("samples", 0) + 1e-9


@settings(max_examples=100, deadline=None)
@given(stacks)
def test_prop_inclusive_equals_self_plus_children(ss):
    t = CallTree()
    for s in ss:
        t.add_stack(s)
    for _, node in t.root.walk():
        child_sum = sum(c.metrics.get("samples", 0) for c in node.children.values())
        assert math.isclose(
            node.metrics.get("samples", 0),
            node.self_metrics.get("samples", 0) + child_sum,
        )


@settings(max_examples=100, deadline=None)
@given(stacks)
def test_prop_flatten_conserves_leaf_mass(ss):
    """Sum of self-metrics over the tree == number of samples."""
    t = CallTree()
    for s in ss:
        t.add_stack(s)
    self_mass = sum(node.self_metrics.get("samples", 0) for _, node in t.root.walk())
    assert math.isclose(self_mass, len(ss))


@settings(max_examples=100, deadline=None)
@given(stacks, st.integers(min_value=0, max_value=9))
def test_prop_levels_preserves_total(ss, n):
    t = CallTree()
    for s in ss:
        t.add_stack(s)
    assert math.isclose(t.levels(n).total(), t.total())


@settings(max_examples=100, deadline=None)
@given(stacks, stacks)
def test_prop_merge_is_additive(s1, s2):
    t1, t2 = CallTree(), CallTree()
    for s in s1:
        t1.add_stack(s)
    for s in s2:
        t2.add_stack(s)
    merged = t1.copy().merge(t2)
    assert math.isclose(merged.total(), len(s1) + len(s2))
    both = CallTree()
    for s in s1 + s2:
        both.add_stack(s)
    assert merged.to_json() == both.to_json()


@settings(max_examples=60, deadline=None)
@given(stacks, stacks)
def test_prop_diff_inverts_add(s1, s2):
    t = CallTree()
    for s in s1:
        t.add_stack(s)
    snap = t.copy()
    for s in s2:
        t.add_stack(s)
    assert math.isclose(t.diff(snap).total(), len(s2))
