"""Timeline subsystem tests (ISSUE 3).

Pinned invariants:

* snapshot codec — full/delta/counts roundtrips reconstruct trees exactly;
  corruption is detected (CRC), version skew refuses loudly, a torn ring
  tail is tolerated (crash-safe append);
* timeline ring — retention stays bounded in segments, and reconstruction
  through keyframes survives dropped history;
* sealers — chain-tracked (EpochSealer) and counts (CountSealer) sealing
  both reconstruct the live tree exactly, including the untracked fallback;
* trend detection — livelock (dominance + zero progress) is distinguished
  from plain dominance, both stamped with the epoch where they began; drift
  fires against a trailing baseline; phase segmentation splits on jumps;
* CLI — ``check`` exit codes (0 pass / 2 regression / 3 unreadable),
  ``diff`` share deltas, ``timeline`` phase output.
"""

import os
import sys

import pytest

from repro.core.calltree import CallTree
from repro.core.detector import (
    DOMINANT,
    LIVELOCK,
    SHARE_DRIFT,
    TrendDetector,
    TrendRule,
    segment_phases,
)
from repro.core.report import render_diff, share_regressions
from repro.core.snapshot import (
    CountSealer,
    EpochMeta,
    EpochSealer,
    SnapshotCorrupt,
    SnapshotVersionError,
    TimelineReader,
    TimelineWriter,
    list_segments,
    load_snapshot,
    read_epochs,
    save_snapshot,
)
from repro.profilerd.__main__ import main as profilerd_main
from repro.profilerd.ingest import TreeIngestor
from repro.profilerd.wire import RawFrame, RawSample

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "data"))
import gen_workload  # noqa: E402


def sample_tree() -> CallTree:
    t = CallTree()
    for i in range(40):
        t.add_stack(["thread::main", f"f{i % 4}", f"g{i % 3}"])
    t.add_stack(["thread::main", "device"], {"flops": 2.5, "bytes": 100.0})
    return t


class TestSnapshotCodec:
    def test_roundtrip_full(self, tmp_path):
        t = sample_tree()
        p = str(tmp_path / "t.snap")
        save_snapshot(t, p, EpochMeta(7, wall_time=3.5, progress=12.0))
        meta, t2 = load_snapshot(p)
        assert t2.root == t.root
        assert (meta.epoch, meta.wall_time, meta.progress) == (7, 3.5, 12.0)

    def test_snapshot_is_deterministic(self, tmp_path):
        a, b = str(tmp_path / "a.snap"), str(tmp_path / "b.snap")
        save_snapshot(sample_tree(), a)
        save_snapshot(sample_tree(), b)
        assert open(a, "rb").read() == open(b, "rb").read()

    def test_corruption_detected(self, tmp_path):
        p = str(tmp_path / "t.snap")
        save_snapshot(sample_tree(), p)
        raw = bytearray(open(p, "rb").read())
        raw[len(raw) // 2] ^= 0xFF
        open(p, "wb").write(bytes(raw))
        with pytest.raises(SnapshotCorrupt):
            load_snapshot(p)

    def test_version_skew_refused(self, tmp_path):
        p = str(tmp_path / "t.snap")
        save_snapshot(sample_tree(), p)
        with open(p, "r+b") as f:
            f.seek(4)
            f.write((99).to_bytes(2, "little"))
        with pytest.raises(SnapshotVersionError):
            load_snapshot(p)

    def test_bad_magic_refused(self, tmp_path):
        p = str(tmp_path / "t.snap")
        with open(p, "wb") as f:
            f.write(b"NOPE" + b"\0" * 32)
        with pytest.raises(SnapshotCorrupt):
            load_snapshot(p)


def drive_sealer(tmp_path, epochs, stacks_per_epoch, **writer_kw):
    """Seal `epochs` epochs of chain-tracked activity; returns (dir, tree)."""
    d = str(tmp_path / "tl")
    tree = CallTree()
    w = TimelineWriter(d, **writer_kw)
    s = EpochSealer(tree, w)
    for e in range(epochs):
        chains = []
        for stack, count in stacks_per_epoch(e):
            ch = tree.path_nodes(stack)
            CallTree.add_stack_nodes(ch, float(count))
            chains.append(ch)
        s.seal(chains, wall_time=float(e))
    w.close()
    return d, tree


class TestTimelineRing:
    def steady(self, e):
        return [(["thread::m", "serve", "model"], 6), (["thread::m", "data"], 2)]

    def test_reconstruction_exact(self, tmp_path):
        d, tree = drive_sealer(tmp_path, 10, self.steady, epochs_per_segment=3)
        r = TimelineReader(d)
        last = r.last()
        assert last is not None and last[1].root == tree.root
        assert not r.truncated
        eps = read_epochs(d)
        assert [m.epoch for m, _, _ in eps] == list(range(10))
        # every window carries exactly one epoch's activity
        assert all(w.total() == 8.0 for _, w, _ in eps)

    def test_retention_bounded_and_decodable(self, tmp_path):
        d, tree = drive_sealer(
            tmp_path, 20, self.steady, epochs_per_segment=2, max_segments=3
        )
        assert len(list_segments(d)) <= 3
        eps = read_epochs(d)
        # oldest epochs dropped, newest survive, cumulative still exact
        # (each segment keyframe carries the absolute tree).
        assert eps and eps[-1][0].epoch == 19
        assert eps[-1][2].root == tree.root

    def test_torn_tail_tolerated(self, tmp_path):
        d, tree = drive_sealer(tmp_path, 4, self.steady, epochs_per_segment=100)
        seg = list_segments(d)[0]
        raw = open(seg, "rb").read()
        open(seg, "wb").write(raw[: len(raw) - 7])  # tear mid-record
        r = TimelineReader(d)
        eps = [(m.epoch) for m, _, _ in r.epochs()]
        assert eps == [0, 1, 2]  # last record lost, earlier ones fine
        assert r.truncated

    def test_reused_dir_drops_previous_runs_segments(self, tmp_path):
        # Run 1 seals more epochs (more segments) than run 2; a reader on the
        # shared dir must see ONLY run 2 — stale keyframes from run 1 would
        # otherwise resurrect the old run's tree.
        drive_sealer(tmp_path, 9, self.steady, epochs_per_segment=2)
        d, tree2 = drive_sealer(tmp_path, 3, lambda e: [(["thread::m", "run2"], 1)],
                                epochs_per_segment=2)
        eps = read_epochs(d)
        assert [m.epoch for m, _, _ in eps] == [0, 1, 2]
        assert eps[-1][2].root == tree2.root

    def test_writer_construction_alone_keeps_previous_ring(self, tmp_path):
        # A daemon whose attach times out constructs a TimelineWriter but
        # never seals; the previous run's ring must survive (the stale purge
        # is deferred to the first write).
        d, tree = drive_sealer(tmp_path, 3, self.steady)
        w = TimelineWriter(d)
        w.close()
        eps = read_epochs(d)
        assert len(eps) == 3 and eps[-1][2].root == tree.root

    def test_headerless_segment_skipped_not_fatal(self, tmp_path):
        # Crash between segment open() and header write leaves a 0-byte file;
        # readers must skip it, and check-style consumers must not crash.
        d, tree = drive_sealer(tmp_path, 3, self.steady)
        open(os.path.join(d, "seg-9999999999.tl"), "wb").close()
        r = TimelineReader(d)
        eps = [(m, w, c) for m, w, c in r.epochs()]
        assert len(eps) == 3 and r.truncated
        assert eps[-1][2].root == tree.root

    def test_corrupt_mid_segment_resyncs_at_next_keyframe(self, tmp_path):
        d, tree = drive_sealer(tmp_path, 8, self.steady, epochs_per_segment=2)
        segs = list_segments(d)
        assert len(segs) == 4
        raw = bytearray(open(segs[1], "rb").read())
        raw[-10] ^= 0xFF  # corrupt the 2nd segment's delta record
        open(segs[1], "wb").write(bytes(raw))
        r = TimelineReader(d)
        eps = [(m, w, c.copy()) for m, w, c in r.epochs()]
        assert r.truncated
        # epoch 3 (the corrupt delta) is gone; the next keyframe resyncs,
        # so the final cumulative is still exact.
        assert [m.epoch for m, _, _ in eps] == [0, 1, 2, 4, 5, 6, 7]
        assert eps[-1][2].root == tree.root


class TestSealers:
    def v2_samples(self, spec):
        """spec: list of (leaf_tag, count) -> RawSamples sharing a root."""
        out = []
        sid = 0
        for tag, count in spec:
            frames = [
                RawFrame("/root/repo/src/repro/serve.py", "serve_step", 10),
                RawFrame("/root/repo/src/repro/model.py", tag, 20),
            ]
            for _ in range(count):
                out.append(RawSample(0.0, 1, "MainThread", frames, None))
            sid += 1
        return out

    def test_count_sealer_exact_and_keyframes(self, tmp_path):
        d = str(tmp_path / "tl")
        ing = TreeIngestor()
        w = TimelineWriter(d, epochs_per_segment=3)
        s = CountSealer(ing.tree, w)
        enc_sid = 0
        for epoch in range(8):
            for tag, count in [("attention", 5), ("mlp", 3)]:
                frames = [
                    RawFrame("/r/serve.py", "serve_step", 1),
                    RawFrame("/r/model.py", tag, 2),
                ]
                for _ in range(count):
                    ing.ingest(RawSample(0.0, 1, "MainThread", frames, enc_sid))
                enc_sid += 1
            entries, untracked = ing.drain_epoch()
            assert not untracked
            s.seal(entries, wall_time=float(epoch))
        w.close()
        r = TimelineReader(d)
        last = r.last()
        assert last is not None and last[1].root == ing.tree.root
        eps = read_epochs(d)
        assert len(eps) == 8 and all(w.total() == 8.0 for _, w, _ in eps)

    def test_count_sealer_untracked_forces_keyframe(self, tmp_path):
        d = str(tmp_path / "tl")
        ing = TreeIngestor()
        w = TimelineWriter(d, epochs_per_segment=100)
        s = CountSealer(ing.tree, w)
        # epoch 0: interned (v2) samples
        frames = [RawFrame("/r/a.py", "f", 1)]
        ing.ingest(RawSample(0.0, 1, "T", frames, 0))
        entries, untracked = ing.drain_epoch()
        s.seal(entries, wall_time=0.0, untracked=untracked)
        # epoch 1: a legacy v1 sample (stack_id None) -> untracked
        ing.ingest(RawSample(0.1, 1, "T", [RawFrame("/r/b.py", "g", 2)], None))
        entries, untracked = ing.drain_epoch()
        assert untracked
        s.seal(entries, wall_time=1.0, untracked=untracked)
        w.close()
        last = TimelineReader(d).last()
        assert last is not None and last[1].root == ing.tree.root

    def test_epoch_sealer_full_walk_matches_chain_tracking(self, tmp_path):
        da, db = str(tmp_path / "a"), str(tmp_path / "b")
        ta, tb = CallTree(), CallTree()
        sa = EpochSealer(ta, TimelineWriter(da))
        sb = EpochSealer(tb, TimelineWriter(db))
        for e in range(5):
            chains = []
            for t, chains_out in ((ta, chains), (tb, None)):
                for stack in (["m", "x"], ["m", "y", "z"]):
                    ch = t.path_nodes(stack)
                    CallTree.add_stack_nodes(ch)
                    if chains_out is not None:
                        chains_out.append(ch)
            sa.seal(chains, wall_time=float(e))
            sb.seal(None, wall_time=float(e), full_walk=True)
        assert TimelineReader(da).last()[1].root == ta.root
        assert TimelineReader(db).last()[1].root == tb.root
        assert ta.root == tb.root


def window(spec, extra=()) -> CallTree:
    t = CallTree()
    for stack, count in list(spec) + list(extra):
        t.add_stack(stack, {"samples": float(count)})
    return t


class TestTrendDetector:
    SPIN = (("t", "spin", "lock_wait"), 95.0)
    WORK = [ (("t", "serve", "model"), 3.0), (("t", "data"), 2.0) ]

    def test_livelock_needs_zero_progress(self):
        det = TrendDetector(TrendRule(epochs=3, min_baseline_epochs=99))
        # dominant every epoch but progress grows -> DOMINANT only
        kinds = set()
        for e in range(6):
            for v in det.observe_epoch(window([self.SPIN], self.WORK), progress=float(e)):
                kinds.add(v.kind)
        assert DOMINANT in kinds and LIVELOCK not in kinds

    def test_livelock_stamped_at_onset_epoch(self):
        det = TrendDetector(TrendRule(epochs=3, min_baseline_epochs=99))
        verdicts = []
        # progress grows for epochs 0-2, freezes from epoch 3 on
        for e in range(8):
            progress = float(min(e, 3))
            verdicts += det.observe_epoch(window([self.SPIN], self.WORK), progress=progress)
        livelocks = [v for v in verdicts if v.kind == LIVELOCK]
        assert livelocks, [v.kind for v in verdicts]
        # progress last grew at epoch 3; the stalled-dominance run began at 4
        assert livelocks[0].began_epoch == 4
        assert livelocks[0].epoch == 6  # 3 stalled epochs: 4, 5, 6
        assert livelocks[0].path == ("t", "spin", "lock_wait")

    def test_plain_dominance_not_livelock_on_short_stall(self):
        det = TrendDetector(TrendRule(epochs=3, min_baseline_epochs=99))
        verdicts = []
        # progress stalls for only 2 epochs, then grows again
        for _e, p in enumerate([0.0, 1.0, 2.0, 2.0, 2.0, 3.0, 4.0]):
            verdicts += det.observe_epoch(window([self.SPIN], self.WORK), progress=p)
        assert all(v.kind != LIVELOCK for v in verdicts)

    def test_drift_vs_trailing_baseline(self):
        det = TrendDetector(TrendRule(drift_threshold=0.3, min_baseline_epochs=3))
        steady = [(("t", "serve", "model"), 6.0), (("t", "data"), 4.0)]
        shifted = [(("t", "serve", "model"), 1.0), (("t", "compile", "xla"), 9.0)]
        verdicts = []
        for e in range(5):
            verdicts += det.observe_epoch(window(steady), progress=float(e))
        assert all(v.kind != SHARE_DRIFT for v in verdicts)
        drift = det.observe_epoch(window(shifted), progress=6.0)
        kinds = [v.kind for v in drift]
        assert SHARE_DRIFT in kinds
        v = next(v for v in drift if v.kind == SHARE_DRIFT)
        assert v.began_epoch == 5 and v.share >= 0.3

    def test_segment_phases(self):
        a = {"x": 0.8, "y": 0.2}
        b = {"x": 0.1, "z": 0.9}
        assert segment_phases([a, a, a, b, b]) == [(0, 2), (3, 4)]
        assert segment_phases([a]) == [(0, 0)]
        assert segment_phases([]) == []


class TestDifferential:
    def test_share_regressions_only_increases(self):
        base = window([(("t", "model"), 8.0), (("t", "data"), 2.0)])
        cur = window([(("t", "model"), 4.0), (("t", "data"), 1.0), (("t", "spin"), 5.0)])
        regs = share_regressions(base, cur, tolerance=0.05)
        names = [r[0] for r in regs]
        assert names == ["spin"]  # data/model *lost* share: not regressions
        assert regs[0][3] == pytest.approx(0.5)

    def test_render_diff_shows_signed_deltas(self):
        a = window([(("t", "model"), 8.0), (("t", "data"), 2.0)])
        b = window([(("t", "model"), 2.0), (("t", "data"), 8.0)])
        out = render_diff(a, b, label_a="base", label_b="cand")
        assert "t/model" in out and "t/data" in out
        assert "+60.00%" in out and "-60.00%" in out


class TestCheckCLI:
    @pytest.fixture
    def gate(self, tmp_path):
        base_snap = str(tmp_path / "base.snap")
        good = str(tmp_path / "good")
        bad = str(tmp_path / "bad")
        tree = gen_workload.build(good)
        save_snapshot(tree, base_snap)
        gen_workload.build(bad, inject_hot_loop=True)
        return base_snap, good, bad

    def test_check_pass(self, gate, capsys):
        base, good, _ = gate
        rc = profilerd_main(["check", good, "--baseline", base, "--tolerance", "0.02"])
        assert rc == 0
        assert "PASS" in capsys.readouterr().out

    def test_check_regression(self, gate, capsys):
        base, _, bad = gate
        rc = profilerd_main(["check", bad, "--baseline", base, "--tolerance", "0.02"])
        assert rc == 2
        out = capsys.readouterr().out
        assert "REGRESSION" in out and "spin_retry_loop" in out

    def test_check_missing_baseline(self, gate, tmp_path):
        _, good, _ = gate
        rc = profilerd_main(
            ["check", good, "--baseline", str(tmp_path / "nope.snap")]
        )
        assert rc == 3

    def test_check_missing_profile(self, gate, tmp_path):
        base, _, _ = gate
        rc = profilerd_main(["check", str(tmp_path / "nope"), "--baseline", base])
        assert rc == 3

    def test_check_accepts_tree_json_and_snap(self, gate, tmp_path):
        base, good, _ = gate
        rc = profilerd_main(
            ["check", os.path.join(good, "tree.json"), "--baseline", base]
        )
        assert rc == 0

    def test_committed_ci_baseline_matches_workload(self):
        """The committed baseline gates the deterministic workload (the CI
        profile-gate contract); regenerate with gen_workload.py --snapshot
        if the workload ever changes deliberately."""
        committed = os.path.join(os.path.dirname(__file__), "data", "ci_baseline.snap")
        _meta, tree = load_snapshot(committed)
        assert tree.root == gen_workload.build(None).root

    def test_diff_cli(self, gate, capsys):
        base, good, bad = gate
        rc = profilerd_main(["diff", good, bad, "--self-only"])
        assert rc == 0
        assert "spin_retry_loop" in capsys.readouterr().out

    def test_timeline_cli(self, gate, capsys):
        _, good, _ = gate
        rc = profilerd_main(["timeline", "--store", good])
        assert rc == 0
        out = capsys.readouterr().out
        assert "phase epochs 0..7" in out and "scores" in out

    def test_timeline_cli_missing(self, tmp_path):
        assert profilerd_main(["timeline", "--store", str(tmp_path / "none")]) == 3

    def test_check_empty_profile_is_unreadable_not_pass(self, gate, tmp_path):
        # A profile with zero samples must not pass vacuously: the gate
        # would otherwise go green exactly when profiling broke.
        base, _, _ = gate
        empty = tmp_path / "empty.json"
        empty.write_text(CallTree().to_json())
        rc = profilerd_main(["check", str(empty), "--baseline", base])
        assert rc == 3

    def test_check_falls_back_to_tree_json_when_ring_undecodable(self, gate, tmp_path):
        # Daemon killed mid-keyframe: ring has a header-only segment, but a
        # valid tree.json sits beside it — check must use it, not exit 3.
        base, good, _ = gate
        out = tmp_path / "out"
        out.mkdir()
        (out / "tree.json").write_text(gen_workload.build(None).to_json())
        ring = out / "timeline"
        ring.mkdir()
        seg = ring / "seg-0000000000.tl"
        seg.write_bytes(b"")  # crash before the header landed
        rc = profilerd_main(["check", str(out), "--baseline", base])
        assert rc == 0


class TestLauncherTimelineMerge:
    def host_timeline(self, root, name, epochs, leaf):
        out = root / f"{name}.spool.d"
        tree = CallTree()
        w = TimelineWriter(str(out / "timeline"))
        s = EpochSealer(tree, w)
        for e in range(epochs):
            ch = tree.path_nodes(["thread::main", "serve", leaf])
            CallTree.add_stack_nodes(ch, 10.0)
            s.seal([ch], wall_time=float(e))
        w.close()
        return tree

    def test_merge_aligns_on_epoch_number_not_index(self, tmp_path):
        # Host A's ring lost its oldest segments to retention (first retained
        # epoch is 6); host B has epochs 0..3.  Alignment must join on the
        # sealed epoch number, not the list index.
        from repro.launch.launcher import LaunchConfig, Launcher

        out_a = tmp_path / "attempt0.spool.d"
        tree_a = CallTree()
        w = TimelineWriter(str(out_a / "timeline"), epochs_per_segment=2, max_segments=2)
        s = EpochSealer(tree_a, w)
        for e in range(10):
            ch = tree_a.path_nodes(["thread::m", "hostA"])
            CallTree.add_stack_nodes(ch, 1.0)
            s.seal([ch], wall_time=float(e))
        w.close()
        self.host_timeline(tmp_path, "attempt1", epochs=4, leaf="hostB")
        launcher = Launcher(
            LaunchConfig(cmd=["true"], workdir=str(tmp_path),
                         heartbeat_path=str(tmp_path / "hb"),
                         profile_dir=str(tmp_path))
        )
        out = launcher._merge_timelines()
        eps = read_epochs(out)
        # merged epochs = union of retained epoch numbers (6..9 from A, 0..3 from B)
        assert [m.epoch for m, _, _ in eps] == [0, 1, 2, 3, 6, 7, 8, 9]
        by_epoch = {m.epoch: c.total() for m, _, c in read_epochs(out, copy_cumulative=True)}
        # epoch 3: only host B's 4 epochs x 10 samples; host A not retained yet
        assert by_epoch[3] == 40.0
        # epoch 9: A's full cumulative (10) + B's final (40)
        assert by_epoch[9] == 50.0

    def test_per_epoch_fleet_merge(self, tmp_path):
        from repro.launch.launcher import LaunchConfig, Launcher

        t0 = self.host_timeline(tmp_path, "attempt0", epochs=4, leaf="attention")
        t1 = self.host_timeline(tmp_path, "attempt1", epochs=2, leaf="mlp")  # died early
        launcher = Launcher(
            LaunchConfig(cmd=["true"], workdir=str(tmp_path),
                         heartbeat_path=str(tmp_path / "hb"),
                         profile_dir=str(tmp_path))
        )
        out = launcher._merge_timelines()
        assert out is not None
        eps = read_epochs(out)
        assert [m.epoch for m, _, _ in eps] == [0, 1, 2, 3]
        final = eps[-1][2]
        merged = CallTree().merge(t0.copy()).merge(t1.copy())
        # the early host contributes its last cumulative to later epochs
        assert final.root == merged.root
        # fleet total never dips across epochs
        totals = [c.total() for _, _, c in read_epochs(out, copy_cumulative=True)]
        assert totals == sorted(totals)


class TestDaemonStatusJson:
    def test_tree_json_profile_roundtrip(self, tmp_path):
        # load_profile on a daemon-style out dir without a timeline falls
        # back to tree.json
        from repro.profilerd.__main__ import load_profile

        out = tmp_path / "out"
        out.mkdir()
        t = sample_tree()
        (out / "tree.json").write_text(t.to_json())
        assert load_profile(str(out)).root == t.root
