"""shard_map EP MoE vs dense-dispatch MoE: numeric equivalence on a real
multi-device mesh (subprocess: device-count forcing must precede jax init)."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from dataclasses import replace
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models.moe import moe, moe_spec
from repro.models.moe_shard_map import moe_shard_map
from repro.models.modules import init_params
from repro.sharding.ctx import sharding_ctx
from repro.launch.mesh import axis_types_kw

cfg = get_config("deepseek-moe-16b", smoke=True)
# high capacity so neither path drops tokens -> exact equivalence expected
cfg = replace(cfg, capacity_factor=8.0, n_shared_experts=0)
mesh = jax.make_mesh((2, 4), ("data", "model"), **axis_types_kw(2))
params = init_params(moe_spec(cfg), jax.random.key(0))
B, S = 4, 16
x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model), jnp.float32)

with mesh, sharding_ctx(mesh, {"batch": ("data",), "expert_buf": "model"}):
    y_dense, aux_d = jax.jit(lambda p, x: moe(p, x, cfg))(params, x)
    y_ep, aux_e = jax.jit(
        lambda p, x: moe_shard_map(p, x, cfg, mesh=mesh, data_axes=("data",))
    )(params, x)

err = float(jnp.abs(y_dense - y_ep).max())
rel = err / float(jnp.abs(y_dense).max())
print("MAXERR", err, "REL", rel)
print("LB", float(aux_d["lb_loss"]), float(aux_e["lb_loss"]))
print("DROP", float(aux_d["dropped_frac"]), float(aux_e["dropped_frac"]))
assert rel < 2e-5, (err, rel)
assert abs(float(aux_d["lb_loss"]) - float(aux_e["lb_loss"])) < 1e-4
assert float(aux_e["dropped_frac"]) == 0.0
print("OK")
"""


@pytest.mark.slow
def test_shard_map_moe_matches_dense_on_8_devices():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        cwd=REPO,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        capture_output=True,
        text=True,
        timeout=420,
    )
    assert r.returncode == 0, r.stdout[-3000:] + "\n" + r.stderr[-3000:]
    assert "OK" in r.stdout
