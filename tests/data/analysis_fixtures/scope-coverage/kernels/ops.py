"""Seeded violation: a public kernel wrapper that never opens named_scope."""

import jax


def flash_attention(q, k, v):  # SEEDED: public wrapper, no named_scope
    return q @ k.T @ v


def covered_op(x, *, scope="covered"):  # control: must NOT be flagged
    with jax.named_scope(scope):
        return x * 2


def _private_helper(x):  # control: private, exempt
    return x
