"""Seeded violation: a model forward that accepts scope= and drops it."""

import jax


def forward(params, x, *, scope="toy"):  # SEEDED: scope accepted, never opened
    return x @ params["w"]


def good_forward(params, x, *, scope="toy"):  # control: opens the scope
    with jax.named_scope(scope):
        return x @ params["w"]


def delegating_step(params, x, *, scope="toy"):  # control: forwards scope=
    return good_forward(params, x, scope=scope)
