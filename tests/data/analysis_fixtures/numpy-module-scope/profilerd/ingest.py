"""Seeded violation: module-scope numpy import in a numpy-optional module."""

import numpy as np  # SEEDED: must be behind the lazy _numpy() probe


def decode(buf):
    return np.frombuffer(buf, dtype="<u8")
