"""Seeded violation: two threads acquire the same pair of locks in
opposite orders — a textbook deadlock the pass must flag statically."""

import threading


class Daemon:
    def __init__(self, agg):
        self._lock = threading.Lock()
        self.agg = agg

    def publish(self):
        with self._lock:  # Daemon._lock -> agg._lock
            with self.agg._lock:
                return dict(self.agg.rows)

    def push(self):
        with self.agg._lock:  # SEEDED: agg._lock -> Daemon._lock (inverted)
            with self._lock:
                return list(self.agg.rows)
