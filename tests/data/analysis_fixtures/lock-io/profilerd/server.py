"""Seeded violation: blocking I/O while holding the publish lock."""

import json
import threading


class SharedProfileState:
    def __init__(self):
        self._lock = threading.Lock()
        self._status = {}

    def update(self, status, path):
        with self._lock:
            self._status = status
            with open(path, "w") as f:  # SEEDED: file I/O under the lock
                json.dump(status, f)  # SEEDED: serialization under the lock
