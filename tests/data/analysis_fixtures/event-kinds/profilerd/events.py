"""Fixture canonical table: registers KNOWN_KIND only."""

KNOWN_KIND = "KNOWN_KIND"

EVENT_KINDS = frozenset({KNOWN_KIND})
