"""Seeded violation: emits an event kind the canonical table never heard of."""

import time


class Daemon:
    def __init__(self):
        self.events = []

    def publish(self):
        self.events.append({"kind": "KNOWN_KIND", "wall_time": time.time()})
        self.events.append(
            {"kind": "ROGUE_EVENT", "wall_time": time.time()}  # SEEDED: unregistered
        )
