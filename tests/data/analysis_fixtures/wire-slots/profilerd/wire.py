"""Seeded violation: a wire record dataclass without slots."""

from dataclasses import dataclass


@dataclass
class Sample:  # SEEDED: no slots=True, no __slots__
    t: float
    tid: int


@dataclass(slots=True)
class GoodRecord:  # control: this one must NOT be flagged
    n: int
