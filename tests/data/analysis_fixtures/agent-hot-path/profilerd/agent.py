"""Seeded violation: blocking + serializing calls inside Agent.tick."""

import json
import time


class Agent:
    def tick(self):
        frames = self._raw_stack()
        time.sleep(0.001)  # SEEDED: blocking call in the per-sample path
        return json.dumps(frames)  # SEEDED: per-sample serialization

    def _raw_stack(self):
        return []
