"""Deterministic synthetic profiling workload for the CI profile gate.

Simulates a steady serve-like host plane — a fixed set of stacks hit with
fixed weights over a fixed number of epochs, no RNG, no wall clock — so every
run (any OS, any Python >= 3.10) produces the *identical* call tree.  CI's
``profile-gate`` job runs this, seals a timeline, and ``profilerd check``s
the result against the committed baseline snapshot ``ci_baseline.snap``;
``--inject-hot-loop`` adds a synthetic regression (a spin stack stealing a
third of the samples) that the gate must reject.

``--spool`` writes the same deterministic workload as a wire-v2 *spool file*
(HELLO + interned sample ticks + BYE) instead of sealed artifacts — the shape
a multi-target ``profilerd attach --targets a.spool,b.spool`` drains, so CI
can exercise one daemon over several generated targets.

Usage::

  python tests/data/gen_workload.py --out /tmp/gate          # profile + timeline
  python tests/data/gen_workload.py --out /tmp/bad --inject-hot-loop
  python tests/data/gen_workload.py --snapshot tests/data/ci_baseline.snap
  python tests/data/gen_workload.py --spool /tmp/a.spool     # raw spool target
"""

from __future__ import annotations

import argparse
import os
import sys

if __package__ in (None, ""):  # `python tests/data/gen_workload.py`
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.core.calltree import CallTree  # noqa: E402
from repro.core.snapshot import EpochSealer, TimelineWriter, save_snapshot  # noqa: E402

EPOCHS = 8
TICKS_PER_EPOCH = 100

# (stack root->leaf, samples per tick) — a steady serving profile.
WORKLOAD: list[tuple[list[str], int]] = [
    (["thread::MainThread", "serve_step", "model", "attention", "scores"], 4),
    (["thread::MainThread", "serve_step", "model", "attention", "context"], 2),
    (["thread::MainThread", "serve_step", "model", "mlp", "gate_proj"], 3),
    (["thread::MainThread", "serve_step", "model", "lm_head"], 1),
    (["thread::MainThread", "serve_step", "sampler", "top_p"], 1),
    (["thread::prefetch-0", "data", "pipeline", "next_batch"], 2),
    (["thread::repro-ckpt", "checkpoint", "serialize"], 1),
]

HOT_LOOP = (["thread::MainThread", "serve_step", "spin_retry_loop"], 7)


def build(out_dir: str | None, inject_hot_loop: bool = False) -> CallTree:
    """Run the workload; when ``out_dir`` is set, also seal a timeline ring
    and dump ``tree.json`` there (the shape a daemon --out dir has)."""
    tree = CallTree()
    writer = sealer = None
    if out_dir is not None:
        os.makedirs(out_dir, exist_ok=True)
        writer = TimelineWriter(os.path.join(out_dir, "timeline"), epochs_per_segment=4)
        sealer = EpochSealer(tree, writer)
    workload = list(WORKLOAD)
    if inject_hot_loop:
        workload.append(HOT_LOOP)
    for epoch in range(EPOCHS):
        chains = []
        for _tick in range(TICKS_PER_EPOCH):
            for stack, weight in workload:
                chain = tree.path_nodes(stack)
                CallTree.add_stack_nodes(chain, float(weight))
                chains.append(chain)
        if sealer is not None:
            sealer.seal(chains, wall_time=float(epoch))
    if writer is not None:
        writer.close()
    if out_dir is not None:
        with open(os.path.join(out_dir, "tree.json"), "w") as f:
            f.write(tree.to_json())
    return tree


def write_spool(path: str, inject_hot_loop: bool = False, ticks: int = 60) -> int:
    """Emit the workload as a finished wire-v2 spool (HELLO..samples..BYE).

    Weighted stacks become ``weight`` unit samples per tick, so the drained
    tree carries the same shape as :func:`build` — deterministically (fixed
    tids, fixed timestamps).  Returns the number of samples committed.
    """
    from repro.profilerd.spool import SpoolWriter
    from repro.profilerd.wire import Encoder, RawFrame, RawSample

    workload = list(WORKLOAD)
    if inject_hot_loop:
        workload.append(HOT_LOOP)
    threads = sorted({stack[0] for stack, _ in workload})
    writer = SpoolWriter(path)
    enc = Encoder()
    writer.write(enc.encode_hello(os.getpid(), 0.01))
    n = 0
    for tick in range(ticks):
        samples = []
        for stack, weight in workload:
            thread = stack[0].split("::", 1)[1]
            tid = 1000 + threads.index(stack[0])
            frames = [RawFrame(f"/synthetic/{s}.py", s, 1) for s in stack[1:]]
            for _ in range(weight):
                samples.append(RawSample(tick * 0.01, tid, thread, frames))
        payload, fresh = enc.encode_tick(samples)
        if writer.write(payload):
            n += len(samples)
        else:
            enc.rollback(fresh)
    writer.write_bye(enc.encode_bye(ticks))
    writer.close()
    return n


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="write tree.json + timeline/ here")
    ap.add_argument("--snapshot", default=None, help="also save a .snap of the final tree")
    ap.add_argument("--spool", default=None,
                    help="write the workload as a raw wire-v2 spool file here")
    ap.add_argument("--inject-hot-loop", action="store_true",
                    help="add a synthetic regression (spin stack)")
    args = ap.parse_args(argv)
    if args.out is None and args.snapshot is None and args.spool is None:
        ap.error("need --out, --snapshot and/or --spool")
    if args.spool:
        n = write_spool(args.spool, args.inject_hot_loop)
        print(f"spool: {args.spool} ({n} samples committed)")
    if args.out is None and args.snapshot is None:
        return 0
    tree = build(args.out, args.inject_hot_loop)
    if args.snapshot:
        save_snapshot(tree, args.snapshot)
        print(f"snapshot: {args.snapshot}")
    if args.out:
        print(f"profile: {args.out} (total={tree.total():.0f} samples)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
