"""Device-plane tree tests: HLO parsing, attribution, cost metrics."""

import random

import jax
import jax.numpy as jnp
import pytest

from repro.launch.mesh import axis_types_kw

from repro.core import (
    build_device_tree,
    collective_summary,
    parse_hlo_module,
    tree_from_compiled,
)
from repro.core.hlo_tree import (
    _DTYPE_BYTES,
    DEVICE_TREE_SCHEMA,
    HloOp,
    load_device_tree,
    save_device_tree,
)


def compile_fn(fn, *args):
    return jax.jit(fn).lower(*args).compile()


class TestParser:
    def test_parse_simple_module(self):
        text = """HloModule test
ENTRY %main (p0: f32[4,8]) -> f32[4,8] {
  %p0 = f32[4,8]{1,0} parameter(0)
  ROOT %exp = f32[4,8]{1,0} exponential(%p0), metadata={op_name="jit(f)/exp"}
}
"""
        comps = parse_hlo_module(text)
        assert "main" in comps
        ops = comps["main"].ops
        assert ops["exp"].opcode == "exponential"
        assert ops["exp"].op_name == "jit(f)/exp"
        assert ops["exp"].shapes == [("f32", (4, 8))]
        assert ops["exp"].operands == ["p0"]

    def test_parse_tuple_and_trip_count(self):
        text = """HloModule test
%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]{0}) parameter(0)
  ROOT %t = (s32[], f32[8]{0}) tuple(%p)
}
%cond (p2: (s32[], f32[8])) -> pred[] {
  %p2 = (s32[], f32[8]{0}) parameter(0)
  ROOT %lt = pred[] constant(true)
}
ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8]{0} parameter(0)
  %init = (s32[], f32[8]{0}) tuple(%a)
  %w = (s32[], f32[8]{0}) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %out = f32[8]{0} get-tuple-element(%w), index=1
}
"""
        comps = parse_hlo_module(text)
        w = comps["main"].ops["w"]
        assert w.opcode == "while"
        assert w.trip_count == 12
        assert "body" in w.called and "cond" in w.called

    def test_real_compiled_module_parses(self):
        def f(x, w):
            with jax.named_scope("mlp"):
                return jax.nn.relu(x @ w).sum()

        comp = compile_fn(f, jnp.ones((8, 16)), jnp.ones((16, 32)))
        comps = parse_hlo_module(comp.as_text())
        assert comps
        all_ops = [op for c in comps.values() for op in c.ops.values()]
        assert any(op.opcode == "dot" for op in all_ops)


class TestAttribution:
    def test_named_scope_paths_in_tree(self):
        def f(x, w1, w2):
            with jax.named_scope("layer0"):
                with jax.named_scope("mlp"):
                    h = jax.nn.relu(x @ w1)
            with jax.named_scope("head"):
                return (h @ w2).sum()

        comp = compile_fn(f, jnp.ones((8, 16)), jnp.ones((16, 32)), jnp.ones((32, 4)))
        tree = tree_from_compiled(comp)
        flat = tree.flatten("flops")
        assert flat.get("mlp", 0) > 0
        assert flat.get("head", 0) > 0

    def test_dot_flops_exact(self):
        def f(x, w):
            return x @ w

        m, k, n = 8, 16, 32
        comp = compile_fn(f, jnp.ones((m, k)), jnp.ones((k, n)))
        tree = tree_from_compiled(comp)
        assert tree.total("flops") == pytest.approx(2 * m * k * n)

    def test_flops_match_xla_cost_analysis(self):
        def f(x, w1, w2):
            return ((x @ w1) @ w2).sum()

        comp = compile_fn(f, jnp.ones((32, 64)), jnp.ones((64, 128)), jnp.ones((128, 16)))
        tree = tree_from_compiled(comp)
        ca = comp.cost_analysis()
        if isinstance(ca, (list, tuple)):  # older jax returns [per-device dict]
            ca = ca[0]
        # Dots dominate; our dot-only count must be within 5% of XLA's total.
        assert tree.total("flops") == pytest.approx(float(ca["flops"]), rel=0.05)

    def test_scan_trip_count_multiplies(self):
        n_layers = 7

        def layer(x, w):
            return jnp.tanh(x @ w)

        def f(x, ws):
            def body(c, w):
                return layer(c, w), None

            y, _ = jax.lax.scan(body, x, ws)
            return y.sum()

        d = 16
        comp = compile_fn(f, jnp.ones((4, d)), jnp.ones((n_layers, d, d)))
        tree = tree_from_compiled(comp)
        got = tree.total("flops")
        want = n_layers * 2 * 4 * d * d
        assert got == pytest.approx(want, rel=0.01)

    def test_bytes_metric_positive_and_sane(self):
        def f(x):
            return (x * 2.0).sum()

        x = jnp.ones((1024, 1024), jnp.float32)
        comp = compile_fn(f, x)
        tree = tree_from_compiled(comp)
        b = tree.total("bytes")
        assert b >= x.size * 4  # must at least read the input
        assert b < 20 * x.size * 4  # and not wildly overcount

    def test_unattributed_ops_bucketed(self):
        text = """HloModule t
ENTRY %main (p0: f32[4]) -> f32[4] {
  %p0 = f32[4]{0} parameter(0)
  ROOT %c = f32[4]{0} copy(%p0)
}
"""
        tree = build_device_tree(text)
        assert "<unattributed>" in tree.root.children


class TestCollectives:
    def make_sharded(self):
        from jax.sharding import NamedSharding, PartitionSpec as P

        if len(jax.devices()) < 2:
            pytest.skip("needs >1 device (run under forced host device count)")
        mesh = jax.make_mesh((2,), ("model",), **axis_types_kw(1))

        def f(x, w):
            return (x @ w).sum()

        xs = jax.ShapeDtypeStruct((8, 16), jnp.float32)
        ws = jax.ShapeDtypeStruct((16, 32), jnp.float32)
        with mesh:
            return (
                jax.jit(
                    f,
                    in_shardings=(
                        NamedSharding(mesh, P(None, "model")),
                        NamedSharding(mesh, P("model", None)),
                    ),
                )
                .lower(xs, ws)
                .compile()
            )

    def test_collective_bytes_counted(self):
        comp = self.make_sharded()
        tree = tree_from_compiled(comp)
        summ = collective_summary(tree)
        # Contracting-dim sharding forces an all-reduce of the f32 partial sums.
        assert summ["total"] > 0
        assert summ.get("all-reduce", 0) > 0

    def test_collective_attribution_under_op_name(self):
        comp = self.make_sharded()
        tree = tree_from_compiled(comp)
        colls = [p for p, n in tree.root.walk() if n.metrics.get("coll_bytes")]
        assert colls  # attributed somewhere under the jit scope, not lost


class TestDtypeBytes:
    @pytest.mark.parametrize("dtype,size", [("bf16", 2), ("f32", 4), ("s8", 1), ("pred", 1), ("f64", 8)])
    def test_table(self, dtype, size):
        assert _DTYPE_BYTES[dtype] == size

    def test_result_bytes_tuple(self):
        op = HloOp("t", "tuple", [("f32", (4, 4)), ("bf16", (8,))], [], None)
        assert op.result_bytes() == 4 * 4 * 4 + 8 * 2


class TestRoundtrip:
    """save_device_tree/load_device_tree must be bit-exact on every metric.

    Property-style: generated modules with *nested* scanned layers (while
    loops carrying known_trip_count) and rng-chosen dims/trip counts, so the
    metric values exercise awkward trip-count-multiplied floats rather than a
    hand-picked happy path.
    """

    @staticmethod
    def _module(t0: int, t1: int, m: int, k: int, n: int, w: int) -> str:
        return f"""HloModule gen
%body1 (p1: (s32[], f32[{w}])) -> (s32[], f32[{w}]) {{
  %p1 = (s32[], f32[{w}]{{0}}) parameter(0)
  %a1 = f32[{m},{k}]{{1,0}} get-tuple-element(%p1), index=1
  %b1 = f32[{k},{n}]{{1,0}} get-tuple-element(%p1), index=1
  %d1 = f32[{m},{n}]{{1,0}} dot(%a1, %b1), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}, metadata={{op_name="jit(step)/layers/inner/mlp"}}
  %ar1 = f32[{n}]{{0}} all-reduce(%d1), metadata={{op_name="jit(step)/layers/inner/psum"}}
  %ds1 = f32[1,{n}]{{1,0}} dynamic-slice(%d1, %p1), dynamic_slice_sizes={{1,{n}}}, metadata={{op_name="jit(step)/layers/inner/slice"}}
  ROOT %t1 = (s32[], f32[{w}]{{0}}) tuple(%p1)
}}
%cond1 (q1: (s32[], f32[{w}])) -> pred[] {{
  %q1 = (s32[], f32[{w}]{{0}}) parameter(0)
  ROOT %lt1 = pred[] constant(true)
}}
%body0 (p0: (s32[], f32[{w}])) -> (s32[], f32[{w}]) {{
  %p0 = (s32[], f32[{w}]{{0}}) parameter(0)
  %a0 = f32[{m},{k}]{{1,0}} get-tuple-element(%p0), index=1
  %b0 = f32[{k},{n}]{{1,0}} get-tuple-element(%p0), index=1
  %d0 = f32[{m},{n}]{{1,0}} dot(%a0, %b0), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}, metadata={{op_name="jit(step)/layers/outer_mlp"}}
  %init1 = (s32[], f32[{w}]{{0}}) tuple(%p0)
  %w1 = (s32[], f32[{w}]{{0}}) while(%init1), condition=%cond1, body=%body1, backend_config={{"known_trip_count":{{"n":"{t1}"}}}}, metadata={{op_name="jit(step)/layers/inner_scan"}}
  ROOT %t0 = (s32[], f32[{w}]{{0}}) tuple(%p0)
}}
%cond0 (q0: (s32[], f32[{w}])) -> pred[] {{
  %q0 = (s32[], f32[{w}]{{0}}) parameter(0)
  ROOT %lt0 = pred[] constant(true)
}}
ENTRY %main (x: f32[{w}]) -> f32[{w}] {{
  %x = f32[{w}]{{0}} parameter(0)
  %init0 = (s32[], f32[{w}]{{0}}) tuple(%x)
  %w0 = (s32[], f32[{w}]{{0}}) while(%init0), condition=%cond0, body=%body0, backend_config={{"known_trip_count":{{"n":"{t0}"}}}}, metadata={{op_name="jit(step)/layers_scan"}}
  ROOT %out = f32[{w}]{{0}} get-tuple-element(%w0), index=1
}}
"""

    @staticmethod
    def _snapshot(tree):
        return {
            tuple(path): (dict(node.metrics), dict(node.self_metrics))
            for path, node in tree.root.walk()
        }

    @pytest.mark.parametrize("seed", range(5))
    def test_save_load_exact(self, seed, tmp_path):
        rng = random.Random(seed)
        t0, t1 = rng.randint(2, 13), rng.randint(2, 9)
        m, k, n = rng.randint(3, 37), rng.randint(3, 37), rng.randint(3, 37)
        tree = build_device_tree(self._module(t0, t1, m, k, n, rng.randint(5, 101)))
        # The generated module must exercise all four metric keys + a per-kind
        # collective counter before the roundtrip assertion means anything.
        root = tree.root.metrics
        for key in ("flops", "bytes", "coll_bytes", "ops"):
            assert root.get(key, 0) > 0, key
        assert root.get("coll_bytes::all-reduce", 0) > 0

        path = str(tmp_path / "device_tree.json")
        save_device_tree(tree, path, meta={"seed": seed})
        loaded = load_device_tree(path)
        assert self._snapshot(loaded) == self._snapshot(tree)  # exact, every key

    def test_nested_trip_counts_multiply_exactly(self):
        base = build_device_tree(self._module(1, 1, 8, 16, 4, 64))
        scaled = build_device_tree(self._module(5, 3, 8, 16, 4, 64))
        bf, sf = base.flatten("flops"), scaled.flatten("flops")
        # inner dot sits under both whiles: x(5*3); outer dot under one: x5
        assert sf["mlp"] == pytest.approx(15 * bf["mlp"], rel=0, abs=0)
        assert sf["outer_mlp"] == pytest.approx(5 * bf["outer_mlp"], rel=0, abs=0)
        bc, sc = base.total("coll_bytes"), scaled.total("coll_bytes")
        assert sc == 15 * bc

    def test_envelope_schema_and_legacy(self, tmp_path):
        import json

        tree = build_device_tree(self._module(2, 2, 4, 4, 4, 8))
        path = str(tmp_path / "device_tree.json")
        save_device_tree(tree, path)
        with open(path) as f:
            doc = json.load(f)
        assert doc["schema"] == DEVICE_TREE_SCHEMA
        # legacy bare-root dumps (pre-envelope) still load
        legacy = str(tmp_path / "legacy.json")
        with open(legacy, "w") as f:
            json.dump(doc["root"], f)
        assert self._snapshot(load_device_tree(legacy)) == self._snapshot(tree)
