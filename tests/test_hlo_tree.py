"""Device-plane tree tests: HLO parsing, attribution, cost metrics."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    build_device_tree,
    collective_summary,
    parse_hlo_module,
    tree_from_compiled,
)
from repro.core.hlo_tree import _DTYPE_BYTES, HloOp


def compile_fn(fn, *args):
    return jax.jit(fn).lower(*args).compile()


class TestParser:
    def test_parse_simple_module(self):
        text = """HloModule test
ENTRY %main (p0: f32[4,8]) -> f32[4,8] {
  %p0 = f32[4,8]{1,0} parameter(0)
  ROOT %exp = f32[4,8]{1,0} exponential(%p0), metadata={op_name="jit(f)/exp"}
}
"""
        comps = parse_hlo_module(text)
        assert "main" in comps
        ops = comps["main"].ops
        assert ops["exp"].opcode == "exponential"
        assert ops["exp"].op_name == "jit(f)/exp"
        assert ops["exp"].shapes == [("f32", (4, 8))]
        assert ops["exp"].operands == ["p0"]

    def test_parse_tuple_and_trip_count(self):
        text = """HloModule test
%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]{0}) parameter(0)
  ROOT %t = (s32[], f32[8]{0}) tuple(%p)
}
%cond (p2: (s32[], f32[8])) -> pred[] {
  %p2 = (s32[], f32[8]{0}) parameter(0)
  ROOT %lt = pred[] constant(true)
}
ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8]{0} parameter(0)
  %init = (s32[], f32[8]{0}) tuple(%a)
  %w = (s32[], f32[8]{0}) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %out = f32[8]{0} get-tuple-element(%w), index=1
}
"""
        comps = parse_hlo_module(text)
        w = comps["main"].ops["w"]
        assert w.opcode == "while"
        assert w.trip_count == 12
        assert "body" in w.called and "cond" in w.called

    def test_real_compiled_module_parses(self):
        def f(x, w):
            with jax.named_scope("mlp"):
                return jax.nn.relu(x @ w).sum()

        comp = compile_fn(f, jnp.ones((8, 16)), jnp.ones((16, 32)))
        comps = parse_hlo_module(comp.as_text())
        assert comps
        all_ops = [op for c in comps.values() for op in c.ops.values()]
        assert any(op.opcode == "dot" for op in all_ops)


class TestAttribution:
    def test_named_scope_paths_in_tree(self):
        def f(x, w1, w2):
            with jax.named_scope("layer0"):
                with jax.named_scope("mlp"):
                    h = jax.nn.relu(x @ w1)
            with jax.named_scope("head"):
                return (h @ w2).sum()

        comp = compile_fn(f, jnp.ones((8, 16)), jnp.ones((16, 32)), jnp.ones((32, 4)))
        tree = tree_from_compiled(comp)
        flat = tree.flatten("flops")
        assert flat.get("mlp", 0) > 0
        assert flat.get("head", 0) > 0

    def test_dot_flops_exact(self):
        def f(x, w):
            return x @ w

        m, k, n = 8, 16, 32
        comp = compile_fn(f, jnp.ones((m, k)), jnp.ones((k, n)))
        tree = tree_from_compiled(comp)
        assert tree.total("flops") == pytest.approx(2 * m * k * n)

    def test_flops_match_xla_cost_analysis(self):
        def f(x, w1, w2):
            return ((x @ w1) @ w2).sum()

        comp = compile_fn(f, jnp.ones((32, 64)), jnp.ones((64, 128)), jnp.ones((128, 16)))
        tree = tree_from_compiled(comp)
        ca = comp.cost_analysis()
        # Dots dominate; our dot-only count must be within 5% of XLA's total.
        assert tree.total("flops") == pytest.approx(float(ca["flops"]), rel=0.05)

    def test_scan_trip_count_multiplies(self):
        n_layers = 7

        def layer(x, w):
            return jnp.tanh(x @ w)

        def f(x, ws):
            def body(c, w):
                return layer(c, w), None

            y, _ = jax.lax.scan(body, x, ws)
            return y.sum()

        d = 16
        comp = compile_fn(f, jnp.ones((4, d)), jnp.ones((n_layers, d, d)))
        tree = tree_from_compiled(comp)
        got = tree.total("flops")
        want = n_layers * 2 * 4 * d * d
        assert got == pytest.approx(want, rel=0.01)

    def test_bytes_metric_positive_and_sane(self):
        def f(x):
            return (x * 2.0).sum()

        x = jnp.ones((1024, 1024), jnp.float32)
        comp = compile_fn(f, x)
        tree = tree_from_compiled(comp)
        b = tree.total("bytes")
        assert b >= x.size * 4  # must at least read the input
        assert b < 20 * x.size * 4  # and not wildly overcount

    def test_unattributed_ops_bucketed(self):
        text = """HloModule t
ENTRY %main (p0: f32[4]) -> f32[4] {
  %p0 = f32[4]{0} parameter(0)
  ROOT %c = f32[4]{0} copy(%p0)
}
"""
        tree = build_device_tree(text)
        assert "<unattributed>" in tree.root.children


class TestCollectives:
    def make_sharded(self):
        from jax.sharding import NamedSharding, PartitionSpec as P

        if len(jax.devices()) < 2:
            pytest.skip("needs >1 device (run under forced host device count)")
        mesh = jax.make_mesh((2,), ("model",), axis_types=(jax.sharding.AxisType.Auto,))

        def f(x, w):
            return (x @ w).sum()

        xs = jax.ShapeDtypeStruct((8, 16), jnp.float32)
        ws = jax.ShapeDtypeStruct((16, 32), jnp.float32)
        with mesh:
            return (
                jax.jit(
                    f,
                    in_shardings=(
                        NamedSharding(mesh, P(None, "model")),
                        NamedSharding(mesh, P("model", None)),
                    ),
                )
                .lower(xs, ws)
                .compile()
            )

    def test_collective_bytes_counted(self):
        comp = self.make_sharded()
        tree = tree_from_compiled(comp)
        summ = collective_summary(tree)
        # Contracting-dim sharding forces an all-reduce of the f32 partial sums.
        assert summ["total"] > 0
        assert summ.get("all-reduce", 0) > 0

    def test_collective_attribution_under_op_name(self):
        comp = self.make_sharded()
        tree = tree_from_compiled(comp)
        colls = [p for p, n in tree.root.walk() if n.metrics.get("coll_bytes")]
        assert colls  # attributed somewhere under the jit scope, not lost


class TestDtypeBytes:
    @pytest.mark.parametrize("dtype,size", [("bf16", 2), ("f32", 4), ("s8", 1), ("pred", 1), ("f64", 8)])
    def test_table(self, dtype, size):
        assert _DTYPE_BYTES[dtype] == size

    def test_result_bytes_tuple(self):
        op = HloOp("t", "tuple", [("f32", (4, 4)), ("bf16", (8,))], [], None)
        assert op.result_bytes() == 4 * 4 * 4 + 8 * 2
