"""Dry-run machinery tests.

The full production sweeps run via the CLI (results/ records); here we verify
the machinery end-to-end in a subprocess (XLA device-count forcing must happen
before jax init, hence no in-process test) on the cheapest real cells, plus
unit-test the pieces that don't need 512 devices.
"""

import json
import os
import subprocess
import sys

import pytest

from repro.launch.mesh import axis_types_kw

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def run_dryrun(args, timeout=420):
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        cwd=REPO, env=ENV, capture_output=True, text=True, timeout=timeout,
    )


@pytest.mark.slow
def test_dryrun_cheapest_cell_single_pod(tmp_path):
    r = run_dryrun(
        ["--arch", "xlstm-125m", "--shape", "decode_32k", "--mesh", "single", "--out", str(tmp_path)]
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    cell = json.load(open(tmp_path / "xlstm-125m__decode_32k__16x16__tp_fsdp.json"))
    assert cell["status"] == "ok"
    assert cell["chips"] == 256
    assert cell["roofline"]["t_step_s"] > 0
    assert cell["memory_analysis"]["fits_hbm_16g"]
    assert cell["tree_metrics"]["ops"] > 0


@pytest.mark.slow
def test_dryrun_multi_pod_mesh_shards_pod_axis(tmp_path):
    r = run_dryrun(
        ["--arch", "xlstm-125m", "--shape", "decode_32k", "--mesh", "multi", "--out", str(tmp_path)]
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    cell = json.load(open(tmp_path / "xlstm-125m__decode_32k__2x16x16__tp_fsdp.json"))
    assert cell["status"] == "ok"
    assert cell["chips"] == 512


def test_skip_rule_for_full_attention_long_context():
    from repro.launch.dryrun import run_cell

    # applicability check happens before any mesh/jax work
    cell = run_cell("qwen3-4b", "long_500k", False, verbose=False)
    assert cell["status"] == "skip"
    assert "quadratic" in cell["reason"]


def test_batch_shardings_shard_batch_dim_only():
    import jax

    import jax.numpy as jnp

    from repro.launch.dryrun import batch_shardings

    class MeshStub:
        shape = {"data": 2, "model": 1}

    # real 1-device mesh for NamedSharding construction
    mesh = jax.make_mesh((1, 1), ("data", "model"), **axis_types_kw(2))
    batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    sh = batch_shardings(batch, mesh, ("data",))
    assert sh["tokens"].spec[0] in ("data", ("data",))


def test_state_shardings_prefer_head_axis():
    import jax
    import jax.numpy as jnp

    from repro.launch.dryrun import state_shardings

    mesh = jax.make_mesh((1, 1), ("data", "model"), **axis_types_kw(2))
    state = {"scan": {"block0": {"k": jax.ShapeDtypeStruct((12, 4, 128, 16, 64), jnp.bfloat16)}}}
    sh = state_shardings(state, mesh, ("data",))
    spec = sh["scan"]["block0"]["k"].spec
    assert spec[0] is None  # layer-stack axis unsharded
    assert spec[1] in ("data", ("data",))  # batch
    assert spec[3] == "model"  # heads
