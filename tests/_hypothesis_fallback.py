"""Minimal stand-in for ``hypothesis`` when it is not installed.

The property tests in this suite only use a small strategy subset
(``lists``/``sampled_from``/``integers``) plus ``@given``/``@settings``.  When
the real library is available the test modules import it; otherwise they fall
back to this shim, which replays each property over a fixed number of
deterministically-seeded random examples.  That keeps the properties exercised
everywhere (CI images without the ``[test]`` extra included) instead of
skipping whole modules.
"""

from __future__ import annotations

import functools
import inspect
import random
from collections.abc import Callable
from typing import Any

_DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    """A strategy is just a draw function: rng -> value."""

    def __init__(self, draw: Callable[[random.Random], Any]):
        self.draw = draw


class strategies:  # mirrors `from hypothesis import strategies as st`
    @staticmethod
    def sampled_from(seq) -> _Strategy:
        items = list(seq)
        return _Strategy(lambda rng: items[rng.randrange(len(items))])

    @staticmethod
    def integers(min_value: int = 0, max_value: int = 1 << 16) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def lists(elem: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
        return _Strategy(
            lambda rng: [elem.draw(rng) for _ in range(rng.randint(min_size, max_size))]
        )


def given(*strategies_: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
            for i in range(n):
                rng = random.Random(0xC0FFEE + i)
                drawn = [s.draw(rng) for s in strategies_]
                fn(*args, *drawn, **kwargs)

        wrapper._hypothesis_fallback = True
        # Hide the strategy-filled trailing parameters from pytest, which
        # would otherwise try to resolve them as fixtures (`self` survives).
        params = list(inspect.signature(fn).parameters.values())
        kept = params[: len(params) - len(strategies_)]
        wrapper.__signature__ = inspect.Signature(kept)
        del wrapper.__wrapped__
        return wrapper

    return deco


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco
