"""Sampler (host plane) + dominance detector tests (paper §III-D, §V-D)."""

import threading
import time


from repro.core import (
    CallTree,
    DominanceDetector,
    Rule,
    SamplerConfig,
    StackSampler,
    StragglerDetector,
    WatchdogLoop,
)


def spin_named(stop_evt, fn_name="injected_livelock_spin"):
    """A busy loop with a recognizable frame name (the Fig. 13 injection)."""
    d = {}
    exec(
        f"def {fn_name}(stop_evt):\n"
        f"    x = 0\n"
        f"    while not stop_evt.is_set():\n"
        f"        x += 1\n",
        d,
    )
    d[fn_name](stop_evt)


class TestSampler:
    def test_captures_known_hot_function(self):
        stop = threading.Event()
        t = threading.Thread(target=spin_named, args=(stop,), daemon=True)
        t.start()
        s = StackSampler(SamplerConfig(period_s=0.01))
        s.start()
        time.sleep(0.4)
        tree = s.stop()
        stop.set()
        t.join()
        flat = tree.flatten()
        hot = [k for k in flat if "injected_livelock_spin" in k]
        assert hot, f"spin frame not captured; saw {sorted(flat)[:20]}"

    def test_sampler_is_external_no_instrumentation(self):
        """The profiled function body contains no profiler calls at all."""
        s = StackSampler(SamplerConfig(period_s=0.01))
        acc = 0.0

        def workload():
            nonlocal acc
            t0 = time.monotonic()
            i = 0
            while time.monotonic() - t0 < 0.15:  # run past several periods
                acc += i * 0.5
                i += 1

        with s:
            workload()
        assert s.n_samples >= 1
        assert acc > 0

    def test_timeline_depth_trace(self):
        s = StackSampler(SamplerConfig(period_s=0.005))
        with s:
            time.sleep(0.1)
        trace = s.depth_trace()
        assert trace and all(d >= 1 for _, d in trace)

    def test_snapshot_is_isolated_copy(self):
        s = StackSampler(SamplerConfig(period_s=10))
        s.sample_now()
        snap = s.snapshot()
        s.sample_now()
        assert s.snapshot().total() > snap.total()

    def test_collapse_origins(self):
        cfg = SamplerConfig(period_s=10, collapse_origins=("py",))
        s = StackSampler(cfg)
        s.sample_now()
        tree = s.snapshot()
        names = set(tree.flatten())
        # All non-repro/jax frames collapse into py::* bookkeeping nodes.
        assert any(n == "py::*" for n in names)


class TestDetector:
    def make_snapshots(self, dominant_share, n_windows=3, window=100):
        """Cumulative snapshots where `spin` takes dominant_share of each window."""
        t = CallTree()
        snaps = []
        for _ in range(n_windows):
            for i in range(window):
                if i < dominant_share * window:
                    t.add_stack(["main", "step", "spin"])
                else:
                    t.add_stack(["main", "step", f"other{i % 7}"])
            snaps.append(t.copy())
        return snaps

    def test_fires_above_threshold(self):
        det = DominanceDetector([Rule(threshold=0.9)])
        fired = []
        det.add_callback(fired.append)
        for snap in self.make_snapshots(0.95):
            det.observe(snap)
        assert fired and fired[0].share >= 0.9
        assert fired[0].path[-1] == "spin"

    def test_silent_below_threshold(self):
        det = DominanceDetector([Rule(threshold=0.9)])
        for snap in self.make_snapshots(0.5):
            assert det.observe(snap) == []

    def test_consecutive_windows_requirement(self):
        det = DominanceDetector([Rule(threshold=0.9, consecutive=3)])
        snaps = self.make_snapshots(0.95, n_windows=3)
        assert det.observe(snaps[0]) == []
        assert det.observe(snaps[1]) == []
        assert len(det.observe(snaps[2])) == 1

    def test_windowing_detects_fresh_anomaly_after_long_healthy_run(self):
        """A long healthy history must not dilute a new livelock (why diff())."""
        t = CallTree()
        for i in range(10000):
            t.add_stack(["main", "step", f"healthy{i % 13}"])
        det = DominanceDetector([Rule(threshold=0.9)])
        det.observe(t.copy())
        for _ in range(200):
            t.add_stack(["main", "step", "stuck_collective_wait"])
        events = det.observe(t.copy())
        assert events and events[0].path[-1] == "stuck_collective_wait"

    def test_pattern_scoped_rule(self):
        det = DominanceDetector([Rule(pattern="ruby", threshold=0.5)])
        t = CallTree()
        for _ in range(100):
            t.add_stack(["main", "not_matching_spin"])
        assert det.observe(t.copy()) == []
        det2 = DominanceDetector([Rule(pattern="ruby", threshold=0.5)])
        t2 = CallTree()
        for _ in range(100):
            t2.add_stack(["main", "ruby_recycle"])
        assert len(det2.observe(t2.copy())) == 1

    def test_min_window_total_guards_empty_windows(self):
        det = DominanceDetector([Rule(threshold=0.9, min_window_total=10)])
        t = CallTree()
        t.add_stack(["only", "one"])
        assert det.observe(t.copy()) == []

    def test_checkpoint_trigger_callback(self):
        """The paper's warn+checkpoint flow: callback ordering is respected."""
        order = []
        det = DominanceDetector(
            [Rule(threshold=0.8)],
            on_anomaly=[lambda e: order.append("warn"), lambda e: order.append("checkpoint")],
        )
        for snap in self.make_snapshots(0.95, n_windows=1):
            det.observe(snap)
        assert order == ["warn", "checkpoint"]


class TestStraggler:
    def test_flags_divergent_host(self):
        healthy = CallTree()
        for i in range(300):
            healthy.add_stack(["step", "compute", f"op{i % 5}"])
        straggler = CallTree()
        for _ in range(300):
            straggler.add_stack(["step", "allreduce_wait"])
        hosts = {f"host{i}": healthy.copy() for i in range(7)}
        hosts["host7"] = straggler
        flagged = StragglerDetector(threshold=0.4).observe(hosts)
        assert [h for h, _ in flagged] == ["host7"]

    def test_uniform_fleet_is_silent(self):
        healthy = CallTree()
        for i in range(300):
            healthy.add_stack(["step", "compute", f"op{i % 5}"])
        hosts = {f"host{i}": healthy.copy() for i in range(8)}
        assert StragglerDetector(threshold=0.2).observe(hosts) == []


class TestWatchdogIntegration:
    def test_end_to_end_livelock_detection(self):
        """Inject a spin (Fig. 13), sampler+watchdog flag it and 'checkpoint'."""
        stop = threading.Event()
        worker = threading.Thread(target=spin_named, args=(stop,), daemon=True)
        worker.start()
        sampler = StackSampler(SamplerConfig(period_s=0.01))
        events = []
        det = DominanceDetector(
            # Threshold is deliberately low: ambient interpreter threads (pytest
            # plugins etc.) share the sample budget with the spinning worker.
            [Rule(pattern="injected_livelock_spin", threshold=0.20, min_window_total=2, self_only=False)],
            on_anomaly=[events.append],
        )
        wd = WatchdogLoop(sampler, det, interval_s=0.08)
        sampler.start()
        wd.start()
        time.sleep(0.8)
        wd.stop()
        sampler.stop()
        stop.set()
        worker.join()
        assert events, "watchdog failed to flag injected livelock"
        assert any("injected_livelock_spin" in p for p in events[0].path)
