"""Unified host+device plane tests: annotation, serving, CLI, timeline gating.

Covers the merge layer (``core/planes.py``), the ``?plane=`` query plane, the
CLI ``--plane`` flag, and the acceptance contract that merged-plane annotation
metrics survive the timeline seal -> decode -> diff roundtrip and can gate a
``profilerd check`` run.
"""

import json
import os
import subprocess
import sys
import urllib.error
import urllib.request

import pytest

from repro.core import CallTree, EpochMeta, TimelineReader, TimelineWriter, share_regressions
from repro.core.export import export_tree, from_folded, to_folded, to_speedscope
from repro.core.hlo_tree import build_device_tree, save_device_tree
from repro.core.planes import (
    DOMINANT_PREFIX,
    HLO_PREFIX,
    OCCUPANCY,
    PLANES,
    PlaneError,
    annotate_tree,
    default_metric,
    dominant_term,
    missing_device_hint,
    select_plane,
)

SRC_ROOT = os.path.join(os.path.dirname(__file__), "..", "src")

# Hand-written compiled-HLO text whose op_name paths mirror the host stacks
# below (scores/gate_proj are compute-heavy dots, top_p is a pure-traffic
# slice, lm_head carries an all-reduce -> three distinct dominant terms).
HLO_TEXT = """HloModule m
ENTRY %main (p0: f32[4096,4096], p1: f32[4096,4096], p2: f32[4096,4096]) -> f32[4096,4096] {
  %p0 = f32[4096,4096]{1,0} parameter(0)
  %p1 = f32[4096,4096]{1,0} parameter(1)
  %p2 = f32[4096,4096]{1,0} parameter(2)
  %scores = f32[4096,4096]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}, metadata={op_name="jit(serve_step)/model/attention/scores"}
  %context = f32[4096,4096]{1,0} dot(%scores, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}, metadata={op_name="jit(serve_step)/model/attention/context"}
  %gate = f32[4096,4096]{1,0} dot(%scores, %context), lhs_contracting_dims={1}, rhs_contracting_dims={0}, metadata={op_name="jit(serve_step)/model/mlp/gate_proj"}
  %hs = f32[64,64]{1,0} dynamic-slice(%gate, %p0), dynamic_slice_sizes={64,64}, metadata={op_name="jit(serve_step)/model/lm_head"}
  %head = f32[64,64]{1,0} dot(%hs, %hs), lhs_contracting_dims={1}, rhs_contracting_dims={0}, metadata={op_name="jit(serve_step)/model/lm_head"}
  %ar = f32[4096,4096]{1,0} all-reduce(%p2), metadata={op_name="jit(serve_step)/model/lm_head"}
  %tp = f32[1,64]{1,0} dynamic-slice(%gate, %p0), dynamic_slice_sizes={1,64}, metadata={op_name="jit(serve_step)/sampler/top_p"}
  ROOT %out = f32[4096,4096]{1,0} copy(%ar), metadata={op_name="jit(serve_step)/out"}
}
"""


def device_tree() -> CallTree:
    return build_device_tree(HLO_TEXT)


def host_tree() -> CallTree:
    """A daemon-shaped host tree: frames carry spool origin prefixes."""
    t = CallTree()
    stacks = [
        (["thread::MainThread", "py::serve_step", "py::model", "py::attention", "py::scores"], 40),
        (["thread::MainThread", "py::serve_step", "py::model", "py::attention", "py::context"], 10),
        (["thread::MainThread", "py::serve_step", "py::model", "py::mlp", "py::gate_proj"], 30),
        (["thread::MainThread", "py::serve_step", "py::model", "py::lm_head"], 15),
        (["thread::MainThread", "py::serve_step", "py::sampler", "py::top_p"], 5),
    ]
    for frames, n in stacks:
        for _ in range(n):
            t.add_stack(frames)
    return t


def _descend(tree: CallTree, *names):
    node = tree.root
    for n in names:
        node = node.children[n]
    return node


def _http_get(url: str) -> tuple[int, str]:
    try:
        with urllib.request.urlopen(url, timeout=5) as resp:
            return resp.status, resp.read().decode()
    except urllib.error.HTTPError as e:
        return e.code, e.read().decode()


class TestAnnotate:
    def test_origin_prefixes_match_device_paths(self):
        merged = annotate_tree(host_tree(), device_tree())
        scores = _descend(
            merged, "thread::MainThread", "py::serve_step", "py::model", "py::attention", "py::scores"
        )
        dev_scores = _descend(device_tree(), "jit(serve_step)", "model", "attention", "scores")
        assert scores.metrics[HLO_PREFIX + "flops"] == dev_scores.total("flops")
        assert scores.metrics[OCCUPANCY] > 0

    def test_root_occupancy_is_one(self):
        merged = annotate_tree(host_tree(), device_tree())
        assert merged.root.metrics[OCCUPANCY] == pytest.approx(1.0)

    def test_unmatched_glue_frames_inherit_child_sums(self):
        merged = annotate_tree(host_tree(), device_tree())
        main = _descend(merged, "thread::MainThread")
        # thread::MainThread matches nothing on the device plane but must
        # aggregate its matched descendants (monotone inclusive metrics).
        child_flops = sum(c.metrics.get(HLO_PREFIX + "flops", 0) for c in main.children.values())
        assert main.metrics[HLO_PREFIX + "flops"] == pytest.approx(child_flops)
        assert main.metrics[HLO_PREFIX + "flops"] > 0

    def test_dominant_terms_by_workload_shape(self):
        merged = annotate_tree(host_tree(), device_tree())
        pre = ("thread::MainThread", "py::serve_step")
        scores = _descend(merged, *pre, "py::model", "py::attention", "py::scores")
        top_p = _descend(merged, *pre, "py::sampler", "py::top_p")
        lm_head = _descend(merged, *pre, "py::model", "py::lm_head")
        assert dominant_term(scores.metrics) == "compute"  # dot-only node
        assert dominant_term(top_p.metrics) == "memory"  # pure-slice node
        assert dominant_term(lm_head.metrics) == "collective"  # all-reduce
        # exactly one dominant::<term> key per annotated node
        for node in (scores, top_p, lm_head):
            assert sum(1 for k in node.metrics if k.startswith(DOMINANT_PREFIX)) == 1

    def test_annotations_survive_json_roundtrip(self):
        merged = annotate_tree(host_tree(), device_tree())
        back = CallTree.from_json(merged.to_json())
        for (path, node), (bpath, bnode) in zip(merged.root.walk(), back.root.walk(), strict=True):
            assert tuple(path) == tuple(bpath)
            assert dict(node.metrics) == dict(bnode.metrics)

    def test_host_tree_not_mutated(self):
        host = host_tree()
        before = host.to_json()
        annotate_tree(host, device_tree())
        assert host.to_json() == before


class TestSelectPlane:
    def test_host_passthrough(self):
        host = host_tree()
        assert select_plane(host, None, "host") is host

    def test_unknown_plane_is_value_error(self):
        with pytest.raises(ValueError, match="unknown plane"):
            select_plane(host_tree(), None, "bogus")

    def test_missing_device_artifact_raises_with_remedy(self):
        for plane in ("device", "merged"):
            with pytest.raises(PlaneError, match="device_tree.json"):
                select_plane(host_tree(), None, plane, profile="/some/profile")
        hint = missing_device_hint("/some/profile")
        assert "dryrun" in hint and "/some/profile" in hint

    def test_device_default_metric_is_flops(self):
        assert default_metric("device", None) == "flops"
        assert default_metric("device", "bytes") == "bytes"
        assert default_metric("merged", None) is None
        assert default_metric("host", None) is None


class TestServerPlanes:
    @pytest.fixture
    def profile_dir(self, tmp_path):
        d = tmp_path / "prof"
        d.mkdir()
        (d / "tree.json").write_text(host_tree().to_json())
        return d

    def _serve(self, path):
        from repro.profilerd.server import OfflineSource, ProfileServer

        return ProfileServer(OfflineSource(str(path))).start()

    def test_plane_404_without_artifact_has_remedy_hint(self, profile_dir):
        server = self._serve(profile_dir)
        try:
            for plane in ("device", "merged"):
                code, body = _http_get(server.url + f"/tree?plane={plane}")
                assert code == 404
                assert "device_tree.json" in body  # remedy hint, not a bare 404
            code, body = _http_get(server.url + "/diff?plane=merged")
            assert code in (400, 404)  # no baseline param -> 400; plane checked too
        finally:
            server.stop()

    def test_unknown_plane_is_400(self, profile_dir):
        server = self._serve(profile_dir)
        try:
            code, body = _http_get(server.url + "/tree?plane=bogus")
            assert code == 400
            assert "plane" in body
        finally:
            server.stop()

    def test_all_planes_served_with_artifact(self, profile_dir):
        from repro.analysis.static_tree import save_static_tree

        save_device_tree(device_tree(), str(profile_dir / "device_tree.json"))
        static = CallTree()
        static.add_stack(["mod::pkg", "repro::fn"], metrics={"defs": 1.0})
        save_static_tree(static, str(profile_dir / "static_tree.json"))
        server = self._serve(profile_dir)
        try:
            for plane in PLANES:
                code, body = _http_get(server.url + f"/tree?plane={plane}&fmt=json")
                assert code == 200, (plane, body)
            code, body = _http_get(server.url + "/tree?plane=merged&fmt=json")
            merged = CallTree.from_json(body)
            occs = [n.metrics.get(OCCUPANCY, 0) for _p, n in merged.root.walk()]
            assert max(occs) == pytest.approx(1.0)
            code, body = _http_get(server.url + "/tree?plane=device&fmt=folded")
            assert code == 200 and "scores" in body
        finally:
            server.stop()

    def test_merged_html_carries_roofline_legend(self, profile_dir):
        save_device_tree(device_tree(), str(profile_dir / "device_tree.json"))
        server = self._serve(profile_dir)
        try:
            code, html = _http_get(server.url + "/tree?plane=merged&fmt=html")
            assert code == 200
            for term in ("compute", "memory", "collective"):
                assert term in html
        finally:
            server.stop()


class TestExportRoundtrip:
    def test_merged_folded_roundtrip(self):
        merged = annotate_tree(host_tree(), device_tree())
        folded = to_folded(merged, OCCUPANCY)
        back = from_folded(folded, OCCUPANCY)
        # folded carries self-values; totals must agree to float precision
        assert back.total(OCCUPANCY) == pytest.approx(merged.total(OCCUPANCY))
        assert back.flatten(OCCUPANCY)["py::scores"] == pytest.approx(
            merged.flatten(OCCUPANCY)["py::scores"]
        )

    def test_merged_speedscope_uses_annotation_metric(self):
        merged = annotate_tree(host_tree(), device_tree())
        doc = to_speedscope(merged, OCCUPANCY, name="merged")
        assert doc["profiles"], "speedscope document has no profiles"
        assert doc["profiles"][0]["endValue"] > 0
        frames = [f["name"] for f in doc["shared"]["frames"]]
        assert any("scores" in f for f in frames)

    def test_merged_html_export_self_contained(self):
        merged = annotate_tree(host_tree(), device_tree())
        html = export_tree(merged, fmt="html", roofline=True)
        assert "<html" in html.lower()
        assert "src=\"http" not in html and "href=\"http" not in html  # no CDN deps
        for term in ("compute", "memory", "collective"):
            assert term in html


class TestTimelineSealRoundtrip:
    """Acceptance: annotations survive seal -> decode -> diff, and gate check."""

    def _seal(self, tmp_path, merged):
        tdir = str(tmp_path / "timeline")
        w = TimelineWriter(tdir)
        w.append_full(merged, EpochMeta(0, kind=0))
        delta = annotate_tree(host_tree(), device_tree()).diff(CallTree())
        w.append_delta(delta, EpochMeta(1))
        w.close()
        return tdir

    def test_seal_decode_preserves_annotations(self, tmp_path):
        merged = annotate_tree(host_tree(), device_tree())
        tdir = self._seal(tmp_path, merged)
        epochs = list(TimelineReader(tdir).epochs())
        assert len(epochs) == 2
        _meta, _window, cum = epochs[-1]
        flat = cum.flatten(OCCUPANCY)
        assert flat["py::scores"] == pytest.approx(2 * merged.flatten(OCCUPANCY)["py::scores"])
        assert cum.total(HLO_PREFIX + "flops") > 0

    def test_diff_and_share_regression_gate_on_device_metric(self, tmp_path):
        base = annotate_tree(host_tree(), device_tree())
        # a "regressed" run: the recompiled program doubles the scores matmul,
        # so scores' share of the roofline step time grows
        extra = (
            '  %scores2 = f32[4096,4096]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, '
            'rhs_contracting_dims={0}, metadata={op_name="jit(serve_step)/model/attention/scores"}\n'
        )
        worse_device = build_device_tree(HLO_TEXT.replace("  %context", extra + "  %context"))
        worse = annotate_tree(host_tree(), worse_device)
        sc = ("thread::MainThread", "py::serve_step", "py::model", "py::attention", "py::scores")
        assert _descend(worse, *sc).metrics[OCCUPANCY] > _descend(base, *sc).metrics[OCCUPANCY]
        regs = share_regressions(base, worse, metric=OCCUPANCY, tolerance=0.01, self_only=False)
        assert any("scores" in name for name, *_rest in regs)


class TestCLIPlanes:
    def _run(self, *argv, cwd=None):
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC_ROOT + os.pathsep + env.get("PYTHONPATH", "")
        return subprocess.run(
            [sys.executable, "-m", "repro.profilerd", *argv],
            env=env, capture_output=True, text=True, timeout=120, cwd=cwd,
        )

    @pytest.fixture
    def host_only(self, tmp_path):
        d = tmp_path / "hostonly"
        d.mkdir()
        (d / "tree.json").write_text(host_tree().to_json())
        return str(d)

    @pytest.fixture
    def with_device(self, tmp_path):
        d = tmp_path / "full"
        d.mkdir()
        (d / "tree.json").write_text(host_tree().to_json())
        save_device_tree(device_tree(), str(d / "device_tree.json"))
        return str(d)

    def test_export_device_plane_without_artifact_exits_4(self, host_only, tmp_path):
        r = self._run(
            "export", host_only, "--plane", "device",
            "--fmt", "folded", "--out", str(tmp_path / "o.folded"),
        )
        assert r.returncode == 4, (r.stdout, r.stderr)
        assert "device_tree.json" in (r.stdout + r.stderr)

    def test_export_merged_folded_roundtrips(self, with_device, tmp_path):
        out = str(tmp_path / "m.folded")
        r = self._run(
            "export", with_device, "--plane", "merged",
            "--fmt", "folded", "--metric", OCCUPANCY, "--out", out,
        )
        assert r.returncode == 0, (r.stdout, r.stderr)
        back = from_folded(open(out).read(), OCCUPANCY)
        merged = annotate_tree(host_tree(), device_tree())
        assert back.total(OCCUPANCY) == pytest.approx(merged.total(OCCUPANCY))

    def test_check_gates_on_device_plane_share(self, with_device):
        r = self._run(
            "check", with_device, "--baseline", with_device,
            "--plane", "merged", "--metric", OCCUPANCY,
        )
        assert r.returncode == 0, (r.stdout, r.stderr)
