"""Thread-local sharding context.

Model code never mentions mesh axes: it annotates activations with *logical*
axes (``shard_activation(x, ("batch", None, None))``). The launcher installs a
context mapping logical -> mesh axes; outside any context the call is an
identity, so smoke tests and single-device runs are untouched.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from collections.abc import Sequence

import jax
from jax.sharding import PartitionSpec as P

_tls = threading.local()


def set_sharding_ctx(mesh, act_rules: dict[str, object]) -> None:
    _tls.mesh = mesh
    _tls.act_rules = dict(act_rules)


def clear_sharding_ctx() -> None:
    _tls.mesh = None
    _tls.act_rules = None


def current_sharding_ctx():
    """-> (mesh, act_rules) or (None, None) when no context is installed."""
    return getattr(_tls, "mesh", None), getattr(_tls, "act_rules", None)


@contextmanager
def sharding_ctx(mesh, act_rules: dict[str, object]):
    prev = (getattr(_tls, "mesh", None), getattr(_tls, "act_rules", None))
    set_sharding_ctx(mesh, act_rules)
    try:
        yield
    finally:
        _tls.mesh, _tls.act_rules = prev


def shard_activation(x: jax.Array, logical_axes: Sequence[str | None]):
    """Apply a sharding constraint if a context is installed; else identity."""
    mesh = getattr(_tls, "mesh", None)
    rules = getattr(_tls, "act_rules", None)
    if mesh is None or rules is None:
        return x
    axes = []
    used: set[str] = set()
    for name in logical_axes:
        mapped = rules.get(name) if name else None
        if mapped is None:
            axes.append(None)
            continue
        mapped_t = (mapped,) if isinstance(mapped, str) else tuple(mapped)
        # never reuse a mesh axis within one spec; verify divisibility
        mapped_t = tuple(m for m in mapped_t if m not in used)
        dim = x.shape[len(axes)]
        size = 1
        for m in mapped_t:
            size *= mesh.shape[m]
        if mapped_t and size and dim % size == 0:
            axes.append(mapped_t if len(mapped_t) > 1 else mapped_t[0])
            used.update(mapped_t)
        else:
            axes.append(None)
    return jax.lax.with_sharding_constraint(x, jax.sharding.NamedSharding(mesh, P(*axes)))
