from .ctx import clear_sharding_ctx, set_sharding_ctx, shard_activation, sharding_ctx
from .rules import Strategy, make_strategy, params_shardings, spec_for

__all__ = [
    "clear_sharding_ctx",
    "set_sharding_ctx",
    "shard_activation",
    "sharding_ctx",
    "Strategy",
    "make_strategy",
    "params_shardings",
    "spec_for",
]
