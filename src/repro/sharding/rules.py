"""Logical-axis -> mesh-axis rules (MaxText-style), per parallelism strategy.

A :class:`Strategy` maps each *logical* parameter axis (``"embed"``,
``"mlp"``, ``"q_heads"``, ``"expert"``, ...) to zero or more mesh axes, plus
activation rules (``"batch"`` -> ``("pod","data")``). ``spec_for`` resolves an
:class:`~repro.models.modules.ArraySpec` into a ``PartitionSpec``, enforcing:

* divisibility — a dim that does not divide by its mesh-axes product falls
  back to replication for that dim (e.g. 8 KV heads on a 16-way model axis:
  KV weights replicate across TP, the Megatron GQA convention);
* uniqueness — a mesh axis is used at most once per spec (first logical axis
  in declaration order wins).

Strategies are plain data: §Perf hillclimbs by swapping rule tables, never by
touching model code.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.modules import ArraySpec, is_spec

AxisMap = dict[str, str | tuple[str, ...] | None]


@dataclass(frozen=True)
class Strategy:
    name: str
    param_rules: AxisMap
    act_rules: AxisMap
    # logical axes whose sharding is load-bearing (EP experts etc.) — checked
    # by tests so a silent fallback cannot drop them.
    required: tuple[str, ...] = ()

    def mesh_axes_for(self, logical: str | None) -> tuple[str, ...]:
        if logical is None:
            return ()
        m = self.param_rules.get(logical)
        if m is None:
            return ()
        return (m,) if isinstance(m, str) else tuple(m)


def spec_for(aspec: ArraySpec, strategy: Strategy, mesh) -> P:
    axes: list = []
    used: set[str] = set()
    for dim, logical in zip(aspec.shape, aspec.logical, strict=True):
        mapped = tuple(m for m in strategy.mesh_axes_for(logical) if m not in used)
        size = 1
        for m in mapped:
            size *= mesh.shape[m]
        if mapped and size > 1 and dim % size == 0:
            axes.append(mapped if len(mapped) > 1 else mapped[0])
            used.update(mapped)
        else:
            axes.append(None)
    return P(*axes)


def params_shardings(spec_tree, strategy: Strategy, mesh):
    """NamedSharding pytree matching a params spec tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_for(s, strategy, mesh)),
        spec_tree,
        is_leaf=is_spec,
    )


# ---------------------------------------------------------------------------
# Presets
# ---------------------------------------------------------------------------


def make_strategy(
    name: str,
    *,
    multi_pod: bool = False,
    fsdp_over_pod: bool = True,
) -> Strategy:
    """Build a strategy preset for the production mesh.

    Presets:
      tp_fsdp  — TP over 'model' for wide axes (vocab/mlp/heads/experts),
                 FSDP (ZeRO-3) over 'data' (+'pod' when multi_pod and
                 fsdp_over_pod) for the embed axis; batch over (pod, data).
      tp_only  — TP over 'model'; weights otherwise replicated (pure DP+TP).
      fsdp_only— ZeRO-3 without TP (all wide axes replicated).
      ddp      — pure data parallelism (all weights replicated).
    """
    fsdp: tuple[str, ...] = ("data",)
    batch: tuple[str, ...] = ("data",)
    if multi_pod:
        batch = ("pod", "data")
        if fsdp_over_pod:
            fsdp = ("pod", "data")
    common_acts: AxisMap = {"batch": batch, "expert_buf": "model", "ctx_chunk": "model"}
    if name == "tp_fsdp":
        return Strategy(
            name,
            param_rules={
                "vocab": "model",
                "mlp": "model",
                "q_heads": "model",
                "kv_heads": "model",
                "expert": "model",
                "state_out": "model",
                "embed": fsdp,
                "state": fsdp,
            },
            act_rules=common_acts,
            required=("expert",),
        )
    if name == "tp_only":
        return Strategy(
            name,
            param_rules={
                "vocab": "model",
                "mlp": "model",
                "q_heads": "model",
                "kv_heads": "model",
                "expert": "model",
                "state_out": "model",
            },
            act_rules=common_acts,
            required=("expert",),
        )
    if name == "fsdp_only":
        return Strategy(
            name,
            param_rules={"embed": fsdp, "state": fsdp, "expert": "model"},
            act_rules=common_acts,
        )
    if name == "ddp":
        return Strategy(name, param_rules={}, act_rules={"batch": batch})
    raise ValueError(f"unknown strategy {name}")
