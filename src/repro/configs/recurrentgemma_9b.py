"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2 recurrent : 1 attn
pattern (arXiv:2402.19427). 38L = 12 scan units x (rec,rec,attn) + 2 remainder."""

from .base import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,  # MQA on the local-attention layers
        d_ff=12288,
        vocab=256_000,
        head_dim_=256,
        act="gelu",  # GeGLU
        tied_embeddings=True,
        window=2048,  # local attention
        pattern=("rec", "rec", "attn"),
        lru_width=4096,
        conv_width=4,
        logit_softcap=30.0,
        notes="RG-LRU + local attn 1:2; runs long_500k (sub-quadratic decode)",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-smoke",
        family="hybrid",
        n_layers=5,  # 1 scan unit + 2 remainder layers (exercises both paths)
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab=256,
        head_dim_=16,
        act="gelu",
        tied_embeddings=True,
        window=8,
        pattern=("rec", "rec", "attn"),
        lru_width=64,
        conv_width=4,
        logit_softcap=30.0,
        chunk=16,
        remat="none",
    )


register("recurrentgemma-9b", config, smoke)
