"""qwen3-moe-235b-a22b [moe] — 94L, 128 routed experts top-8, GQA kv=4,
qk_norm. The largest assigned arch; the EP+FSDP+TP flagship cell."""

from .base import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        d_ff=1536,  # per-expert FF dim
        vocab=151_936,
        head_dim_=128,
        qk_norm=True,
        rope_theta=1_000_000.0,
        n_experts=128,
        n_shared_experts=0,
        top_k=8,
        moe_d_ff=1536,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b-smoke",
        family="moe",
        n_layers=4,
        d_model=32,
        n_heads=4,
        n_kv_heads=2,
        d_ff=16,
        vocab=128,
        head_dim_=8,
        qk_norm=True,
        n_experts=8,
        n_shared_experts=0,
        top_k=2,
        moe_d_ff=16,
        remat="none",
    )


register("qwen3-moe-235b-a22b", config, smoke)
