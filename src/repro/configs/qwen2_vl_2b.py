"""qwen2-vl-2b [vlm] — M-RoPE backbone; vision frontend is a STUB: the model
consumes precomputed patch embeddings (assignment note), with (t,h,w)
position ids driving multimodal RoPE."""

from .base import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b",
        family="vlm",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab=151_936,
        head_dim_=128,
        mrope=True,
        rope_theta=1_000_000.0,
        input_mode="embeddings",
        notes="vision frontend stubbed: input_specs() provides patch embeddings",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b-smoke",
        family="vlm",
        n_layers=3,
        d_model=48,
        n_heads=6,
        n_kv_heads=2,
        d_ff=96,
        vocab=256,
        head_dim_=8,
        mrope=True,
        rope_theta=1_000_000.0,
        input_mode="embeddings",
        remat="none",
    )


register("qwen2-vl-2b", config, smoke)
