"""deepseek-moe-16b [moe] — fine-grained MoE: 2 shared + 64 routed top-6
(arXiv:2401.06066); layer 0 is a dense FFN (d_ff 10944), MHA (kv=16)."""

from .base import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,  # per-routed-expert FF dim (assignment)
        vocab=102_400,
        head_dim_=128,
        n_experts=64,
        n_shared_experts=2,
        top_k=6,
        moe_d_ff=1408,
        first_dense=1,
        dense_d_ff=10944,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b-smoke",
        family="moe",
        n_layers=3,
        d_model=32,
        n_heads=4,
        n_kv_heads=4,
        d_ff=24,
        vocab=128,
        head_dim_=8,
        n_experts=8,
        n_shared_experts=2,
        top_k=2,
        moe_d_ff=24,
        first_dense=1,
        dense_d_ff=64,
        remat="none",
    )


register("deepseek-moe-16b", config, smoke)
