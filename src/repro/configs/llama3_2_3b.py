"""llama3.2-3b [dense] — GQA kv=8, tied embeddings (llama3.2 small variants)."""

from .base import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b",
        family="dense",
        n_layers=28,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=8192,
        vocab=128_256,
        head_dim_=128,
        tied_embeddings=True,
        rope_theta=500_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b-smoke",
        family="dense",
        n_layers=3,
        d_model=48,
        n_heads=6,
        n_kv_heads=2,
        d_ff=96,
        vocab=256,
        head_dim_=8,
        tied_embeddings=True,
        rope_theta=500_000.0,
        remat="none",
    )


register("llama3.2-3b", config, smoke)
