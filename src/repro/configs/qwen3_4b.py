"""qwen3-4b [dense] — GQA kv=8, qk_norm, explicit head_dim=128."""

from .base import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b",
        family="dense",
        n_layers=36,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        d_ff=9728,
        vocab=151_936,
        head_dim_=128,
        qk_norm=True,
        rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b-smoke",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        head_dim_=16,
        qk_norm=True,
        rope_theta=1_000_000.0,
        remat="none",
    )


register("qwen3-4b", config, smoke)
