"""xlstm-125m [ssm] — sLSTM + mLSTM blocks (arXiv:2405.04517), attention-free.
Pattern (slstm, mlstm, mlstm, mlstm) x 3 = 12 layers; d_ff=0 (cells only)."""

from .base import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        family="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab=50_304,
        pattern=("slstm", "mlstm", "mlstm", "mlstm"),
        chunk=256,  # mLSTM chunkwise-parallel chunk
        notes="attention-free; runs long_500k (O(1) decode state)",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m-smoke",
        family="ssm",
        n_layers=4,
        d_model=32,
        n_heads=2,
        n_kv_heads=2,
        d_ff=0,
        vocab=128,
        pattern=("slstm", "mlstm", "mlstm", "mlstm"),
        chunk=8,
        remat="none",
    )


register("xlstm-125m", config, smoke)
