"""musicgen-medium [audio] — decoder-only transformer over EnCodec tokens
(arXiv:2306.05284). The EnCodec frontend is a STUB: input_specs() provides
precomputed frame embeddings; the LM head predicts the 2048-entry codebook."""

from .base import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        family="audio",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,  # MHA
        d_ff=6144,
        vocab=2048,  # EnCodec codebook
        head_dim_=64,
        act="gelu",
        input_mode="embeddings",
        notes="EnCodec frontend stubbed: input_specs() provides frame embeddings",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium-smoke",
        family="audio",
        n_layers=3,
        d_model=48,
        n_heads=6,
        n_kv_heads=6,
        d_ff=96,
        vocab=128,
        head_dim_=8,
        act="gelu",
        input_mode="embeddings",
        remat="none",
    )


register("musicgen-medium", config, smoke)
