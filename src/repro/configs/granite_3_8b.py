"""granite-3-8b [dense] — GQA kv=8."""

from .base import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=12800,
        vocab=49_155,
        head_dim_=128,
        rope_theta=10_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b-smoke",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=160,
        vocab=256,
        head_dim_=16,
        remat="none",
    )


register("granite-3-8b", config, smoke)
