"""gemma-2b [dense] — MQA (kv=1), head_dim=256, GeGLU, tied embeddings."""

from .base import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b",
        family="dense",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        d_ff=16384,
        vocab=256_000,
        head_dim_=256,
        act="gelu",
        tied_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b-smoke",
        family="dense",
        n_layers=2,
        d_model=32,
        n_heads=2,
        n_kv_heads=1,
        d_ff=64,
        vocab=128,
        head_dim_=16,
        act="gelu",
        tied_embeddings=True,
        remat="none",
    )


register("gemma-2b", config, smoke)
