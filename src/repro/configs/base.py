"""Config system: ModelConfig (architecture), ShapeSpec (workload), registry.

Every assigned architecture registers a full config plus a reduced ``smoke``
variant (same family, tiny dims) used by CPU tests. The full configs are only
ever lowered via the dry-run (ShapeDtypeStruct stand-ins, no allocation).
"""

from __future__ import annotations

from dataclasses import dataclass
from collections.abc import Callable

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim_: int | None = None  # explicit head dim (default d_model/n_heads)
    act: str = "silu"
    qk_norm: bool = False
    tied_embeddings: bool = False
    # attention
    window: int | None = None  # sliding-window size for attn layers
    pattern: tuple[str, ...] = ("attn",)  # layer-kind cycle
    rope_theta: float = 10000.0
    mrope: bool = False
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    first_dense: int = 0  # leading dense-FFN layers (DeepSeekMoE)
    dense_d_ff: int = 0
    moe_impl: str = "dense"  # dense (pjit dispatch) | shard_map (explicit EP a2a)
    # recurrent / ssm
    lru_width: int | None = None
    conv_width: int = 4
    # execution
    chunk: int = 512  # q-chunk (attention) / time-chunk (mLSTM)
    chunk_threshold: int = 8192  # switch to chunked attention above this seq len
    attn_cp: bool = False  # context-parallel q-chunks (for TP-unshardable heads)
    attention_impl: str = "xla"  # xla | pallas | pallas_interpret
    remat: str = "full"  # none | full | dots
    input_mode: str = "tokens"  # tokens | embeddings (vlm/audio frontend stubs)
    logit_softcap: float = 0.0
    notes: str = ""

    @property
    def head_dim(self) -> int:
        return self.head_dim_ or self.d_model // self.n_heads

    def n_params(self) -> int:
        from repro.models.model import Model

        return Model(self).n_params

    def n_active_params(self) -> int:
        from repro.models.model import Model

        return Model(self).n_active_params


# ---------------------------------------------------------------------------
# Workload shapes (assignment: 4 per architecture)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# Families with sub-quadratic decode state: the only ones that run long_500k.
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, "full-attention arch: 500k dense-KV decode is quadratic-cost (skip per assignment)"
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str, full: Callable[[], ModelConfig], smoke: Callable[[], ModelConfig]) -> None:
    _REGISTRY[name] = full
    _SMOKE[name] = smoke


def get_config(name: str, *, smoke: bool = False) -> ModelConfig:
    _ensure_loaded()
    table = _SMOKE if smoke else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(table)}")
    return table[name]()


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_loaded = False


def _ensure_loaded() -> None:
    global _loaded
    if _loaded:
        return
    from . import (  # noqa: F401
        deepseek_moe_16b,
        gemma_2b,
        granite_3_8b,
        llama3_2_3b,
        musicgen_medium,
        qwen2_vl_2b,
        qwen3_4b,
        qwen3_moe_235b,
        recurrentgemma_9b,
        xlstm_125m,
    )

    _loaded = True
