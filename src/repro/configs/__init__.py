from .base import SHAPES, ModelConfig, ShapeSpec, get_config, list_archs, register, shape_applicable

__all__ = [
    "SHAPES",
    "ModelConfig",
    "ShapeSpec",
    "get_config",
    "list_archs",
    "register",
    "shape_applicable",
]
