"""Scoreboard: grade each (detector, scenario) cell on the harness's runs.

The paper grades its gem5 profiler by whether a known Ruby livelock is
surfaced, and how much of the run's wall time the dominant-stack rule took to
flag it.  This module does the same bookkeeping for the fault corpus:

* every *scored* event from a fault run is a true positive iff its wall time
  falls inside ``[t_inject, t_clear + grace]``, else a false positive;
* every scored event from the matching control run is a false positive;
* time-to-detect is the gap from injection to the detector's first in-window
  verdict, expressed in daemon epochs (the profiler's own clock).

Scored detector columns (event ``detector`` provenance + kind):

=================  ========================================================
``dominance``      windowed dominance rules (global + per-scenario pattern)
``trend_livelock`` epoch-trend LIVELOCK (dominance + progress stall)
``trend_drift``    epoch-trend SHARE_DRIFT (TV distance vs. baseline)
``stall``          liveness: TARGET_STALLED (spool silent, pid alive)
``straggler``      fleet skew: per-epoch cross-target share divergence
=================  ========================================================

``DOMINANT`` trend verdicts are deliberately *unscored*: a legitimately hot
clean loop is dominant without being anomalous, and a detector graded on
precision must not be penalized for reporting it.
"""

from __future__ import annotations

from dataclasses import dataclass, field

DETECTOR_COLUMNS = ("dominance", "trend_livelock", "trend_drift", "stall", "straggler")

# Lifecycle / plumbing events: never scored, never counted as FPs.
UNSCORED_KINDS = {
    "TARGET_ATTACHED",
    "TARGET_RESTARTED",
    "TARGET_NEVER_APPEARED",
    "TARGET_EXITED",
    "SOURCE_ATTACH_FAILED",
    "SOURCE_GAVE_UP",
    "DEVICE_TREE_LOADED",
    "DEVICE_TREE_UNREADABLE",
    "SERVING",
    "SERVE_FAILED",
    "SUPERVISOR_GONE",
    "TIMELINE_WRITE_FAILED",
    "CALLBACK_FAILED",
    "FAULT_INJECT",
    "FAULT_CLEAR",
    "FAULT_MARKER_INVALID",
}

# Recovery confirmations: not detections and not FPs, but the scoreboard
# records whether the pipeline observed the fault *clearing*.
RECOVERY_KINDS = {"LIVELOCK_CLEARED", "TARGET_RESUMED"}


def detector_of(event: dict) -> str | None:
    """Map one daemon event to its scored detector column (None = unscored)."""
    kind = event.get("kind", "")
    if kind in UNSCORED_KINDS or kind in RECOVERY_KINDS:
        return None
    det = event.get("detector")
    if det == "dominance":
        return "dominance"
    if det == "trend":
        if kind == "LIVELOCK":
            return "trend_livelock"
        if kind == "SHARE_DRIFT":
            return "trend_drift"
        return None  # DOMINANT et al.: informational
    if kind == "TARGET_STALLED":
        return "stall"
    if kind == "STRAGGLER":
        return "straggler"
    return None


@dataclass
class CellScore:
    """One (scenario, detector) cell."""

    detected: bool = False
    ttd_epochs: float | None = None  # injection -> first in-window verdict
    ttd_s: float | None = None
    true_positives: int = 0
    fault_run_fps: int = 0    # scored events outside the fault window
    control_fps: int = 0      # scored events on the clean control run
    recovery_observed: bool = False
    kinds: list[str] = field(default_factory=list)  # distinct TP kinds seen

    def to_json(self) -> dict:
        return {
            "detected": self.detected,
            "ttd_epochs": None if self.ttd_epochs is None else round(self.ttd_epochs, 2),
            "ttd_s": None if self.ttd_s is None else round(self.ttd_s, 3),
            "true_positives": self.true_positives,
            "fault_run_fps": self.fault_run_fps,
            "control_fps": self.control_fps,
            "recovery_observed": self.recovery_observed,
            "kinds": sorted(set(self.kinds)),
        }


def score_runs(
    fault_events: list[dict],
    control_events: list[dict],
    *,
    t_inject: float,
    t_clear: float,
    epoch_s: float,
    grace_epochs: int = 3,
) -> dict[str, CellScore]:
    window_end = t_clear + grace_epochs * epoch_s
    cells = {col: CellScore() for col in DETECTOR_COLUMNS}

    for ev in fault_events:
        kind = ev.get("kind", "")
        wall = float(ev.get("wall_time", 0.0))
        if kind in RECOVERY_KINDS and wall >= t_clear:
            col = "trend_livelock" if kind == "LIVELOCK_CLEARED" else "stall"
            cells[col].recovery_observed = True
            continue
        col = detector_of(ev)
        if col is None:
            continue
        cell = cells[col]
        if t_inject <= wall <= window_end:
            cell.true_positives += 1
            cell.kinds.append(kind)
            if not cell.detected:
                cell.detected = True
                cell.ttd_s = wall - t_inject
                cell.ttd_epochs = cell.ttd_s / epoch_s
        else:
            cell.fault_run_fps += 1

    for ev in control_events:
        col = detector_of(ev)
        if col is not None:
            cells[col].control_fps += 1

    return cells


# ---------------------------------------------------------------------------
# bench document


def build_bench(
    scenario_cells: dict[str, dict[str, CellScore]],
    *,
    config: dict,
    skipped: dict[str, str] | None = None,
    ttd_floor_epochs: float = 10.0,
) -> dict:
    matrix = {
        scen: {col: cell.to_json() for col, cell in cells.items()}
        for scen, cells in sorted(scenario_cells.items())
    }

    summary = {}
    n_scen = max(len(scenario_cells), 1)
    for col in DETECTOR_COLUMNS:
        det_cells = [cells[col] for cells in scenario_cells.values()]
        tp = sum(c.true_positives for c in det_cells)
        fp = sum(c.fault_run_fps + c.control_fps for c in det_cells)
        detected = sum(1 for c in det_cells if c.detected)
        ttds = [c.ttd_epochs for c in det_cells if c.ttd_epochs is not None]
        summary[col] = {
            "scenarios_detected": detected,
            "recall": round(detected / n_scen, 3),
            "precision": None if tp + fp == 0 else round(tp / (tp + fp), 3),
            "mean_ttd_epochs": None if not ttds else round(sum(ttds) / len(ttds), 2),
        }

    floors = floor_report(scenario_cells, ttd_floor_epochs=ttd_floor_epochs)
    return {
        "schema": 1,
        "bench": "fault-injection detector matrix",
        "config": config,
        "detectors": list(DETECTOR_COLUMNS),
        "skipped": dict(sorted((skipped or {}).items())),
        "matrix": matrix,
        "summary": summary,
        "floors": floors,
    }


def floor_report(
    scenario_cells: dict[str, dict[str, CellScore]],
    *,
    ttd_floor_epochs: float = 10.0,
) -> dict:
    """The committed floors: every scenario caught fast, clean runs silent."""
    per_scenario = {}
    problems = []
    for scen, cells in sorted(scenario_cells.items()):
        ttds = [c.ttd_epochs for c in cells.values() if c.ttd_epochs is not None]
        best = min(ttds) if ttds else None
        detected = any(c.detected for c in cells.values())
        per_scenario[scen] = {
            "detected": detected,
            "best_ttd_epochs": None if best is None else round(best, 2),
        }
        if not detected:
            problems.append(f"{scen}: no detector fired inside the fault window")
        elif best is not None and best > ttd_floor_epochs:
            problems.append(
                f"{scen}: best time-to-detect {best:.1f} epochs > floor {ttd_floor_epochs}"
            )
        cfps = sum(c.control_fps for c in cells.values())
        if cfps:
            problems.append(f"{scen}: {cfps} false positive(s) on the clean control run")
    return {
        "ttd_floor_epochs": ttd_floor_epochs,
        "per_scenario": per_scenario,
        "pass": not problems,
        "problems": problems,
    }


def diff_bench(baseline: dict, new: dict) -> list[str]:
    """Regressions of ``new`` vs. the committed ``baseline``.

    Gated: a (scenario, detector) cell that flips detected -> missed, or a
    clean control run that starts producing false positives.  Latency changes
    are informational only (CI boxes jitter).
    """
    problems: list[str] = []
    base_m = baseline.get("matrix", {})
    new_m = new.get("matrix", {})
    for scen, base_cells in base_m.items():
        new_cells = new_m.get(scen)
        if new_cells is None:
            if scen in new.get("skipped", {}):
                continue  # environment lacks a dep: skip, don't fail
            problems.append(f"{scen}: present in baseline but missing from new run")
            continue
        for col, base_cell in base_cells.items():
            new_cell = new_cells.get(col, {})
            if base_cell.get("detected") and not new_cell.get("detected"):
                problems.append(f"{scen}/{col}: regressed detected -> missed")
            if not base_cell.get("control_fps") and new_cell.get("control_fps"):
                problems.append(
                    f"{scen}/{col}: new false positive(s) on the clean control run "
                    f"({new_cell.get('control_fps')})"
                )
    return problems
