"""CLI for the fault corpus: list scenarios, run one, or score the matrix.

  PYTHONPATH=src python -m repro.faults list
  PYTHONPATH=src python -m repro.faults run --scenario injected_spin
  PYTHONPATH=src python -m repro.faults bench --out BENCH_detect.json
  PYTHONPATH=src python -m repro.faults bench --smoke --check \\
      --baseline BENCH_detect.json

``bench`` runs every requested scenario twice (fault + clean control),
scores each (detector, scenario) cell, and writes the bench JSON.  With
``--check`` it additionally enforces the floors and — when a baseline is
given — fails on detected->missed or new-control-FP regressions.
"""

from __future__ import annotations

import argparse
import json
import sys

from .harness import HarnessConfig, HarnessError, run_scenario
from .scenarios import SCENARIOS, SMOKE_SCENARIOS
from .scoreboard import build_bench, diff_bench, score_runs


def _select(args) -> list[str]:
    if getattr(args, "scenarios", None):
        names = [s.strip() for s in args.scenarios.split(",") if s.strip()]
        unknown = [n for n in names if n not in SCENARIOS]
        if unknown:
            raise SystemExit(f"unknown scenario(s): {', '.join(unknown)}")
        return names
    if getattr(args, "smoke", False):
        return list(SMOKE_SCENARIOS)
    return sorted(SCENARIOS)


def _mk_config(args) -> HarnessConfig:
    cfg = HarnessConfig(keep_artifacts=getattr(args, "keep", False))
    return cfg


def cmd_list(args) -> int:
    for name in sorted(SCENARIOS):
        s = SCENARIOS[name]
        ok, why = s.available()
        tag = "" if ok else f"  [unavailable: {why}]"
        hosts = f" x{s.n_hosts}" if s.n_hosts > 1 else ""
        print(f"{name:18s}{hosts:4s} {s.description}{tag}")
    return 0


def cmd_run(args) -> int:
    scenario = SCENARIOS[args.scenario]
    cfg = _mk_config(args)
    res = run_scenario(scenario, cfg, control=args.control)
    kinds: dict[str, int] = {}
    for ev in res.events:
        kinds[ev.get("kind", "?")] = kinds.get(ev.get("kind", "?"), 0) + 1
    print(json.dumps({
        "scenario": res.scenario,
        "control": res.control,
        "n_events": len(res.events),
        "kinds": dict(sorted(kinds.items())),
        "t_inject": res.t_inject,
        "t_clear": res.t_clear,
        "out_dir": res.out_dir,
    }, indent=1))
    return 0


def cmd_bench(args) -> int:
    cfg = _mk_config(args)
    names = _select(args)
    cells_by_scenario = {}
    # Scenarios outside the requested subset are recorded as skipped, not
    # silently absent — the baseline diff tolerates skips but flags vanished
    # scenarios, so a --smoke run must not read as seven regressions.
    skipped: dict[str, str] = {
        name: "not selected (subset run)" for name in sorted(SCENARIOS) if name not in names
    }
    for name in names:
        scenario = SCENARIOS[name]
        ok, why = scenario.available()
        if not ok:
            skipped[name] = why
            print(f"[bench] SKIP {name}: {why}", file=sys.stderr)
            continue
        print(f"[bench] {name}: fault run ...", file=sys.stderr)
        fault = run_scenario(scenario, cfg, control=False)
        print(f"[bench] {name}: control run ...", file=sys.stderr)
        control = run_scenario(scenario, cfg, control=True)
        cells_by_scenario[name] = score_runs(
            fault.events,
            control.events,
            t_inject=fault.t_inject,
            t_clear=fault.t_clear,
            epoch_s=cfg.epoch_s,
            grace_epochs=cfg.grace_epochs,
        )
        got = sorted(
            {c for c, cell in cells_by_scenario[name].items() if cell.detected}
        )
        print(f"[bench] {name}: detected by {got or 'NOTHING'}", file=sys.stderr)

    bench = build_bench(
        cells_by_scenario,
        config={
            "epoch_s": cfg.epoch_s,
            "publish_s": cfg.publish_s,
            "agent_period_s": cfg.agent_period_s,
            "clean_s": cfg.clean_s,
            "fault_s": cfg.fault_s,
            "recovery_s": cfg.recovery_s,
            "grace_epochs": cfg.grace_epochs,
            "global_threshold": cfg.global_threshold,
            "global_consecutive": cfg.global_consecutive,
        },
        skipped=skipped,
    )
    out = args.out
    if out:
        with open(out, "w") as f:
            json.dump(bench, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"[bench] wrote {out}", file=sys.stderr)
    else:
        print(json.dumps(bench, indent=1, sort_keys=True))

    rc = 0
    if args.check:
        problems = list(bench["floors"]["problems"])
        if args.baseline:
            try:
                with open(args.baseline) as f:
                    baseline = json.load(f)
                problems += diff_bench(baseline, bench)
            except OSError as e:
                problems.append(f"baseline unreadable: {e}")
        for p in problems:
            print(f"[bench] FAIL {p}", file=sys.stderr)
        rc = 1 if problems else 0
        if rc == 0:
            print("[bench] floors pass", file=sys.stderr)
    return rc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.faults")
    sub = ap.add_subparsers(dest="cmd", required=True)

    sub.add_parser("list", help="list the scenario corpus")

    rn = sub.add_parser("run", help="run one scenario and dump its verdicts")
    rn.add_argument("--scenario", required=True, choices=sorted(SCENARIOS))
    rn.add_argument("--control", action="store_true", help="clean run (no fault)")
    rn.add_argument("--keep", action="store_true", help="keep run artifacts on disk")

    bn = sub.add_parser("bench", help="score the full detector x scenario matrix")
    bn.add_argument("--smoke", action="store_true",
                    help=f"jax-free fast subset: {', '.join(SMOKE_SCENARIOS)}")
    bn.add_argument("--scenarios", default=None, help="comma-separated subset")
    bn.add_argument("--out", default=None, help="write bench JSON here")
    bn.add_argument("--check", action="store_true",
                    help="enforce floors (and baseline diff when given)")
    bn.add_argument("--baseline", default=None,
                    help="committed BENCH_detect.json to diff against")
    bn.add_argument("--keep", action="store_true", help="keep run artifacts on disk")

    args = ap.parse_args(argv)
    try:
        return {"list": cmd_list, "run": cmd_run, "bench": cmd_bench}[args.cmd](args)
    except HarnessError as e:
        print(f"[faults] error: {e}", file=sys.stderr)
        return 1


if __name__ == "__main__":
    sys.exit(main())
