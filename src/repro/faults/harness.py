"""Scenario harness: spawn workload children + an attached daemon, drive the
fault window, collect the daemon's verdicts.

One run of :func:`run_scenario` is the paper's validation loop in miniature:
a workload with a *known*, timestamped failure is profiled from outside, and
the events the daemon publishes (``events.jsonl``) become the raw material
the scoreboard grades.  A ``control=True`` run is the same workload with no
fault — any scored verdict it produces is a false positive.

Ground truth reaches the daemon in-band: the harness appends inject/clear
marker lines to ``<out>/fault_markers.jsonl`` *before* flipping the child's
control sentinel, and the daemon echoes them into the event log stamped with
each target's current epoch — so detection latency is measured in the
daemon's own epoch clock, not just wall time.
"""

from __future__ import annotations

import json
import os
import shutil
import signal
import subprocess
import sys
import tempfile
import time
from dataclasses import dataclass, field

from repro.core.detector import TrendRule
from repro.profilerd.daemon import FAULT_MARKERS_FILENAME, spawn_attached_daemon

from .base import FaultScenario


class HarnessError(RuntimeError):
    pass


@dataclass
class HarnessConfig:
    epoch_s: float = 0.4
    publish_s: float = 0.2
    agent_period_s: float = 0.004
    clean_s: float = 3.4       # pre-fault baseline (~8 epochs)
    fault_s: float = 4.2       # fault window (~10 epochs)
    recovery_s: float = 2.2    # post-clear (~5 epochs)
    # Verdicts caused by the fault can land a little after clear (trailing
    # windows, recovery drift): still true positives within this many epochs.
    grace_epochs: int = 3
    stall_timeout_s: float = 8.0  # default; scenarios may override shorter
    # The global catch-all dominance rule runs hot (0.97/3): scenario rules
    # carry detection, the global rule exists to catch the pure-spin shape
    # without false-firing on legitimately hot clean loops (jit dispatch).
    global_threshold: float = 0.97
    global_consecutive: int = 3
    ready_timeout_s: float = 180.0  # jax compile can be slow on cold caches
    keep_artifacts: bool = False


@dataclass
class RunResult:
    scenario: str
    control: bool
    events: list[dict]
    status: dict
    t_start: float
    t_inject: float | None
    t_clear: float | None
    epoch_s: float
    out_dir: str | None = None  # only when keep_artifacts
    host_logs: dict[str, str] = field(default_factory=dict)


def _tail(path: str, n: int = 20) -> str:
    try:
        with open(path, errors="replace") as f:
            return "".join(f.readlines()[-n:])
    except OSError:
        return ""


def _wait_for(predicate, timeout_s: float, what: str, on_fail=None) -> None:
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if predicate():
            return
        time.sleep(0.05)
    extra = on_fail() if on_fail else ""
    raise HarnessError(f"timed out waiting for {what}" + (f"\n{extra}" if extra else ""))


def _append_marker(out_dir: str, scenario: str, op: str) -> float:
    """Write one ground-truth marker line; returns its wall timestamp."""
    wall = time.time()
    line = json.dumps({"op": op, "scenario": scenario, "wall_time": wall})
    with open(os.path.join(out_dir, FAULT_MARKERS_FILENAME), "a") as f:
        f.write(line + "\n")
        f.flush()
        os.fsync(f.fileno())
    return wall


def run_scenario(
    scenario: FaultScenario,
    cfg: HarnessConfig | None = None,
    *,
    control: bool = False,
) -> RunResult:
    cfg = cfg or HarnessConfig()
    ok, why = scenario.available()
    if not ok:
        raise HarnessError(f"scenario {scenario.name} unavailable: {why}")

    root = tempfile.mkdtemp(prefix=f"faults-{scenario.name}-")
    ctl = os.path.join(root, "ctl")
    work = os.path.join(root, "work")
    out = os.path.join(root, "out")
    for d in (ctl, work, out):
        os.makedirs(d)

    src_root = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    env.update(scenario.extra_child_env)

    children: list[subprocess.Popen] = []
    logs: dict[str, str] = {}
    daemon = None
    spools = [os.path.join(root, f"host{i}.spool") for i in range(scenario.n_hosts)]
    status_path = os.path.join(out, "status.json")
    t_inject = t_clear = None

    def _read_status() -> dict:
        try:
            with open(status_path) as f:
                return json.load(f)
        except (OSError, ValueError):
            return {}

    def _children_dead_tail() -> str:
        parts = []
        for i, p in enumerate(children):
            if p.poll() is not None:
                parts.append(f"host{i} exited rc={p.returncode}:\n{_tail(logs[f'host{i}'])}")
        return "\n".join(parts)

    try:
        for i in range(scenario.n_hosts):
            log = os.path.join(root, f"host{i}.log")
            logs[f"host{i}"] = log
            with open(log, "w") as lf:
                children.append(
                    subprocess.Popen(
                        [
                            sys.executable, "-m", "repro.faults._target",
                            "--scenario", scenario.name,
                            "--spool", spools[i],
                            "--ctl", ctl,
                            "--workdir", work,
                            "--host-index", str(i),
                            "--n-hosts", str(scenario.n_hosts),
                            "--period", str(cfg.agent_period_s),
                        ],
                        env=env, stdout=lf, stderr=subprocess.STDOUT,
                    )
                )

        _wait_for(
            lambda: all(
                os.path.exists(os.path.join(ctl, f"ready.{i}"))
                for i in range(scenario.n_hosts)
            )
            and all(p.poll() is None for p in children),
            cfg.ready_timeout_s,
            f"{scenario.name} children ready",
            on_fail=_children_dead_tail,
        )
        if any(p.poll() is not None for p in children):
            raise HarnessError(
                f"{scenario.name}: child died during warmup\n" + _children_dead_tail()
            )

        daemon = spawn_attached_daemon(
            targets=spools,
            out_dir=out,
            interval_s=cfg.publish_s,
            epoch_s=cfg.epoch_s,
            stall_timeout_s=scenario.stall_timeout_s or cfg.stall_timeout_s,
            rules=scenario.rules,
            trend_rule=TrendRule(),  # enable epoch-trend verdicts (LIVELOCK/DRIFT)
            threshold=cfg.global_threshold,
            consecutive=cfg.global_consecutive,
        )
        _wait_for(
            lambda: _read_status().get("n_targets") == scenario.n_hosts,
            30.0,
            f"{scenario.name} daemon attach ({scenario.n_hosts} targets)",
        )

        t_start = time.time()
        time.sleep(cfg.clean_s)

        if not control:
            t_inject = _append_marker(out, scenario.name, "inject")
            if scenario.harness_side:
                for p in children:
                    os.kill(p.pid, signal.SIGSTOP)
            else:
                with open(os.path.join(ctl, "inject"), "w"):
                    pass
            time.sleep(cfg.fault_s)
            t_clear = _append_marker(out, scenario.name, "clear")
            if scenario.harness_side:
                for p in children:
                    os.kill(p.pid, signal.SIGCONT)
            else:
                with open(os.path.join(ctl, "clear"), "w"):
                    pass
            time.sleep(cfg.recovery_s)
        else:
            time.sleep(cfg.fault_s + cfg.recovery_s)

        with open(os.path.join(ctl, "stop"), "w"):
            pass
        for p in children:
            try:
                p.wait(timeout=60.0)
            except subprocess.TimeoutExpired:
                p.kill()
                p.wait()

        # All targets sent BYE -> the daemon drains, publishes, and exits.
        try:
            daemon.wait(timeout=45.0)
        except subprocess.TimeoutExpired:
            daemon.terminate()
            try:
                daemon.wait(timeout=15.0)
            except subprocess.TimeoutExpired:
                daemon.kill()
                daemon.wait()

        events = []
        ev_path = os.path.join(out, "events.jsonl")
        if os.path.exists(ev_path):
            with open(ev_path) as f:
                for line in f:
                    line = line.strip()
                    if line:
                        events.append(json.loads(line))
        status = _read_status()
        return RunResult(
            scenario=scenario.name,
            control=control,
            events=events,
            status=status,
            t_start=t_start,
            t_inject=t_inject,
            t_clear=t_clear,
            epoch_s=cfg.epoch_s,
            out_dir=root if cfg.keep_artifacts else None,
            host_logs={k: _tail(v, 10) for k, v in logs.items()},
        )
    finally:
        for p in children:
            if p.poll() is None:
                try:
                    os.kill(p.pid, signal.SIGCONT)  # in case we left it stopped
                except OSError:
                    pass
                p.kill()
                p.wait()
        if daemon is not None and daemon.poll() is None:
            daemon.kill()
            daemon.wait()
        if not cfg.keep_artifacts:
            shutil.rmtree(root, ignore_errors=True)
