"""Fault-injection corpus + scored detector harness.

Measure the detectors the way the paper measures gem5: reproduce a known
failure on demand, profile it from outside, and grade every detector on
precision, recall, and time-to-detect against the injection's ground truth.

Import surface stays lazy where it matters: the scenario registry and
scoreboard are import-light; heavyweight drivers (jax models) only load
inside the child process that runs them.

  PYTHONPATH=src python -m repro.faults list
  PYTHONPATH=src python -m repro.faults run --scenario injected_spin
  PYTHONPATH=src python -m repro.faults bench --smoke --out BENCH_detect.json
"""

from .base import Driver, FaultScenario, ScenarioContext
from .harness import HarnessConfig, HarnessError, RunResult, run_scenario
from .scenarios import SCENARIOS, SMOKE_SCENARIOS
from .scoreboard import (
    DETECTOR_COLUMNS,
    CellScore,
    build_bench,
    detector_of,
    diff_bench,
    floor_report,
    score_runs,
)


def get_scenario(name: str) -> FaultScenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; have: {', '.join(sorted(SCENARIOS))}"
        ) from None


__all__ = [
    "Driver",
    "FaultScenario",
    "ScenarioContext",
    "HarnessConfig",
    "HarnessError",
    "RunResult",
    "run_scenario",
    "SCENARIOS",
    "SMOKE_SCENARIOS",
    "DETECTOR_COLUMNS",
    "CellScore",
    "build_bench",
    "detector_of",
    "diff_bench",
    "floor_report",
    "score_runs",
    "get_scenario",
]
