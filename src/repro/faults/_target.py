"""Child-process entrypoint: run one fault-scenario driver under an Agent.

The harness spawns one of these per simulated host.  The child builds the
scenario's driver, warms it up (compiles jax, primes pipelines) *before*
starting the profiling agent — so the published profile is the steady-state
workload, not startup — then loops ``driver.step()`` until the harness drops
a ``stop`` sentinel in the control directory.

Control protocol (files in ``--ctl``):
  harness -> child:  ``inject``, ``clear``, ``stop`` (touched once, in order)
  child -> harness:  ``ready.<host_index>`` (written after the agent starts)

The control poller runs on a thread named ``repro-prof-faults-ctl`` so the
sampler excludes it from profiles — the ground-truth machinery must never
appear in the data being scored.
"""

from __future__ import annotations

import argparse
import os
import sys
import threading
import time

from repro.faults.base import ScenarioContext
from repro.faults.scenarios import SCENARIOS
from repro.profilerd.agent import Agent


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.faults._target")
    ap.add_argument("--scenario", required=True)
    ap.add_argument("--spool", required=True)
    ap.add_argument("--ctl", required=True)
    ap.add_argument("--workdir", required=True)
    ap.add_argument("--host-index", type=int, default=0)
    ap.add_argument("--n-hosts", type=int, default=1)
    ap.add_argument("--period", type=float, default=0.004)
    args = ap.parse_args(argv)

    scenario = SCENARIOS[args.scenario]
    ctx = ScenarioContext(args.host_index, args.n_hosts, args.workdir)
    driver = scenario.make_driver(ctx)
    driver.warmup()

    stop = threading.Event()
    driver.stop_event = stop  # drivers with blocking waits bail on shutdown

    def poll_ctl() -> None:
        seen: set[str] = set()
        while not stop.is_set():
            for op in ("inject", "clear", "stop"):
                if op in seen or not os.path.exists(os.path.join(args.ctl, op)):
                    continue
                seen.add(op)
                if op == "inject":
                    driver.inject()
                elif op == "clear":
                    driver.clear()
                else:
                    stop.set()
                    return
            time.sleep(0.02)

    poller = threading.Thread(target=poll_ctl, name="repro-prof-faults-ctl", daemon=True)

    agent = Agent(args.spool, period_s=args.period)
    agent.start()
    poller.start()
    # Ready only after the agent is live: the harness's daemon attach then
    # finds a spool with a HELLO already in it.
    ready = os.path.join(args.ctl, f"ready.{args.host_index}")
    with open(ready + ".tmp", "w") as f:
        f.write(str(os.getpid()))
    os.rename(ready + ".tmp", ready)

    try:
        while not stop.is_set():
            driver.step()
    finally:
        driver.close()
        agent.stop()  # writes BYE so the daemon sees a clean detach
    return 0


if __name__ == "__main__":
    sys.exit(main())
