"""Fault-scenario plumbing: the corpus's scenario spec + driver protocol.

A :class:`FaultScenario` is a deterministic, injectable failure: a workload
driver (run in a *child* process so the harness's own threads never pollute
the sampled profile), ``inject()``/``clear()`` hooks flipped mid-run by the
harness, the dominance rules the daemon should watch it with, and the verdict
kinds that count as detecting it.  This mirrors how the paper validates the
gem5 profiler: a known failure (Ruby deadlock/livelock) is reproduced on
demand and the detector is graded on whether — and how fast — it fires.

The module stays import-light (no jax): scenario *construction* is lazy via
``make_driver``, so listing the corpus or running the jax-free subset never
pays for the accelerator stack.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from importlib.util import find_spec
from collections.abc import Callable

import numpy as np

from repro.core.detector import Rule


@dataclass
class ScenarioContext:
    """What a driver knows about its placement: which host it is, how many
    peers exist, and the run's shared scratch directory (file barriers,
    checkpoint dirs)."""

    host_index: int
    n_hosts: int
    workdir: str


class Driver:
    """One scenario's workload, run on the child process's main thread.

    ``step()`` is one iteration of the deterministic workload loop; the child
    calls it until told to stop.  ``inject()``/``clear()`` are called from the
    child's control-poller thread, so implementations must flip thread-safe
    flags (events) that ``step()`` observes, never mutate shared state
    non-atomically.
    """

    def warmup(self) -> None:  # compile/allocate before the agent starts
        pass

    def step(self) -> None:
        raise NotImplementedError

    def inject(self) -> None:
        pass

    def clear(self) -> None:
        pass

    def close(self) -> None:
        pass


@dataclass
class FaultScenario:
    name: str
    description: str
    make_driver: Callable[[ScenarioContext], Driver]
    # Daemon-side dominance rules for this workload (the paper's
    # protocol-scoped rule, e.g. its SLICC-action threshold): each scenario
    # names the failure signature it should be watched for.
    rules: tuple[Rule, ...] = ()
    # Verdict kinds that count as detecting this fault (scoreboard ground
    # truth); any other scored verdict inside the fault window still counts
    # as a detection by its own detector column.
    expected_kinds: tuple[str, ...] = ()
    n_hosts: int = 1
    # Modules the driver needs importable in the child (e.g. "jax"); the
    # harness skips — loudly — scenarios whose deps are missing.
    requires: tuple[str, ...] = ()
    # True: inject/clear are applied by the harness to the child *process*
    # (SIGSTOP/SIGCONT) — the fully-wedged-interpreter case only an
    # out-of-process observer can see.
    harness_side: bool = False
    # Daemon stall-timeout override (the hard-wedge scenario needs it shorter
    # than the fault window so TARGET_STALLED can fire inside it).
    stall_timeout_s: float | None = None
    extra_child_env: dict = field(default_factory=dict)

    def available(self) -> tuple[bool, str]:
        for mod in self.requires:
            if find_spec(mod) is None:
                return False, f"missing dependency: {mod}"
        return True, ""


# ---------------------------------------------------------------------------
# Deterministic clean-phase compute: a rotating mixture of distinct named
# frames, so healthy windows have a diverse share vector (no single frame
# dominates) and a steady baseline for SHARE_DRIFT.  Each phase is a real
# numpy workload — the profiles under test are genuine, not synthetic trees.

_RNG = np.random.default_rng(0xFA017)


def phase_matmul(reps: int = 3) -> float:
    a = _RNG.standard_normal((48, 48))
    s = 0.0
    for _ in range(reps):
        s += float((a @ a.T).trace())
    return s


def phase_sort(reps: int = 3) -> float:
    v = _RNG.standard_normal(12_000)
    s = 0.0
    for _ in range(reps):
        s += float(np.sort(v)[0])
    return s


def phase_fft(reps: int = 2) -> float:
    v = _RNG.standard_normal(8_192)
    s = 0.0
    for _ in range(reps):
        s += float(np.abs(np.fft.rfft(v)).sum())
    return s


def phase_reduce(reps: int = 4) -> float:
    m = _RNG.standard_normal((64, 256))
    s = 0.0
    for _ in range(reps):
        s += float(np.log1p(np.abs(m)).sum())
    return s


_PHASES = (phase_matmul, phase_sort, phase_fft, phase_reduce)


def mix_compute(step: int, scale: int = 1) -> float:
    """One slice of rotating compute (~a few ms): ``step`` picks the phase."""
    s = 0.0
    for k in range(scale):
        s += _PHASES[(step + k) % len(_PHASES)]()
    return s


def park_while(flag, poll_s: float = 0.005) -> None:
    """Busy-park until ``flag`` (threading.Event) clears — the generic
    "thread pinned in one wait frame" shape every scenario's fault needs.
    Callers wrap this in a *named* function so the profile shows the fault's
    own signature frame, not this helper."""
    while flag.is_set():
        time.sleep(poll_s)
