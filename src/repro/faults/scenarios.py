"""The fault corpus: deterministic injectable failures across the model zoo.

Each scenario reproduces one production failure shape the paper's mechanism
should catch (its showcase is Ruby coherence livelock — "the simulation
either appears to run normally or terminates abruptly"):

* ``injected_spin``     — classic hot livelock loop (the Fig. 13 analogue);
* ``data_starvation``   — throttled pipeline refill parks the consumer in
                          ``Pipeline.__next__``;
* ``collective_stall``  — one of three hosts parks mid-step, the others pin
                          in the allreduce barrier (straggler + stall);
* ``hard_wedge``        — the whole interpreter is SIGSTOPed (harness-side):
                          only an out-of-process observer can see this one;
* ``moe_imbalance``     — a biased router gate drops >80 % of tokens and the
                          rebalance-retry loop livelocks (jax);
* ``ckpt_wedge``        — a blocking fsync wedges the checkpoint writer and
                          then the train loop in ``CheckpointManager.wait``;
* ``serve_convoy``      — a metrics scraper holds the serving loop's lock,
                          parking decode in ``ServeMetrics.record_step`` (jax).

Fault frames are *named functions* on purpose: the profile signature — not
any instrumentation — is what the daemon's rules key on, exactly like the
paper's per-protocol-action dominance rule.
"""

from __future__ import annotations

import os
import threading
import time

import numpy as np

from repro.core.detector import Rule

from .base import Driver, FaultScenario, ScenarioContext, mix_compute, park_while

# ---------------------------------------------------------------------------
# injected_spin — single-thread hot livelock (the paper's Fig. 13 shape)


def injected_livelock_spin(driver) -> float:
    """The fault signature frame: a pure spin that mints no new stacks.

    The loop condition is a plain attribute load (not ``Event.is_set``, a
    Python-level call) so every sample's leaf is *this* frame — the clean
    single-dominant-self-frame shape the trend detector's LIVELOCK rule and
    the paper's dominant-stack rule both key on.
    """
    x = 1.0
    while driver.fault_on:
        x = x * 1.0000001 + 1e-9
    return x


class SpinDriver(Driver):
    def __init__(self, ctx: ScenarioContext):
        self.fault_on = False
        self._i = 0

    def step(self) -> None:
        mix_compute(self._i)
        self._i += 1
        if self.fault_on:
            injected_livelock_spin(self)

    def inject(self) -> None:
        self.fault_on = True

    def clear(self) -> None:
        self.fault_on = False


# ---------------------------------------------------------------------------
# data_starvation — throttled refill: producer parks in the (shimmed)
# dataset, consumer parks in Pipeline.__next__ on the empty queue.


def starved_refill_wait(flag) -> None:
    park_while(flag)


class StarvationDriver(Driver):
    def __init__(self, ctx: ScenarioContext):
        self._fault = threading.Event()
        self._i = 0
        self.pipe = None

    def warmup(self) -> None:
        from repro.data.pipeline import DataConfig, Pipeline, SyntheticLM

        ds = SyntheticLM(DataConfig(vocab=256, seq_len=48, global_batch=8, seed=7))
        inner = ds.batch

        def throttled_batch(step: int):
            starved_refill_wait(self._fault)
            return inner(step)

        ds.batch = throttled_batch  # the injection seam: refill can be parked
        self.pipe = Pipeline(ds, prefetch=2)
        next(self.pipe)  # prime the queue before the agent starts

    def step(self) -> None:
        batch = next(self.pipe)
        # Consumer-side work deliberately slower than batch generation, so a
        # healthy queue is never empty and __next__ returns immediately.
        mix_compute(self._i, scale=2)
        self._i += int(batch["tokens"][0, 0]) % 2 + 1

    def inject(self) -> None:
        self._fault.set()

    def clear(self) -> None:
        self._fault.clear()

    def close(self) -> None:
        self._fault.clear()  # never leave the producer parked
        if self.pipe is not None:
            self.pipe.close()


# ---------------------------------------------------------------------------
# collective_stall — 3 hosts step through a file barrier; host 0 parks
# mid-step during the fault, pinning its peers in the barrier wait.


def parked_worker_wait(flag) -> None:
    park_while(flag)


def allreduce_barrier_wait(ctx: ScenarioContext, step: int, stop_event=None) -> None:
    bdir = os.path.join(ctx.workdir, "barrier")
    os.makedirs(bdir, exist_ok=True)
    mine = os.path.join(bdir, f"h{ctx.host_index}_s{step}")
    with open(mine, "w") as f:
        f.write("1")
    deadline = time.monotonic() + 120.0
    while time.monotonic() < deadline:
        if all(
            os.path.exists(os.path.join(bdir, f"h{h}_s{step}"))
            for h in range(ctx.n_hosts)
        ):
            return
        if stop_event is not None and stop_event.is_set():
            return  # a peer already shut down; don't wedge teardown
        time.sleep(0.002)


class CollectiveDriver(Driver):
    def __init__(self, ctx: ScenarioContext):
        self.ctx = ctx
        self._fault = threading.Event()
        self._step_no = 0
        self.stop_event = None  # set by the child before the run loop

    BARRIER_EVERY = 4  # amortize the barrier so healthy waits stay small

    def step(self) -> None:
        if self._fault.is_set() and self.ctx.host_index == 0:
            parked_worker_wait(self._fault)
        # Identical compute on every host: clean arrival times align, so the
        # barrier share stays far below the COLLECTIVE_STALL threshold.
        mix_compute(self._step_no, scale=3)
        self._step_no += 1
        if self._step_no % self.BARRIER_EVERY == 0:
            allreduce_barrier_wait(
                self.ctx, self._step_no // self.BARRIER_EVERY, self.stop_event
            )

    def inject(self) -> None:
        self._fault.set()

    def clear(self) -> None:
        self._fault.clear()


# ---------------------------------------------------------------------------
# hard_wedge — SIGSTOP from the harness: the agent itself goes silent, which
# only the out-of-process daemon can notice (TARGET_STALLED).


class BusyDriver(Driver):
    def __init__(self, ctx: ScenarioContext):
        self._i = 0

    def step(self) -> None:
        mix_compute(self._i)
        self._i += 1


# ---------------------------------------------------------------------------
# moe_imbalance — collapsed token distribution (an upstream data bug: every
# token near-identical) routes the whole batch to one top-k pair; capacity
# drops >60 % of assignments and the rebalance-retry loop livelocks.


def router_imbalance_retry(driver, x) -> float:
    """Retry frame: re-dispatch until the drop rate recovers (livelock while
    the token distribution stays collapsed)."""
    dropped = 1.0
    while dropped > 0.5 and not driver._fault_cleared():
        _, aux = driver._step_fn(driver.params, x)
        dropped = float(aux["dropped_frac"])
    return dropped


def make_router_tokens(rng, batch: int, seq: int, d_model: int):
    return rng.standard_normal((batch, seq, d_model)).astype(np.float32)


def collapsed_router_tokens(rng, batch: int, seq: int, d_model: int, v):
    """Degenerate inputs: one direction + a whisper of noise, so every
    token's top-k lands on the same expert pair and capacity drops the rest."""
    noise = rng.standard_normal((batch, seq, d_model)).astype(np.float32)
    return v[None, None, :] + 0.05 * noise


class MoEImbalanceDriver(Driver):
    BATCH, SEQ = 2, 64

    def __init__(self, ctx: ScenarioContext):
        self._fault = threading.Event()
        self._i = 0
        self._rng = np.random.default_rng(11)

    def _fault_cleared(self) -> bool:
        if not self._fault.is_set():
            return True
        stop = getattr(self, "stop_event", None)
        return stop is not None and stop.is_set()

    def warmup(self) -> None:
        import jax

        from repro.configs import get_config
        from repro.models.modules import init_params
        from repro.models.moe import moe, moe_spec

        cfg = get_config("deepseek-moe-16b", smoke=True)
        self.cfg = cfg
        self.params = init_params(moe_spec(cfg), jax.random.key(0))
        self._step_fn = jax.jit(lambda p, x: moe(p, x, cfg))
        self._collapse_v = (
            3.0 * self._rng.standard_normal(cfg.d_model).astype(np.float32)
        )
        x = make_router_tokens(self._rng, self.BATCH, self.SEQ, cfg.d_model)
        self._step_fn(self.params, x)  # compile before the agent starts

    def step(self) -> None:
        if self._fault.is_set():
            x = collapsed_router_tokens(
                self._rng, self.BATCH, self.SEQ, self.cfg.d_model, self._collapse_v
            )
        else:
            x = make_router_tokens(self._rng, self.BATCH, self.SEQ, self.cfg.d_model)
        mix_compute(self._i)
        self._i += 1
        _, aux = self._step_fn(self.params, x)
        if float(aux["dropped_frac"]) > 0.5:
            router_imbalance_retry(self, x)

    def inject(self) -> None:
        self._fault.set()

    def clear(self) -> None:
        self._fault.clear()


# ---------------------------------------------------------------------------
# ckpt_wedge — blocking fsync: the writer thread parks in the shimmed
# _sync_path, then the train loop parks in CheckpointManager.wait.


def wedged_fsync_wait(flag) -> None:
    park_while(flag)


class CkptWedgeDriver(Driver):
    def __init__(self, ctx: ScenarioContext):
        self.ctx = ctx
        self._fault = threading.Event()
        self._i = 0

    def warmup(self) -> None:
        from repro.checkpoint import manager as manager_mod

        self._mod = manager_mod
        self._orig_sync = manager_mod._sync_path
        self.mgr = manager_mod.CheckpointManager(
            os.path.join(self.ctx.workdir, "ckpt"), keep=2, fsync=True
        )
        self.state = {
            "w": np.zeros(16_384, np.float32),
            "opt": {"m": np.zeros(16_384, np.float32)},
        }
        self.mgr.save(0, self.state, blocking=True)

    def _wedged_sync(self, path: str) -> None:
        wedged_fsync_wait(self._fault)
        self._orig_sync(path)

    def step(self) -> None:
        mix_compute(self._i)
        self._i += 1
        # Sparse enough that the previous async writer has long finished:
        # a healthy loop's wait() in save is near-instant, so the clean
        # "repro::wait" share stays far under the CKPT_WEDGE threshold.
        if self._i % 8 == 0:
            self.mgr.save(self._i, self.state)

    def inject(self) -> None:
        self._fault.set()
        self._mod._sync_path = self._wedged_sync

    def clear(self) -> None:
        self._fault.clear()
        self._mod._sync_path = self._orig_sync

    def close(self) -> None:
        self.clear()
        self.mgr.wait()


# ---------------------------------------------------------------------------
# serve_convoy — a scraper holds ServeMetrics' lock; decode parks in
# record_step (lock convoy in the serving loop).


def hold_metrics_lock(metrics, flag) -> None:
    with metrics._lock:
        park_while(flag)


class ServeConvoyDriver(Driver):
    def __init__(self, ctx: ScenarioContext):
        self._fault = threading.Event()
        self._scraper_stop = threading.Event()
        self._round = 0

    def warmup(self) -> None:
        import numpy as _np

        from repro.configs import get_config
        from repro.launch.serve import BatchedServer, Request
        from repro.models import Model

        self._Request = Request
        cfg = get_config("gemma-2b", smoke=True)
        self.model = Model(cfg)
        self.vocab = cfg.vocab
        self.server = BatchedServer(self.model, batch=2, max_len=64)
        self._req_rng = _np.random.default_rng(3)
        self._run_round(max_new=2)  # compile before the agent starts

        def scrape():
            while not self._scraper_stop.is_set():
                if self._fault.is_set():
                    hold_metrics_lock(self.server.metrics, self._fault)
                else:
                    self.server.metrics.snapshot()
                    time.sleep(0.02)

        self._scraper = threading.Thread(
            target=scrape, name="serve-metrics-scraper", daemon=True
        )
        self._scraper.start()

    def _run_round(self, max_new: int = 6) -> None:
        rng = self._req_rng
        reqs = [
            self._Request(
                rid=self._round * 10 + i,
                prompt=rng.integers(0, self.vocab, 4).astype(np.int32),
                max_new=max_new,
            )
            for i in range(2)
        ]
        # Fresh decode state per round: the demo server's context is finite.
        self.server.state = self.model.init_decode_state(self.server.batch, self.server.max_len)
        self.server.pos = 0
        self.server.slots = [None] * self.server.batch
        self.server.consumed = [0] * self.server.batch
        self.server.run(reqs)
        self._round += 1

    def step(self) -> None:
        self._run_round()

    def inject(self) -> None:
        self._fault.set()

    def clear(self) -> None:
        self._fault.clear()

    def close(self) -> None:
        self._fault.clear()
        self._scraper_stop.set()


# ---------------------------------------------------------------------------
# registry


SCENARIOS: dict[str, FaultScenario] = {
    s.name: s
    for s in (
        FaultScenario(
            name="injected_spin",
            description="hot livelock loop on the main thread (Fig. 13 analogue)",
            make_driver=SpinDriver,
            rules=(
                Rule(pattern="injected_livelock_spin", threshold=0.5,
                     consecutive=2, kind="LIVELOCK_SUSPECT", self_only=False),
            ),
            expected_kinds=("LIVELOCK_SUSPECT", "LIVELOCK"),
        ),
        FaultScenario(
            name="data_starvation",
            description="throttled pipeline refill starves the training consumer",
            make_driver=StarvationDriver,
            rules=(
                Rule(pattern="repro::__next__", threshold=0.35,
                     consecutive=2, kind="INPUT_STARVED", self_only=False),
            ),
            expected_kinds=("INPUT_STARVED",),
        ),
        FaultScenario(
            name="collective_stall",
            description="one of three hosts parks mid-step; peers pin in the allreduce barrier",
            make_driver=CollectiveDriver,
            rules=(
                Rule(pattern="allreduce_barrier_wait", threshold=0.6,
                     consecutive=3, kind="COLLECTIVE_STALL", self_only=False),
            ),
            expected_kinds=("COLLECTIVE_STALL", "STRAGGLER", "LIVELOCK"),
            n_hosts=3,
        ),
        FaultScenario(
            name="hard_wedge",
            description="SIGSTOPed interpreter: the agent goes silent, only the daemon can tell",
            make_driver=BusyDriver,
            expected_kinds=("TARGET_STALLED",),
            harness_side=True,
            stall_timeout_s=1.5,
        ),
        FaultScenario(
            name="moe_imbalance",
            description="collapsed router inputs swamp one expert pair: >60% tokens dropped, rebalance retry livelocks",
            make_driver=MoEImbalanceDriver,
            rules=(
                Rule(pattern="router_imbalance_retry", threshold=0.5,
                     consecutive=2, kind="MOE_IMBALANCE", self_only=False),
            ),
            expected_kinds=("MOE_IMBALANCE", "LIVELOCK", "SHARE_DRIFT"),
            requires=("jax",),
        ),
        FaultScenario(
            name="ckpt_wedge",
            description="blocking fsync wedges the checkpoint writer, then the train loop",
            make_driver=CkptWedgeDriver,
            rules=(
                Rule(pattern="repro::wait", threshold=0.3,
                     consecutive=2, kind="CKPT_WEDGE", self_only=False),
            ),
            expected_kinds=("CKPT_WEDGE",),
        ),
        FaultScenario(
            name="serve_convoy",
            description="metrics scraper holds the serving lock; decode parks in record_step",
            make_driver=ServeConvoyDriver,
            rules=(
                Rule(pattern="record_step", threshold=0.35,
                     consecutive=2, kind="LOCK_CONVOY", self_only=False),
            ),
            expected_kinds=("LOCK_CONVOY", "SHARE_DRIFT"),
            requires=("jax",),
        ),
    )
}

SMOKE_SCENARIOS = ("injected_spin", "data_starvation")
