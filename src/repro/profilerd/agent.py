"""Target-side shim: the only profilerd code that runs inside the target.

:class:`Agent` is a minimal publisher — on each tick it snapshots
``sys._current_frames()`` and writes *raw, unresolved* frame records
(filename, function, lineno, thread) into the spool.  No symbol resolution,
no origin classification, no tree merging: everything else happens in the
daemon process (:mod:`repro.profilerd.daemon`), which is the paper's
non-intrusiveness contract — the target pays only for frame capture.

:class:`DaemonBackend` adapts the agent to the
:class:`~repro.core.sampler.SamplerBackend` protocol so the train/serve
drivers can swap it in for :class:`~repro.core.sampler.StackSampler` via
``SamplerConfig(backend="daemon")``.  It optionally spawns the daemon as a
subprocess; with an explicit spool path it assumes an external
``python -m repro.profilerd attach`` drains the spool instead.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import threading
import time

from repro.core.calltree import CallNode, CallTree
from repro.core.sampler import SamplerConfig, is_profiler_thread, open_psutil_process

from .spool import SpoolWriter
from .wire import WIRE_VERSION, Encoder, RawFrame, RawSample, Rusage


class Agent:
    """Raw-frame publisher: ``sys._current_frames()`` -> codec -> spool.

    ``wire_version=2`` (the default) interns whole stacks: steady-state ticks
    cost a fixed-size ``SAMPLE2`` record per thread instead of 12 bytes per
    frame.  ``wire_version=1`` keeps the per-frame encoding for old
    consumers; either way the daemon's decoder handles both.
    """

    def __init__(
        self,
        spool_path: str,
        period_s: float = 0.5,
        max_depth: int = 256,
        spool_bytes: int = 4 << 20,
        record_rusage: bool = False,
        wire_version: int = WIRE_VERSION,
    ):
        self.spool_path = spool_path
        self.period_s = period_s
        self.max_depth = max_depth
        self.record_rusage = record_rusage
        self._writer = SpoolWriter(spool_path, spool_bytes)
        self._enc = Encoder(version=wire_version)
        # Encoder + SpoolWriter are single-writer; sample_now() may race the
        # helper thread's own tick, so ticks are serialized.
        self._tick_lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._t0 = time.monotonic()
        self.n_ticks = 0
        self.n_stacks = 0  # stacks offered to the spool (dropped ones included)
        self.n_dropped_batches = 0
        self._psutil_proc = open_psutil_process() if record_rusage else None
        self._writer.write(self._enc.encode_hello(os.getpid(), period_s))

    # -- capture -----------------------------------------------------------

    def _raw_stack(self, frame) -> list[RawFrame]:
        rev: list[RawFrame] = []
        depth = 0
        while frame is not None and depth < self.max_depth:
            code = frame.f_code
            # f_lineno can be None when snapshotting a thread suspended
            # mid-bytecode (3.11+); the codec packs it as u32.
            rev.append(RawFrame(code.co_filename, code.co_name, frame.f_lineno or 0))
            frame = frame.f_back
            depth += 1
        rev.reverse()  # root -> leaf
        return rev

    def tick(self) -> int:
        """Capture one snapshot of every thread and publish it. Returns the
        number of stacks in the batch (0 if the batch was dropped)."""
        helper = self._thread.ident if self._thread is not None else None
        names = {t.ident: t.name for t in threading.enumerate()}
        now = time.monotonic() - self._t0
        frames = sys._current_frames()
        samples = []
        for ident, frame in frames.items():
            # Same exclusion rule as the thread backend: profiler
            # infrastructure (this publisher, watchdog threads) is invisible.
            if ident == helper or is_profiler_thread(names.get(ident, "")):
                continue
            samples.append(
                RawSample(now, ident, names.get(ident, f"tid{ident}"), self._raw_stack(frame))
            )
        rusage = None
        if self._psutil_proc is not None:
            try:
                cpu = self._psutil_proc.cpu_times()
                rusage = Rusage(now, cpu.user + cpu.system, self._psutil_proc.memory_info().rss)
            except Exception:
                rusage = None
        with self._tick_lock:
            payload, fresh = self._enc.encode_tick(samples, rusage)
            self.n_ticks += 1
            self.n_stacks += len(samples)
            if not self._writer.write(payload):
                self._enc.rollback(fresh)
                self.n_dropped_batches += 1
                return 0
        return len(samples)

    def _run(self) -> None:
        while not self._stop.wait(self.period_s):
            try:
                self.tick()
            except Exception:
                # Never take down the target.
                pass

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "Agent":
        if self._thread is not None:
            raise RuntimeError("agent already started")
        self._t0 = time.monotonic()
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, name="repro-profilerd-agent", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is not None:
            self._stop.set()
            self._thread.join(timeout=5.0)
            self._thread = None
        with self._tick_lock:
            self._writer.write_bye(self._enc.encode_bye(self.n_ticks))
            self._writer.close()


class DaemonBackend:
    """``SamplerBackend`` adapter: agent in-process, aggregation out-of-process.

    ``snapshot()``/``depth_trace()`` read the daemon's published artifacts
    (``tree.json`` / ``status.json`` under the out dir, written atomically),
    so the in-process watchdog keeps working unchanged — it just observes a
    tree that was built in another process.
    """

    def __init__(self, config: SamplerConfig | None = None):
        self.config = config or SamplerConfig(backend="daemon")
        explicit_spool = self.config.spool_path is not None
        if explicit_spool:
            self.spool_path = self.config.spool_path
        else:
            d = tempfile.mkdtemp(prefix="repro-profilerd-")
            self.spool_path = os.path.join(d, "target.spool")
        self.out_dir = self.config.daemon_out or f"{self.spool_path}.d"
        spawn = self.config.spawn_daemon
        self.spawn_daemon = (not explicit_spool) if spawn is None else spawn
        self.agent: Agent | None = None
        self._proc: subprocess.Popen | None = None
        self._stopped_tree: CallTree | None = None

    # -- published-artifact readers -----------------------------------------

    def _read_json(self, name: str):
        try:
            with open(os.path.join(self.out_dir, name)) as f:
                return json.load(f)
        except (OSError, ValueError):
            return None

    # -- SamplerBackend protocol --------------------------------------------

    def start(self) -> "DaemonBackend":
        if self.agent is not None:
            raise RuntimeError("sampler already started")
        self.agent = Agent(
            self.spool_path,
            period_s=self.config.period_s,
            max_depth=self.config.max_depth,
            spool_bytes=self.config.spool_bytes,
            record_rusage=self.config.record_rusage,
            wire_version=self.config.wire_version,
        )
        self.agent.start()
        if self.spawn_daemon:
            from .daemon import spawn_attached_daemon

            self._proc = spawn_attached_daemon(
                self.spool_path,
                self.out_dir,
                interval_s=max(self.config.period_s, 0.2),
                collapse_origins=self.config.collapse_origins,
                push=self.config.push_url,
                push_node=self.config.push_node,
            )
        return self

    def stop(self) -> CallTree:
        if self._stopped_tree is not None:
            return self._stopped_tree
        was_running = self.agent is not None
        if self.agent is not None:
            self.agent.stop()  # writes BYE: the daemon drains, publishes, exits
            self.agent = None
        if self._proc is not None:
            try:
                self._proc.wait(timeout=30.0)
            except subprocess.TimeoutExpired:
                self._proc.kill()
                self._proc.wait()
            self._proc = None
        elif was_running and self._read_json("status.json") is not None:
            # An external daemon attached: wait (bounded) for it to see BYE
            # and publish its final tree, otherwise we would snapshot a stale
            # window.  No status.json means nobody ever attached — don't wait.
            deadline = time.monotonic() + 15.0
            while time.monotonic() < deadline:
                status = self._read_json("status.json")
                if status is None or status.get("done"):
                    break
                time.sleep(0.1)
        self._stopped_tree = self.snapshot()
        return self._stopped_tree

    def snapshot(self) -> CallTree:
        d = self._read_json("tree.json")
        if d is None:
            return CallTree()
        return CallTree(CallNode.from_dict(d))

    def sample_now(self) -> None:
        if self.agent is not None:
            self.agent.tick()

    def wait_ready(self, timeout_s: float = 15.0) -> bool:
        """Block until the daemon has published once (benchmarks use this to
        keep daemon start-up cost out of steady-state overhead numbers)."""
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            if self._read_json("status.json") is not None:
                return True
            time.sleep(0.05)
        return False

    def depth_trace(self) -> list[tuple[float, int]]:
        status = self._read_json("status.json") or {}
        return [(float(t), int(d)) for t, d in status.get("depth_timeline", [])]

    @property
    def n_samples(self) -> int:
        """Publisher ticks (mirrors StackSampler.n_samples for benchmarks)."""
        if self.agent is not None:
            return self.agent.n_ticks
        status = self._read_json("status.json") or {}
        return int(status.get("n_ticks", 0))

    def __enter__(self) -> "DaemonBackend":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
