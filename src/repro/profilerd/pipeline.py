"""IngestPipeline — one object for the decode -> accumulate -> seal path.

Before this module the per-record ``drain -> Decoder.feed -> ingest(sample)``
loop was written out by every consumer of a spool byte stream (the daemon's
:class:`~repro.profilerd.sources.SpoolSource`, the throughput benchmarks,
half the test suite).  :class:`IngestPipeline` owns that composition — reader
+ decoder + ingestor + sealer + stats — behind four calls:

* :meth:`IngestPipeline.feed`        — bytes in, non-sample events out
  (samples are ingested internally, batched when possible);
* :meth:`IngestPipeline.drain_chunk` — one bounded reader chunk through
  :meth:`feed`;
* :meth:`IngestPipeline.seal_epoch`  — drain the ingestor's epoch dirty list
  into the timeline ring;
* :meth:`IngestPipeline.reset_stream`— writer re-attach: fresh decoder, every
  ``stack_id``-keyed cache dropped, loss counters carried over.

Batch vs per-sample is selected at construction: when numpy is importable
(and ``vectorized`` was not forced off) the pipeline routes chunks through
``Decoder.feed_batch`` + ``TreeIngestor.ingest_batch``; otherwise it runs
the scalar path — the documented fallback for v1 records, unknown stack ids
and numpy-free installs (v1/unknown records take the scalar core *inside*
the batch path too; the construction-time switch only disables the
vectorized fast lane).  The choice is surfaced as ``ingest_stats.vectorized``
and the daemon logs one ``INGEST_SCALAR_FALLBACK`` event on attach when the
fast lane is unavailable.

The unified ``ingest_stats`` schema
-----------------------------------

Every surface that reports ingest progress — ``TreeIngestor.stats()``,
``SpoolSource.status_row()["ingest"]``, daemon ``status.json``, ``/status``
and ``top`` — now renders this one dict:

=================== =========================================================
key                 meaning
=================== =========================================================
vectorized          True when this pipeline runs the numpy batch fast lane
samples             samples ingested (scalar + batch)
fast_hits           samples served by the cached-chain fast path
slow_ingests        samples that resolved symbols / built a chain
batch_samples       samples that arrived inside a ``SampleBatch``
batch_chunks        ``SampleBatch`` objects ingested
cached_paths        live ``(thread, stack_id) -> chain`` cache entries
unknown_stack_refs  samples whose interned stack was never seen (re-attach)
degraded_stackdefs  STACKDEFs dropped for lack of delta context (re-attach)
=================== =========================================================

``merge_ingest_stats`` sums rows across sources for fleet-level views and
``format_ingest_stats`` renders one human line for ``top``.
"""

from __future__ import annotations

from collections import deque
from collections.abc import Sequence

from repro.core.snapshot import CountSealer, EpochMeta, TimelineWriter

from .ingest import TreeIngestor
from .resolver import SymbolResolver
from .wire import Decoder, Event, RawSample, SampleBatch, numpy_available

INGEST_STATS_KEYS = (
    "vectorized",
    "samples",
    "fast_hits",
    "slow_ingests",
    "batch_samples",
    "batch_chunks",
    "cached_paths",
    "unknown_stack_refs",
    "degraded_stackdefs",
)


class IngestPipeline:
    """Reader + decoder + ingestor + sealer + stats, one object.

    Every component is injectable (tests swap trees and sealers freely); the
    defaults compose the production path.  ``reader`` is optional — a
    pipeline can be fed bytes directly via :meth:`feed` (benchmarks, tests,
    socket transports).
    """

    def __init__(
        self,
        reader=None,
        *,
        decoder: Decoder | None = None,
        ingestor: TreeIngestor | None = None,
        resolver: SymbolResolver | None = None,
        collapse_origins: Sequence[str] = (),
        timeline_writer: TimelineWriter | None = None,
        metric: str = "samples",
        vectorized: bool | None = None,
        depth_timeline: deque | None = None,
    ):
        self.reader = reader
        self.decoder = decoder if decoder is not None else Decoder()
        self.ingestor = (
            ingestor
            if ingestor is not None
            else TreeIngestor(resolver=resolver, collapse_origins=collapse_origins)
        )
        self.tree = self.ingestor.tree
        self.resolver = self.ingestor.resolver
        self.sealer: CountSealer | None = None
        if timeline_writer is not None:
            self.sealer = CountSealer(self.tree, timeline_writer, metric)
        # Batch vs per-sample is decided once, here: auto-detect on None,
        # and an explicit True still degrades gracefully when numpy is
        # missing (the flag reports what actually runs, never the wish).
        avail = numpy_available()
        self.vectorized = avail if vectorized is None else bool(vectorized) and avail
        # (t, depth) pairs for status depth sparklines; callers may pass
        # their own bounded deque to share it across surfaces.
        self.depth_timeline: deque = depth_timeline if depth_timeline is not None else deque(maxlen=2048)
        self.samples = 0
        # Loss counters carried across decoder incarnations (re-attach).
        self._unknown_refs_base = 0
        self._degraded_defs_base = 0

    # -- ingest ------------------------------------------------------------

    def feed(self, data: bytes) -> list[Event]:
        """Decode + ingest one chunk of stream bytes.

        Samples (batched or scalar) are merged into the tree and the depth
        timeline here; everything the caller owns policy for — ``Hello``,
        ``Rusage``, ``Bye`` — is returned, in stream order.
        """
        events: list[Event] = []
        ing = self.ingestor
        tl = self.depth_timeline
        cap = tl.maxlen
        if self.vectorized:
            for item in self.decoder.feed_batch(data):
                if type(item) is SampleBatch:
                    depths = ing.ingest_batch(item)
                    self.samples += len(item)
                    ts = item.t
                    if cap is not None and len(ts) > cap:
                        ts = ts[-cap:]
                        depths = depths[-cap:]
                    tl.extend(zip(ts.tolist(), depths.tolist(), strict=True))
                elif type(item) is RawSample:
                    tl.append((item.t, ing.ingest(item)))
                    self.samples += 1
                else:
                    events.append(item)
        else:
            for ev in self.decoder.feed(data):
                if type(ev) is RawSample:
                    tl.append((ev.t, ing.ingest(ev)))
                    self.samples += 1
                else:
                    events.append(ev)
        return events

    def drain_chunk(self) -> tuple[int, list[Event]]:
        """One bounded reader chunk through :meth:`feed`; returns
        ``(bytes_drained, events)``."""
        chunk = self.reader.read()
        if not chunk:
            return 0, []
        return len(chunk), self.feed(chunk)

    # -- lifecycle ---------------------------------------------------------

    def reset_stream(self, decoder: Decoder | None = None) -> None:
        """Writer re-attach: the restarted target re-assigns ids from 0, so
        the decoder and every ``stack_id``-keyed cache must die together.
        Loss counters fold into the pipeline so totals survive."""
        self._unknown_refs_base += self.decoder.unknown_stack_refs
        self._degraded_defs_base += self.decoder.degraded_stackdefs
        self.decoder = decoder if decoder is not None else Decoder()
        self.resolver.reset_interned()
        self.ingestor.reset_chain_cache()

    def seal_epoch(self, wall_time: float = 0.0) -> tuple[EpochMeta | None, list]:
        """Drain the epoch dirty list into the ring; returns
        ``(meta, entries)`` (entries for trend windows etc.), or
        ``(None, [])`` when no sealer is configured."""
        if self.sealer is None:
            return None, []
        entries, untracked = self.ingestor.drain_epoch()
        meta = self.sealer.seal(entries, wall_time=wall_time, untracked=untracked)
        return meta, entries

    # -- stats -------------------------------------------------------------

    @property
    def unknown_stack_refs(self) -> int:
        return self._unknown_refs_base + self.decoder.unknown_stack_refs

    @property
    def degraded_stackdefs(self) -> int:
        return self._degraded_defs_base + self.decoder.degraded_stackdefs

    def ingest_stats(self) -> dict:
        """The unified ``ingest_stats`` dict (schema in the module doc)."""
        stats = self.ingestor.stats()
        stats["vectorized"] = self.vectorized
        stats["samples"] = self.samples
        stats["unknown_stack_refs"] = self.unknown_stack_refs
        stats["degraded_stackdefs"] = self.degraded_stackdefs
        return stats


def merge_ingest_stats(rows: Sequence[dict]) -> dict:
    """Sum ``ingest_stats`` rows across sources (fleet ``status.json``).

    ``vectorized`` is AND-ed: it answers "is the whole fleet on the fast
    lane" — with no sources yet it reports plain availability."""
    merged = dict.fromkeys(INGEST_STATS_KEYS, 0)
    merged["vectorized"] = all(r.get("vectorized", False) for r in rows) if rows else numpy_available()
    for r in rows:
        for k in INGEST_STATS_KEYS:
            if k != "vectorized":
                merged[k] += r.get(k, 0)
    return merged


def format_ingest_stats(stats: dict) -> str:
    """One ``top``-style line for an ``ingest_stats`` dict."""
    lane = "vectorized" if stats.get("vectorized") else "scalar"
    line = (
        f"ingest[{lane}]: samples={stats.get('samples', 0)} "
        f"fast={stats.get('fast_hits', 0)} slow={stats.get('slow_ingests', 0)} "
        f"batched={stats.get('batch_samples', 0)}/{stats.get('batch_chunks', 0)} "
        f"cached={stats.get('cached_paths', 0)}"
    )
    lost = stats.get("unknown_stack_refs", 0)
    degraded = stats.get("degraded_stackdefs", 0)
    if lost or degraded:
        line += f" unknown={lost} degraded_defs={degraded}"
    return line
