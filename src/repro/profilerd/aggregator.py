"""Regional aggregator: merge pushed per-node epochs into a fleet profile.

One ``profilerd aggregate`` process is the next tier above the per-node
daemon: node daemons POST sealed epoch deltas (``repro.profilerd.push`` wire
format — snapshot-codec segments over HTTP) and the aggregator replays them
into

* per-node timeline rings under ``targets/<node>/timeline`` (so every
  existing offline surface — ``serve``, ``timeline``, ``diff``, ``check``,
  ``export`` — works on a node's history via ``--target <node>``);
* a continuously merged **fleet tree**, sealed into two rings: ``timeline/``
  holds recent epochs exact (bounded segment ring), ``timeline_coarse/``
  holds one keyframe every ``coarse_every`` fleet epochs over a much longer
  horizon — recent history exact, old history at coarser grain, retention in
  both enforced by dropping whole segments;
* the standard daemon artifact shape (``status.json``, ``tree.json``,
  ``events.jsonl``, ``region.json``) in its out dir, so ``check --baseline``
  and ``profilerd top`` gate/observe the *regional* profile with zero
  special cases.

Replay is idempotent and loss-bounded: every node tracks a contiguous
applied-epoch floor plus a sparse applied set, so a client retry after a
lost response never double-counts; deltas commute, so out-of-order arrival
within a keyframe era is harmless; and a ``K_FULL`` keyframe is applied by
*replacement*, resynchronizing the node's cumulative exactly (this is what
makes the client's spill-overflow resync lossless in mass).

Node churn is first-class: a new ``X-Repro-Boot`` id folds the previous
incarnation's cumulative into a retained base (``base.json``), so a
crash-looping node keeps contributing everything it ever reported.  Nodes
that stop pushing earn ``NODE_STALLED`` (and ``NODE_RECOVERED`` on
resumption); a clean daemon shutdown marks the node ``done`` instead.

Restart is crash-safe: state is rebuilt from the per-node rings + sidecars
(``node.json``) and both fleet rings are *continued* (monotonic epoch
numbering, ``TimelineWriter(preserve=True)``), so an aggregator crash costs
at most the epochs the clients still hold in their spill queues — which they
re-deliver.
"""

from __future__ import annotations

import json
import os
import re
import signal
import threading
import time
from dataclasses import dataclass, field
from collections.abc import Mapping

from repro.core.calltree import CallTree
from repro.core.snapshot import (
    K_FULL,
    EpochMeta,
    SnapshotError,
    TimelineReader,
    TimelineWriter,
)

from .profiles import REGION_FILENAME, TARGETS_DIRNAME, TIMELINE_DIRNAME
from .push import H_BOOT, H_DONE, H_EPOCH, H_INTERVAL, H_NODE, H_TARGETS, decode_push_body

__all__ = [
    "Aggregator",
    "AggregatorConfig",
    "AggregatorSource",
    "COARSE_TIMELINE_DIRNAME",
    "NODE_STALLED",
    "NODE_RECOVERED",
]

COARSE_TIMELINE_DIRNAME = "timeline_coarse"
NODE_SIDECAR = "node.json"
NODE_BASE = "base.json"

NODE_STALLED = "NODE_STALLED"
NODE_RECOVERED = "NODE_RECOVERED"

_NODE_NAME_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9._-]{0,63}$")


def _atomic_write(path: str, text: str) -> None:
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)


@dataclass
class AggregatorConfig:
    out_dir: str
    region: str = "region"
    host: str = "127.0.0.1"
    port: int = 0
    # Fleet seal + publish cadence.  Node pushes apply immediately (and seal
    # the per-node ring synchronously, before the 200 — that is the
    # crash-safety contract); the merged fleet epoch is sealed on this clock.
    epoch_s: float = 2.0
    epochs_per_segment: int = 16
    max_segments: int = 64
    # Long-horizon ring: one keyframe every `coarse_every` fleet epochs,
    # one keyframe per segment, up to `coarse_segments` segments.
    coarse_every: int = 8
    coarse_segments: int = 256
    # A node is stalled after stall_factor * its announced push interval
    # without a push (floored so sub-second test intervals don't flap).
    stall_factor: float = 1.5
    stall_floor_s: float = 0.25
    default_interval_s: float = 5.0
    max_body_bytes: int = 8 << 20
    hot_k: int = 10
    max_seconds: float | None = None
    fsync: bool = False

    def timeline_dir(self) -> str:
        return os.path.join(self.out_dir, TIMELINE_DIRNAME)

    def coarse_dir(self) -> str:
        return os.path.join(self.out_dir, COARSE_TIMELINE_DIRNAME)


@dataclass
class _NodeState:
    name: str
    boot: str | None = None
    # `base` holds dead incarnations' final cumulatives; `cum` is the live
    # incarnation.  The node's contribution to the fleet is base + cum.
    base: CallTree | None = None
    cum: CallTree = field(default_factory=CallTree)
    # Dedup state: every epoch <= floor is applied; `applied` holds the
    # sparse out-of-order epochs above it.
    floor: int = -1
    applied: set = field(default_factory=set)
    ring_epoch: int = 0  # monotonic across incarnations *and* restarts
    incarnations: int = 0
    targets: list = field(default_factory=list)
    interval_s: float = 5.0
    done: bool = False
    stalled: bool = False
    last_push_mono: float = 0.0
    last_push_wall: float = 0.0
    writer: TimelineWriter | None = None
    epochs_applied: int = 0
    duplicates: int = 0
    stale: int = 0
    bytes_received: int = 0

    def effective(self) -> CallTree:
        """This node's full contribution (do not mutate the result)."""
        if self.base is None:
            return self.cum
        out = self.base.copy()
        out.merge(self.cum)
        return out

    def is_applied(self, epoch: int) -> bool:
        return epoch <= self.floor or epoch in self.applied

    def mark_applied(self, epoch: int) -> None:
        self.applied.add(epoch)
        while self.floor + 1 in self.applied:
            self.floor += 1
            self.applied.discard(self.floor)


class Aggregator:
    """Ingest pushed epochs, maintain per-node + fleet state, publish."""

    def __init__(self, cfg: AggregatorConfig):
        self.cfg = cfg
        self.out_dir = cfg.out_dir
        os.makedirs(self.out_dir, exist_ok=True)
        self._lock = threading.RLock()
        self.nodes: dict[str, _NodeState] = {}
        self.events: list[dict] = []
        self._fleet_tree = CallTree()
        self._fleet_prev: CallTree | None = None
        self._fleet_epoch = 0
        self._dirty = False
        self._stop_requested = False
        self._t_start = time.monotonic()
        self.server = None
        self._recent = TimelineWriter(
            cfg.timeline_dir(),
            epochs_per_segment=cfg.epochs_per_segment,
            max_segments=cfg.max_segments,
            fsync=cfg.fsync,
            preserve=True,
        )
        self._coarse = TimelineWriter(
            cfg.coarse_dir(),
            epochs_per_segment=1,
            max_segments=cfg.coarse_segments,
            fsync=cfg.fsync,
            preserve=True,
        )
        self._restore()

    # -- events --------------------------------------------------------------

    def _record_event(self, ev: dict) -> None:
        self.events.append(ev)
        try:
            with open(os.path.join(self.out_dir, "events.jsonl"), "a") as f:
                f.write(json.dumps(ev) + "\n")
        except OSError:
            pass

    # -- restart recovery ----------------------------------------------------

    def _node_dir(self, name: str) -> str:
        return os.path.join(self.out_dir, TARGETS_DIRNAME, name)

    def _restore(self) -> None:
        """Rebuild per-node + fleet state from our own rings and sidecars.

        Runs before any writer appends (``TimelineWriter`` defers its purge
        to the first write, and these writers preserve anyway), so a crashed
        aggregator resumes with every node's cumulative, dedup floor and
        monotonic epoch numbering intact.
        """
        tdir = os.path.join(self.out_dir, TARGETS_DIRNAME)
        restored = 0
        if os.path.isdir(tdir):
            for name in sorted(os.listdir(tdir)):
                ring = os.path.join(self._node_dir(name), TIMELINE_DIRNAME)
                try:
                    last = TimelineReader(ring).last()
                except SnapshotError:
                    last = None
                if last is None:
                    continue
                meta, tree = last
                node = _NodeState(name=name, interval_s=self.cfg.default_interval_s)
                node.ring_epoch = meta.epoch + 1
                sidecar = None
                try:
                    with open(os.path.join(self._node_dir(name), NODE_SIDECAR)) as f:
                        sidecar = json.load(f)
                except (OSError, ValueError):
                    pass
                base = None
                try:
                    with open(os.path.join(self._node_dir(name), NODE_BASE)) as f:
                        base = CallTree.from_json(f.read())
                except (OSError, ValueError, KeyError):
                    pass
                if sidecar is not None:
                    # The ring seals the *effective* tree; the sidecar's boot
                    # + floor let the live incarnation's share be split back
                    # out (cum = effective - base), so a same-boot client can
                    # keep pushing deltas/keyframes without double-counting.
                    node.boot = sidecar.get("boot")
                    node.floor = int(sidecar.get("floor", -1))
                    node.incarnations = int(sidecar.get("incarnations", 0))
                    node.targets = list(sidecar.get("targets", []))
                    node.interval_s = float(
                        sidecar.get("interval_s", self.cfg.default_interval_s)
                    )
                    node.done = bool(sidecar.get("done", False))
                    node.base = base
                    node.cum = tree.diff(base) if base is not None else tree
                else:
                    # No sidecar: the live incarnation cannot be identified,
                    # so everything restored is treated as a dead base — the
                    # next push from any boot folds in on top.
                    node.base = tree
                    node.cum = CallTree()
                    node.floor = -1
                node.last_push_mono = time.monotonic()
                node.last_push_wall = time.time()
                self.nodes[name] = node
                restored += 1
        try:
            last = TimelineReader(self.cfg.timeline_dir()).last()
        except SnapshotError:
            last = None
        if last is not None:
            meta, tree = last
            self._fleet_prev = tree
            self._fleet_tree = tree
            self._fleet_epoch = meta.epoch + 1
        if restored:
            self._record_event(
                {"kind": "AGGREGATOR_RESTORED", "nodes": restored,
                 "fleet_epoch": self._fleet_epoch, "wall_time": time.time()}
            )

    # -- push ingest ---------------------------------------------------------

    def handle_push(self, headers: Mapping[str, str], body: bytes) -> tuple[int, dict]:
        """Apply one pushed epoch; called from HTTP handler threads.

        Returns ``(http_status, response_json_dict)``.  Anything wrong with
        the request itself — missing node, torn/corrupt frame, oversized
        body — is a clean 4xx; the 200 is sent only after the epoch is
        applied *and* sealed into the node's ring (crash-safety: an epoch
        the client saw acknowledged survives an aggregator restart).
        """
        if len(body) > self.cfg.max_body_bytes:
            return 413, {"error": f"body of {len(body)} bytes exceeds "
                                  f"{self.cfg.max_body_bytes}"}
        name = (headers.get(H_NODE) or "").strip()
        if not _NODE_NAME_RE.match(name):
            return 400, {"error": f"missing or invalid {H_NODE} header: {name!r}"}
        try:
            meta, tree = decode_push_body(body)
        except SnapshotError as e:
            return 400, {"error": f"bad push body: {e}"}
        boot = (headers.get(H_BOOT) or "").strip() or None
        done = headers.get(H_DONE) == "1"
        try:
            interval_s = float(headers.get(H_INTERVAL) or 0) or self.cfg.default_interval_s
        except ValueError:
            interval_s = self.cfg.default_interval_s
        targets = [t for t in (headers.get(H_TARGETS) or "").split(",") if t]
        with self._lock:
            return self._apply(name, boot, meta, tree, len(body),
                               interval_s=interval_s, targets=targets, done=done)

    def _apply(
        self,
        name: str,
        boot: str | None,
        meta: EpochMeta,
        tree: CallTree,
        n_bytes: int,
        *,
        interval_s: float,
        targets: list,
        done: bool,
    ) -> tuple[int, dict]:
        node = self.nodes.get(name)
        if node is None:
            node = self.nodes[name] = _NodeState(name=name)
            os.makedirs(self._node_dir(name), exist_ok=True)
            self._record_event(
                {"kind": "NODE_ATTACHED", "target": name, "boot": boot,
                 "wall_time": time.time()}
            )
        if boot is not None and node.boot is not None and boot != node.boot:
            self._fold_incarnation(node, boot)
        elif node.boot is None and boot is not None:
            if node.cum.total() or node.base is not None:
                # Restored without a sidecar: the old mass is already in
                # base; a known-boot client starting now is a new incarnation.
                self._fold_incarnation(node, boot)
            node.boot = boot
        now = time.monotonic()
        was_stalled = node.stalled
        node.last_push_mono = now
        node.last_push_wall = time.time()
        node.interval_s = interval_s
        if targets:
            node.targets = targets
        node.done = done
        node.bytes_received += n_bytes
        if was_stalled:
            node.stalled = False
            self._record_event(
                {"kind": NODE_RECOVERED, "detector": "liveness", "target": name,
                 "path": [], "share": 0.0, "wall_time": node.last_push_wall}
            )
        applied = False
        if node.is_applied(meta.epoch):
            node.duplicates += 1
        elif meta.kind == K_FULL:
            if meta.epoch >= max(node.applied, default=node.floor):
                # Replacement resync: the keyframe is the client's exact
                # cumulative, superseding every earlier epoch (including any
                # the client spilled and dropped).
                node.cum = tree
                node.floor = meta.epoch
                node.applied = {e for e in node.applied if e > node.floor}
                applied = True
            else:
                # A keyframe arriving after later deltas were applied cannot
                # replace (it would erase their mass); the client's next
                # keyframe resyncs exactly.
                node.stale += 1
        else:
            # Deltas are additive windows: they commute, so out-of-order
            # arrival within a keyframe era merges to the same cumulative.
            node.cum.merge(tree)
            node.mark_applied(meta.epoch)
            applied = True
        if applied:
            node.epochs_applied += 1
            self._dirty = True
            try:
                self._seal_node(node, meta, tree)
            except OSError as e:
                self._record_event(
                    {"kind": "TIMELINE_WRITE_FAILED", "target": name, "path": [],
                     "share": 0.0, "error": str(e), "wall_time": time.time()}
                )
        return 200, {
            "applied": applied,
            "duplicate": not applied and node.duplicates > 0,
            "epoch": meta.epoch,
            "node": name,
            "fleet_epoch": self._fleet_epoch,
        }

    def _fold_incarnation(self, node: _NodeState, new_boot: str) -> None:
        """A restarted node: retain the dead incarnation's mass in `base`."""
        if node.base is None:
            node.base = node.cum
        else:
            node.base.merge(node.cum)
        try:
            _atomic_write(
                os.path.join(self._node_dir(node.name), NODE_BASE),
                node.base.to_json(),
            )
        except OSError:
            pass
        node.cum = CallTree()
        node.applied = set()
        node.floor = -1
        node.incarnations += 1
        node.boot = new_boot
        node.done = False
        self._record_event(
            {"kind": "NODE_REBOOTED", "target": node.name,
             "incarnations": node.incarnations, "wall_time": time.time()}
        )

    def _seal_node(self, node: _NodeState, meta: EpochMeta, window: CallTree) -> None:
        """Seal one applied epoch into the node's ring + sidecar.

        Ring epoch numbering is the aggregator's own monotonic counter (the
        client's restarts at 0 per incarnation); ``progress`` carries the
        client's epoch so replay tooling can still see it.
        """
        if node.writer is None:
            node.writer = TimelineWriter(
                os.path.join(self._node_dir(node.name), TIMELINE_DIRNAME),
                epochs_per_segment=self.cfg.epochs_per_segment,
                max_segments=self.cfg.max_segments,
                fsync=self.cfg.fsync,
                preserve=True,
            )
        ring_meta = EpochMeta(node.ring_epoch, meta.wall_time, float(meta.epoch))
        if meta.kind == K_FULL or node.writer.needs_keyframe():
            node.writer.append_full(node.effective(), ring_meta)
        else:
            node.writer.append_delta(window, ring_meta)
        node.ring_epoch += 1
        try:
            _atomic_write(
                os.path.join(self._node_dir(node.name), NODE_SIDECAR),
                json.dumps(
                    {
                        "node": node.name,
                        "boot": node.boot,
                        "floor": node.floor,
                        "incarnations": node.incarnations,
                        "targets": node.targets,
                        "interval_s": node.interval_s,
                        "done": node.done,
                        "epochs_applied": node.epochs_applied,
                    }
                ),
            )
        except OSError:
            pass

    # -- liveness ------------------------------------------------------------

    def check_liveness(self) -> None:
        now = time.monotonic()
        with self._lock:
            for node in self.nodes.values():
                if node.done or node.stalled or node.last_push_mono == 0.0:
                    continue
                timeout = max(
                    self.cfg.stall_floor_s, self.cfg.stall_factor * node.interval_s
                )
                silent = now - node.last_push_mono
                if silent > timeout:
                    node.stalled = True
                    self._record_event(
                        {"kind": NODE_STALLED, "detector": "liveness",
                         "target": node.name, "path": [], "share": 0.0,
                         "silent_s": round(silent, 3),
                         "timeout_s": round(timeout, 3),
                         "wall_time": time.time()}
                    )

    # -- fleet sealing + publication -----------------------------------------

    def fleet_tree(self) -> CallTree:
        with self._lock:
            return self._fleet_tree

    def seal_fleet_epoch(self, force: bool = False) -> bool:
        """Merge every node's contribution and seal one fleet epoch."""
        with self._lock:
            if not self._dirty and not force:
                return False
            fleet = CallTree()
            for node in self.nodes.values():
                fleet.merge(node.effective())
            wall = time.time()
            progress = float(sum(n.epochs_applied for n in self.nodes.values()))
            meta = EpochMeta(self._fleet_epoch, wall, progress)
            try:
                if self._fleet_prev is None or self._recent.needs_keyframe():
                    self._recent.append_full(fleet, meta)
                else:
                    self._recent.append_delta(fleet.diff(self._fleet_prev), meta)
                if self._fleet_epoch % self.cfg.coarse_every == 0:
                    self._coarse.append_full(
                        fleet, EpochMeta(self._fleet_epoch, wall, progress)
                    )
            except OSError as e:
                self._record_event(
                    {"kind": "TIMELINE_WRITE_FAILED", "target": "<fleet>",
                     "path": [], "share": 0.0, "error": str(e), "wall_time": wall}
                )
                return False
            self._fleet_prev = fleet
            self._fleet_tree = fleet
            self._fleet_epoch += 1
            self._dirty = False
            return True

    def node_row(self, node: _NodeState) -> dict:
        state = (
            "done" if node.done
            else "STALLED" if node.stalled
            else "live"
        )
        return {
            "node": node.name,
            "state": state,
            "done": node.done,
            "stalled": node.stalled,
            "alive": not node.done and not node.stalled,
            "boot": node.boot,
            "incarnations": node.incarnations,
            "epochs_applied": node.epochs_applied,
            "duplicates": node.duplicates,
            "stale": node.stale,
            "bytes": node.bytes_received,
            "mass": node.effective().total(),
            "interval_s": node.interval_s,
            "last_push_age_s": round(
                max(0.0, time.monotonic() - node.last_push_mono), 3
            ) if node.last_push_mono else None,
            "targets": list(node.targets),
        }

    def status(self) -> dict:
        with self._lock:
            nodes = {name: self.node_row(n) for name, n in sorted(self.nodes.items())}
            fleet = self._fleet_tree
            return {
                "aggregator": True,
                "region": self.cfg.region,
                "alive": True,
                "done": bool(nodes) and all(r["done"] for r in nodes.values()),
                "stalled": any(r["stalled"] for r in nodes.values()),
                "n_nodes": len(nodes),
                "n_targets": sum(len(r["targets"]) for r in nodes.values()),
                "nodes": nodes,
                "fleet": {
                    "epochs": self._fleet_epoch,
                    "mass": fleet.total(),
                    "call_sites": fleet.node_count(),
                    "epochs_applied": sum(r["epochs_applied"] for r in nodes.values()),
                    "duplicates": sum(r["duplicates"] for r in nodes.values()),
                    "bytes": sum(r["bytes"] for r in nodes.values()),
                },
                "timeline": {
                    "dir": self.cfg.timeline_dir(),
                    "coarse_dir": self.cfg.coarse_dir(),
                    "epochs": self._fleet_epoch,
                    "epoch_s": self.cfg.epoch_s,
                    "coarse_every": self.cfg.coarse_every,
                },
                "hot_paths": [
                    {"path": list(p), "share": round(s, 4)}
                    for p, s in fleet.hot_paths(k=self.cfg.hot_k)
                ],
                "events": self.events[-20:],
                "updated": time.time(),
            }

    def hierarchy(self) -> dict:
        """The region -> node -> target tree behind hierarchical /targets."""
        with self._lock:
            nodes = []
            for name, node in sorted(self.nodes.items()):
                row = self.node_row(node)
                row["name"] = name
                row["targets"] = [{"name": t} for t in node.targets]
                nodes.append(row)
            return {"region": self.cfg.region, "nodes": nodes}

    def publish(self) -> None:
        status = self.status()
        _atomic_write(
            os.path.join(self.out_dir, "tree.json"), self.fleet_tree().to_json()
        )
        _atomic_write(os.path.join(self.out_dir, "status.json"), json.dumps(status))
        _atomic_write(
            os.path.join(self.out_dir, REGION_FILENAME), json.dumps(self.hierarchy())
        )
        with self._lock:
            for name, node in self.nodes.items():
                tdir = self._node_dir(name)
                try:
                    os.makedirs(tdir, exist_ok=True)
                    _atomic_write(
                        os.path.join(tdir, "tree.json"), node.effective().to_json()
                    )
                except OSError:
                    pass

    # -- serving + main loop -------------------------------------------------

    def enable_serving(self, port: int | None = None, host: str | None = None):
        from .server import ProfileServer

        if self.server is not None:
            return self.server
        self.server = ProfileServer(
            AggregatorSource(self),
            host=host if host is not None else self.cfg.host,
            port=port if port is not None else self.cfg.port,
            push_sink=self.handle_push,
            push_max_bytes=self.cfg.max_body_bytes,
        ).start()
        self._record_event(
            {"kind": "SERVING", "path": [], "share": 0.0, "url": self.server.url,
             "wall_time": time.time()}
        )
        return self.server

    def request_stop(self) -> None:
        self._stop_requested = True

    def run(self) -> CallTree:
        """Serve + seal/publish until SIGTERM-style stop or ``max_seconds``."""
        self.enable_serving()
        next_epoch = time.monotonic() + self.cfg.epoch_s
        self.publish()  # the artifact shape exists from second zero
        while not self._stop_requested:
            now = time.monotonic()
            if now >= next_epoch:
                self.check_liveness()
                self.seal_fleet_epoch()
                self.publish()
                next_epoch = now + self.cfg.epoch_s
            if (
                self.cfg.max_seconds is not None
                and now - self._t_start >= self.cfg.max_seconds
            ):
                break
            time.sleep(min(0.1, self.cfg.epoch_s / 4))
        self.check_liveness()
        self.seal_fleet_epoch(force=self._dirty)
        self.publish()
        self.close()
        return self.fleet_tree()

    def install_signal_handlers(self) -> None:
        def _stop(signum, frame):
            self.request_stop()

        signal.signal(signal.SIGTERM, _stop)
        signal.signal(signal.SIGINT, _stop)

    def close(self) -> None:
        if self.server is not None:
            self.server.stop()
            self.server = None
        with self._lock:
            self._recent.close()
            self._coarse.close()
            for node in self.nodes.values():
                if node.writer is not None:
                    node.writer.close()


class AggregatorSource:
    """Serve a live aggregator through the standard query plane.

    The duck type matches ``LiveSource``/``OfflineSource``: ``/tree`` without
    a target is the merged fleet tree, ``?target=<node>`` is that node's
    contribution, ``/timeline`` serves the fleet ring (per-node rings via
    ``?target=``), and ``/targets`` goes hierarchical.
    """

    def __init__(self, agg: Aggregator):
        self.agg = agg
        self.label = f"region:{agg.cfg.region}"

    def status(self) -> dict:
        return self.agg.status()

    def tree(self, target: str | None = None) -> CallTree:
        if target is None:
            return self.agg.fleet_tree()
        with self.agg._lock:
            node = self.agg.nodes.get(target)
            if node is None:
                from .profiles import ProfileLoadError

                known = ", ".join(sorted(self.agg.nodes)) or "<none yet>"
                raise ProfileLoadError(f"unknown node {target!r} (nodes: {known})")
            return node.effective().copy()

    def targets(self) -> list[dict]:
        out = []
        for row in self.agg.hierarchy()["nodes"]:
            flat = dict(row)
            flat["targets"] = [t["name"] for t in row["targets"]]
            out.append(flat)
        return out

    def targets_hierarchy(self) -> dict:
        h = self.agg.hierarchy()
        return {"region": h["region"], "targets": self.targets(), "nodes": h["nodes"]}

    def device_tree(self, target: str | None = None):
        return None

    def timeline_dir(self, target: str | None = None) -> str | None:
        if target is None:
            return self.agg.cfg.timeline_dir()
        return os.path.join(self.agg._node_dir(target), TIMELINE_DIRNAME)
