"""Multi-source ingest plane: one daemon draining a fleet of spools.

The paper's design point is a *single* external profiler observing the whole
simulated system; this module is the fan-in that makes one daemon process
scale to N targets.  Two pieces:

* :class:`SpoolSource` — everything one attached target owns: an
  :class:`~repro.profilerd.pipeline.IngestPipeline` (reader -> decoder ->
  ingestor -> sealer, vectorized when numpy is available, so dispatch
  between sources happens per *chunk*, never per sample), per-target
  dominance/trend detectors, an optional per-target timeline ring, stall
  bookkeeping, and crash-and-restart re-attach (a restarted writer recreates
  the spool file; the old mmap is drained dry, then the reader/decoder and
  every ``stack_id``-keyed cache are rebuilt against the new incarnation).
* :class:`SpoolSet`  — attach/discovery plus fair draining: explicit paths
  attach as they appear, a ``--watch`` directory is rescanned every drain
  pass so spools created *after* the daemon started are picked up within one
  drain interval, and :meth:`SpoolSet.drain_all` cycles the sources
  round-robin in bounded (1 MiB) chunks so one backlogged target cannot
  starve the others.

The daemon (:mod:`repro.profilerd.daemon`) composes these into per-target
trees plus a continuously merged fleet tree, publishes both to the query
plane, and epoch-seals per-target rings merged at seal time.
"""

from __future__ import annotations

import fnmatch
import os
import random
import time
from collections import deque
from collections.abc import Callable, Sequence

from repro.core.calltree import CallTree
from repro.core.detector import DominanceDetector, Rule, TrendDetector, TrendRule
from repro.core.snapshot import EpochMeta, TimelineWriter

from .pipeline import IngestPipeline
from .spool import SpoolError, SpoolReader
from .wire import Bye, Hello, Rusage

STALLED = "TARGET_STALLED"
RESUMED = "TARGET_RESUMED"


def _pid_alive(pid: int) -> bool:
    if pid <= 0:
        return False
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True
    return True


def source_name_for(path: str) -> str:
    """Default target name: the spool's basename minus its extension."""
    base = os.path.basename(path)
    if base.endswith(".spool"):
        base = base[: -len(".spool")]
    return base or "target"


class SpoolSource:
    """One attached target: reader -> decoder -> resolver -> ingestor -> tree."""

    def __init__(
        self,
        name: str,
        path: str,
        *,
        reader: SpoolReader | None = None,
        collapse_origins: Sequence[str] = (),
        rules: Sequence[Rule] | None = None,
        trend_rule: TrendRule | None = None,
        timeline_dir: str | None = None,
        epochs_per_segment: int = 16,
        max_segments: int = 64,
        timeline_cap: int = 2048,
    ):
        self.name = name
        self.path = path
        self.detector = DominanceDetector(list(rules) if rules else [Rule()])
        self.timeline_writer: TimelineWriter | None = None
        self.trend: TrendDetector | None = None
        if timeline_dir is not None:
            self.timeline_writer = TimelineWriter(
                timeline_dir,
                epochs_per_segment=epochs_per_segment,
                max_segments=max_segments,
            )
            self.trend = TrendDetector(trend_rule)
        # The whole decode -> accumulate -> seal path lives in the pipeline;
        # the source owns target policy (stall/re-attach/detectors/status).
        self.pipeline = IngestPipeline(
            reader if reader is not None else SpoolReader(path),
            collapse_origins=collapse_origins,
            timeline_writer=self.timeline_writer,
            depth_timeline=deque(maxlen=timeline_cap),  # (t, depth)
        )
        self.tree = self.pipeline.tree
        self.rusage: deque = deque(maxlen=timeline_cap)
        self.target_pid = self.reader.writer_pid
        self.period_s = 0.0
        self.wire_version = 0  # from HELLO; 0 until the target announced
        self.n_stacks = 0
        self.n_ticks_reported = 0
        self.bye_seen = False
        self.stalled = False
        self.resumed_pending = False  # stalled->live edge awaiting an event
        self.restarts = 0
        self.drained_bytes = 0
        self.backlog_bytes = 0
        self.samples_since_publish = 0
        # The last published immutable tree copy (query-plane handoff).
        self.last_snapshot: CallTree | None = None
        self.attached_wall = time.monotonic()
        self._last_sample_wall: float | None = None
        # Re-attach carries this across reader incarnations (decoder loss
        # counters carry inside the pipeline).
        self._dropped_base = 0

    # -- pipeline views ------------------------------------------------------

    @property
    def reader(self) -> SpoolReader | None:
        return self.pipeline.reader

    @reader.setter
    def reader(self, value: SpoolReader | None) -> None:
        self.pipeline.reader = value

    @property
    def decoder(self):
        return self.pipeline.decoder

    @property
    def resolver(self):
        return self.pipeline.resolver

    @property
    def ingestor(self):
        return self.pipeline.ingestor

    @property
    def sealer(self):
        return self.pipeline.sealer

    @property
    def timeline(self) -> deque:
        return self.pipeline.depth_timeline

    # -- aggregate counters --------------------------------------------------

    @property
    def alive(self) -> bool:
        return _pid_alive(self.target_pid)

    @property
    def dropped_batches(self) -> int:
        if self.reader is None:  # closed: the base holds the final count
            return self._dropped_base
        return self._dropped_base + self.reader.dropped

    @property
    def unknown_stack_refs(self) -> int:
        return self.pipeline.unknown_stack_refs

    @property
    def degraded_stackdefs(self) -> int:
        return self.pipeline.degraded_stackdefs

    # -- ingest --------------------------------------------------------------

    def _apply(self, ev) -> None:
        """Target policy for the pipeline's non-sample events."""
        if isinstance(ev, Hello):
            self.target_pid = ev.pid
            self.period_s = ev.period_s
            self.wire_version = ev.version
        elif isinstance(ev, Rusage):
            self.rusage.append((ev.t, ev.cpu_s, ev.rss_bytes))
        elif isinstance(ev, Bye):
            self.bye_seen = True
            self.n_ticks_reported += ev.n_ticks

    def drain_chunk(self) -> int:
        """One bounded read (1 MiB cap) decoded and ingested; returns bytes.

        The cap is the fairness unit: :meth:`SpoolSet.drain_all` interleaves
        chunks across sources, so a minutes-deep backlog on one target
        streams through without starving the rest.
        """
        before = self.pipeline.samples
        nbytes, events = self.pipeline.drain_chunk()
        fresh = self.pipeline.samples - before
        if fresh:
            self.n_stacks += fresh
            self.samples_since_publish += fresh
            self._last_sample_wall = time.monotonic()
            if self.stalled:
                self.resumed_pending = True  # recovery is an event, not silence
            self.stalled = False
        for ev in events:
            self._apply(ev)
        if nbytes:
            self.drained_bytes += nbytes
        self.backlog_bytes = self.reader.backlog
        # The writer sets the header flag even when the BYE *record* was
        # dropped on a full spool; honor it so a cleanly stopped target is
        # never mistaken for a stalled one.
        if self.reader.bye_seen:
            self.bye_seen = True
        return nbytes

    def maybe_reattach(self) -> bool:
        """Re-attach to a recreated spool (writer crash-and-restart).

        The old incarnation's mmap outlives the rename, so it is drained dry
        first — nothing the dead writer committed is lost.  Then the reader
        and decoder are rebuilt and every ``stack_id``-keyed cache is reset
        (a restarted writer re-assigns ids from 0 for different stacks), the
        pid/stall/bye state flips back to live, and counters carry over.  A
        half-created replacement (``SpoolError``) is retried next pass.
        """
        if not self.reader.replaced():
            return False
        try:
            fresh = SpoolReader(self.path)
        except SpoolError:
            return False
        while self.drain_chunk():
            pass
        self._dropped_base += self.reader.dropped
        self.reader.close()
        self.reader = fresh
        self.pipeline.reset_stream()
        self.target_pid = fresh.writer_pid
        self.period_s = 0.0  # until the new HELLO arrives
        self.bye_seen = False  # a stale bye=1 belongs to the dead incarnation
        self.stalled = False
        self.backlog_bytes = fresh.backlog
        self._last_sample_wall = time.monotonic()
        self.restarts += 1
        return True

    # -- analysis ------------------------------------------------------------

    def check_stall(self, stall_timeout_s: float) -> dict | None:
        """Silence from a live target beyond the timeout -> a STALLED event."""
        if self.bye_seen or self.stalled:
            return None
        ref = self._last_sample_wall
        if ref is None:
            ref = self.attached_wall  # attached but never saw a sample
        silent = time.monotonic() - ref
        # A slow-ticking but healthy target must not look stalled: silence is
        # only suspicious once it clearly exceeds the publisher's own period.
        timeout = max(stall_timeout_s, 3.0 * self.period_s)
        if silent >= timeout and _pid_alive(self.target_pid):
            self.stalled = True
            return {
                "kind": STALLED,
                "detector": "stall",
                "target": self.name,
                "path": [],
                "share": 1.0,
                "silent_s": round(silent, 3),
                "pid": self.target_pid,
                "wall_time": time.time(),
            }
        return None

    def publish_window(self) -> CallTree | None:
        """Snapshot + run the dominance detector if samples arrived; returns
        the new immutable tree copy (None on a quiet window)."""
        if not self.samples_since_publish:
            return None
        snap = self.tree.copy()
        self.last_snapshot = snap
        self.detector.observe(snap)
        self.samples_since_publish = 0
        return snap

    def seal_epoch(self, wall_time: float) -> tuple[EpochMeta | None, list]:
        """Seal this target's epoch into its ring; returns (meta, verdicts)."""
        meta, entries = self.pipeline.seal_epoch(wall_time)
        if meta is None:
            return None, []
        verdicts: list = []
        if self.trend is not None:
            # The trend window: rebuilt from the epoch's (chain, count) pairs —
            # untracked mutations (v1 samples) are invisible here, which only
            # softens detection for legacy spools, never ring correctness.
            window = CallTree()
            for e in entries:
                if e[3] > 0:
                    window.add_stack([n.name for n in e[0][1:]], {"samples": float(e[3])})
            verdicts = self.trend.observe_epoch(
                window, progress=meta.progress, epoch=meta.epoch, wall_time=meta.wall_time
            )
        return meta, verdicts

    def status_row(self) -> dict:
        return {
            "path": self.path,
            "pid": self.target_pid,
            "alive": self.alive,
            "stalled": self.stalled,
            "done": self.bye_seen,
            "period_s": self.period_s,
            "wire_version": self.wire_version,
            "n_stacks": self.n_stacks,
            "n_ticks": self.n_ticks_reported,
            "dropped_batches": self.dropped_batches,
            "backlog_bytes": self.backlog_bytes,
            "drained_bytes": self.drained_bytes,
            "restarts": self.restarts,
            "unknown_stack_refs": self.unknown_stack_refs,
            "degraded_stackdefs": self.degraded_stackdefs,
            "ingest": self.ingest_stats(),
        }

    def ingest_stats(self) -> dict:
        """The unified ``ingest_stats`` dict for this target (schema in
        :mod:`repro.profilerd.pipeline`)."""
        return self.pipeline.ingest_stats()

    def close(self) -> None:
        if self.timeline_writer is not None:
            self.timeline_writer.close()
        if self.reader is not None:
            # Fold the reader-backed counters into the source so status()
            # keeps working after the mmap is gone.
            self._dropped_base += self.reader.dropped
            if self.reader.bye_seen:
                self.bye_seen = True
            self.reader.close()
            self.reader = None


class SpoolSet:
    """Attach and drain N spools: explicit paths plus ``--watch`` discovery.

    ``make_source(name, path)`` is the daemon's factory — it builds the
    :class:`SpoolSource` (per-target timeline dir, detector wiring, events)
    and returns None on a transient attach failure, which keeps the path
    pending for the next pass.
    """

    def __init__(
        self,
        *,
        paths: Sequence[str] = (),
        watch_dir: str | None = None,
        watch_glob: str = "*.spool",
        make_source: Callable[[str, str], SpoolSource | None],
        attach_retry_base_s: float = 0.5,
        attach_retry_cap_s: float = 30.0,
        attach_max_attempts: int = 8,
    ):
        self.sources: dict[str, SpoolSource] = {}  # insertion order = rotation
        self.watch_dir = watch_dir
        self.watch_glob = watch_glob
        self._make = make_source
        self._pending: dict[str, None] = dict.fromkeys(paths)
        self._attached_paths: set[str] = set()
        # Attach failures back off exponentially (with jitter, so a fleet of
        # daemons never stampedes a shared filesystem in lockstep) instead of
        # retrying every drain pass; after the budget the path is parked as
        # given-up — visible, terminal, and only revived if the file changes.
        self.attach_retry_base_s = attach_retry_base_s
        self.attach_retry_cap_s = attach_retry_cap_s
        self.attach_max_attempts = attach_max_attempts
        # path -> {"attempts": int, "next_t": monotonic, "fingerprint": (sz, mtime_ns)}
        self._backoff: dict[str, dict] = {}
        self._given_up: dict[str, dict] = {}
        self.gave_up_now: list[str] = []  # drained by the daemon per pass

    @staticmethod
    def _fingerprint(path: str) -> tuple[int, int] | None:
        try:
            st = os.stat(path)
        except OSError:
            return None
        return (st.st_size, st.st_mtime_ns)

    def _note_attach_failure(self, path: str) -> None:
        state = self._backoff.setdefault(
            path, {"attempts": 0, "next_t": 0.0, "fingerprint": None}
        )
        state["attempts"] += 1
        state["fingerprint"] = self._fingerprint(path)
        if state["attempts"] >= self.attach_max_attempts:
            self._given_up[path] = self._backoff.pop(path)
            self.gave_up_now.append(path)
            return
        delay = min(
            self.attach_retry_cap_s,
            self.attach_retry_base_s * (2.0 ** (state["attempts"] - 1)),
        )
        state["next_t"] = time.monotonic() + delay * random.uniform(0.8, 1.2)

    def _attach_allowed(self, path: str) -> bool:
        gave = self._given_up.get(path)
        if gave is not None:
            # A rewritten file is a new incarnation: one fresh budget.
            if self._fingerprint(path) != gave["fingerprint"]:
                del self._given_up[path]
                self._backoff.pop(path, None)
                return True
            return False
        state = self._backoff.get(path)
        return state is None or time.monotonic() >= state["next_t"]

    def attach_failure_rows(self) -> list[dict]:
        """Backoff/give-up state for status(), ``/targets`` and ``top``."""
        now = time.monotonic()
        rows = []
        for path, state in self._backoff.items():
            rows.append(
                {
                    "path": path,
                    "attempts": state["attempts"],
                    "gave_up": False,
                    "retry_in_s": round(max(0.0, state["next_t"] - now), 3),
                }
            )
        for path, state in self._given_up.items():
            rows.append({"path": path, "attempts": state["attempts"], "gave_up": True})
        return rows

    def name_for(self, path: str) -> str:
        name = source_name_for(path)
        if name in self.sources:
            i = 2
            while f"{name}-{i}" in self.sources:
                i += 1
            name = f"{name}-{i}"
        return name

    def adopt(self, source: SpoolSource) -> SpoolSource:
        """Register an externally-constructed source (solo blocking attach)."""
        self.sources[source.name] = source
        self._attached_paths.add(source.path)
        self._pending.pop(source.path, None)
        return source

    @property
    def all_explicit_attached(self) -> bool:
        return not self._pending

    def abandon_pending(self) -> list[str]:
        """Give up on explicit paths that never attached; returns them.

        The daemon calls this once the attach window closes, so a typo'd or
        never-created ``--targets`` path cannot keep the run from exiting
        after every real target finished."""
        gone = list(self._pending)
        self._pending.clear()
        return gone

    def discover(self) -> list[SpoolSource]:
        """One attach pass: pending explicit paths + new watch-dir spools."""
        candidates = list(self._pending)
        if self.watch_dir is not None:
            try:
                entries = sorted(os.listdir(self.watch_dir))
            except OSError:
                entries = []
            for e in entries:
                if fnmatch.fnmatch(e, self.watch_glob):
                    p = os.path.join(self.watch_dir, e)
                    if p not in self._attached_paths and p not in self._pending:
                        candidates.append(p)
        fresh: list[SpoolSource] = []
        for p in candidates:
            if p in self._attached_paths or not os.path.exists(p):
                continue
            if not self._attach_allowed(p):
                continue  # backing off, or given up on this incarnation
            src = self._make(self.name_for(p), p)
            if src is None:
                self._note_attach_failure(p)
                continue  # half-created / unreadable; retried with backoff
            self._backoff.pop(p, None)
            self._given_up.pop(p, None)
            fresh.append(self.adopt(src))
        return fresh

    def drain_all(self) -> int:
        """Drain every source dry, round-robin in bounded chunks.

        Each rotation reads at most one capped chunk per source; sources that
        returned bytes stay in the rotation, so all backlogs shrink together
        instead of head-of-line blocking on the deepest one.
        """
        total = 0
        busy = list(self.sources.values())
        while busy:
            still = []
            for s in busy:
                n = s.drain_chunk()
                total += n
                if n:
                    still.append(s)
            busy = still
        return total
