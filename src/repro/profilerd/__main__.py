"""``python -m repro.profilerd`` — attach the profiling daemon to a running job.

Typical flow (the paper's workflow, one process over):

  # terminal 1: run a job that publishes raw frames to a spool
  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --profile \\
      --backend daemon --spool /tmp/serve.spool

  # terminal 2: attach, watch live hot paths, get a report at the end
  PYTHONPATH=src python -m repro.profilerd attach --spool /tmp/serve.spool --follow

Subcommands:

  attach   — drain one or more spools until every target says BYE (or dies),
             publishing status.json / tree.json / events.jsonl / report.html
             / timeline/ under --out (default <spool>.d); one daemon attaches
             a whole fleet: --targets a.spool,b.spool names explicit spools,
             --watch DIR discovers spools created after the daemon starts
             (per-target artifacts land under <out>/targets/<name>/, the
             merged fleet tree stays at <out>/tree.json; a --watch daemon
             runs until SIGTERM, which triggers a clean final drain+publish);
             --follow prints live hot paths; --serve PORT exposes the live
             HTTP query plane while attached; --push URL ships each sealed
             epoch to a regional aggregator.
  aggregate— regional fleet tier: ingest epochs POSTed by node daemons
             (attach --push) into per-node timeline rings + a merged fleet
             tree with downsampled long-term retention, serving the same
             query plane (/targets goes region -> node -> target).
  serve    — HTTP API (/status /targets /tree /timeline /diff) over an
             *offline* profile artifact (daemon out dir — multi-target dirs
             serve /tree?target=NAME too — timeline ring, tree.json, .snap);
             pointing it at a dir a daemon is still writing works too.
  top      — refreshing terminal view of the hottest paths + verdicts,
             polling a serve/attach --serve endpoint.
  export   — render a profile as folded stacks, speedscope JSON, flamegraph
             HTML, or a view CSV (exit 4 when --view/--root matches nothing).
  status   — print the latest status.json published by a running daemon.
  report   — render an HTML report from a previously dumped tree.json.
  timeline — phase segmentation + per-epoch table over a sealed timeline ring.
  diff     — cross-run tree diff with per-node share deltas; --html writes the
             share-delta flamegraph (red = candidate grew).
  check    — gate a profile against a baseline snapshot (CI): exit 0 on pass,
             2 on share regression beyond --tolerance, 3 on unreadable input.

``serve``/``export``/``timeline``/``diff``/``check`` accept profiles in any
of these shapes: a daemon --out dir (uses its ``timeline/`` ring, falling
back to ``tree.json``), a timeline ring dir, a ``tree.json`` dump, or a
binary ``.snap`` snapshot (``repro.core.snapshot.save_snapshot``).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from repro.core.detector import Rule, TrendRule
from repro.core.planes import PLANES, PlaneError, default_metric, select_plane

from .daemon import DaemonConfig, ProfilerDaemon, rule_from_spec
from .profiles import (
    TIMELINE_DIRNAME,
    ProfileLoadError,
    load_device_plane,
    load_profile,
    load_static_plane,
)
from .spool import SpoolError

EXIT_REGRESSION = 2
EXIT_UNREADABLE = 3
EXIT_NO_MATCH = 4  # a --view/--root selector (or --plane artifact) matched nothing


def _resolve_plane(tree, profile_path: str, plane: str):
    """Apply ``--plane`` to a loaded profile via its own device artifact.

    Raises :class:`PlaneError` (caller exits ``EXIT_NO_MATCH`` with the remedy
    hint — a missing artifact is "selector matched nothing", not corruption)
    or :class:`ProfileLoadError` for a present-but-garbage artifact."""
    if plane == "host":
        return tree
    if plane == "static":
        return select_plane(
            tree, None, plane, profile=profile_path, static=load_static_plane(profile_path)
        )
    return select_plane(tree, load_device_plane(profile_path), plane, profile=profile_path)


def _print_status(d: ProfilerDaemon) -> None:
    s = d.status()
    state = "STALLED" if s["stalled"] else ("done" if s["done"] else "live")
    who = f"targets={s['n_targets']}" if s["n_targets"] > 1 else f"pid={s['pid']}"
    print(
        f"[profilerd] {who} {state} stacks={s['n_stacks']} "
        f"dropped={s['dropped_batches']} events={len(d.events)}"
    )
    for hp in s["hot_paths"][:5]:
        print(f"  {hp['share']:7.2%}  {'/'.join(hp['path'])}")


def cmd_attach(args) -> int:
    targets = tuple(t.strip() for t in (args.targets or "").split(",") if t.strip())
    if not (args.spool or targets or args.watch):
        print("[profilerd] attach needs --spool, --targets and/or --watch",
              file=sys.stderr)
        return 2
    rules = [Rule(threshold=args.threshold, consecutive=args.consecutive)]
    for spec in args.rule or ():
        try:
            rules.append(rule_from_spec(spec))
        except ValueError as e:
            print(f"[profilerd] {e}", file=sys.stderr)
            return 2
    trend_rule = None
    if args.trend_threshold is not None or args.trend_epochs is not None or args.trend_drift is not None:
        trend_rule = TrendRule()
        if args.trend_threshold is not None:
            trend_rule.threshold = args.trend_threshold
        if args.trend_epochs is not None:
            trend_rule.epochs = args.trend_epochs
        if args.trend_drift is not None:
            trend_rule.drift_threshold = args.trend_drift
    cfg = DaemonConfig(
        spool_path=args.spool,
        spool_paths=targets,
        watch_dir=args.watch,
        out_dir=args.out,
        publish_interval_s=args.interval,
        collapse_origins=tuple(o for o in (args.collapse or "").split(",") if o),
        rules=rules,
        trend_rule=trend_rule,
        stall_timeout_s=args.stall_timeout,
        attach_timeout_s=args.attach_timeout,
        max_seconds=args.max_seconds,
        epoch_s=args.epoch,
        serve_port=args.serve,
        exit_with_pid=args.exit_with,
        device_tree=args.device_tree,
        push_url=args.push,
        push_node=args.push_node,
    )
    daemon = ProfilerDaemon(cfg)
    # SIGTERM = finish cleanly: final drain + seal + publish + report.  This
    # is how a supervisor (the launcher's shared per-node daemon, CI) ends a
    # --watch run, which has no natural BYE to exit on.
    try:
        import signal

        signal.signal(signal.SIGTERM, lambda *_: daemon.request_stop())
    except ValueError:  # not the main thread (embedded use)
        pass
    try:
        daemon.attach()
        if args.serve is not None:
            try:
                print(f"[profilerd] live query plane: {daemon.enable_serving().url}", flush=True)
            except OSError as e:
                # A busy/privileged port must not cost the profiling run:
                # attach continues unserved, like the launcher's fallback.
                print(f"[profilerd] serve on port {args.serve} failed ({e}); "
                      "continuing without the query plane", file=sys.stderr)
        tree = daemon.run(on_publish=_print_status if args.follow else None)
    except SpoolError as e:
        print(f"[profilerd] {e}", file=sys.stderr)
        return 1
    out = cfg.resolved_out_dir()
    print(f"[profilerd] merged {daemon.n_stacks} stacks -> {os.path.join(out, 'tree.json')}")
    if len(daemon.sources) > 1 or args.watch:
        for s in daemon.sources:
            print(f"[profilerd] target {s.name}: stacks={s.n_stacks} "
                  f"dropped={s.dropped_batches} restarts={s.restarts} "
                  f"-> {os.path.join(out, 'targets', s.name, 'tree.json')}")
    print(f"[profilerd] report: {os.path.join(out, 'report.html')}")
    for ev in daemon.events:
        print(f"[profilerd] event: {json.dumps(ev)}")
    if tree.total() > 0:
        print(tree.render(min_share=0.02, max_depth=4))
    return 0


def cmd_aggregate(args) -> int:
    from .aggregator import Aggregator, AggregatorConfig

    cfg = AggregatorConfig(
        out_dir=args.out,
        region=args.region,
        host=args.host,
        port=args.port,
        epoch_s=args.epoch,
        coarse_every=args.coarse_every,
        stall_factor=args.stall_factor,
        max_seconds=args.max_seconds,
    )
    agg = Aggregator(cfg)
    try:
        agg.install_signal_handlers()
    except ValueError:  # not the main thread (embedded use)
        pass
    try:
        server = agg.enable_serving()
    except OSError as e:
        print(f"[profilerd] cannot bind {args.host}:{args.port}: {e}", file=sys.stderr)
        return 1
    print(f"[profilerd] aggregating region {cfg.region!r} at {server.url} "
          f"(push endpoint {server.url}/push) -> {args.out}", flush=True)
    try:
        tree = agg.run()
    except KeyboardInterrupt:
        agg.request_stop()
        tree = agg.fleet_tree()
        agg.close()
    status = agg.status()
    print(f"[profilerd] fleet: nodes={status['n_nodes']} "
          f"epochs={status['fleet']['epochs']} mass={tree.total():.6g} "
          f"-> {os.path.join(args.out, 'tree.json')}")
    return 0


def cmd_serve(args) -> int:
    from .server import OfflineSource, ProfileServer

    source = OfflineSource(args.profile)
    try:
        source.tree()  # fail fast on an unreadable profile
    except ProfileLoadError as e:
        print(f"[profilerd] {e}", file=sys.stderr)
        return EXIT_UNREADABLE
    try:
        server = ProfileServer(
            source, host=args.host, port=args.port, baseline=args.baseline, verbose=args.verbose
        )
    except OSError as e:  # busy/privileged port: message, not a traceback
        print(f"[profilerd] cannot bind {args.host}:{args.port}: {e}", file=sys.stderr)
        return 1
    print(f"[profilerd] serving {args.profile} at {server.url}")
    print(f"[profilerd] endpoints: {server.url}/status /targets /tree /timeline /diff (see /help)")
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        print("[profilerd] bye")
    return 0


def cmd_top(args) -> int:
    from .server import top_loop

    try:
        return top_loop(args.url, interval_s=args.interval, k=args.k, once=args.once,
                        plane=args.plane)
    except KeyboardInterrupt:
        return 0


def cmd_export(args) -> int:
    from repro.core.export import EXPORT_FORMATS, diff_flamegraph_html, export_tree, prepare_view
    from repro.core.report import ViewConfig

    try:
        tree = _resolve_plane(load_profile(args.profile), args.profile, args.plane)
    except PlaneError as e:
        print(f"[profilerd] {e}", file=sys.stderr)
        return EXIT_NO_MATCH
    except ProfileLoadError as e:
        print(f"[profilerd] {e}", file=sys.stderr)
        return EXIT_UNREADABLE
    metric_arg = default_metric(args.plane, args.metric)
    fmt = args.fmt or ("html" if args.baseline else "folded")
    view = None
    if args.view:
        from repro.core.views_library import VIEWS

        if args.view not in VIEWS:
            print(f"[profilerd] unknown view {args.view!r}; views: {', '.join(sorted(VIEWS))}",
                  file=sys.stderr)
            return EXIT_UNREADABLE
        view = VIEWS[args.view]
    # Ad-hoc selectors refine the named view (or stand alone without one).
    overrides = {k: v for k, v in
                 [("root", args.root), ("level", args.level), ("min_share", args.min_share)]
                 if v is not None}
    if view is not None and overrides:
        from dataclasses import replace

        view = replace(view, **overrides)
    elif view is None and overrides:
        view = ViewConfig(name=args.root or "adhoc", **overrides)
    # A selector that matches nothing must fail loudly, not ship an empty
    # artifact that reads as "this code path costs nothing".  prepare_view
    # applies zoom/filters/level/min_share exactly once and owns every
    # emptiness verdict (incl. fmt stacklessness, e.g. a level=0 fold).
    applied, metric, marker = prepare_view(tree, view, metric_arg, fmt=fmt)
    if marker is not None:
        print(f"[profilerd] {marker}", file=sys.stderr)
        if fmt == "csv":
            print(export_tree(tree, "csv", view=view, metric=metric_arg, title=args.profile))
        return EXIT_NO_MATCH
    if args.baseline:
        if fmt != "html":  # usage error, not an unreadable profile: exit 2
            print(f"[profilerd] --baseline renders a diff flamegraph; it requires "
                  f"--fmt html (got --fmt {fmt})", file=sys.stderr)
            return 2
        try:
            baseline = _resolve_plane(load_profile(args.baseline), args.baseline, args.plane)
        except PlaneError as e:
            print(f"[profilerd] baseline: {e}", file=sys.stderr)
            return EXIT_NO_MATCH
        except ProfileLoadError as e:
            print(f"[profilerd] {e}", file=sys.stderr)
            return EXIT_UNREADABLE
        # The baseline goes through the SAME prepare_view pipeline as the
        # candidate (incl. min_share pruning) — asymmetric filtering would
        # paint sub-threshold call-sites as phantom share deltas.
        baseline, _, _ = prepare_view(baseline, view, metric_arg)
        payload = diff_flamegraph_html(baseline, applied, metric,
                                       title=f"{args.baseline} vs {args.profile}")
    else:
        assert fmt in EXPORT_FORMATS
        title = os.path.basename(args.profile.rstrip("/")) or args.profile
        if args.plane != "host":
            title = f"{title} [{args.plane} plane]"
        if fmt == "csv":
            payload = export_tree(tree, "csv", view=view, metric=metric_arg, title=title)
        else:
            if view is not None:
                title = f"{title} [{view.name}]"
            payload = export_tree(applied, fmt, metric=metric, title=title,
                                  roofline=args.plane == "merged")
    if args.out:
        with open(args.out, "w") as f:
            f.write(payload)
        print(f"[profilerd] wrote {args.out} ({len(payload)} bytes, fmt={fmt})")
    else:
        print(payload)
    return 0


def cmd_status(args) -> int:
    path = os.path.join(args.out, "status.json")
    try:
        with open(path) as f:
            print(json.dumps(json.load(f), indent=1))
    except OSError as e:
        print(f"no status at {path}: {e}", file=sys.stderr)
        return 1
    return 0


def cmd_report(args) -> int:
    from repro.core.calltree import CallTree
    from repro.core.report import render_html

    with open(args.tree) as f:
        tree = CallTree.from_json(f.read())
    out = args.html or (os.path.splitext(args.tree)[0] + ".html")
    with open(out, "w") as f:
        f.write(render_html(tree, title=os.path.basename(args.tree)))
    print(out)
    return 0


def cmd_timeline(args) -> int:
    from repro.core.snapshot import SnapshotError, TimelineReader, is_timeline_dir
    from repro.core.views_library import phase_table, timeline_table

    store = args.store
    nested = os.path.join(store, TIMELINE_DIRNAME)
    if not is_timeline_dir(store) and is_timeline_dir(nested):
        store = nested
    if not is_timeline_dir(store):
        print(f"no timeline ring at {args.store}", file=sys.stderr)
        return EXIT_UNREADABLE
    reader = TimelineReader(store)
    epochs = []  # (meta, window, None): the reader's cumulative is a live
    final = None  # accumulator, so only the final state is retained here
    try:
        for meta, window, cum in reader.epochs():
            epochs.append((meta, window, None))
            final = cum
    except SnapshotError as e:  # e.g. version skew from a newer build
        print(f"[profilerd] {store}: {e}", file=sys.stderr)
        return EXIT_UNREADABLE
    if not epochs:
        print(f"{store}: timeline ring holds no decodable epochs", file=sys.stderr)
        return EXIT_UNREADABLE
    if reader.truncated:
        print("# note: torn/corrupt record(s) skipped (crash-safe append)", file=sys.stderr)
    print(phase_table(epochs, boundary=args.boundary, metric=args.metric))
    print()
    print(timeline_table(epochs, metric=args.metric))
    print(f"\ncumulative: {final.total(args.metric):.6g} {args.metric} over {final.node_count()} call sites")
    return 0


def cmd_diff(args) -> int:
    from repro.core.report import render_diff

    try:
        a = _resolve_plane(load_profile(args.a), args.a, args.plane)
        b = _resolve_plane(load_profile(args.b), args.b, args.plane)
    except PlaneError as e:
        print(f"[profilerd] {e}", file=sys.stderr)
        return EXIT_NO_MATCH
    except ProfileLoadError as e:
        print(f"[profilerd] {e}", file=sys.stderr)
        return EXIT_UNREADABLE
    metric = default_metric(args.plane, args.metric) or "samples"
    print(
        render_diff(
            a,
            b,
            metric=metric,
            label_a=os.path.basename(args.a.rstrip("/")) or args.a,
            label_b=os.path.basename(args.b.rstrip("/")) or args.b,
            min_delta=args.min_delta,
            max_rows=args.top,
            self_only=args.self_only,
        )
    )
    if args.html:
        from repro.core.export import diff_flamegraph_html

        with open(args.html, "w") as f:
            f.write(
                diff_flamegraph_html(
                    a, b, metric,
                    title=f"{os.path.basename(args.a.rstrip('/')) or args.a} vs "
                          f"{os.path.basename(args.b.rstrip('/')) or args.b}",
                )
            )
        print(f"# diff flamegraph: {args.html}")
    return 0


def cmd_check(args) -> int:
    from repro.core.detector import share_distance
    from repro.core.report import name_shares, share_regressions

    try:
        baseline = _resolve_plane(load_profile(args.baseline), args.baseline, args.plane)
    except PlaneError as e:
        print(f"[profilerd] baseline: {e}", file=sys.stderr)
        return EXIT_NO_MATCH
    except ProfileLoadError as e:
        print(f"[profilerd] missing/unreadable baseline: {e}", file=sys.stderr)
        return EXIT_UNREADABLE
    try:
        current = _resolve_plane(load_profile(args.profile), args.profile, args.plane)
    except PlaneError as e:
        print(f"[profilerd] {e}", file=sys.stderr)
        return EXIT_NO_MATCH
    except ProfileLoadError as e:
        print(f"[profilerd] missing/unreadable profile: {e}", file=sys.stderr)
        return EXIT_UNREADABLE
    metric = default_metric(args.plane, args.metric) or "samples"
    # An empty profile must not pass vacuously (every baseline function
    # "lost share"): a gate that stops gating when profiling broke is worse
    # than a red build.
    if current.total(metric) <= 0:
        print(f"[profilerd] profile {args.profile} holds no '{metric}' data", file=sys.stderr)
        return EXIT_UNREADABLE
    if baseline.total(metric) <= 0:
        print(f"[profilerd] baseline {args.baseline} holds no '{metric}' data", file=sys.stderr)
        return EXIT_UNREADABLE
    self_only = not args.inclusive
    regs = share_regressions(
        baseline, current, metric=metric, tolerance=args.tolerance, self_only=self_only
    )
    dist = share_distance(
        name_shares(baseline, metric, self_only=self_only),
        name_shares(current, metric, self_only=self_only),
    )
    verdict = "REGRESSION" if regs else "PASS"
    print(
        f"[check] {verdict} tolerance={args.tolerance:.2%} share_distance={dist:.4f} "
        f"profile={args.profile} baseline={args.baseline}"
    )
    for name, b, c, d in regs[: args.top]:
        print(f"  {d:+7.2%}  {b:7.2%} -> {c:7.2%}  {name}")
    return EXIT_REGRESSION if regs else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.profilerd", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    at = sub.add_parser("attach", help="attach to one or more spools and stream until the targets exit")
    at.add_argument("--spool", default=None, help="spool file the target publishes to")
    at.add_argument("--targets", default=None, metavar="SPOOL[,SPOOL...]",
                    help="explicit multi-target attach: comma-separated spool paths")
    at.add_argument("--watch", default=None, metavar="DIR",
                    help="attach every *.spool in DIR, incl. ones created later "
                         "(runs until SIGTERM; clean final drain+publish)")
    at.add_argument("--out", default=None,
                    help="artifact dir (default: <spool>.d, or <watch>/fleet.d)")
    at.add_argument("--interval", type=float, default=1.0, help="publish/analysis window seconds")
    at.add_argument("--collapse", default="", help="comma-separated origins to fold (e.g. py,jax)")
    at.add_argument("--threshold", type=float, default=0.9, help="dominance-rule threshold")
    at.add_argument("--consecutive", type=int, default=2, help="windows before a rule fires")
    at.add_argument("--rule", action="append", default=[], metavar="SPEC",
                    help="extra dominance rule, repeatable: "
                         "pattern=P,threshold=T,consecutive=N,kind=K,self_only=0|1")
    at.add_argument("--trend-threshold", type=float, default=None,
                    help="epoch-trend dominance threshold (default 0.9)")
    at.add_argument("--trend-epochs", type=int, default=None,
                    help="stalled-dominance epochs before LIVELOCK (default 3)")
    at.add_argument("--trend-drift", type=float, default=None,
                    help="SHARE_DRIFT TV-distance threshold (default 0.35)")
    at.add_argument("--stall-timeout", type=float, default=5.0,
                    help="seconds of silence from a live target before TARGET_STALLED")
    at.add_argument("--attach-timeout", type=float, default=30.0)
    at.add_argument("--max-seconds", type=float, default=None, help="bound the attach run")
    at.add_argument("--follow", action="store_true", help="print live hot paths every window")
    at.add_argument("--epoch", type=float, default=5.0,
                    help="timeline epoch seconds (0 disables the timeline ring)")
    at.add_argument("--serve", type=int, default=None, metavar="PORT",
                    help="serve the live HTTP query plane on this port while attached (0 = ephemeral)")
    at.add_argument("--exit-with", type=int, default=None, metavar="PID",
                    help="finish cleanly when PID dies (supervisors pass their own "
                         "pid so a --watch daemon can never be leaked)")
    at.add_argument("--device-tree", default=None, metavar="PATH",
                    help="device-plane artifact (launch.dryrun --dump-tree) for the "
                         "fleet's compiled program; enables plane=device|merged on the "
                         "query plane and roofline-annotated timeline epochs (default: "
                         "discover device_tree.json dropped into the out/target dirs)")
    at.add_argument("--push", default=None, metavar="URL",
                    help="POST each sealed epoch to a regional aggregator "
                         "(profilerd aggregate) at this URL; outages spill "
                         "locally and resync — ingest never blocks")
    at.add_argument("--push-node", default=None, metavar="NAME",
                    help="node name announced to the aggregator (default: hostname)")
    at.set_defaults(fn=cmd_attach)

    ag = sub.add_parser("aggregate",
                        help="regional aggregator: ingest epochs pushed by node "
                             "daemons (attach --push) into a merged fleet profile")
    ag.add_argument("--out", required=True, help="aggregator artifact dir")
    ag.add_argument("--port", type=int, default=0,
                    help="bind the ingest + query plane here (0 = ephemeral; "
                         "the bound URL is printed on start)")
    ag.add_argument("--host", default="127.0.0.1")
    ag.add_argument("--region", default="region", help="region label for /targets and top")
    ag.add_argument("--epoch", type=float, default=2.0,
                    help="fleet seal + publish cadence seconds")
    ag.add_argument("--coarse-every", type=int, default=8,
                    help="long-horizon ring keeps one keyframe every N fleet epochs")
    ag.add_argument("--stall-factor", type=float, default=1.5,
                    help="NODE_STALLED after this many push intervals of silence")
    ag.add_argument("--max-seconds", type=float, default=None, help="bound the run (tests)")
    ag.set_defaults(fn=cmd_aggregate)

    sv = sub.add_parser("serve", help="HTTP API over an offline profile artifact")
    sv.add_argument("--profile", required=True,
                    help="profile to serve (out dir / timeline ring / tree.json / .snap)")
    sv.add_argument("--port", type=int, default=8787)
    sv.add_argument("--host", default="127.0.0.1")
    sv.add_argument("--baseline", default=None, help="default baseline for /diff")
    sv.add_argument("--verbose", action="store_true", help="log every request")
    sv.set_defaults(fn=cmd_serve)

    tp = sub.add_parser("top", help="refreshing terminal view of a serve endpoint")
    tp.add_argument("--url", default="http://127.0.0.1:8787", help="serve endpoint base URL")
    tp.add_argument("--interval", type=float, default=2.0)
    tp.add_argument("-k", type=int, default=10, help="hot paths shown")
    tp.add_argument("--once", action="store_true", help="print one frame and exit (CI/tests)")
    tp.add_argument("--plane", default="host", choices=list(PLANES),
                    help="also show the plane's hottest paths with roofline occupancy "
                         "+ dominant-term columns (exit 4 if the server has no device plane)")
    tp.set_defaults(fn=cmd_top)

    ex = sub.add_parser("export", help="render a profile as folded/speedscope/html/csv/json")
    ex.add_argument("profile", help="profile (out dir / timeline / tree.json / .snap)")
    ex.add_argument("--fmt", default=None, choices=["csv", "folded", "speedscope", "html", "json"],
                    help="output format (default: folded; html when --baseline is given)")
    ex.add_argument("--view", default=None, help="library view name (views_library.list_views())")
    ex.add_argument("--root", default=None, help="zoom selector (substring); refines --view")
    ex.add_argument("--level", type=int, default=None, help="fold level (-1 = expand to leaves)")
    ex.add_argument("--min-share", type=float, default=None, help="prune below this share")
    ex.add_argument("--metric", default=None)
    ex.add_argument("--plane", default="host", choices=list(PLANES),
                    help="profile plane: sampled host tree, HLO device cost tree, or the "
                         "roofline-annotated merge (exit 4 when device_tree.json is absent)")
    ex.add_argument("--baseline", default=None,
                    help="render a share-delta diff flamegraph against this profile (--fmt html)")
    ex.add_argument("--out", default=None, help="write here instead of stdout")
    ex.set_defaults(fn=cmd_export)

    st = sub.add_parser("status", help="print the latest published status.json")
    st.add_argument("--out", required=True, help="daemon artifact dir")
    st.set_defaults(fn=cmd_status)

    rp = sub.add_parser("report", help="render HTML from a dumped tree.json")
    rp.add_argument("--tree", required=True)
    rp.add_argument("--html", default=None)
    rp.set_defaults(fn=cmd_report)

    tl = sub.add_parser("timeline", help="phase segmentation + epoch table over a timeline ring")
    tl.add_argument("--store", required=True, help="timeline ring dir (or a daemon --out dir)")
    tl.add_argument("--boundary", type=float, default=0.25,
                    help="TV-distance jump that starts a new phase")
    tl.add_argument("--metric", default="samples")
    tl.set_defaults(fn=cmd_timeline)

    df = sub.add_parser("diff", help="cross-run tree diff (per-node share deltas)")
    df.add_argument("a", help="baseline profile (out dir / timeline / tree.json / .snap)")
    df.add_argument("b", help="candidate profile")
    df.add_argument("--metric", default=None, help="default: samples (flops on --plane device)")
    df.add_argument("--plane", default="host", choices=list(PLANES),
                    help="diff this plane on both sides (each via its own device_tree.json)")
    df.add_argument("--min-delta", type=float, default=0.002, help="hide smaller share deltas")
    df.add_argument("--top", type=int, default=40, help="max rows")
    df.add_argument("--self-only", action="store_true", help="diff self shares instead of inclusive")
    df.add_argument("--html", default=None, metavar="FILE",
                    help="also write a share-delta diff flamegraph (red = b grew)")
    df.set_defaults(fn=cmd_diff)

    ck = sub.add_parser("check", help="gate a profile against a baseline (CI; exit 2 on regression)")
    ck.add_argument("profile", help="profile to check (out dir / timeline / tree.json / .snap)")
    ck.add_argument("--baseline", required=True, help="reference profile")
    ck.add_argument("--tolerance", type=float, default=0.05,
                    help="max allowed per-function share increase")
    ck.add_argument("--metric", default=None, help="default: samples (flops on --plane device)")
    ck.add_argument("--plane", default="host", choices=list(PLANES),
                    help="gate this plane (e.g. --plane merged --metric roofline_occupancy "
                         "to fail on device-plane share regressions)")
    ck.add_argument("--inclusive", action="store_true",
                    help="compare inclusive shares instead of self shares")
    ck.add_argument("--top", type=int, default=20, help="max regression rows printed")
    ck.set_defaults(fn=cmd_check)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    try:
        rc = main()
    except BrokenPipeError:
        # `profilerd timeline ... | head` is routine; die quietly.  Point
        # stdout at devnull so the interpreter's shutdown flush of the
        # broken pipe can't raise a second traceback.
        devnull = os.open(os.devnull, os.O_WRONLY)
        os.dup2(devnull, sys.stdout.fileno())
        rc = 0
    raise SystemExit(rc)
