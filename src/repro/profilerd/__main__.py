"""``python -m repro.profilerd`` — attach the profiling daemon to a running job.

Typical flow (the paper's workflow, one process over):

  # terminal 1: run a job that publishes raw frames to a spool
  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --profile \\
      --backend daemon --spool /tmp/serve.spool

  # terminal 2: attach, watch live hot paths, get a report at the end
  PYTHONPATH=src python -m repro.profilerd attach --spool /tmp/serve.spool --follow

Subcommands:

  attach  — drain the spool until the target says BYE (or dies), publishing
            status.json / tree.json / events.jsonl / report.html under --out
            (default <spool>.d); --follow prints live hot paths.
  status  — print the latest status.json published by a running daemon.
  report  — render an HTML report from a previously dumped tree.json.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from repro.core.detector import Rule

from .daemon import DaemonConfig, ProfilerDaemon
from .spool import SpoolError


def _print_status(d: ProfilerDaemon) -> None:
    s = d.status()
    state = "STALLED" if s["stalled"] else ("done" if s["done"] else "live")
    print(
        f"[profilerd] pid={s['pid']} {state} stacks={s['n_stacks']} "
        f"dropped={s['dropped_batches']} events={len(d.events)}"
    )
    for hp in s["hot_paths"][:5]:
        print(f"  {hp['share']:7.2%}  {'/'.join(hp['path'])}")


def cmd_attach(args) -> int:
    rules = [Rule(threshold=args.threshold, consecutive=args.consecutive)]
    cfg = DaemonConfig(
        spool_path=args.spool,
        out_dir=args.out,
        publish_interval_s=args.interval,
        collapse_origins=tuple(o for o in (args.collapse or "").split(",") if o),
        rules=rules,
        stall_timeout_s=args.stall_timeout,
        attach_timeout_s=args.attach_timeout,
        max_seconds=args.max_seconds,
    )
    daemon = ProfilerDaemon(cfg)
    try:
        tree = daemon.run(on_publish=_print_status if args.follow else None)
    except SpoolError as e:
        print(f"[profilerd] {e}", file=sys.stderr)
        return 1
    out = cfg.resolved_out_dir()
    print(f"[profilerd] merged {daemon.n_stacks} stacks -> {os.path.join(out, 'tree.json')}")
    print(f"[profilerd] report: {os.path.join(out, 'report.html')}")
    for ev in daemon.events:
        print(f"[profilerd] event: {json.dumps(ev)}")
    if tree.total() > 0:
        print(tree.render(min_share=0.02, max_depth=4))
    return 0


def cmd_status(args) -> int:
    path = os.path.join(args.out, "status.json")
    try:
        with open(path) as f:
            print(json.dumps(json.load(f), indent=1))
    except OSError as e:
        print(f"no status at {path}: {e}", file=sys.stderr)
        return 1
    return 0


def cmd_report(args) -> int:
    from repro.core.calltree import CallTree
    from repro.core.report import render_html

    with open(args.tree) as f:
        tree = CallTree.from_json(f.read())
    out = args.html or (os.path.splitext(args.tree)[0] + ".html")
    with open(out, "w") as f:
        f.write(render_html(tree, title=os.path.basename(args.tree)))
    print(out)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.profilerd", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    at = sub.add_parser("attach", help="attach to a spool and stream until the target exits")
    at.add_argument("--spool", required=True, help="spool file the target publishes to")
    at.add_argument("--out", default=None, help="artifact dir (default: <spool>.d)")
    at.add_argument("--interval", type=float, default=1.0, help="publish/analysis window seconds")
    at.add_argument("--collapse", default="", help="comma-separated origins to fold (e.g. py,jax)")
    at.add_argument("--threshold", type=float, default=0.9, help="dominance-rule threshold")
    at.add_argument("--consecutive", type=int, default=2, help="windows before a rule fires")
    at.add_argument("--stall-timeout", type=float, default=5.0,
                    help="seconds of silence from a live target before TARGET_STALLED")
    at.add_argument("--attach-timeout", type=float, default=30.0)
    at.add_argument("--max-seconds", type=float, default=None, help="bound the attach run")
    at.add_argument("--follow", action="store_true", help="print live hot paths every window")
    at.set_defaults(fn=cmd_attach)

    st = sub.add_parser("status", help="print the latest published status.json")
    st.add_argument("--out", required=True, help="daemon artifact dir")
    st.set_defaults(fn=cmd_status)

    rp = sub.add_parser("report", help="render HTML from a dumped tree.json")
    rp.add_argument("--tree", required=True)
    rp.add_argument("--html", default=None)
    rp.set_defaults(fn=cmd_report)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
