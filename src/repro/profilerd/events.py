"""Canonical registry of every event ``kind`` the profiling plane emits.

Events land in ``events.jsonl`` / ``status.json`` and are consumed by the
faults scoreboard (``faults/scoreboard.py`` maps kinds to detectors), the
CI gates, and operators grepping a fleet's logs.  An emitter minting a kind
that is not registered here is invisible to all of them — so the
``event-kinds`` repro-lint pass (:mod:`repro.analysis.lint`) checks every
literally-emitted kind in ``profilerd``/``faults``/``launch`` against this
table, and new kinds earn their place by being added here *with* whatever
scoreboard/doc wiring they need.

Constants are grouped by emitter; :data:`EVENT_KINDS` is the flat set the
lint pass (and tests) consume.
"""

from __future__ import annotations

# -- daemon lifecycle (profilerd/daemon.py) ---------------------------------
TARGET_ATTACHED = "TARGET_ATTACHED"
TARGET_RESTARTED = "TARGET_RESTARTED"
TARGET_NEVER_APPEARED = "TARGET_NEVER_APPEARED"
SOURCE_ATTACH_FAILED = "SOURCE_ATTACH_FAILED"
SOURCE_GAVE_UP = "SOURCE_GAVE_UP"
INGEST_SCALAR_FALLBACK = "INGEST_SCALAR_FALLBACK"
TIMELINE_WRITE_FAILED = "TIMELINE_WRITE_FAILED"
CALLBACK_FAILED = "CALLBACK_FAILED"
SERVING = "SERVING"
SERVE_FAILED = "SERVE_FAILED"
SUPERVISOR_GONE = "SUPERVISOR_GONE"
DEVICE_TREE_LOADED = "DEVICE_TREE_LOADED"
DEVICE_TREE_UNREADABLE = "DEVICE_TREE_UNREADABLE"
STATIC_TREE_LOADED = "STATIC_TREE_LOADED"
STATIC_TREE_UNREADABLE = "STATIC_TREE_UNREADABLE"
FAULT_INJECT = "FAULT_INJECT"
FAULT_CLEAR = "FAULT_CLEAR"
FAULT_MARKER_INVALID = "FAULT_MARKER_INVALID"

# -- per-target liveness (profilerd/sources.py) -----------------------------
TARGET_STALLED = "TARGET_STALLED"
TARGET_RESUMED = "TARGET_RESUMED"

# -- detector verdicts (core/detector.py + daemon straggler loop) -----------
DOMINANT = "DOMINANT"
LIVELOCK = "LIVELOCK"
LIVELOCK_CLEARED = "LIVELOCK_CLEARED"
LIVELOCK_SUSPECT = "LIVELOCK_SUSPECT"
SHARE_DRIFT = "SHARE_DRIFT"
STRAGGLER = "STRAGGLER"

# -- fleet aggregator (profilerd/aggregator.py) -----------------------------
AGGREGATOR_RESTORED = "AGGREGATOR_RESTORED"
NODE_ATTACHED = "NODE_ATTACHED"
NODE_REBOOTED = "NODE_REBOOTED"
NODE_STALLED = "NODE_STALLED"
NODE_RECOVERED = "NODE_RECOVERED"

# -- epoch push client (profilerd/push.py) ----------------------------------
PUSH_FAILED = "PUSH_FAILED"
PUSH_RECOVERED = "PUSH_RECOVERED"
PUSH_REJECTED = "PUSH_REJECTED"

# -- scenario detector rules (faults/scenarios.py, launch/train.py) ---------
INPUT_STARVED = "INPUT_STARVED"
INPUT_STARVATION = "INPUT_STARVATION"
COLLECTIVE_STALL = "COLLECTIVE_STALL"
MOE_IMBALANCE = "MOE_IMBALANCE"
CKPT_WEDGE = "CKPT_WEDGE"
LOCK_CONVOY = "LOCK_CONVOY"

EVENT_KINDS = frozenset(
    {
        TARGET_ATTACHED,
        TARGET_RESTARTED,
        TARGET_NEVER_APPEARED,
        SOURCE_ATTACH_FAILED,
        SOURCE_GAVE_UP,
        INGEST_SCALAR_FALLBACK,
        TIMELINE_WRITE_FAILED,
        CALLBACK_FAILED,
        SERVING,
        SERVE_FAILED,
        SUPERVISOR_GONE,
        DEVICE_TREE_LOADED,
        DEVICE_TREE_UNREADABLE,
        STATIC_TREE_LOADED,
        STATIC_TREE_UNREADABLE,
        FAULT_INJECT,
        FAULT_CLEAR,
        FAULT_MARKER_INVALID,
        TARGET_STALLED,
        TARGET_RESUMED,
        DOMINANT,
        LIVELOCK,
        LIVELOCK_CLEARED,
        LIVELOCK_SUSPECT,
        SHARE_DRIFT,
        STRAGGLER,
        AGGREGATOR_RESTORED,
        NODE_ATTACHED,
        NODE_REBOOTED,
        NODE_STALLED,
        NODE_RECOVERED,
        PUSH_FAILED,
        PUSH_RECOVERED,
        PUSH_REJECTED,
        INPUT_STARVED,
        INPUT_STARVATION,
        COLLECTIVE_STALL,
        MOE_IMBALANCE,
        CKPT_WEDGE,
        LOCK_CONVOY,
    }
)

__all__ = ["EVENT_KINDS"] + sorted(k for k in EVENT_KINDS)
