"""Size-bounded byte ring over an mmap'd file (the default spool transport).

Single writer (the target's agent thread) / single reader (the daemon
process).  Offsets are monotonically increasing ``u64`` byte counts; the
physical position is ``offset % capacity``.  The writer only commits a batch
if the *whole* batch fits (``capacity - (head - tail)`` bytes free), otherwise
it drops the batch and bumps the ``dropped`` counter — the target never
blocks on the profiler, which is the paper's non-intrusiveness contract.

Because records are self-delimiting (see :mod:`repro.profilerd.wire`) the
ring stores a raw byte stream; the reader drains whatever contiguous bytes
are available (two copies on wrap) and feeds them to a streaming decoder.

No locks: the writer only writes ``head``/``dropped``/``bye``, the reader
only writes ``tail``.  Each field is a single 8-byte aligned slot updated
*after* its payload, which is sufficient for this SPSC design.
"""

from __future__ import annotations

import mmap
import os
import struct
import time

MAGIC = b"RPSP"
SPOOL_VERSION = 1
HEADER_SIZE = 64

_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")

# header field offsets (bytes)
_OFF_MAGIC = 0
_OFF_VERSION = 4
_OFF_CAPACITY = 8
_OFF_HEAD = 16
_OFF_TAIL = 24
_OFF_DROPPED = 32
_OFF_WRITER_PID = 40
_OFF_BYE = 48  # writer sets to 1 after its final record

DEFAULT_CAPACITY = 4 << 20
# Per-read() drain cap: a reader that fell minutes behind sees the backlog as
# a stream of bounded chunks instead of one giant bytes object (the records
# are self-delimiting, so a chunk boundary mid-record is fine — the streaming
# decoder buffers the partial record).
DEFAULT_READ_CAP = 1 << 20


class SpoolError(RuntimeError):
    pass


class _Mapped:
    def __init__(self, path: str, size: int, create: bool):
        flags = os.O_RDWR | (os.O_CREAT if create else 0)
        self._fd = os.open(path, flags, 0o644)
        if create:
            os.ftruncate(self._fd, size)
        self.mm = mmap.mmap(self._fd, size)

    def close(self) -> None:
        try:
            self.mm.close()
        finally:
            os.close(self._fd)

    def get_u64(self, off: int) -> int:
        return _U64.unpack_from(self.mm, off)[0]

    def set_u64(self, off: int, value: int) -> None:
        _U64.pack_into(self.mm, off, value)


class SpoolWriter:
    """Target-side end: create the spool file and append batches."""

    def __init__(self, path: str, capacity: int = DEFAULT_CAPACITY):
        if capacity <= 0:
            raise SpoolError("capacity must be positive")
        self.path = path
        self.capacity = capacity
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        # Build under a temp name then rename, so a reader polling for the
        # spool never maps a half-initialised header.
        tmp = f"{path}.tmp.{os.getpid()}"
        self._m = _Mapped(tmp, HEADER_SIZE + capacity, create=True)
        mm = self._m.mm
        mm[_OFF_MAGIC : _OFF_MAGIC + 4] = MAGIC
        _U32.pack_into(mm, _OFF_VERSION, SPOOL_VERSION)
        self._m.set_u64(_OFF_CAPACITY, capacity)
        self._m.set_u64(_OFF_HEAD, 0)
        self._m.set_u64(_OFF_TAIL, 0)
        self._m.set_u64(_OFF_DROPPED, 0)
        self._m.set_u64(_OFF_WRITER_PID, os.getpid())
        self._m.set_u64(_OFF_BYE, 0)
        os.replace(tmp, path)
        self._head = 0
        self.dropped = 0

    def write(self, payload: bytes) -> bool:
        """Append one batch; returns False (and counts a drop) if it won't fit."""
        n = len(payload)
        if n == 0:
            return True
        tail = self._m.get_u64(_OFF_TAIL)
        free = self.capacity - (self._head - tail)
        if n > free:
            self.dropped += 1
            self._m.set_u64(_OFF_DROPPED, self.dropped)
            return False
        pos = self._head % self.capacity
        first = min(n, self.capacity - pos)
        mm = self._m.mm
        mm[HEADER_SIZE + pos : HEADER_SIZE + pos + first] = payload[:first]
        if first < n:
            mm[HEADER_SIZE : HEADER_SIZE + n - first] = payload[first:]
        self._head += n
        self._m.set_u64(_OFF_HEAD, self._head)
        return True

    def write_bye(self, payload: bytes, retries: int = 20, wait_s: float = 0.05) -> bool:
        """Final record: retry briefly (the reader may still be draining)."""
        for _ in range(retries):
            if self.write(payload):
                self._m.set_u64(_OFF_BYE, 1)
                return True
            self.dropped -= 1  # the retry loop is one logical attempt
            self._m.set_u64(_OFF_DROPPED, self.dropped)
            time.sleep(wait_s)
        self.dropped += 1
        self._m.set_u64(_OFF_DROPPED, self.dropped)
        self._m.set_u64(_OFF_BYE, 1)
        return False

    def close(self) -> None:
        self._m.close()


class _ShortHeader(SpoolError):
    """File smaller than the spool header (possibly still being created)."""


class SpoolReader:
    """Daemon-side end: drain available bytes and advance ``tail``.

    Attaching validates the whole header — magic, version, declared capacity
    against the file size — and every failure mode (empty file, truncated
    header, foreign file, mmap race) raises :class:`SpoolError` with a clean
    message, never a raw ``struct.error``/``ValueError``/``OSError``.  A
    short header gets one retry after ``header_retry_s``: a ``--watch``
    discovery loop races freshly-created files, and the writer's
    temp-then-rename protocol still leaves a brief window on filesystems
    that surface renames before data (network mounts, some CI overlays).
    """

    def __init__(self, path: str, header_retry_s: float = 0.05):
        self.path = path
        try:
            self._open(path)
        except _ShortHeader:
            time.sleep(header_retry_s)
            self._open(path)

    def _open(self, path: str) -> None:
        try:
            size = os.path.getsize(path)
        except OSError as e:
            raise SpoolError(f"{path}: cannot stat spool: {e}") from None
        if size < HEADER_SIZE:
            raise _ShortHeader(
                f"{path}: truncated spool header ({size} < {HEADER_SIZE} bytes)"
            )
        try:
            m = _Mapped(path, size, create=False)
        except (OSError, ValueError) as e:
            raise SpoolError(f"{path}: cannot map spool: {e}") from None
        ok = False
        try:
            mm = m.mm
            if bytes(mm[_OFF_MAGIC : _OFF_MAGIC + 4]) != MAGIC:
                raise SpoolError(f"{path}: bad spool magic (not a spool file?)")
            try:
                (version,) = _U32.unpack_from(mm, _OFF_VERSION)
                capacity = m.get_u64(_OFF_CAPACITY)
                tail = m.get_u64(_OFF_TAIL)
            except struct.error as e:
                raise SpoolError(f"{path}: unreadable spool header: {e}") from None
            if version != SPOOL_VERSION:
                raise SpoolError(f"{path}: spool version {version} != {SPOOL_VERSION}")
            if capacity <= 0:
                raise SpoolError(f"{path}: declared capacity {capacity} is not positive")
            if size < HEADER_SIZE + capacity:
                raise SpoolError(
                    f"{path}: file size {size} smaller than declared capacity "
                    f"{capacity} + header"
                )
            st = os.fstat(m._fd)
            ok = True
        finally:
            if not ok:
                m.close()
        self._m = m
        self.capacity = capacity
        self._tail = tail
        # Identity of the mapped file: a crashed-and-restarted writer
        # recreates the spool via temp+rename, so the path pointing at a
        # different inode is the re-attach signal (see replaced()).
        self.file_id = (st.st_dev, st.st_ino)

    @classmethod
    def wait_for(cls, path: str, timeout_s: float = 30.0, poll_s: float = 0.05) -> "SpoolReader":
        deadline = time.monotonic() + timeout_s
        while True:
            try:
                if os.path.exists(path) and os.path.getsize(path) >= HEADER_SIZE:
                    return cls(path)
            except OSError:
                pass
            if time.monotonic() >= deadline:
                raise SpoolError(f"spool {path} did not appear within {timeout_s:.0f}s")
            time.sleep(poll_s)

    @property
    def writer_pid(self) -> int:
        return self._m.get_u64(_OFF_WRITER_PID)

    @property
    def dropped(self) -> int:
        return self._m.get_u64(_OFF_DROPPED)

    @property
    def bye_seen(self) -> bool:
        return self._m.get_u64(_OFF_BYE) == 1

    @property
    def backlog(self) -> int:
        """Bytes written but not yet drained (backpressure accounting)."""
        return self._m.get_u64(_OFF_HEAD) - self._tail

    def replaced(self) -> bool:
        """True when ``path`` now names a different file than the one mapped.

        A target that crashed and restarted recreates its spool under the
        same path (temp+rename), leaving this reader mapped to the unlinked
        old inode — which stays drainable, so callers drain it dry and then
        attach a fresh reader to the new incarnation.  A deleted (not
        replaced) spool returns False: there is nothing new to attach to.
        """
        try:
            st = os.stat(self.path)
        except OSError:
            return False
        return (st.st_dev, st.st_ino) != self.file_id

    def read(self, max_bytes: int | None = DEFAULT_READ_CAP) -> bytes:
        """Drain up to ``max_bytes`` (``None`` = everything available)."""
        head = self._m.get_u64(_OFF_HEAD)
        n = head - self._tail
        if max_bytes is not None:
            n = min(n, max_bytes)
        if n <= 0:
            return b""
        pos = self._tail % self.capacity
        first = min(n, self.capacity - pos)
        mm = self._m.mm
        out = bytes(mm[HEADER_SIZE + pos : HEADER_SIZE + pos + first])
        if first < n:
            out += bytes(mm[HEADER_SIZE : HEADER_SIZE + n - first])
        self._tail += n
        self._m.set_u64(_OFF_TAIL, self._tail)
        return out

    def close(self) -> None:
        self._m.close()
