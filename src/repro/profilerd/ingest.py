"""Cached-path call-tree ingestion — the daemon half of wire-v2 stack interning.

The agent interns stacks (one ``STACKDEF`` per unique stack, then fixed-size
``SAMPLE2`` references); :class:`TreeIngestor` completes the contract on the
daemon side: each ``(thread_name, stack_id)`` pair is resolved through the
:class:`~repro.profilerd.resolver.SymbolResolver` exactly once, and the
resulting :class:`~repro.core.calltree.CallNode` chain (root -> leaf, for
inclusive bumps plus the leaf's self bump) is cached by direct reference.
Ingesting a repeated sample is then an O(depth) float-add loop over the
cached chain — zero hashing, zero allocation — via the node fast lane
(:meth:`~repro.core.calltree.CallTree.add_stack_nodes`).

v1 samples (no ``stack_id``) fall back to the per-frame resolve + generic
``add_stack`` path, so old spools ingest unchanged.

:meth:`TreeIngestor.ingest_batch` is the vectorized lane over the same cache:
a columnar :class:`~repro.profilerd.wire.SampleBatch` is grouped by packed
``(thread_name_id, stack_id)`` key with ``np.unique`` + ``np.bincount``, and
each *group* costs one cache lookup plus one batched float-add of the group's
hit count along the cached chain — per-sample Python work disappears
entirely on repeated stacks.  Groups are applied in first-occurrence order,
so epoch dirty lists (and therefore sealed timeline bytes) come out identical
to per-sample ingestion of the same stream.

The cache never needs invalidation: the tree only grows, chains reference
live accumulator nodes, and collapse settings are fixed per daemon run.

Epoch dirty tracking
--------------------

The timeline sealer (:class:`repro.core.snapshot.CountSealer`) needs to know
*which* chains changed during an epoch — and by how much — without walking
the tree.  Each cache entry carries an epoch stamp and a per-epoch hit count:
the first hit per epoch appends the entry to an epoch-local dirty list, every
hit bumps the count (one integer compare + one integer add per sample — the
fast lane stays flat).  :meth:`drain_epoch` hands the dirty entries to the
sealer and opens the next epoch.  v1 samples mutate the tree outside the
chain cache, so they flip an ``untracked`` flag that forces the sealer to
write a keyframe instead of a counts record.
"""

from __future__ import annotations

from collections.abc import Sequence

from repro.core.calltree import CallTree

from .resolver import SymbolResolver
from .wire import RawSample, SampleBatch, _numpy


# Cache-entry ceiling: one chain per (thread, stack_id); the agent's own
# stack table is capped (wire.DEFAULT_MAX_STACKS), this guards the daemon
# against thread-name churn on top of that.  Overflow degrades to the
# uncached path — correctness is unaffected.
DEFAULT_MAX_PATHS = 1 << 18


class TreeIngestor:
    """Streams :class:`RawSample` events into a :class:`CallTree`."""

    def __init__(
        self,
        tree: CallTree | None = None,
        resolver: SymbolResolver | None = None,
        collapse_origins: Sequence[str] = (),
        max_paths: int = DEFAULT_MAX_PATHS,
    ):
        self.tree = tree if tree is not None else CallTree()
        self.resolver = resolver if resolver is not None else SymbolResolver(collapse_origins)
        self.max_paths = max_paths
        # (thread_name, stack_id) -> [node chain incl. root + thread node,
        # resolved stack depth for the timeline, epoch stamp of last hit,
        # samples ingested through this chain in the current epoch].
        self._paths: dict[tuple[str, int], list] = {}
        self._epoch = 0
        self._epoch_entries: list[list] = []
        self._epoch_untracked = False
        self.fast_hits = 0
        self.slow_ingests = 0
        self.batch_samples = 0  # samples ingested through ingest_batch
        self.batch_chunks = 0  # SampleBatch objects ingested

    def ingest(self, sample: RawSample) -> int:
        """Merge one sample; returns the resolved stack depth (timeline)."""
        sid = sample.stack_id
        if sid is not None:
            key = (sample.thread_name, sid)
            entry = self._paths.get(key)
            if entry is not None:
                if entry[2] != self._epoch:
                    entry[2] = self._epoch
                    entry[3] = 0
                    self._epoch_entries.append(entry)
                entry[3] += 1
                CallTree.add_stack_nodes(entry[0])
                self.fast_hits += 1
                return entry[1]
            stack = self.resolver.resolve_stack_interned(sid, sample.frames)
            chain = self.tree.path_nodes([f"thread::{sample.thread_name}"] + stack)
            if len(self._paths) < self.max_paths:
                entry = [chain, len(stack), self._epoch, 1]
                self._paths[key] = entry
                self._epoch_entries.append(entry)
            else:
                # Not cached: hits can't be counted next epoch either, so
                # sealing must keyframe instead of trusting the entry set.
                self._epoch_untracked = True
            CallTree.add_stack_nodes(chain)
            self.slow_ingests += 1
            return len(stack)
        stack = self.resolver.resolve_stack(sample.frames)
        self.tree.add_stack([f"thread::{sample.thread_name}"] + stack)
        self._epoch_untracked = True
        self.slow_ingests += 1
        return len(stack)

    def ingest_batch(self, batch: SampleBatch):
        """Merge one columnar :class:`SampleBatch`; returns the per-sample
        resolved stack depths as an int array (timeline feed), in stream
        order.

        Samples are grouped by packed ``(thread_name_id, stack_id)`` key —
        group sizes via ``np.bincount`` over the ``np.unique`` inverse — and
        each group becomes *one* cache lookup + one batched
        ``add_stack_nodes(chain, count)`` float-add, instead of ``count``
        scalar ingests.  Identical-by-construction to per-sample ingestion:

        * float parity — adding ``n`` ones and adding ``n.0`` once are the
          same IEEE double for any realistic count, so tree metrics match
          bit-for-bit;
        * order parity — groups are applied in first-occurrence order, so
          the epoch dirty list (hence sealed-ring bytes) matches;
        * stats parity — a cached group counts ``n`` fast hits; an uncached
          one counts 1 slow ingest + ``n - 1`` fast hits, exactly what the
          scalar loop would have reported.
        """
        np = _numpy()
        dec = batch.decoder
        sid_col = batch.stack_id
        packed = (batch.name_id.astype(np.uint64) << np.uint64(32)) | sid_col.astype(np.uint64)
        keys, first_at, inverse = np.unique(packed, return_index=True, return_inverse=True)
        # Bulk-convert the tiny per-group arrays once: the loop below then
        # touches only plain Python ints (a numpy scalar index per group
        # would dominate the batch win at realistic group counts).
        counts_l = np.bincount(inverse, minlength=len(keys)).tolist()
        keys_l = keys.tolist()
        group_depths = [0] * len(keys_l)
        epoch = self._epoch
        paths = self._paths
        for gi in np.argsort(first_at).tolist():
            n = counts_l[gi]
            key64 = keys_l[gi]
            sid = key64 & 0xFFFFFFFF
            frames = dec.batch_stack(sid, n)  # degraded-mode accounting per sample
            tname = dec.thread_name(key64 >> 32)
            entry = paths.get((tname, sid))
            if entry is not None:
                if entry[2] != epoch:
                    entry[2] = epoch
                    entry[3] = 0
                    self._epoch_entries.append(entry)
                entry[3] += n
                CallTree.add_stack_nodes(entry[0], float(n))
                self.fast_hits += n
                group_depths[gi] = entry[1]
                continue
            stack = self.resolver.resolve_stack_interned(sid, frames)
            chain = self.tree.path_nodes([f"thread::{tname}"] + stack)
            if len(paths) < self.max_paths:
                entry = [chain, len(stack), epoch, n]
                paths[(tname, sid)] = entry
                self._epoch_entries.append(entry)
                self.slow_ingests += 1
                self.fast_hits += n - 1
            else:
                self._epoch_untracked = True
                self.slow_ingests += n
            CallTree.add_stack_nodes(chain, float(n))
            group_depths[gi] = len(stack)
        self.batch_samples += len(packed)
        self.batch_chunks += 1
        return np.asarray(group_depths, dtype=np.intp)[inverse]

    def reset_chain_cache(self) -> None:
        """Forget every ``(thread, stack_id)`` -> chain association.

        Required on writer re-attach: a restarted target re-assigns stack ids
        from 0, so a cached id could silently route a different stack through
        an old chain.  Counts already drained into the current epoch stay
        valid — entries reference live tree nodes, and the sealer adds
        duplicate-chain counts additively — only the id association dies.
        """
        self._paths.clear()

    def drain_epoch(self) -> tuple[list[list], bool]:
        """Close the current epoch: ``(dirty entries, untracked_mutations)``.

        Each entry is ``[chain, depth, stamp, count]`` — ``count`` samples
        were ingested through ``chain`` this epoch.  ``untracked_mutations``
        is True when the tree changed outside the chain cache (v1 samples,
        cache overflow); the caller must then seal from the full tree instead
        of trusting the entry set.
        """
        entries, self._epoch_entries = self._epoch_entries, []
        untracked, self._epoch_untracked = self._epoch_untracked, False
        self._epoch += 1
        return entries, untracked

    def stats(self) -> dict:
        """The ingestor's slice of the unified ``ingest_stats`` schema (see
        :mod:`repro.profilerd.pipeline` for the full documented dict)."""
        return {
            "fast_hits": self.fast_hits,
            "slow_ingests": self.slow_ingests,
            "batch_samples": self.batch_samples,
            "batch_chunks": self.batch_chunks,
            "cached_paths": len(self._paths),
        }
