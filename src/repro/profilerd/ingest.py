"""Cached-path call-tree ingestion — the daemon half of wire-v2 stack interning.

The agent interns stacks (one ``STACKDEF`` per unique stack, then fixed-size
``SAMPLE2`` references); :class:`TreeIngestor` completes the contract on the
daemon side: each ``(thread_name, stack_id)`` pair is resolved through the
:class:`~repro.profilerd.resolver.SymbolResolver` exactly once, and the
resulting :class:`~repro.core.calltree.CallNode` chain (root -> leaf, for
inclusive bumps plus the leaf's self bump) is cached by direct reference.
Ingesting a repeated sample is then an O(depth) float-add loop over the
cached chain — zero hashing, zero allocation — via the node fast lane
(:meth:`~repro.core.calltree.CallTree.add_stack_nodes`).

v1 samples (no ``stack_id``) fall back to the per-frame resolve + generic
``add_stack`` path, so old spools ingest unchanged.

The cache never needs invalidation: the tree only grows, chains reference
live accumulator nodes, and collapse settings are fixed per daemon run.
"""

from __future__ import annotations

from typing import Optional, Sequence

from repro.core.calltree import CallNode, CallTree

from .resolver import SymbolResolver
from .wire import RawSample


# Cache-entry ceiling: one chain per (thread, stack_id); the agent's own
# stack table is capped (wire.DEFAULT_MAX_STACKS), this guards the daemon
# against thread-name churn on top of that.  Overflow degrades to the
# uncached path — correctness is unaffected.
DEFAULT_MAX_PATHS = 1 << 18


class TreeIngestor:
    """Streams :class:`RawSample` events into a :class:`CallTree`."""

    def __init__(
        self,
        tree: Optional[CallTree] = None,
        resolver: Optional[SymbolResolver] = None,
        collapse_origins: Sequence[str] = (),
        max_paths: int = DEFAULT_MAX_PATHS,
    ):
        self.tree = tree if tree is not None else CallTree()
        self.resolver = resolver if resolver is not None else SymbolResolver(collapse_origins)
        self.max_paths = max_paths
        # (thread_name, stack_id) -> (node chain incl. root + thread node,
        # resolved stack depth for the timeline).
        self._paths: dict[tuple[str, int], tuple[list[CallNode], int]] = {}
        self.fast_hits = 0
        self.slow_ingests = 0

    def ingest(self, sample: RawSample) -> int:
        """Merge one sample; returns the resolved stack depth (timeline)."""
        sid = sample.stack_id
        if sid is not None:
            key = (sample.thread_name, sid)
            cached = self._paths.get(key)
            if cached is not None:
                chain, depth = cached
                CallTree.add_stack_nodes(chain)
                self.fast_hits += 1
                return depth
            stack = self.resolver.resolve_stack_interned(sid, sample.frames)
            chain = self.tree.path_nodes([f"thread::{sample.thread_name}"] + stack)
            if len(self._paths) < self.max_paths:
                self._paths[key] = (chain, len(stack))
            CallTree.add_stack_nodes(chain)
            self.slow_ingests += 1
            return len(stack)
        stack = self.resolver.resolve_stack(sample.frames)
        self.tree.add_stack([f"thread::{sample.thread_name}"] + stack)
        self.slow_ingests += 1
        return len(stack)

    def stats(self) -> dict:
        return {
            "fast_hits": self.fast_hits,
            "slow_ingests": self.slow_ingests,
            "cached_paths": len(self._paths),
        }
