"""Network push plane: ship sealed epoch deltas to a regional aggregator.

The wire format *is* the snapshot codec (``repro.core.snapshot``): every POST
body is a self-contained one-record segment — the ``RTL1`` header followed by
one CRC-framed ``K_FULL``/``K_DELTA`` payload with a fresh per-body string
table.  The aggregator decodes with the same torn-tail-tolerant
``_parse_segment`` the timeline ring uses, so a truncated or bit-flipped body
is detected by CRC, never half-applied.  Node identity and epoch metadata
ride in HTTP headers (``X-Repro-Node``/``-Boot``/``-Epoch``/...), keeping the
binary payload byte-identical to what a local ring would have stored.

:class:`PushClient` is the daemon-side producer.  Its contract is that a dead
or slow aggregator never blocks ingest and never loses epoch *mass*:

* each sealed epoch is encoded once and enqueued in a bounded in-memory
  spill queue; delivery attempts happen at enqueue time only when the
  backoff window allows one, so an unreachable aggregator costs at most one
  connect timeout per backoff interval, not per epoch;
* backoff is bounded exponential with jitter (the same policy as the spool
  attach retries in ``sources.SpoolSet``), re-armed by the next success;
* if the spill queue overflows, oldest bodies are dropped and the client
  *resyncs*: the next push is a ``K_FULL`` cumulative keyframe, which the
  aggregator applies by replacement — dropped deltas are subsumed, so the
  fleet totals converge to the truth as soon as connectivity returns;
* outage edges surface as ``PUSH_FAILED`` / ``PUSH_RECOVERED`` events
  through the daemon's event log.
"""

from __future__ import annotations

import random
import time
import uuid
from collections.abc import Callable, Mapping, Sequence

from repro.core.calltree import CallTree
from repro.core.snapshot import (
    FORMAT_VERSION,
    K_DELTA,
    K_FULL,
    MAGIC,
    _HDR,
    EpochMeta,
    SnapshotCorrupt,
    _encode_payload,
    _frame,
    _parse_segment,
    _StringTable,
)

__all__ = [
    "PUSH_PATH",
    "H_NODE",
    "H_BOOT",
    "H_EPOCH",
    "H_INTERVAL",
    "H_TARGETS",
    "H_DONE",
    "PushClient",
    "decode_push_body",
    "encode_push_body",
    "push_url_for",
]

PUSH_PATH = "/push"

# Node identity + epoch metadata headers.  The binary body stays exactly the
# snapshot codec; everything the aggregator needs *about* the sender is here.
H_NODE = "X-Repro-Node"
H_BOOT = "X-Repro-Boot"  # fresh per client instance: detects node restarts
H_EPOCH = "X-Repro-Epoch"
H_INTERVAL = "X-Repro-Interval"  # expected push cadence (liveness timeout base)
H_TARGETS = "X-Repro-Targets"  # member target names (the node->target hierarchy)
H_DONE = "X-Repro-Done"  # final push of a clean shutdown


def push_url_for(url: str) -> str:
    """Normalize an aggregator URL to its ingest endpoint.

    Accepts ``host:port``, ``http://host:port`` or a full ``.../push``.
    """
    url = url.strip().rstrip("/")
    if "://" not in url:
        url = f"http://{url}"
    if not url.endswith(PUSH_PATH):
        url += PUSH_PATH
    return url


def encode_push_body(kind: int, meta: EpochMeta, tree: CallTree) -> bytes:
    """One self-contained single-record segment: header + framed payload."""
    meta.kind = kind
    payload = _encode_payload(kind, meta, tree, _StringTable())
    return _HDR.pack(MAGIC, FORMAT_VERSION, 0) + _frame(payload)


def decode_push_body(body: bytes) -> tuple[EpochMeta, CallTree]:
    """Decode a push body; raises :class:`SnapshotCorrupt` on anything torn.

    The ring parser tolerates a torn tail (crash-safe append contract); over
    HTTP a torn body means the POST itself is bad, so ``clean`` must hold and
    exactly one record must be present.
    """
    records, clean = _parse_segment(body, "<push body>")
    if not clean:
        raise SnapshotCorrupt("torn or corrupt push body")
    if len(records) != 1:
        raise SnapshotCorrupt(f"push body holds {len(records)} records, want 1")
    meta, tree = records[0]
    if meta.kind not in (K_FULL, K_DELTA):
        raise SnapshotCorrupt(f"push record kind {meta.kind} not pushable")
    return meta, tree


def _default_post(url: str, body: bytes, headers: Mapping[str, str], timeout_s: float) -> int:
    import urllib.error
    import urllib.request

    req = urllib.request.Request(
        url, data=body, headers=dict(headers), method="POST"
    )
    try:
        with urllib.request.urlopen(req, timeout=timeout_s) as resp:
            resp.read()
            return resp.status
    except urllib.error.HTTPError as e:
        return e.code


class PushClient:
    """POST sealed epochs to an aggregator; spill + resync through outages."""

    def __init__(
        self,
        url: str,
        node: str,
        *,
        interval_hint_s: float = 5.0,
        keyframe_every: int = 16,
        max_spill_bytes: int = 16 << 20,
        timeout_s: float = 5.0,
        retry_base_s: float = 0.5,
        retry_cap_s: float = 30.0,
        on_event: Callable[[dict], None] | None = None,
        post: Callable[..., int] | None = None,
    ):
        if keyframe_every < 1:
            raise ValueError("keyframe_every must be >= 1")
        self.url = push_url_for(url)
        self.node = node
        self.boot = uuid.uuid4().hex
        self.interval_hint_s = interval_hint_s
        self.keyframe_every = keyframe_every
        self.max_spill_bytes = max_spill_bytes
        self.timeout_s = timeout_s
        self.retry_base_s = retry_base_s
        self.retry_cap_s = retry_cap_s
        self.on_event = on_event
        self._post = post or _default_post
        self.epoch = 0
        self._prev: CallTree | None = None
        self._need_keyframe = True
        # Spill queue: (epoch, headers, body), oldest first.  Bodies are
        # already encoded — an outage costs memory bounded by
        # max_spill_bytes, never re-encoding work.
        self._queue: list[tuple[int, dict, bytes]] = []
        self._queue_bytes = 0
        self._failing_since: float | None = None
        self._attempts = 0
        self._next_attempt = 0.0
        self._last_error = ""
        self.counters = {
            "pushed_epochs": 0,
            "pushed_bytes": 0,
            "spilled": 0,
            "dropped": 0,
            "rejected": 0,
            "failures": 0,
            "recoveries": 0,
        }

    # -- events --------------------------------------------------------------

    def _emit(self, ev: dict) -> None:
        if self.on_event is not None:
            self.on_event(ev)

    # -- encode + enqueue ----------------------------------------------------

    def _headers(self, meta: EpochMeta, targets: Sequence[str], done: bool) -> dict:
        h = {
            "Content-Type": "application/octet-stream",
            H_NODE: self.node,
            H_BOOT: self.boot,
            H_EPOCH: str(meta.epoch),
            H_INTERVAL: f"{self.interval_hint_s:g}",
        }
        if targets:
            h[H_TARGETS] = ",".join(targets)
        if done:
            h[H_DONE] = "1"
        return h

    def push_epoch(
        self,
        tree: CallTree,
        *,
        wall_time: float = 0.0,
        progress: float = 0.0,
        targets: Sequence[str] = (),
        done: bool = False,
    ) -> None:
        """Encode the fleet tree's current epoch and try to deliver it.

        ``tree`` is the node's *cumulative* fleet tree; the client keeps its
        own shadow copy and ships either the delta against it or (on the
        keyframe cadence / after a resync) the full cumulative.  Never raises
        on delivery failure — that is the spill queue's job.
        """
        keyframe = (
            self._need_keyframe
            or self._prev is None
            or self.epoch % self.keyframe_every == 0
        )
        meta = EpochMeta(self.epoch, wall_time, progress)
        if keyframe:
            body = encode_push_body(K_FULL, meta, tree)
        else:
            body = encode_push_body(K_DELTA, meta, tree.diff(self._prev))
        self._prev = tree.copy()
        self._need_keyframe = False
        self._enqueue(meta.epoch, self._headers(meta, targets, done), body)
        self.epoch += 1
        self.flush(force=done)

    def _enqueue(self, epoch: int, headers: dict, body: bytes) -> None:
        self._queue.append((epoch, headers, body))
        self._queue_bytes += len(body)
        while self._queue_bytes > self.max_spill_bytes and len(self._queue) > 1:
            _, _, dropped = self._queue.pop(0)
            self._queue_bytes -= len(dropped)
            self.counters["dropped"] += 1
            # Dropped deltas are unrecoverable individually, but the next
            # keyframe's cumulative subsumes them — force one.
            self._need_keyframe = True

    # -- delivery ------------------------------------------------------------

    def _backoff(self, now: float) -> None:
        self._attempts += 1
        delay = min(self.retry_cap_s, self.retry_base_s * (2 ** (self._attempts - 1)))
        self._next_attempt = now + delay * random.uniform(0.8, 1.2)

    def flush(self, force: bool = False) -> bool:
        """Drain the spill queue in order while the aggregator accepts.

        Returns True when the queue emptied.  ``force`` ignores the backoff
        window (one extra attempt) — used for the final ``done`` push so a
        clean shutdown gets its last epoch out even mid-outage.
        """
        now = time.monotonic()
        if self._queue and not force and now < self._next_attempt:
            self.counters["spilled"] = len(self._queue)
            return False
        while self._queue:
            epoch, headers, body = self._queue[0]
            try:
                code = self._post(self.url, body, headers, self.timeout_s)
            except OSError as e:
                self._delivery_failed(str(e))
                return False
            if code == 200:
                self._queue.pop(0)
                self._queue_bytes -= len(body)
                self.counters["pushed_epochs"] += 1
                self.counters["pushed_bytes"] += len(body)
                if self._failing_since is not None:
                    self._recovered()
                continue
            if 400 <= code < 500:
                # The aggregator understood us and said no (corrupt frame,
                # body too large): retrying the same bytes cannot succeed.
                # Drop it, resync via keyframe, and keep draining.
                self._queue.pop(0)
                self._queue_bytes -= len(body)
                self.counters["rejected"] += 1
                self._need_keyframe = True
                self._emit(
                    {"kind": "PUSH_REJECTED", "url": self.url, "epoch": epoch,
                     "http_status": code, "wall_time": time.time()}
                )
                continue
            self._delivery_failed(f"HTTP {code}")
            return False
        self.counters["spilled"] = 0
        return True

    def _delivery_failed(self, error: str) -> None:
        now = time.monotonic()
        self.counters["failures"] += 1
        self.counters["spilled"] = len(self._queue)
        self._last_error = error
        self._backoff(now)
        if self._failing_since is None:
            self._failing_since = now
            self._emit(
                {"kind": "PUSH_FAILED", "url": self.url, "error": error,
                 "spilled": len(self._queue), "wall_time": time.time()}
            )

    def _recovered(self) -> None:
        outage_s = time.monotonic() - (self._failing_since or time.monotonic())
        self._failing_since = None
        self._attempts = 0
        self._next_attempt = 0.0
        self.counters["recoveries"] += 1
        self._emit(
            {"kind": "PUSH_RECOVERED", "url": self.url,
             "outage_s": round(outage_s, 3), "wall_time": time.time()}
        )

    # -- introspection -------------------------------------------------------

    def stats(self) -> dict:
        return {
            "url": self.url,
            "node": self.node,
            "boot": self.boot,
            "epoch": self.epoch,
            "failing": self._failing_since is not None,
            "last_error": self._last_error,
            "queue_epochs": len(self._queue),
            "queue_bytes": self._queue_bytes,
            **self.counters,
        }
