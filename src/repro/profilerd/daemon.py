"""The profiling daemon: live streaming aggregation in a separate process.

One daemon drains a *fleet* of spools (explicit ``--targets`` paths and/or a
``--watch`` directory whose new spools attach within one drain interval),
routes each source through its own decoder/resolver/``TreeIngestor`` into a
source-tagged forest — per-target trees plus a continuously merged fleet
tree — and publishes:

* ``status.json`` — fleet hot paths, per-target status rows (drop/stall/bye/
  backlog/restart state), detector verdicts naming the offending target,
  drop/ingest counters (atomically replaced every publish interval);
* ``tree.json``   — the merged fleet tree (the drivers' ``snapshot()`` reads
  this, so the in-process watchdog works unchanged with the daemon backend);
* ``targets/<name>/`` — per-target ``tree.json`` + ``timeline/`` ring
  (multi-target mode); the fleet ring under ``<out>/timeline`` is merged at
  seal time;
* ``events.jsonl``— append-only anomaly log, each event tagged ``target``;
* ``report.html`` / final ``tree.json`` — on-demand / at shutdown.

Because the daemon is a separate process it also detects the one failure an
in-process helper thread cannot: a target whose interpreter is fully wedged
(GIL held in native code, SIGSTOP, hard livelock).  The agent goes silent,
the spool stops advancing, and after ``stall_timeout_s`` the daemon emits a
``TARGET_STALLED`` verdict naming the target — see
``examples/hang_detection.py``.  A target that crashes and restarts
recreates its spool; the daemon re-attaches to the new incarnation (old
bytes drained dry first) instead of reporting a phantom stall.
"""

from __future__ import annotations

import json
import os
import time
from collections import deque
from dataclasses import dataclass
from collections.abc import Sequence

from repro.core.calltree import CallTree
from repro.core.detector import Rule, TrendRule
from repro.core.snapshot import EpochMeta, TimelineWriter

from .pipeline import merge_ingest_stats
from .profiles import (
    DEVICE_TREE_FILENAME,
    STATIC_TREE_FILENAME,
    TARGETS_DIRNAME,
    TIMELINE_DIRNAME,
)
from .sources import RESUMED, STALLED, SpoolSet, SpoolSource, _pid_alive, source_name_for
from .spool import SpoolError, SpoolReader, _ShortHeader

__all__ = [
    "STALLED",
    "RESUMED",
    "DaemonConfig",
    "ProfilerDaemon",
    "rule_from_spec",
    "rule_to_spec",
    "spawn_attached_daemon",
]

FAULT_MARKERS_FILENAME = "fault_markers.jsonl"


def rule_to_spec(rule: Rule) -> str:
    """Serialize a dominance rule for the ``attach --rule`` flag."""
    return (
        f"pattern={rule.pattern},threshold={rule.threshold},"
        f"consecutive={rule.consecutive},kind={rule.kind},"
        f"self_only={int(rule.self_only)},min_window={rule.min_window_total}"
    )


def rule_from_spec(spec: str) -> Rule:
    """Parse ``key=value[,key=value...]`` into a :class:`Rule`.

    Keys: pattern, threshold, consecutive, kind, self_only (0/1),
    min_window.  Unknown keys raise — a typo'd rule must fail loudly, not
    silently detect nothing.
    """
    rule = Rule()
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        key, sep, value = part.partition("=")
        if not sep:
            raise ValueError(f"bad --rule field {part!r} (want key=value)")
        key = key.strip()
        value = value.strip()
        if key == "pattern":
            rule.pattern = value
        elif key == "threshold":
            rule.threshold = float(value)
        elif key == "consecutive":
            rule.consecutive = int(value)
        elif key == "kind":
            rule.kind = value
        elif key == "self_only":
            rule.self_only = bool(int(value))
        elif key == "min_window":
            rule.min_window_total = float(value)
        else:
            raise ValueError(f"unknown --rule key {key!r}")
    return rule


def spawn_attached_daemon(
    spool_path: str | None = None,
    out_dir: str | None = None,
    *,
    targets: Sequence[str] = (),
    watch_dir: str | None = None,
    interval_s: float = 1.0,
    collapse_origins: Sequence[str] = (),
    stall_timeout_s: float | None = None,
    epoch_s: float | None = None,
    serve_port: int | None = None,
    exit_with_pid: int | None = None,
    device_tree: str | None = None,
    rules: Sequence[Rule] = (),
    trend_rule: TrendRule | None = None,
    threshold: float | None = None,
    consecutive: int | None = None,
    cwd: str | None = None,
    push: str | None = None,
    push_node: str | None = None,
):
    """Spawn ``python -m repro.profilerd attach`` as a detached subprocess.

    The one place that knows the spawn recipe (absolute source root on
    PYTHONPATH so a relative one still resolves from any cwd, CPU-only JAX,
    flag spelling) — used by both :class:`~repro.profilerd.agent.DaemonBackend`
    and the launcher's shared per-node attach.  Returns the
    ``subprocess.Popen``; send it SIGTERM for a clean final drain + publish.
    """
    import subprocess
    import sys

    src_root = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    cmd = [sys.executable, "-m", "repro.profilerd", "attach"]
    if spool_path is not None:
        cmd += ["--spool", spool_path]
    if targets:
        cmd += ["--targets", ",".join(targets)]
    if watch_dir is not None:
        cmd += ["--watch", watch_dir]
    default_out = f"{spool_path}.d" if spool_path else None
    if out_dir or default_out:
        cmd += ["--out", out_dir or default_out]
    cmd += ["--interval", str(interval_s)]
    if collapse_origins:
        cmd += ["--collapse", ",".join(collapse_origins)]
    if stall_timeout_s is not None:
        cmd += ["--stall-timeout", str(stall_timeout_s)]
    if epoch_s is not None:
        cmd += ["--epoch", str(epoch_s)]
    if serve_port is not None:
        cmd += ["--serve", str(serve_port)]
    if exit_with_pid is not None:
        cmd += ["--exit-with", str(exit_with_pid)]
    if device_tree is not None:
        cmd += ["--device-tree", device_tree]
    if push is not None:
        cmd += ["--push", push]
    if push_node is not None:
        cmd += ["--push-node", push_node]
    if threshold is not None:
        cmd += ["--threshold", str(threshold)]
    if consecutive is not None:
        cmd += ["--consecutive", str(consecutive)]
    for rule in rules:
        cmd += ["--rule", rule_to_spec(rule)]
    if trend_rule is not None:
        cmd += [
            "--trend-threshold", str(trend_rule.threshold),
            "--trend-epochs", str(trend_rule.epochs),
            "--trend-drift", str(trend_rule.drift_threshold),
        ]
    return subprocess.Popen(
        cmd, cwd=cwd, env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL
    )


@dataclass
class DaemonConfig:
    # One of spool_path / spool_paths / watch_dir must be set.  A single
    # spool_path with neither of the others runs in "solo" mode — exactly the
    # classic one-target layout (flat out dir, CountSealer ring).
    spool_path: str | None = None
    spool_paths: tuple[str, ...] = ()  # explicit multi-target attach
    watch_dir: str | None = None  # attach spools created after daemon start
    watch_glob: str = "*.spool"
    out_dir: str | None = None  # default: "<spool_path>.d" / "<watch>/fleet.d"
    publish_interval_s: float = 1.0
    drain_interval_s: float = 0.05
    collapse_origins: tuple[str, ...] = ()
    rules: Sequence[Rule] | None = None
    # No fresh samples for this long while the target is alive => stalled.
    stall_timeout_s: float = 5.0
    attach_timeout_s: float = 30.0
    # Attach-failure retry policy (SpoolSet backoff): exponential with jitter
    # from base to cap, then a terminal SOURCE_GAVE_UP after max attempts.
    attach_retry_base_s: float = 0.5
    attach_retry_cap_s: float = 30.0
    attach_max_attempts: int = 8
    # Multi-target straggler detection: a host whose publish-window share
    # vector diverges from the merged fleet by >= threshold (TV distance)
    # for `consecutive` windows earns a STRAGGLER event.
    straggler_threshold: float = 0.5
    straggler_consecutive: int = 2
    straggler_min_window: float = 8.0
    max_seconds: float | None = None  # bound the run (tests/benchmarks)
    hot_k: int = 10
    timeline_cap: int = 2048
    window_ring: int = 32
    # Timeline ring: every epoch_s the current window is sealed into an
    # on-disk segment under <out>/timeline (0 disables; a final epoch is
    # always sealed at shutdown so short runs still leave a timeline).
    epoch_s: float = 5.0
    epochs_per_segment: int = 16
    max_segments: int = 64
    trend_rule: TrendRule | None = None
    # Live HTTP query plane (repro.profilerd.server): serve /status /targets
    # /tree /timeline /diff while attached.  None disables; 0 binds an
    # ephemeral port.  Handlers read the published snapshot under a lock —
    # the ingest path is never touched by a request.
    serve_port: int | None = None
    serve_host: str = "127.0.0.1"
    # Stop (clean final drain+publish) when this pid dies.  A --watch daemon
    # has no BYE-based exit, so a supervisor that crashes before sending
    # SIGTERM would otherwise leak it forever; the launcher passes its own
    # pid here.
    exit_with_pid: int | None = None
    # Device-plane artifact (core/hlo_tree.save_device_tree) for the fleet's
    # compiled program.  Explicit path, or None to lazily discover a
    # ``device_tree.json`` dropped into the out dir / a target dir — targets
    # compile *after* the daemon starts, so discovery must be late-bound.
    # When present the fleet timeline seals roofline-annotated epochs (solo
    # mode switches from the CountSealer fast path to the generic fleet ring
    # to carry them) and the live server gains plane=device|merged.
    device_tree: str | None = None
    # Fleet push plane: POST each sealed epoch (snapshot-codec framing, see
    # repro.profilerd.push) to a regional aggregator.  None disables.  Push
    # rides the epoch cadence, so it needs epoch_s > 0.
    push_url: str | None = None
    push_node: str | None = None  # default: the hostname
    push_keyframe_every: int = 16
    push_max_spill_bytes: int = 16 << 20
    push_timeout_s: float = 5.0

    def resolved_out_dir(self) -> str:
        if self.out_dir:
            return self.out_dir
        if self.spool_path:
            return f"{self.spool_path}.d"
        if self.watch_dir:
            return os.path.join(self.watch_dir, "fleet.d")
        if self.spool_paths:
            return f"{self.spool_paths[0]}.d"
        raise ValueError("DaemonConfig needs spool_path, spool_paths or watch_dir")

    def resolved_timeline_dir(self) -> str:
        return os.path.join(self.resolved_out_dir(), TIMELINE_DIRNAME)

    def all_spool_paths(self) -> tuple[str, ...]:
        paths = (self.spool_path,) if self.spool_path else ()
        return paths + tuple(p for p in self.spool_paths if p != self.spool_path)


def _atomic_write(path: str, text: str) -> None:
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)


class ProfilerDaemon:
    """Streaming aggregator over a fleet of target spools."""

    def __init__(self, cfg: DaemonConfig):
        self.cfg = cfg
        if not (cfg.spool_path or cfg.spool_paths or cfg.watch_dir):
            raise ValueError("DaemonConfig needs spool_path, spool_paths or watch_dir")
        self.out_dir = cfg.resolved_out_dir()
        os.makedirs(self.out_dir, exist_ok=True)
        # Solo mode = the classic single-target daemon: flat artifact layout,
        # the source's tree IS the fleet tree, its CountSealer ring IS the
        # fleet ring (O(touched chains) per epoch, no merge work at all).
        self.solo = bool(cfg.spool_path) and not cfg.spool_paths and not cfg.watch_dir
        self.spools = SpoolSet(
            paths=cfg.all_spool_paths(),
            watch_dir=cfg.watch_dir,
            watch_glob=cfg.watch_glob,
            make_source=self._make_source,
            attach_retry_base_s=cfg.attach_retry_base_s,
            attach_retry_cap_s=cfg.attach_retry_cap_s,
            attach_max_attempts=cfg.attach_max_attempts,
        )
        # Device plane: loaded from cfg.device_tree or discovered beside the
        # out dir once a target drops its artifact (see _refresh_device_tree).
        self._device_tree: CallTree | None = None
        self._device_tree_mtime = -1.0
        self._device_tree_error: str | None = None
        # Static call-graph plane: discovered beside the out dir, same
        # lazy-artifact lifecycle as the device plane (_refresh_static_tree).
        self._static_tree: CallTree | None = None
        self._static_tree_mtime = -1.0
        self._static_tree_error: str | None = None
        # Fleet timeline ring (multi mode): per-target rings are sealed by
        # each source's CountSealer; the fleet ring is merged at seal time.
        # Solo mode with an explicit device tree also takes this path — the
        # CountSealer fast lane is samples-only and cannot carry roofline
        # annotations, so annotated epochs go through the generic codec.
        self.fleet_writer: TimelineWriter | None = None
        if cfg.epoch_s > 0 and (not self.solo or cfg.device_tree):
            self.fleet_writer = TimelineWriter(
                cfg.resolved_timeline_dir(),
                epochs_per_segment=cfg.epochs_per_segment,
                max_segments=cfg.max_segments,
            )
        self._fleet_prev: CallTree | None = None
        self._fleet_epoch = 0
        self._fleet_tree = CallTree()  # latest published merge (multi mode)
        self._fleet_n = 0  # source count at the last fleet merge
        self._target_rows: dict[str, str] = {}  # last written status row per target
        self.events: list[dict] = []
        # Logged once per daemon: the vectorized ingest lane being absent
        # (no numpy) is an environment property, not a per-target one.
        self._scalar_fallback_logged = False
        # Ring of windowed fleet snapshots: (wall_time, cumulative-tree copy)
        # serving retrospective "what changed in the last N windows" queries.
        self.windows: deque = deque(maxlen=cfg.window_ring)
        # Live query plane (see enable_serving): the publisher hands each
        # window's status + tree copies to `shared`; HTTP threads read those.
        self.shared = None
        self.server = None
        self._stop_requested = False
        self._attach_errors: dict[str, str] = {}
        self._last_attach_error: SpoolError | None = None
        # Fault-window markers: a harness (repro.faults) appends inject/clear
        # lines to <out>/fault_markers.jsonl; the daemon tails the file and
        # threads each marker into the event log stamped with the current
        # epoch counters, so scoring can align verdicts to injections.
        self._fault_marker_offset = 0
        self._fault_marker_buf = b""
        # Multi-target straggler detection over publish-window deltas.
        from repro.core.detector import StragglerDetector

        self._straggler = StragglerDetector(threshold=cfg.straggler_threshold)
        self._straggler_prev: dict[str, CallTree] = {}
        self._straggler_streaks: dict[str, int] = {}
        # Fleet push plane: ship each sealed epoch to a regional aggregator.
        # Outages spill locally (bounded) and resync via keyframe, so a dead
        # aggregator never blocks ingest or loses epoch mass.
        self._push = None
        self._push_done = False
        if cfg.push_url:
            import socket

            from .push import PushClient

            self._push = PushClient(
                cfg.push_url,
                cfg.push_node or socket.gethostname().split(".")[0] or "node",
                interval_hint_s=cfg.epoch_s if cfg.epoch_s > 0 else cfg.publish_interval_s,
                keyframe_every=cfg.push_keyframe_every,
                max_spill_bytes=cfg.push_max_spill_bytes,
                timeout_s=cfg.push_timeout_s,
                retry_base_s=cfg.attach_retry_base_s,
                retry_cap_s=cfg.attach_retry_cap_s,
                on_event=self._record_event,
            )
        self._t_start = time.monotonic()

    # -- compatibility surface (classic single-target attributes) ------------

    def _solo_source(self) -> SpoolSource | None:
        if len(self.spools.sources) == 1:
            return next(iter(self.spools.sources.values()))
        return None

    @property
    def sources(self) -> list[SpoolSource]:
        return list(self.spools.sources.values())

    @property
    def tree(self) -> CallTree:
        """The fleet tree: the lone source's live tree, or the latest merge."""
        src = self._solo_source()
        if src is not None:
            return src.tree
        return self._fleet_tree

    @property
    def target_pid(self) -> int:
        src = self._solo_source()
        return src.target_pid if src is not None else 0

    @property
    def wire_version(self) -> int:
        return max((s.wire_version for s in self.sources), default=0)

    @property
    def n_stacks(self) -> int:
        return sum(s.n_stacks for s in self.sources)

    @property
    def n_ticks_reported(self) -> int:
        return sum(s.n_ticks_reported for s in self.sources)

    @property
    def dropped_batches(self) -> int:
        return sum(s.dropped_batches for s in self.sources)

    @property
    def bye_seen(self) -> bool:
        srcs = self.sources
        return bool(srcs) and all(s.bye_seen for s in srcs)

    # -- event plumbing ------------------------------------------------------

    def _on_anomaly(self, ev, target: str) -> None:
        self._record_event(
            {
                "kind": ev.kind,
                "detector": "dominance",
                "target": target,
                "path": list(ev.path),
                "share": ev.share,
                "rule_pattern": ev.rule.pattern,
                "window": ev.window_index,
                "wall_time": ev.wall_time,
            }
        )

    def _on_callback_failed(self, ev, tb: str, target: str) -> None:
        # A poisoned verdict action (warn/checkpoint hook) is recorded and
        # survived — the drain loop must keep sampling a sick process.
        self._record_event(
            {
                "kind": "CALLBACK_FAILED",
                "detector": "daemon",
                "target": target,
                "path": list(ev.path),
                "share": ev.share,
                "event_kind": ev.kind,
                "error": tb.strip().splitlines()[-1] if tb.strip() else "",
                "traceback": tb,
                "wall_time": time.time(),
            }
        )

    def _record_event(self, ev: dict) -> None:
        self.events.append(ev)
        try:
            with open(os.path.join(self.out_dir, "events.jsonl"), "a") as f:
                f.write(json.dumps(ev) + "\n")
        except OSError:
            pass

    # -- attach / ingest -----------------------------------------------------

    def _target_dir(self, name: str) -> str:
        return os.path.join(self.out_dir, TARGETS_DIRNAME, name)

    def _make_source(self, name: str, path: str, reader: SpoolReader | None = None):
        try:
            tdir = None
            if self.cfg.epoch_s > 0:
                if self.solo:
                    # The fleet writer owns the solo ring when annotating
                    # (device-tree mode); the source must not also seal there.
                    tdir = None if self.fleet_writer is not None else self.cfg.resolved_timeline_dir()
                else:
                    tdir = os.path.join(self._target_dir(name), TIMELINE_DIRNAME)
            src = SpoolSource(
                name,
                path,
                reader=reader,
                collapse_origins=self.cfg.collapse_origins,
                rules=self.cfg.rules,
                trend_rule=self.cfg.trend_rule,
                timeline_dir=tdir,
                epochs_per_segment=self.cfg.epochs_per_segment,
                max_segments=self.cfg.max_segments,
                timeline_cap=self.cfg.timeline_cap,
            )
        except (SpoolError, OSError, ValueError) as e:
            # OSError covers per-target TimelineWriter/dir creation failures
            # (unwritable out dir): one bad attach must not crash the daemon
            # for every healthy target.
            if isinstance(e, SpoolError):
                self._last_attach_error = e
            # Log each distinct failure once: a half-created file under
            # --watch is retried every drain pass and must not spam the log.
            if self._attach_errors.get(path) != str(e):
                self._attach_errors[path] = str(e)
                self._record_event(
                    {"kind": "SOURCE_ATTACH_FAILED", "target": name, "path": path,
                     "error": str(e), "wall_time": time.time()}
                )
            return None
        self._attach_errors.pop(path, None)
        self._last_attach_error = None
        if not src.pipeline.vectorized and not self._scalar_fallback_logged:
            # Per-sample decode still works — this only flags the missing
            # throughput headroom (numpy absent), visibly but exactly once.
            self._scalar_fallback_logged = True
            self._record_event(
                {"kind": "INGEST_SCALAR_FALLBACK", "detector": "ingest", "target": name,
                 "path": [], "share": 0.0,
                 "reason": "numpy unavailable: vectorized batch ingest disabled",
                 "wall_time": time.time()}
            )
        src.detector.add_callback(lambda ev, _n=name: self._on_anomaly(ev, _n))
        src.detector.on_callback_error = (
            lambda ev, tb, _n=name: self._on_callback_failed(ev, tb, _n)
        )
        if not self.solo:
            os.makedirs(self._target_dir(name), exist_ok=True)
            self._record_event(
                {"kind": "TARGET_ATTACHED", "target": name, "path": path,
                 "pid": src.target_pid, "wall_time": time.time()}
            )
        return src

    def attach(self) -> "ProfilerDaemon":
        """Block until at least one source is attached (``attach_timeout_s``).

        Solo mode waits for the one configured spool, exactly as before.
        Multi mode attaches whatever is already there and returns as soon as
        one source exists; remaining explicit paths and watch discoveries
        attach inside the run loop as they appear.
        """
        deadline = time.monotonic() + self.cfg.attach_timeout_s
        while True:
            self.spools.discover()
            self._drain_gave_up()
            if self.spools.sources:
                break
            # A present-but-garbage spool should fail fast, not time out —
            # but only when no watch dir could still produce a valid one, and
            # never on a short header (the file may still be materializing).
            if (
                self._last_attach_error is not None
                and not isinstance(self._last_attach_error, _ShortHeader)
                and self.cfg.watch_dir is None
                and all(os.path.exists(p) for p in self.cfg.all_spool_paths())
            ):
                raise self._last_attach_error
            if time.monotonic() >= deadline:
                what = ", ".join(self.cfg.all_spool_paths()) or f"watch:{self.cfg.watch_dir}"
                raise SpoolError(
                    f"spool {what} did not appear within {self.cfg.attach_timeout_s:.0f}s"
                )
            if self._stop_requested:
                raise SpoolError("stopped before any spool appeared")
            time.sleep(0.05)
        # Silence (stall detection) and max_seconds count from the moment the
        # first target's spool appeared — a target launched long after the
        # daemon must not start life looking stalled.
        self._t_start = time.monotonic()
        return self

    def drain(self) -> int:
        """One full pass: discovery, re-attach checks, then drain every
        source dry (round-robin bounded chunks).  Returns stacks ingested."""
        before = self.n_stacks
        self.spools.discover()
        self._drain_gave_up()
        self._poll_fault_markers()
        for s in self.sources:
            if s.maybe_reattach():
                self._record_event(
                    {"kind": "TARGET_RESTARTED", "target": s.name, "path": s.path,
                     "pid": s.target_pid, "restarts": s.restarts,
                     "wall_time": time.time()}
                )
        self.spools.drain_all()
        return self.n_stacks - before

    def _drain_gave_up(self) -> None:
        """Terminal SOURCE_GAVE_UP events for paths past the retry budget."""
        for p in self.spools.gave_up_now:
            self._record_event(
                {"kind": "SOURCE_GAVE_UP", "target": source_name_for(p), "path": p,
                 "attempts": self.cfg.attach_max_attempts,
                 "error": self._attach_errors.get(p, ""), "wall_time": time.time()}
            )
        self.spools.gave_up_now.clear()

    def request_stop(self) -> None:
        """Ask the run loop to finalize (final drain + seal + publish) and
        return.  Safe from signal handlers and other threads."""
        self._stop_requested = True

    # -- analysis / publication ---------------------------------------------

    def _device_tree_candidates(self) -> list[str]:
        if self.cfg.device_tree:
            return [self.cfg.device_tree]
        cands = [os.path.join(self.out_dir, DEVICE_TREE_FILENAME)]
        tdir = os.path.join(self.out_dir, TARGETS_DIRNAME)
        if os.path.isdir(tdir):
            for name in sorted(os.listdir(tdir)):
                cands.append(os.path.join(tdir, name, DEVICE_TREE_FILENAME))
        return cands

    def _refresh_device_tree(self) -> None:
        """Pick up the device-plane artifact, possibly dropped mid-run.

        Targets lower+compile *after* attaching, so the artifact usually lands
        after the daemon started; one existence/mtime probe per publish window
        keeps discovery off the ingest path.  A loaded tree is copied to the
        out dir (making it self-contained for later offline serving) and
        handed to the live query plane.
        """
        path = next((p for p in self._device_tree_candidates() if os.path.exists(p)), None)
        if path is None:
            return
        try:
            mtime = os.path.getmtime(path)
        except OSError:
            return
        if self._device_tree is not None and mtime <= self._device_tree_mtime:
            return
        from repro.core.hlo_tree import load_device_tree

        try:
            tree = load_device_tree(path)
        except (OSError, ValueError, KeyError) as e:
            if self._device_tree_error != str(e):  # log each distinct failure once
                self._device_tree_error = str(e)
                self._record_event(
                    {"kind": "DEVICE_TREE_UNREADABLE", "path": path,
                     "error": str(e), "wall_time": time.time()}
                )
            return
        self._device_tree = tree
        self._device_tree_mtime = mtime
        self._device_tree_error = None
        fleet_copy = os.path.join(self.out_dir, DEVICE_TREE_FILENAME)
        if os.path.abspath(path) != os.path.abspath(fleet_copy):
            try:
                with open(path) as f:
                    _atomic_write(fleet_copy, f.read())
            except OSError:
                pass  # serving still works from the in-memory tree
        if self.shared is not None:
            self.shared.set_device_tree(tree)
        self._record_event(
            {"kind": "DEVICE_TREE_LOADED", "path": path,
             "call_sites": tree.node_count(), "wall_time": time.time()}
        )

    def _refresh_static_tree(self) -> None:
        """Pick up the static call-graph artifact, possibly dropped mid-run.

        ``python -m repro.analysis extract --out <out_dir>/static_tree.json``
        (an operator, or CI) drops the artifact at any point; one
        existence/mtime probe per publish window hands it to the live query
        plane so ``/tree?plane=static`` works without a daemon restart.
        """
        path = os.path.join(self.out_dir, STATIC_TREE_FILENAME)
        if not os.path.exists(path):
            return
        try:
            mtime = os.path.getmtime(path)
        except OSError:
            return
        if self._static_tree is not None and mtime <= self._static_tree_mtime:
            return
        from repro.analysis.static_tree import load_static_tree

        try:
            tree = load_static_tree(path)
        except (OSError, ValueError, KeyError) as e:
            if self._static_tree_error != str(e):  # log each distinct failure once
                self._static_tree_error = str(e)
                self._record_event(
                    {"kind": "STATIC_TREE_UNREADABLE", "path": path,
                     "error": str(e), "wall_time": time.time()}
                )
            return
        self._static_tree = tree
        self._static_tree_mtime = mtime
        self._static_tree_error = None
        if self.shared is not None:
            self.shared.set_static_tree(tree)
        self._record_event(
            {"kind": "STATIC_TREE_LOADED", "path": path,
             "call_sites": tree.node_count(), "wall_time": time.time()}
        )

    def seal_epoch(self) -> None:
        """Seal the current window into the timeline ring(s) + trend rules.

        Each source's ingestor hands over the chains it touched this epoch,
        so per-target sealing costs O(touched paths); the fleet ring (multi
        mode) then merges the per-target trees at seal time — one O(forest)
        merge per epoch, never per sample.
        """
        if self.cfg.epoch_s <= 0:
            return
        # A short run can seal its only epoch before the first publish window
        # ever fires — the artifact must still be picked up here.
        self._refresh_device_tree()
        self._refresh_static_tree()
        wall = time.time()
        for s in self.sources:
            try:
                meta, verdicts = s.seal_epoch(wall)
            except OSError as e:
                self._record_event(
                    {"kind": "TIMELINE_WRITE_FAILED", "target": s.name, "path": [],
                     "share": 0.0, "error": str(e), "wall_time": wall}
                )
                continue
            if meta is None:
                continue
            for v in verdicts:
                self._record_event(
                    {
                        "kind": v.kind,
                        "detector": "trend",
                        "target": s.name,
                        "path": list(v.path),
                        "share": round(v.share, 4),
                        "epoch": v.epoch,
                        "began_epoch": v.began_epoch,
                        "latency_epochs": v.latency_epochs,
                        "wall_time": v.wall_time,
                    }
                )
        fleet: CallTree | None = None
        if (self.fleet_writer is not None or self._push is not None) and self.sources:
            solo_src = self._solo_source()
            if self.solo and solo_src is not None and self.fleet_writer is None:
                # Solo push without a fleet ring: the lone source's live tree
                # IS the fleet — no merge copy needed (push only reads it).
                fleet = solo_src.tree
            else:
                fleet = CallTree()
                for s in self.sources:
                    fleet.merge(s.tree)
                if self._device_tree is not None:
                    # Annotations are ordinary metric keys, so the sealed
                    # epochs carry the device plane through the unchanged
                    # codec — and cross-run diff/check can gate on roofline
                    # regressions.
                    from repro.core.planes import annotate_tree

                    # The fleet tree was built fresh above, so annotate in
                    # place: the device plane's marginal cost is one
                    # attribution walk.
                    fleet = annotate_tree(fleet, self._device_tree, copy=False)
        progress = float(
            sum(s.sealer.node_count for s in self.sources if s.sealer)
            or (fleet.node_count() if fleet is not None else 0)
        )
        if self.fleet_writer is not None and fleet is not None:
            meta = EpochMeta(self._fleet_epoch, wall, progress)
            try:
                if self._fleet_prev is None or self.fleet_writer.needs_keyframe():
                    self.fleet_writer.append_full(fleet, meta)
                else:
                    self.fleet_writer.append_delta(fleet.diff(self._fleet_prev), meta)
                self._fleet_prev = fleet
                self._fleet_epoch += 1
            except OSError as e:
                self._record_event(
                    {"kind": "TIMELINE_WRITE_FAILED", "target": "<fleet>", "path": [],
                     "share": 0.0, "error": str(e), "wall_time": wall}
                )
        if self._push is not None and fleet is not None:
            # Ship this epoch to the regional aggregator.  The client keeps
            # its own cumulative shadow (decoupled from the local ring's
            # keyframe cadence), spills through outages, and resyncs with a
            # K_FULL — a dead aggregator costs bounded memory, zero mass.
            self._push.push_epoch(
                fleet,
                wall_time=wall,
                progress=progress,
                targets=[s.name for s in self.sources],
                done=self._push_done,
            )

    def _check_stalls(self) -> None:
        for s in self.sources:
            if s.resumed_pending:
                s.resumed_pending = False
                self._record_event(
                    {"kind": RESUMED, "detector": "stall", "target": s.name,
                     "path": [], "share": 0.0, "pid": s.target_pid,
                     "wall_time": time.time()}
                )
            ev = s.check_stall(self.cfg.stall_timeout_s)
            if ev is not None:
                self._record_event(ev)

    def _check_stragglers(self, changed: list) -> None:
        """Flag hosts whose publish-window activity diverges from the fleet.

        Windows are per-source deltas since this check last saw the source;
        the detector needs at least two busy hosts to define "the fleet".
        A host fires once per divergence streak (at `straggler_consecutive`),
        re-arming when it rejoins the fleet's profile.
        """
        if self.solo:
            return
        windows: dict[str, CallTree] = {}
        for s, snap in changed:
            prev = self._straggler_prev.get(s.name)
            win = snap.diff(prev) if prev is not None else snap
            self._straggler_prev[s.name] = snap
            if win.total() >= self.cfg.straggler_min_window:
                windows[s.name] = win
        if len(windows) < 2:
            return
        flagged = dict(self._straggler.observe(windows))
        for name in windows:
            if name not in flagged:
                self._straggler_streaks.pop(name, None)
        for name, tv in flagged.items():
            streak = self._straggler_streaks.get(name, 0) + 1
            self._straggler_streaks[name] = streak
            if streak == self.cfg.straggler_consecutive:
                self._record_event(
                    {"kind": "STRAGGLER", "detector": "straggler", "target": name,
                     "path": [], "share": round(tv, 4), "peers": len(windows),
                     "wall_time": time.time()}
                )

    def _poll_fault_markers(self) -> None:
        """Tail <out>/fault_markers.jsonl into FAULT_* timeline events.

        Each marker line ({"op": "inject"|"clear", "scenario": ..., ...}) is
        stamped with the daemon's *current* epoch counters at ingest time —
        the ground-truth alignment the fault scoreboard scores against.
        """
        path = os.path.join(self.out_dir, FAULT_MARKERS_FILENAME)
        try:
            with open(path, "rb") as f:
                f.seek(self._fault_marker_offset)
                data = f.read()
        except OSError:
            return
        if not data:
            return
        self._fault_marker_offset += len(data)
        self._fault_marker_buf += data
        *lines, self._fault_marker_buf = self._fault_marker_buf.split(b"\n")
        for line in lines:
            line = line.strip()
            if not line:
                continue
            try:
                marker = json.loads(line)
                op = marker["op"]
            except (ValueError, TypeError, KeyError):
                self._record_event(
                    {"kind": "FAULT_MARKER_INVALID", "detector": "daemon",
                     "line": line.decode("utf-8", "replace")[:200],
                     "wall_time": time.time()}
                )
                continue
            solo_src = self._solo_source()
            self._record_event(
                {
                    "kind": "FAULT_INJECT" if op == "inject" else "FAULT_CLEAR",
                    "detector": "harness",
                    "scenario": marker.get("scenario", ""),
                    "op": op,
                    "epoch": (
                        solo_src.sealer.epoch
                        if self.solo and solo_src is not None and solo_src.sealer is not None
                        else self._fleet_epoch
                    ),
                    "target_epochs": {
                        s.name: s.sealer.epoch for s in self.sources if s.sealer is not None
                    },
                    "marker_wall_time": marker.get("wall_time"),
                    "wall_time": time.time(),
                }
            )

    def enable_serving(self, port: int | None = None, host: str | None = None):
        """Start the HTTP query plane over this daemon's published state.

        Returns the started :class:`~repro.profilerd.server.ProfileServer`.
        Reads are decoupled from ingest: every publish window hands a status
        dict plus immutable fleet/per-target tree copies to
        :class:`SharedProfileState`, and request handlers only ever touch
        those.
        """
        from .server import LiveSource, ProfileServer, SharedProfileState

        if self.server is not None:
            return self.server
        self.shared = SharedProfileState()
        if self._device_tree is not None:
            self.shared.set_device_tree(self._device_tree)
        if self._static_tree is not None:
            self.shared.set_static_tree(self._static_tree)
        tdir = self.cfg.resolved_timeline_dir() if self.cfg.epoch_s > 0 else None
        label = f"pid={self.target_pid or '?'}" if self.solo else f"fleet:{self.out_dir}"
        source = LiveSource(
            self.shared,
            timeline_dir=tdir,
            label=label,
            target_timeline_dir_fn=None if self.solo else self._target_timeline_dir,
        )
        self.server = ProfileServer(
            source,
            host=host if host is not None else self.cfg.serve_host,
            port=port if port is not None else (self.cfg.serve_port or 0),
        ).start()
        self._record_event(
            {"kind": "SERVING", "path": [], "share": 0.0, "url": self.server.url,
             "wall_time": time.time()}
        )
        return self.server

    def _target_timeline_dir(self, name: str) -> str | None:
        if self.cfg.epoch_s <= 0 or name not in self.spools.sources:
            return None
        return os.path.join(self._target_dir(name), TIMELINE_DIRNAME)

    def publish(self) -> None:
        """One analysis window: detector verdicts + status/tree artifacts."""
        self._refresh_device_tree()
        self._refresh_static_tree()
        changed = []
        for s in self.sources:
            snap = s.publish_window()
            if snap is not None:
                changed.append((s, snap))
        solo_src = self._solo_source()
        fleet_snap: CallTree | None = None
        if solo_src is not None:
            # The lone source's snapshot is the fleet snapshot — no merge.
            fleet_snap = changed[0][1] if changed else None
        elif changed or len(self.sources) != self._fleet_n:
            # Re-merge on new samples, and also when the source set changed —
            # `tree` switches from the lone source's live tree to the merged
            # fleet the moment a second target attaches, and the merge must
            # not lag behind that switch.
            fleet_snap = CallTree()
            for s in self.sources:
                if s.last_snapshot is not None:
                    fleet_snap.merge(s.last_snapshot)
            self._fleet_tree = fleet_snap
            self._fleet_n = len(self.sources)
        if fleet_snap is not None:
            self.windows.append((time.time(), fleet_snap))
        self._check_stalls()
        self._check_stragglers(changed)
        status = self.status()
        if self.shared is not None:
            # Snapshots are never mutated after this point; handlers may read
            # them concurrently.  Quiet windows keep the previous trees.
            self.shared.update(
                status,
                fleet_snap,
                targets={s.name: s.last_snapshot for s in self.sources
                         if s.last_snapshot is not None},
            )
        _atomic_write(os.path.join(self.out_dir, "tree.json"), self.tree.to_json())
        if not self.solo:
            fresh = {id(s): snap for s, snap in changed}
            for s in self.sources:
                # Per-target status: the same artifact contract a solo daemon
                # gives its target, so a DaemonBackend pointed here via
                # REPRO_PROFILERD_OUT (the launcher's shared daemon) keeps its
                # snapshot()/depth_trace()/wait-for-done working unchanged.
                # Quiet, unchanged targets are skipped — a long-lived watch
                # daemon must not rewrite N done targets' files every window.
                row = s.status_row()
                row_key = json.dumps(row, sort_keys=True)
                snap = fresh.get(id(s))
                if snap is None and self._target_rows.get(s.name) == row_key:
                    continue
                tdir = self._target_dir(s.name)
                os.makedirs(tdir, exist_ok=True)
                if snap is not None:
                    _atomic_write(os.path.join(tdir, "tree.json"), snap.to_json())
                row["depth_timeline"] = [[round(t, 4), d] for t, d in s.timeline]
                row["updated"] = status["updated"]
                _atomic_write(os.path.join(tdir, "status.json"), json.dumps(row))
                self._target_rows[s.name] = row_key
        _atomic_write(os.path.join(self.out_dir, "status.json"), json.dumps(status))

    def status(self) -> dict:
        srcs = self.sources
        solo_src = self._solo_source()
        tree = self.tree
        if solo_src is not None:
            depth_timeline = [[round(t, 4), d] for t, d in solo_src.timeline]
        else:
            merged = sorted(
                (t, d) for s in srcs for t, d in s.timeline
            )[-self.cfg.timeline_cap :]
            depth_timeline = [[round(t, 4), d] for t, d in merged]
        if self.cfg.epoch_s > 0:
            if self.solo and solo_src is not None and solo_src.sealer is not None:
                timeline_block = {
                    "dir": self.cfg.resolved_timeline_dir(),
                    "epochs": solo_src.sealer.epoch,
                    "call_sites": solo_src.sealer.node_count,
                    "epoch_s": self.cfg.epoch_s,
                }
            else:
                timeline_block = {
                    "dir": self.cfg.resolved_timeline_dir(),
                    "epochs": self._fleet_epoch,
                    "call_sites": sum(s.sealer.node_count for s in srcs if s.sealer),
                    "epoch_s": self.cfg.epoch_s,
                }
        else:
            timeline_block = None
        return {
            "pid": solo_src.target_pid if solo_src is not None else 0,
            "alive": any(s.alive for s in srcs),
            "stalled": any(s.stalled for s in srcs),
            "done": self.bye_seen,
            "period_s": solo_src.period_s if solo_src is not None
            else max((s.period_s for s in srcs), default=0.0),
            "wire_version": self.wire_version,
            "n_stacks": self.n_stacks,
            "n_ticks": self.n_ticks_reported,
            "dropped_batches": self.dropped_batches,
            "resolver": {
                "hits": sum(s.resolver.hits for s in srcs),
                "misses": sum(s.resolver.misses for s in srcs),
            },
            # The unified ingest_stats schema (repro.profilerd.pipeline),
            # summed across sources; per-target rows carry the same dict.
            "ingest": merge_ingest_stats([s.ingest_stats() for s in srcs]),
            # Degraded-mode accounting for re-attaching mid-stream (a
            # previous reader consumed the STRDEF/STACKDEF definitions):
            # such samples ingest as "?" placeholder stacks, never silently.
            "unknown_stack_refs": sum(s.unknown_stack_refs for s in srcs),
            "degraded_stackdefs": sum(s.degraded_stackdefs for s in srcs),
            "n_targets": len(srcs),
            "watch": self.cfg.watch_dir,
            "attach_failures": [
                dict(row, error=self._attach_errors.get(row["path"], ""))
                for row in self.spools.attach_failure_rows()
            ],
            "device_plane": self._device_tree is not None,
            "static_plane": self._static_tree is not None,
            "node": self._push.node if self._push is not None else None,
            "push": self._push.stats() if self._push is not None else None,
            "targets": {s.name: s.status_row() for s in srcs},
            "hot_paths": [
                {"path": list(p), "share": round(s, 4)}
                for p, s in tree.hot_paths(k=self.cfg.hot_k)
            ],
            "depth_timeline": depth_timeline,
            "events": self.events[-20:],
            "windows": len(self.windows),
            "timeline": timeline_block,
            "updated": time.time(),
        }

    def write_report(self, name: str = "report") -> str:
        from repro.core.report import render_html

        title = (
            f"profilerd pid={self.target_pid}"
            if self.solo
            else f"profilerd fleet ({len(self.sources)} targets)"
        )
        path = os.path.join(self.out_dir, f"{name}.html")
        _atomic_write(path, render_html(self.tree, title=title))
        return path

    # -- main loop -----------------------------------------------------------

    def _all_done(self) -> bool:
        srcs = self.sources
        if not srcs or not self.spools.all_explicit_attached:
            return False
        return all(s.bye_seen or not s.alive for s in srcs)

    def run(self, on_publish=None) -> CallTree:
        """Attach, stream until every target says BYE / dies (explicit
        targets), a stop is requested (``--watch`` mode, SIGTERM), or
        ``max_seconds`` — then final-publish and write the HTML report.
        Returns the merged fleet tree."""
        if not self.spools.sources:
            self.attach()
        if self.cfg.serve_port is not None and self.server is None:
            try:
                self.enable_serving()
            except OSError as e:
                # A busy/privileged port must not cost the profiling run.
                self._record_event(
                    {"kind": "SERVE_FAILED", "path": [], "share": 0.0,
                     "error": str(e), "wall_time": time.time()}
                )
        next_publish = time.monotonic() + self.cfg.publish_interval_s
        next_epoch = (
            time.monotonic() + self.cfg.epoch_s if self.cfg.epoch_s > 0 else None
        )
        while True:
            self.drain()
            now = time.monotonic()
            # An explicit target whose spool never appeared must not pin the
            # run open forever: after the attach window it is abandoned with
            # a loud event, and _all_done() can then see the real targets.
            if (
                not self.spools.all_explicit_attached
                and now - self._t_start >= self.cfg.attach_timeout_s
            ):
                for p in self.spools.abandon_pending():
                    self._record_event(
                        {"kind": "TARGET_NEVER_APPEARED", "target": source_name_for(p),
                         "path": p, "timeout_s": self.cfg.attach_timeout_s,
                         "wall_time": time.time()}
                    )
            if now >= next_publish:
                self.publish()
                if on_publish is not None:
                    on_publish(self)
                next_publish = now + self.cfg.publish_interval_s
            if next_epoch is not None and now >= next_epoch:
                self.seal_epoch()
                next_epoch = now + self.cfg.epoch_s
            if self.cfg.exit_with_pid is not None and not _pid_alive(self.cfg.exit_with_pid):
                self._record_event(
                    {"kind": "SUPERVISOR_GONE", "pid": self.cfg.exit_with_pid,
                     "wall_time": time.time()}
                )
                self.request_stop()
            if self._stop_requested:
                break
            # drain() above already emptied every spool.  A --watch daemon
            # outlives done targets: new spools may appear at any time, so it
            # only exits on request_stop()/SIGTERM or max_seconds.
            if self.cfg.watch_dir is None and self._all_done():
                break
            if self.cfg.max_seconds is not None and now - self._t_start >= self.cfg.max_seconds:
                break
            time.sleep(self.cfg.drain_interval_s)
        self.drain()  # salvage whatever dead/late targets left behind
        self._push_done = True  # the final push announces a clean shutdown
        self.seal_epoch()  # final epoch: short runs still leave a timeline
        self.publish()
        if on_publish is not None:
            on_publish(self)
        self.write_report()
        if self.server is not None:
            self.server.stop()
            self.server = None
        if self.fleet_writer is not None:
            self.fleet_writer.close()
        for s in self.sources:
            s.close()
        return self.tree
