"""The profiling daemon: live streaming aggregation in a separate process.

Drains the target's spool, resolves and classifies symbols with an
interned-symbol cache (:mod:`repro.profilerd.resolver`), merges every sample
into a :class:`~repro.core.calltree.CallTree`, keeps a ring of windowed
snapshots driving :class:`~repro.core.detector.DominanceDetector` rules
out-of-process, and publishes:

* ``status.json`` — live hot paths, depth-timeline tail, detector verdicts,
  drop/ingest counters (atomically replaced every publish interval);
* ``tree.json``   — the full merged tree (the drivers' ``snapshot()`` reads
  this, so the in-process watchdog works unchanged with the daemon backend);
* ``events.jsonl``— append-only anomaly log;
* ``report.html`` / final ``tree.json`` — on-demand / at shutdown via
  :func:`~repro.core.report.render_html`.

Because the daemon is a separate process it also detects the one failure an
in-process helper thread cannot: a target whose interpreter is fully wedged
(GIL held in native code, SIGSTOP, hard livelock).  The agent goes silent,
the spool stops advancing, and after ``stall_timeout_s`` the daemon emits a
``TARGET_STALLED`` verdict — see ``examples/hang_detection.py``.
"""

from __future__ import annotations

import json
import os
import time
from collections import deque
from dataclasses import dataclass
from typing import Optional, Sequence

from repro.core.calltree import CallTree
from repro.core.detector import DominanceDetector, Rule, TrendDetector, TrendRule
from repro.core.snapshot import CountSealer, TimelineWriter

from .ingest import TreeIngestor
from .profiles import TIMELINE_DIRNAME
from .resolver import SymbolResolver
from .spool import SpoolReader
from .wire import Bye, Decoder, Hello, RawSample, Rusage

STALLED = "TARGET_STALLED"


def spawn_attached_daemon(
    spool_path: str,
    out_dir: Optional[str] = None,
    *,
    interval_s: float = 1.0,
    collapse_origins: Sequence[str] = (),
    stall_timeout_s: Optional[float] = None,
    epoch_s: Optional[float] = None,
    serve_port: Optional[int] = None,
    cwd: Optional[str] = None,
):
    """Spawn ``python -m repro.profilerd attach`` as a detached subprocess.

    The one place that knows the spawn recipe (absolute source root on
    PYTHONPATH so a relative one still resolves from any cwd, CPU-only JAX,
    flag spelling) — used by both :class:`~repro.profilerd.agent.DaemonBackend`
    and the launcher's per-host attach.  Returns the ``subprocess.Popen``.
    """
    import subprocess
    import sys

    src_root = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    cmd = [
        sys.executable, "-m", "repro.profilerd", "attach",
        "--spool", spool_path,
        "--out", out_dir or f"{spool_path}.d",
        "--interval", str(interval_s),
    ]
    if collapse_origins:
        cmd += ["--collapse", ",".join(collapse_origins)]
    if stall_timeout_s is not None:
        cmd += ["--stall-timeout", str(stall_timeout_s)]
    if epoch_s is not None:
        cmd += ["--epoch", str(epoch_s)]
    if serve_port is not None:
        cmd += ["--serve", str(serve_port)]
    return subprocess.Popen(
        cmd, cwd=cwd, env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL
    )


@dataclass
class DaemonConfig:
    spool_path: str
    out_dir: Optional[str] = None  # default: "<spool_path>.d"
    publish_interval_s: float = 1.0
    drain_interval_s: float = 0.05
    collapse_origins: tuple[str, ...] = ()
    rules: Optional[Sequence[Rule]] = None
    # No fresh samples for this long while the target is alive => stalled.
    stall_timeout_s: float = 5.0
    attach_timeout_s: float = 30.0
    max_seconds: Optional[float] = None  # bound the run (tests/benchmarks)
    hot_k: int = 10
    timeline_cap: int = 2048
    window_ring: int = 32
    # Timeline ring: every epoch_s the current window is sealed into an
    # on-disk segment under <out>/timeline (0 disables; a final epoch is
    # always sealed at shutdown so short runs still leave a timeline).
    epoch_s: float = 5.0
    epochs_per_segment: int = 16
    max_segments: int = 64
    trend_rule: Optional[TrendRule] = None
    # Live HTTP query plane (repro.profilerd.server): serve /status /tree
    # /timeline /diff while attached.  None disables; 0 binds an ephemeral
    # port.  Handlers read the published snapshot under a lock — the ingest
    # path is never touched by a request.
    serve_port: Optional[int] = None
    serve_host: str = "127.0.0.1"

    def resolved_out_dir(self) -> str:
        return self.out_dir or f"{self.spool_path}.d"

    def resolved_timeline_dir(self) -> str:
        return os.path.join(self.resolved_out_dir(), TIMELINE_DIRNAME)


def _pid_alive(pid: int) -> bool:
    if pid <= 0:
        return False
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True
    return True


def _atomic_write(path: str, text: str) -> None:
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)


class ProfilerDaemon:
    """Streaming aggregator over one target's spool."""

    def __init__(self, cfg: DaemonConfig):
        self.cfg = cfg
        self.out_dir = cfg.resolved_out_dir()
        os.makedirs(self.out_dir, exist_ok=True)
        self.reader: Optional[SpoolReader] = None
        self.decoder = Decoder()
        self.resolver = SymbolResolver(cfg.collapse_origins)
        # Cached-path ingestion: v2 samples resolve once per (thread, stack_id)
        # and repeat as an O(depth) float-add loop (see profilerd.ingest).
        self.ingestor = TreeIngestor(resolver=self.resolver)
        self.tree = self.ingestor.tree
        self.detector = DominanceDetector(list(cfg.rules) if cfg.rules else [Rule()])
        self.detector.add_callback(self._on_anomaly)
        # Timeline plane: epoch sealer + trend detection over sealed windows.
        self.timeline_writer: Optional[TimelineWriter] = None
        self.sealer: Optional[CountSealer] = None
        self.trend: Optional[TrendDetector] = None
        if cfg.epoch_s > 0:
            self.timeline_writer = TimelineWriter(
                cfg.resolved_timeline_dir(),
                epochs_per_segment=cfg.epochs_per_segment,
                max_segments=cfg.max_segments,
            )
            self.sealer = CountSealer(self.tree, self.timeline_writer)
            self.trend = TrendDetector(cfg.trend_rule)
        self.events: list[dict] = []
        self.timeline: deque = deque(maxlen=cfg.timeline_cap)
        self.rusage: deque = deque(maxlen=cfg.timeline_cap)
        # Ring of windowed snapshots: (wall_time, cumulative-tree copy).  The
        # detector diffs consecutive entries internally; the ring also serves
        # retrospective "what changed in the last N windows" queries.
        self.windows: deque = deque(maxlen=cfg.window_ring)
        # Live query plane (see enable_serving): the publisher hands each
        # window's status + tree copy to `shared`; HTTP threads read those.
        self.shared = None
        self.server = None
        self.target_pid = 0
        self.period_s = 0.0
        self.wire_version = 0  # from HELLO; 0 until the target announced
        self.n_stacks = 0
        self.dropped_batches = 0
        self.n_ticks_reported = 0  # from BYE
        self.bye_seen = False
        self._last_sample_wall: Optional[float] = None
        self._samples_since_publish = 0
        self._stalled = False
        self._t_start = time.monotonic()

    # -- event plumbing ------------------------------------------------------

    def _on_anomaly(self, ev) -> None:
        self._record_event(
            {
                "kind": ev.kind,
                "path": list(ev.path),
                "share": ev.share,
                "window": ev.window_index,
                "wall_time": ev.wall_time,
            }
        )

    def _record_event(self, ev: dict) -> None:
        self.events.append(ev)
        try:
            with open(os.path.join(self.out_dir, "events.jsonl"), "a") as f:
                f.write(json.dumps(ev) + "\n")
        except OSError:
            pass

    # -- ingest --------------------------------------------------------------

    def attach(self) -> "ProfilerDaemon":
        self.reader = SpoolReader.wait_for(self.cfg.spool_path, self.cfg.attach_timeout_s)
        self.target_pid = self.reader.writer_pid
        # Silence (stall detection) and max_seconds count from the moment the
        # target's spool appeared — a target launched long after the daemon
        # must not start life looking stalled.
        self._t_start = time.monotonic()
        return self

    def _apply(self, ev) -> None:
        if isinstance(ev, RawSample):
            depth = self.ingestor.ingest(ev)
            self.timeline.append((ev.t, depth))
            self.n_stacks += 1
            self._samples_since_publish += 1
            self._last_sample_wall = time.monotonic()
            self._stalled = False
        elif isinstance(ev, Hello):
            self.target_pid = ev.pid
            self.period_s = ev.period_s
            self.wire_version = ev.version
        elif isinstance(ev, Rusage):
            self.rusage.append((ev.t, ev.cpu_s, ev.rss_bytes))
        elif isinstance(ev, Bye):
            self.bye_seen = True
            self.n_ticks_reported = ev.n_ticks

    def drain(self) -> int:
        """Pull everything currently in the spool; returns stacks ingested."""
        assert self.reader is not None, "attach() first"
        before = self.n_stacks
        while True:
            # read() is capped (1 MiB/call by default), so a multi-minute
            # backlog streams through this loop in bounded chunks instead of
            # materializing as one giant bytes object.
            chunk = self.reader.read()
            if not chunk:
                break
            for ev in self.decoder.feed(chunk):
                self._apply(ev)
        self.dropped_batches = self.reader.dropped
        # The writer sets the header flag even when the BYE *record* was
        # dropped on a full spool; once drained, honor it so a cleanly
        # stopped target is never mistaken for a stalled one.
        if self.reader.bye_seen:
            self.bye_seen = True
        return self.n_stacks - before

    # -- analysis / publication ---------------------------------------------

    def seal_epoch(self) -> None:
        """Seal the current window into the timeline ring + run trend rules.

        The ingestor hands over the node chains it touched this epoch, so
        sealing costs O(touched paths); legacy v1 samples (untracked
        mutations) force the sealer's full-walk fallback.
        """
        if self.sealer is None:
            return
        entries, untracked = self.ingestor.drain_epoch()
        try:
            meta = self.sealer.seal(entries, wall_time=time.time(), untracked=untracked)
        except OSError as e:
            self._record_event(
                {"kind": "TIMELINE_WRITE_FAILED", "path": [], "share": 0.0,
                 "error": str(e), "wall_time": time.time()}
            )
            return
        # The trend window: rebuilt from the epoch's (chain, count) pairs —
        # untracked mutations (v1 samples) are invisible here, which only
        # softens detection for legacy spools, never correctness of the ring.
        window = CallTree()
        for e in entries:
            if e[3] > 0:
                window.add_stack([n.name for n in e[0][1:]], {"samples": float(e[3])})
        for v in self.trend.observe_epoch(
            window, progress=meta.progress, epoch=meta.epoch, wall_time=meta.wall_time
        ):
            self._record_event(
                {
                    "kind": v.kind,
                    "path": list(v.path),
                    "share": round(v.share, 4),
                    "epoch": v.epoch,
                    "began_epoch": v.began_epoch,
                    "wall_time": v.wall_time,
                }
            )

    def _check_stall(self) -> None:
        if self.bye_seen or self._stalled:
            return
        ref = self._last_sample_wall
        if ref is None:
            ref = self._t_start  # attached but never saw a sample
        silent = time.monotonic() - ref
        # A slow-ticking but healthy target must not look stalled: silence is
        # only suspicious once it clearly exceeds the publisher's own period.
        timeout = max(self.cfg.stall_timeout_s, 3.0 * self.period_s)
        if silent >= timeout and _pid_alive(self.target_pid):
            self._stalled = True
            self._record_event(
                {
                    "kind": STALLED,
                    "path": [],
                    "share": 1.0,
                    "silent_s": round(silent, 3),
                    "pid": self.target_pid,
                    "wall_time": time.time(),
                }
            )

    def enable_serving(self, port: Optional[int] = None, host: Optional[str] = None):
        """Start the HTTP query plane over this daemon's published state.

        Returns the started :class:`~repro.profilerd.server.ProfileServer`.
        Reads are decoupled from ingest: every publish window hands a status
        dict and an immutable tree copy to :class:`SharedProfileState`, and
        request handlers only ever touch those.
        """
        from .server import LiveSource, ProfileServer, SharedProfileState

        if self.server is not None:
            return self.server
        self.shared = SharedProfileState()
        tdir = self.cfg.resolved_timeline_dir() if self.sealer is not None else None
        source = LiveSource(self.shared, timeline_dir=tdir, label=f"pid={self.target_pid or '?'}")
        self.server = ProfileServer(
            source,
            host=host if host is not None else self.cfg.serve_host,
            port=port if port is not None else (self.cfg.serve_port or 0),
        ).start()
        self._record_event(
            {"kind": "SERVING", "path": [], "share": 0.0, "url": self.server.url,
             "wall_time": time.time()}
        )
        return self.server

    def publish(self) -> None:
        """One analysis window: detector verdicts + status/tree artifacts."""
        snap = None
        if self._samples_since_publish:
            snap = self.tree.copy()
            self.windows.append((time.time(), snap))
            self.detector.observe(snap)
            self._samples_since_publish = 0
        self._check_stall()
        status = self.status()
        if self.shared is not None:
            # `snap` is never mutated after this point; handlers may read it
            # concurrently.  Quiet windows keep the previous tree.
            self.shared.update(status, snap)
        _atomic_write(os.path.join(self.out_dir, "tree.json"), self.tree.to_json())
        _atomic_write(os.path.join(self.out_dir, "status.json"), json.dumps(status))

    def status(self) -> dict:
        return {
            "pid": self.target_pid,
            "alive": _pid_alive(self.target_pid),
            "stalled": self._stalled,
            "done": self.bye_seen,
            "period_s": self.period_s,
            "wire_version": self.wire_version,
            "n_stacks": self.n_stacks,
            "n_ticks": self.n_ticks_reported,
            "dropped_batches": self.dropped_batches,
            "resolver": {"hits": self.resolver.hits, "misses": self.resolver.misses},
            "ingest": self.ingestor.stats(),
            # Degraded-mode accounting for re-attaching mid-stream (a
            # previous reader consumed the STRDEF/STACKDEF definitions):
            # such samples ingest as "?" placeholder stacks, never silently.
            "unknown_stack_refs": self.decoder.unknown_stack_refs,
            "degraded_stackdefs": self.decoder.degraded_stackdefs,
            "hot_paths": [
                {"path": list(p), "share": round(s, 4)}
                for p, s in self.tree.hot_paths(k=self.cfg.hot_k)
            ],
            "depth_timeline": [[round(t, 4), d] for t, d in self.timeline],
            "events": self.events[-20:],
            "windows": len(self.windows),
            "timeline": (
                {
                    "dir": self.cfg.resolved_timeline_dir(),
                    "epochs": self.sealer.epoch,
                    "call_sites": self.sealer.node_count,
                    "epoch_s": self.cfg.epoch_s,
                }
                if self.sealer is not None
                else None
            ),
            "updated": time.time(),
        }

    def write_report(self, name: str = "report") -> str:
        from repro.core.report import render_html

        path = os.path.join(self.out_dir, f"{name}.html")
        _atomic_write(
            path, render_html(self.tree, title=f"profilerd pid={self.target_pid}")
        )
        return path

    # -- main loop -----------------------------------------------------------

    def run(self, on_publish=None) -> CallTree:
        """Attach, stream until BYE / target death / ``max_seconds``, then
        final-publish and write the HTML report.  Returns the merged tree."""
        if self.reader is None:
            self.attach()
        if self.cfg.serve_port is not None and self.server is None:
            try:
                self.enable_serving()
            except OSError as e:
                # A busy/privileged port must not cost the profiling run.
                self._record_event(
                    {"kind": "SERVE_FAILED", "path": [], "share": 0.0,
                     "error": str(e), "wall_time": time.time()}
                )
        next_publish = time.monotonic() + self.cfg.publish_interval_s
        next_epoch = time.monotonic() + self.cfg.epoch_s if self.sealer is not None else None
        while True:
            self.drain()
            now = time.monotonic()
            if now >= next_publish:
                self.publish()
                if on_publish is not None:
                    on_publish(self)
                next_publish = now + self.cfg.publish_interval_s
            if next_epoch is not None and now >= next_epoch:
                self.seal_epoch()
                next_epoch = now + self.cfg.epoch_s
            if self.bye_seen:  # drain() above already emptied the spool
                break
            if self.cfg.max_seconds is not None and now - self._t_start >= self.cfg.max_seconds:
                break
            if not _pid_alive(self.target_pid):
                self.drain()  # the target died: salvage what it left behind
                break
            time.sleep(self.cfg.drain_interval_s)
        self.drain()
        self.seal_epoch()  # final epoch: short runs still leave a timeline
        self.publish()
        if on_publish is not None:
            on_publish(self)
        self.write_report()
        if self.server is not None:
            self.server.stop()
            self.server = None
        if self.timeline_writer is not None:
            self.timeline_writer.close()
        if self.reader is not None:
            self.reader.close()
        return self.tree
