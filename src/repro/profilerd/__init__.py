"""repro.profilerd — out-of-process profiling daemon (paper §III "profiler").

The paper's headline design point: *all* profiling runs in a separate process
alongside the simulator, so the target pays only for raw frame capture and is
never instrumented.  This package is that plane for JAX jobs:

* :mod:`repro.profilerd.wire`     — self-delimiting binary codec for raw,
  *unresolved* frame records (transport-agnostic: ring buffer or socket).
  Wire v2 interns whole stacks: one ``STACKDEF`` per unique stack
  (prefix-delta encoded), then fixed-size ``SAMPLE2`` references, so
  steady-state bytes/sample are independent of stack depth;
* :mod:`repro.profilerd.spool`    — single-writer/single-reader byte ring over
  an mmap'd file, the default transport (the agent never blocks: a full spool
  drops whole batches and counts them; the reader drains in bounded chunks);
* :mod:`repro.profilerd.agent`    — the only code that runs inside the target:
  snapshot ``sys._current_frames()`` each tick and append raw records;
* :mod:`repro.profilerd.resolver` — interned-symbol cache turning raw frames
  into ``origin::name`` symbols, identical to the in-process sampler's,
  plus a per-``stack_id`` whole-stack memo for wire v2;
* :mod:`repro.profilerd.ingest`   — cached-path call-tree ingestion: each
  ``(thread, stack_id)`` resolves once, repeats are an O(depth) float-add
  loop over the cached :class:`~repro.core.calltree.CallNode` chain, and
  whole ``SampleBatch`` columns collapse to one add per group;
* :mod:`repro.profilerd.pipeline` — :class:`IngestPipeline`, the one object
  composing reader + decoder + ingestor + sealer + stats (vectorized via
  numpy when available, per-sample otherwise) shared by the daemon,
  benchmarks and tests;
* :mod:`repro.profilerd.daemon`   — drains the spool, merges into a
  :class:`~repro.core.calltree.CallTree`, runs dominance/stall detection
  out-of-process, publishes live status and HTML/JSON reports;
* :mod:`repro.profilerd.server`   — live HTTP query plane (``/status``,
  ``/tree``, ``/timeline``, ``/diff``) over a running daemon's published
  snapshots or any offline profile artifact, plus the terminal ``top`` view;
* :mod:`repro.profilerd.profiles` — one loader for every profile shape
  (daemon out dir, timeline ring, ``tree.json``, ``.snap``);
* ``python -m repro.profilerd``   — attach to a running job by spool path,
  ``serve``/``top``/``export`` the resulting profiles.

``benchmarks/ingest_throughput.py`` measures the v1 -> v2 win (samples/sec
and bytes/sample across depths and repeat ratios).
"""

from importlib import import_module

# Lazy exports (PEP 562, same pattern as repro.core): the daemon imports this
# package on every attach and must stay importable in milliseconds, while the
# serving plane (http.server machinery) is only paid for on first use.
_EXPORTS = {
    "Agent": ".agent",
    "DaemonBackend": ".agent",
    "DaemonConfig": ".daemon",
    "ProfilerDaemon": ".daemon",
    "TreeIngestor": ".ingest",
    "IngestPipeline": ".pipeline",
    "ProfileLoadError": ".profiles",
    "load_profile": ".profiles",
    "SymbolResolver": ".resolver",
    "LiveSource": ".server",
    "OfflineSource": ".server",
    "ProfileServer": ".server",
    "SharedProfileState": ".server",
    "SpoolSet": ".sources",
    "SpoolSource": ".sources",
    "SpoolReader": ".spool",
    "SpoolWriter": ".spool",
    "WIRE_VERSION": ".wire",
    "Decoder": ".wire",
    "Encoder": ".wire",
    "RawFrame": ".wire",
    "RawSample": ".wire",
}

__all__ = list(_EXPORTS)


def __getattr__(name: str):
    try:
        module = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}") from None
    value = getattr(import_module(module, __name__), name)
    globals()[name] = value  # cache: next access skips __getattr__
    return value


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
