"""repro.profilerd — out-of-process profiling daemon (paper §III "profiler").

The paper's headline design point: *all* profiling runs in a separate process
alongside the simulator, so the target pays only for raw frame capture and is
never instrumented.  This package is that plane for JAX jobs:

* :mod:`repro.profilerd.wire`     — self-delimiting binary codec for raw,
  *unresolved* frame records (transport-agnostic: ring buffer or socket).
  Wire v2 interns whole stacks: one ``STACKDEF`` per unique stack
  (prefix-delta encoded), then fixed-size ``SAMPLE2`` references, so
  steady-state bytes/sample are independent of stack depth;
* :mod:`repro.profilerd.spool`    — single-writer/single-reader byte ring over
  an mmap'd file, the default transport (the agent never blocks: a full spool
  drops whole batches and counts them; the reader drains in bounded chunks);
* :mod:`repro.profilerd.agent`    — the only code that runs inside the target:
  snapshot ``sys._current_frames()`` each tick and append raw records;
* :mod:`repro.profilerd.resolver` — interned-symbol cache turning raw frames
  into ``origin::name`` symbols, identical to the in-process sampler's,
  plus a per-``stack_id`` whole-stack memo for wire v2;
* :mod:`repro.profilerd.ingest`   — cached-path call-tree ingestion: each
  ``(thread, stack_id)`` resolves once, repeats are an O(depth) float-add
  loop over the cached :class:`~repro.core.calltree.CallNode` chain;
* :mod:`repro.profilerd.daemon`   — drains the spool, merges into a
  :class:`~repro.core.calltree.CallTree`, runs dominance/stall detection
  out-of-process, publishes live status and HTML/JSON reports;
* ``python -m repro.profilerd``   — attach to a running job by spool path.

``benchmarks/ingest_throughput.py`` measures the v1 -> v2 win (samples/sec
and bytes/sample across depths and repeat ratios).
"""

from .agent import Agent, DaemonBackend
from .daemon import DaemonConfig, ProfilerDaemon
from .ingest import TreeIngestor
from .resolver import SymbolResolver
from .spool import SpoolReader, SpoolWriter
from .wire import WIRE_VERSION, Decoder, Encoder, RawFrame, RawSample

__all__ = [
    "Agent",
    "DaemonBackend",
    "DaemonConfig",
    "ProfilerDaemon",
    "SymbolResolver",
    "SpoolReader",
    "SpoolWriter",
    "TreeIngestor",
    "Decoder",
    "Encoder",
    "RawFrame",
    "RawSample",
    "WIRE_VERSION",
]
