"""Live profile query plane: a stdlib HTTP API over any profile source.

The paper's point is observing the system *while it runs*; this module is the
read side of that.  A :class:`ProfileServer` (plain ``http.server``, zero
dependencies) exposes:

* ``GET /status``   — the daemon's live status JSON (offline: a synthesized
  summary of the loaded profile);
* ``GET /tree``     — the merged call tree through the universal exporter:
  ``?fmt=csv|folded|speedscope|html|json``, ``?view=<library view>`` or
  ad-hoc ``?root=&level=&metric=&min_share=``;
* ``GET /timeline`` — epoch table + phase segmentation over the timeline
  ring (``?fmt=text|json``);
* ``GET /diff``     — this profile vs ``?baseline=<profile path>`` (or the
  server's ``--baseline``): text share deltas, or ``fmt=html`` for the
  share-delta flamegraph.

Two sources feed it:

* :class:`LiveSource` — a :class:`SharedProfileState` handle the daemon
  updates **once per publish interval** under a lock with an already-copied
  tree.  Request handling never touches daemon internals, so serving adds
  zero work to the ingest path (the lock is held for an attribute swap).
* :class:`OfflineSource` — any profile artifact on disk (daemon out dir,
  timeline ring, ``tree.json``, ``.snap``), cached and re-read only when its
  mtime moves — so pointing it at a dir a daemon is *currently* writing
  also works.

Responses are bounded (``max_bytes``, HTTP 413 beyond it) so a runaway tree
cannot OOM a dashboard poller.  ``render_top`` turns ``/status`` JSON into
the refreshing terminal view behind ``profilerd top``.
"""

from __future__ import annotations

import json
import os
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlsplit

from repro.core.calltree import CallTree
from repro.core.export import (
    CONTENT_TYPES,
    EXPORT_FORMATS,
    diff_flamegraph_html,
    export_tree,
    prepare_view,
)
from repro.core.planes import (
    OCCUPANCY,
    PLANES,
    PlaneError,
    default_metric,
    dominant_term,
    select_plane,
)
from repro.core.report import ViewConfig, render_diff

from .profiles import (
    ProfileLoadError,
    device_tree_path,
    list_profile_targets,
    load_device_plane,
    load_profile,
    load_region,
    load_static_plane,
    profile_mtime,
    static_tree_path,
    target_profile_dir,
    timeline_dir_of,
)
from .sources import source_name_for

DEFAULT_MAX_BYTES = 16 << 20  # bound any single response body
MAX_TIMELINE_EPOCHS = 512  # newest epochs served; older ones need the ring

ENDPOINTS = ("/status", "/targets", "/tree", "/timeline", "/diff")


class SharedProfileState:
    """Daemon -> server hand-off: the latest published status + tree copies.

    The daemon calls :meth:`update` once per publish window with tree copies
    it will never mutate again (the merged fleet tree plus one per target);
    handlers read the same objects concurrently without copying.  The lock
    only ever guards attribute swaps.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._status: dict = {}
        self._tree: CallTree | None = None
        self._targets: dict[str, CallTree] = {}
        self._device_tree: CallTree | None = None
        self._static_tree: CallTree | None = None

    def update(
        self,
        status: dict,
        tree: CallTree | None = None,
        targets: dict | None = None,
    ) -> None:
        with self._lock:
            self._status = status
            if tree is not None:
                self._tree = tree
            if targets is not None:
                self._targets = dict(targets)

    def set_device_tree(self, tree: CallTree | None) -> None:
        """The daemon's device-plane artifact (one per fleet: co-located
        targets run the same compiled program).  Set once at startup; the
        tree is never mutated afterwards, so readers share it lock-free
        after the swap."""
        with self._lock:
            self._device_tree = tree

    def device_tree(self) -> CallTree | None:
        with self._lock:
            return self._device_tree

    def set_static_tree(self, tree: CallTree | None) -> None:
        """The static call-graph artifact (one per fleet: every target runs
        the same source tree).  Same swap discipline as the device plane."""
        with self._lock:
            self._static_tree = tree

    def static_tree(self) -> CallTree | None:
        with self._lock:
            return self._static_tree

    def snapshot(self) -> tuple[dict, CallTree]:
        with self._lock:
            return self._status, (self._tree if self._tree is not None else CallTree())

    def target_tree(self, name: str) -> CallTree | None:
        with self._lock:
            return self._targets.get(name)

    def target_names(self) -> list[str]:
        with self._lock:
            return sorted(self._targets)


class LiveSource:
    """Serve a running daemon through its :class:`SharedProfileState`."""

    def __init__(
        self,
        shared: SharedProfileState,
        timeline_dir: str | None = None,
        label: str = "live",
        target_timeline_dir_fn=None,
    ):
        self.shared = shared
        self._timeline_dir = timeline_dir
        self._target_timeline_dir_fn = target_timeline_dir_fn
        self.label = label

    def status(self) -> dict:
        status, _ = self.shared.snapshot()
        return status or {"live": True, "note": "daemon has not published yet"}

    def tree(self, target: str | None = None) -> CallTree:
        if target is None:
            return self.shared.snapshot()[1]
        t = self.shared.target_tree(target)
        if t is not None:
            return t
        status, _ = self.shared.snapshot()
        if target in (status.get("targets") or {}):
            # Attached but no published sample window yet: an empty tree is
            # the honest answer — /targets lists this name, so a 404 here
            # would contradict the same server one request earlier.
            return CallTree()
        known = ", ".join(self.shared.target_names()) or "<none yet>"
        raise ProfileLoadError(f"unknown target {target!r} (targets: {known})")

    def targets(self) -> list[dict]:
        status, _ = self.shared.snapshot()
        rows = status.get("targets") or {}
        out = [{"name": name, **row} for name, row in sorted(rows.items())]
        # Spools the daemon could not attach (backing off / gave up) are part
        # of the fleet's honest state — a permanently-garbage path must be
        # visible here, not silently absent.
        for row in status.get("attach_failures") or []:
            out.append(
                {
                    "name": source_name_for(row["path"]),
                    "path": row["path"],
                    "attach_failed": True,
                    "gave_up": bool(row.get("gave_up")),
                    "attempts": row.get("attempts", 0),
                    "retry_in_s": row.get("retry_in_s"),
                    "error": row.get("error", ""),
                }
            )
        return out

    def targets_hierarchy(self) -> dict:
        """Region -> node -> target.  A node daemon is one node deep: its
        own targets under the node name it pushes (or would push) as."""
        status, _ = self.shared.snapshot()
        rows = self.targets()
        node = status.get("node") or "local"
        return {
            "region": status.get("region"),
            "targets": rows,
            "nodes": [{"name": node, "targets": rows}],
        }

    def device_tree(self, target: str | None = None) -> CallTree | None:
        # One device artifact per fleet: every co-located target runs the
        # same compiled program, so the per-target plane is the fleet plane.
        return self.shared.device_tree()

    def static_tree(self, target: str | None = None) -> CallTree | None:
        # One static artifact per fleet: every target runs the same source.
        return self.shared.static_tree()

    def timeline_dir(self, target: str | None = None) -> str | None:
        if target is None:
            return self._timeline_dir
        if self._target_timeline_dir_fn is None:
            return None
        return self._target_timeline_dir_fn(target)


class OfflineSource:
    """Serve a profile artifact from disk (mtime-cached).

    A multi-target daemon out dir also exposes its per-target profiles
    (``targets/<name>/``) through ``tree(target=...)``/``targets()``, each
    behind its own mtime cache.
    """

    def __init__(self, profile_path: str, label: str | None = None):
        self.path = profile_path
        self.label = label or profile_path
        self._cached: CallTree | None = None
        self._cached_mtime = -1.0
        self._device_cache: dict[str, tuple[float, CallTree]] = {}
        self._static_cache: dict[str, tuple[float, CallTree]] = {}
        self._target_sources: dict[str, "OfflineSource"] = {}
        self._lock = threading.Lock()

    def _target_source(self, target: str) -> "OfflineSource":
        with self._lock:
            sub = self._target_sources.get(target)
        if sub is None:
            p = target_profile_dir(self.path, target)
            if p is None:
                known = ", ".join(list_profile_targets(self.path)) or "<none>"
                raise ProfileLoadError(
                    f"{self.path}: no target {target!r} (targets: {known})"
                )
            sub = OfflineSource(p, label=f"{self.label}[{target}]")
            with self._lock:
                sub = self._target_sources.setdefault(target, sub)
        return sub

    def tree(self, target: str | None = None) -> CallTree:
        if target is not None:
            return self._target_source(target).tree()
        with self._lock:
            mtime = profile_mtime(self.path)
            if self._cached is None or mtime > self._cached_mtime:
                self._cached = load_profile(self.path)
                self._cached_mtime = mtime
            return self._cached

    def device_tree(self, target: str | None = None) -> CallTree | None:
        """The ``device_tree.json`` beside the profile, mtime-cached per
        resolved path (a per-target dir falls back to the fleet artifact)."""
        p = device_tree_path(self.path, target)
        if p is None:
            return None
        try:
            mtime = os.path.getmtime(p)
        except OSError:
            return None
        with self._lock:
            cached = self._device_cache.get(p)
            if cached is not None and cached[0] >= mtime:
                return cached[1]
        tree = load_device_plane(self.path, target)
        if tree is not None:
            with self._lock:
                self._device_cache[p] = (mtime, tree)
        return tree

    def static_tree(self, target: str | None = None) -> CallTree | None:
        """The ``static_tree.json`` beside the profile, mtime-cached per
        resolved path (a per-target dir falls back to the fleet artifact)."""
        p = static_tree_path(self.path, target)
        if p is None:
            return None
        try:
            mtime = os.path.getmtime(p)
        except OSError:
            return None
        with self._lock:
            cached = self._static_cache.get(p)
            if cached is not None and cached[0] >= mtime:
                return cached[1]
        tree = load_static_plane(self.path, target)
        if tree is not None:
            with self._lock:
                self._static_cache[p] = (mtime, tree)
        return tree

    def targets(self) -> list[dict]:
        rows = []
        for name in list_profile_targets(self.path):
            try:
                t = self.tree(name)
            except ProfileLoadError:
                continue
            rows.append(
                {
                    "name": name,
                    "n_stacks": t.total(),
                    "call_sites": t.node_count(),
                    "depth": t.depth(),
                }
            )
        return rows

    def targets_hierarchy(self) -> dict:
        """An aggregator out dir serves its ``region.json`` map; any other
        profile is a single implicit node holding its own targets."""
        rows = self.targets()
        region = load_region(self.path)
        if region is not None:
            nodes = []
            by_name = {r["name"]: r for r in rows}
            for node in region.get("nodes") or []:
                row = dict(node)
                row["targets"] = [
                    t if isinstance(t, dict) else {"name": t}
                    for t in node.get("targets") or []
                ]
                stats = by_name.get(node.get("name"))
                if stats is not None:
                    row.setdefault("n_stacks", stats["n_stacks"])
                nodes.append(row)
            return {"region": region.get("region"), "targets": rows, "nodes": nodes}
        name = os.path.basename(self.path.rstrip(os.sep)) or self.path
        return {"region": None, "targets": rows, "nodes": [{"name": name, "targets": rows}]}

    def status(self) -> dict:
        tree = self.tree()
        targets = list_profile_targets(self.path)
        return {
            "offline": True,
            "profile": self.path,
            "n_stacks": tree.total(),
            "call_sites": tree.node_count(),
            "depth": tree.depth(),
            "timeline_dir": self.timeline_dir(),
            "n_targets": len(targets),
            "target_names": targets,
            "hot_paths": [
                {"path": list(p), "share": round(s, 4)} for p, s in tree.hot_paths(k=10)
            ],
            "updated": profile_mtime(self.path),
        }

    def timeline_dir(self, target: str | None = None) -> str | None:
        if target is not None:
            return self._target_source(target).timeline_dir()
        return timeline_dir_of(self.path)


class _HTTPError(Exception):
    def __init__(self, code: int, message: str):
        super().__init__(message)
        self.code = code


def _one(q: dict, key: str, default: str | None = None) -> str | None:
    vals = q.get(key)
    return vals[0] if vals else default


class _Handler(BaseHTTPRequestHandler):
    server_version = "repro-profilerd"
    protocol_version = "HTTP/1.1"

    # self.server is the _Server below (source/baseline/max_bytes/verbose).

    def log_message(self, fmt, *args):  # noqa: A003 - BaseHTTPRequestHandler API
        if self.server.verbose:
            super().log_message(fmt, *args)

    def do_GET(self):  # noqa: N802 - BaseHTTPRequestHandler API
        url = urlsplit(self.path)
        q = parse_qs(url.query)
        try:
            if url.path in ("/", "/help"):
                body, ctype = self._help(), "text/plain; charset=utf-8"
            elif url.path == "/status":
                body, ctype = json.dumps(self.server.source.status(), indent=1), "application/json"
            elif url.path == "/targets":
                body, ctype = self._targets(), "application/json"
            elif url.path == "/tree":
                body, ctype = self._tree(q)
            elif url.path == "/timeline":
                body, ctype = self._timeline(q)
            elif url.path == "/diff":
                body, ctype = self._diff(q)
            else:
                raise _HTTPError(404, f"unknown endpoint {url.path}; try {', '.join(ENDPOINTS)}")
        except _HTTPError as e:
            return self._send(e.code, str(e) + "\n", "text/plain; charset=utf-8")
        except ProfileLoadError as e:
            return self._send(404, f"profile unreadable: {e}\n", "text/plain; charset=utf-8")
        except Exception as e:  # a broken query must not kill the server thread
            return self._send(500, f"internal error: {e!r}\n", "text/plain; charset=utf-8")
        self._send(200, body, ctype)

    def do_POST(self):  # noqa: N802 - BaseHTTPRequestHandler API
        """Push-plane ingest (``POST /push``), live only when the server was
        started with a ``push_sink`` (the regional aggregator).  Anything
        malformed is a clean 4xx; the sink decides applied/duplicate."""
        url = urlsplit(self.path)
        sink = getattr(self.server, "push_sink", None)
        if sink is None:
            return self._send(405, "this server does not accept pushes\n",
                              "text/plain; charset=utf-8")
        if url.path != "/push":
            return self._send(404, f"unknown POST endpoint {url.path}; try /push\n",
                              "text/plain; charset=utf-8")
        try:
            length = int(self.headers.get("Content-Length") or 0)
        except ValueError:
            length = -1
        if length <= 0:
            return self._send(411, "need a Content-Length'd push body\n",
                              "text/plain; charset=utf-8")
        cap = getattr(self.server, "push_max_bytes", DEFAULT_MAX_BYTES)
        if length > cap:
            # Drain (bounded) so the client sees the 413 instead of a reset
            # connection, then refuse.
            remaining = min(length, 4 * cap)
            while remaining > 0:
                chunk = self.rfile.read(min(65536, remaining))
                if not chunk:
                    break
                remaining -= len(chunk)
            self.close_connection = True
            return self._send(413, f"push body of {length} bytes exceeds {cap}\n",
                              "text/plain; charset=utf-8")
        body = self.rfile.read(length)
        if len(body) != length:
            return self._send(400, "truncated push body\n", "text/plain; charset=utf-8")
        try:
            code, payload = sink(self.headers, body)
        except Exception as e:  # the ingest plane must not kill the thread
            return self._send(500, f"internal error: {e!r}\n", "text/plain; charset=utf-8")
        self._send(code, json.dumps(payload) + "\n", "application/json")

    def _send(self, code: int, body: str, ctype: str) -> None:
        payload = body.encode("utf-8", errors="replace")
        if len(payload) > self.server.max_bytes:
            code = 413
            payload = (
                f"response of {len(payload)} bytes exceeds the server cap "
                f"({self.server.max_bytes}); narrow the query (view=, level=, min_share=)\n"
            ).encode()
            ctype = "text/plain; charset=utf-8"
        try:
            self.send_response(code)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(payload)))
            self.end_headers()
            self.wfile.write(payload)
        except (BrokenPipeError, ConnectionResetError):
            pass  # client went away mid-response; routine for curls/pollers

    # -- endpoints -----------------------------------------------------------

    def _help(self) -> str:
        return (
            "repro profilerd serve — endpoints:\n"
            "  /status                         live daemon status (or offline summary)\n"
            "  /targets                        per-target status rows (multi-target daemon)\n"
            "  /tree?fmt=csv|folded|speedscope|html|json&view=NAME&target=NAME\n"
            "       &plane=host|device|merged|static&metric=samples&root=SUBSTR&level=N&min_share=F\n"
            "  /timeline?fmt=text|json&metric=samples&target=NAME\n"
            "  /diff?baseline=PATH&fmt=text|html&plane=host|device|merged|static&metric=samples\n"
        )

    def _targets(self) -> str:
        source = self.server.source
        if hasattr(source, "targets_hierarchy"):
            # Hierarchical shape: flat `targets` rows stay for existing
            # consumers, `region`/`nodes` carry the fleet structure.
            return json.dumps(source.targets_hierarchy(), indent=1)
        rows = source.targets() if hasattr(source, "targets") else []
        return json.dumps({"targets": rows, "region": None, "nodes": []}, indent=1)

    def _baseline_source(self, path: str) -> "OfflineSource":
        """Baseline profiles get the same mtime cache as the served profile —
        a 2s /diff poller must not re-decode a timeline ring every tick."""
        cache = self.server._baseline_sources
        src = cache.get(path)
        if src is None:
            if len(cache) >= 16:  # a loopback operator can name many paths
                cache.clear()
            src = cache[path] = OfflineSource(path)
        return src

    def _loopback(self) -> bool:
        host = self.server.server_address[0]
        return host.startswith("127.") or host in ("::1", "localhost")

    def _view_from_query(self, q: dict) -> ViewConfig | None:
        name = _one(q, "view")
        root = _one(q, "root")
        level = _one(q, "level")
        min_share = _one(q, "min_share")
        base = None
        if name is not None:
            from repro.core.views_library import VIEWS

            if name not in VIEWS:
                raise _HTTPError(404, f"unknown view {name!r}; see views_library.list_views()")
            base = VIEWS[name]
        elif root is None and level is None and min_share is None:
            return None
        try:
            from dataclasses import replace

            # Ad-hoc params refine the named view (they are the advertised
            # way out of a 413), or stand alone when no view= is given.
            overrides = {}
            if root is not None:
                overrides["root"] = root
            if level is not None:
                overrides["level"] = int(level)
            if min_share is not None:
                overrides["min_share"] = float(min_share)
            if base is None:
                return ViewConfig(name=root or "adhoc", **overrides)
            return replace(base, **overrides) if overrides else base
        except ValueError as e:
            raise _HTTPError(400, f"bad view parameters: {e}") from None

    def _plane_of(self, q: dict) -> str:
        plane = _one(q, "plane", "host") or "host"
        if plane not in PLANES:
            raise _HTTPError(400, f"unknown plane {plane!r}; choose from {', '.join(PLANES)}")
        return plane

    def _plane_tree(self, tree: CallTree, plane: str, target: str | None) -> CallTree:
        """Resolve the requested plane over a host tree from our source.

        A missing device artifact is a 404 with the remedy hint (the plane
        exists, this profile just lacks the artifact); a source that predates
        device planes entirely behaves the same as one without the artifact.
        """
        if plane == "host":
            return tree
        source = self.server.source
        device = static = None
        if plane == "static":
            getter = getattr(source, "static_tree", None)
            static = getter(target) if getter is not None else None
        else:
            getter = getattr(source, "device_tree", None)
            device = getter(target) if getter is not None else None
        try:
            return select_plane(
                tree, device, plane, profile=getattr(source, "path", None), static=static
            )
        except PlaneError as e:
            raise _HTTPError(404, str(e)) from None

    def _tree(self, q: dict) -> tuple[str, str]:
        fmt = _one(q, "fmt", "csv")
        if fmt not in EXPORT_FORMATS:
            raise _HTTPError(400, f"unknown fmt {fmt!r}; choose from {', '.join(EXPORT_FORMATS)}")
        plane = self._plane_of(q)
        view = self._view_from_query(q)
        target = _one(q, "target")
        tree = self.server.source.tree(target) if target else self.server.source.tree()
        tree = self._plane_tree(tree, plane, target)
        metric = default_metric(plane, _one(q, "metric"))
        roofline = plane == "merged" and fmt == "html"
        label = self.server.source.label
        if target:
            label = f"{label} [{target}]"
        if plane != "host":
            label = f"{label} [{plane} plane]"
        if fmt == "csv":
            # The CSV body carries its own marker rows; serve it as-is.
            return export_tree(tree, "csv", view=view, metric=metric, title=label), CONTENT_TYPES["csv"]
        # The stack-shaped formats would ship a silent empty payload — fail
        # loudly instead (the no-vacuous-empty-artifact contract, HTTP
        # edition).  prepare_view applies zoom/filters/level/min_share once
        # and owns every emptiness verdict, including fmt stacklessness.
        applied, metric, marker = prepare_view(tree, view, metric, fmt=fmt)
        if marker is not None:
            raise _HTTPError(404, marker.lstrip("# "))
        if view is not None:
            label = f"{label} [{view.name}]"
        body = export_tree(applied, fmt, metric=metric, title=label, roofline=roofline)
        return body, CONTENT_TYPES[fmt]

    def _read_timeline(self, tdir: str) -> list:
        """Decode the ring's newest epochs, cached on the segment mtimes.

        Decoding up to ``max_segments`` of keyframes+deltas per request would
        make a 2-second dashboard poller pay the full ring every tick; the
        segments only change when the daemon seals an epoch, so key the cache
        on their (path, mtime) set.  Decoded trees are read-only (their fast
        lane is empty), so concurrent handlers may share the cached windows.
        """
        from repro.core.snapshot import SnapshotError, TimelineReader, list_segments

        def seg_key():
            out = []
            for p in list_segments(tdir):
                try:
                    out.append((p, os.path.getmtime(p)))
                except OSError:
                    pass
            return tuple(out)

        key = seg_key()
        cached = self.server._timeline_cache.get(tdir)
        if cached is not None and cached[0] == key:
            return cached[1]
        epochs = []
        try:
            for meta, window, _cum in TimelineReader(tdir).epochs():
                epochs.append((meta, window, None))
                if len(epochs) > MAX_TIMELINE_EPOCHS:
                    epochs.pop(0)
        except SnapshotError as e:
            raise _HTTPError(500, f"timeline unreadable: {e}") from None
        if len(self.server._timeline_cache) >= 32:  # one entry per ring dir
            self.server._timeline_cache.clear()
        self.server._timeline_cache[tdir] = (key, epochs)
        return epochs

    def _timeline(self, q: dict) -> tuple[str, str]:
        target = _one(q, "target")
        tdir = self.server.source.timeline_dir(target) if target else self.server.source.timeline_dir()
        if tdir is None:
            raise _HTTPError(
                404,
                "this profile has no timeline ring (daemon --epoch 0?)"
                + (f" for target {target!r}" if target else ""),
            )
        from repro.core.views_library import phase_table, timeline_table

        metric = _one(q, "metric", "samples")
        fmt = _one(q, "fmt", "text")
        if fmt not in ("text", "json"):
            raise _HTTPError(400, f"unknown timeline fmt {fmt!r}; choose text or json")
        epochs = self._read_timeline(tdir)
        if not epochs:
            raise _HTTPError(404, f"{tdir}: timeline ring holds no decodable epochs")
        if fmt == "json":
            body = json.dumps(
                [
                    {
                        "epoch": meta.epoch,
                        "wall_time": meta.wall_time,
                        "progress": meta.progress,
                        "window_total": window.total(metric),
                        "top": [
                            {"path": list(p), "share": round(s, 4)}
                            for p, s in window.hot_paths(metric, k=3)
                        ],
                    }
                    for meta, window, _ in epochs
                ]
            )
            return body, "application/json"
        body = phase_table(epochs, metric=metric) + "\n\n" + timeline_table(epochs, metric=metric)
        return body, "text/plain; charset=utf-8"

    def _diff(self, q: dict) -> tuple[str, str]:
        baseline_path = _one(q, "baseline", self.server.baseline)
        if not baseline_path:
            raise _HTTPError(400, "need ?baseline=<profile path> (or start the server with --baseline)")
        # A query-supplied baseline is a server-side filesystem read.  On the
        # loopback default that is the operator diffing their own files; on
        # any other bind it would let remote clients probe/read arbitrary
        # paths, so only the operator-configured --baseline is honored there.
        if baseline_path != self.server.baseline and not self._loopback():
            raise _HTTPError(
                403,
                "?baseline= paths are only honored on a loopback bind; "
                "start the server with --baseline to diff on this host",
            )
        plane = self._plane_of(q)
        baseline_src = self._baseline_source(baseline_path)
        baseline = baseline_src.tree()
        current = self.server.source.tree()
        if plane != "host":
            # Each side resolves the plane through its *own* device artifact;
            # a device-plane diff with either side missing must fail loudly,
            # not silently degrade to a host-only comparison.
            try:
                baseline = select_plane(
                    baseline,
                    baseline_src.device_tree() if plane != "static" else None,
                    plane,
                    profile=baseline_path,
                    static=baseline_src.static_tree() if plane == "static" else None,
                )
            except PlaneError as e:
                raise _HTTPError(404, f"baseline: {e}") from None
            current = self._plane_tree(current, plane, None)
        metric = default_metric(plane, _one(q, "metric")) or "samples"
        fmt = _one(q, "fmt", "text")
        if fmt == "html":
            title = f"{os.path.basename(baseline_path.rstrip(os.sep)) or baseline_path} vs {self.server.source.label}"
            return diff_flamegraph_html(baseline, current, metric, title=title), CONTENT_TYPES["html"]
        if fmt != "text":
            raise _HTTPError(400, f"unknown diff fmt {fmt!r}; choose text or html")
        body = render_diff(
            baseline,
            current,
            metric=metric,
            label_a=os.path.basename(baseline_path.rstrip(os.sep)) or baseline_path,
            label_b=self.server.source.label,
        )
        return body, "text/plain; charset=utf-8"


class _Server(ThreadingHTTPServer):
    daemon_threads = True
    allow_reuse_address = True


class ProfileServer:
    """Bind, serve in a background thread, stop on demand.

    ``port=0`` binds an ephemeral port (tests); ``.port``/``.url`` report the
    actual binding.  The server thread is a daemon thread: an exiting process
    never hangs on it.
    """

    def __init__(
        self,
        source,
        host: str = "127.0.0.1",
        port: int = 0,
        baseline: str | None = None,
        max_bytes: int = DEFAULT_MAX_BYTES,
        verbose: bool = False,
        push_sink=None,
        push_max_bytes: int = 8 << 20,
    ):
        self.source = source
        self._httpd = _Server((host, port), _Handler)
        self._httpd.source = source
        self._httpd.baseline = baseline
        self._httpd.max_bytes = max_bytes
        self._httpd.verbose = verbose
        # push_sink(headers, body) -> (status, json_dict): the aggregator's
        # ingest hook.  None (the default) keeps this a read-only plane.
        self._httpd.push_sink = push_sink
        self._httpd.push_max_bytes = push_max_bytes
        self._httpd._timeline_cache = {}
        self._httpd._baseline_sources = {}
        self._thread: threading.Thread | None = None

    @property
    def host(self) -> str:
        return self._httpd.server_address[0]

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "ProfileServer":
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="profilerd-serve", daemon=True
        )
        self._thread.start()
        return self

    def serve_forever(self) -> None:
        """Blocking variant for the ``profilerd serve`` CLI."""
        self._httpd.serve_forever()

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None


# -- terminal `top` ----------------------------------------------------------


def fetch_status(base_url: str, timeout: float = 5.0) -> dict:
    import urllib.request  # ~200ms of ssl/email machinery only `top` needs

    with urllib.request.urlopen(base_url.rstrip("/") + "/status", timeout=timeout) as resp:
        return json.loads(resp.read().decode())


def fetch_plane_tree(base_url: str, plane: str, timeout: float = 5.0) -> tuple[int, str]:
    """``(http_code, body)`` for ``/tree?fmt=json&plane=...`` — the 404 body
    is the server's remedy hint and is worth showing verbatim."""
    import urllib.error
    import urllib.request

    url = base_url.rstrip("/") + f"/tree?fmt=json&plane={plane}"
    try:
        with urllib.request.urlopen(url, timeout=timeout) as resp:
            return 200, resp.read().decode()
    except urllib.error.HTTPError as e:
        return e.code, e.read().decode("utf-8", errors="replace")


def render_plane_rows(tree: CallTree, plane: str, k: int = 10) -> str:
    """The `top --plane` table: hottest paths with their roofline columns.

    The device plane ranks by flops (it has no samples); merged ranks by
    samples like the host view, with each path's annotated occupancy and
    dominant roofline term alongside.
    """
    metric = default_metric(plane, None) or "samples"
    lines = [f"{'SHARE':>8} {'ROOF-OCC':>9} {'BOUND':<11} HOTTEST PATHS [{plane} plane, {metric}]"]
    for path, share in tree.hot_paths(metric, k=k):
        node = tree.root
        for name in path:
            node = node.children.get(name)
            if node is None:
                break
        occ = node.metrics.get(OCCUPANCY) if node is not None else None
        term = dominant_term(node.metrics) if node is not None else None
        occ_s = f"{occ:9.2%}" if occ is not None else f"{'--':>9}"
        lines.append(f"{share:8.2%} {occ_s} {term or '--':<11} {'/'.join(path)}")
    if len(lines) == 1:
        lines.append(f"      --        --  (no {metric} in this plane yet)")
    return "\n".join(lines)


def render_fleet_rollup(status: dict) -> str:
    """The aggregator's node table for ``profilerd top`` — one row per node
    in the region, plus the fleet totals line."""
    fleet = status.get("fleet") or {}
    lines = [
        f"region={status.get('region', '?')} nodes={status.get('n_nodes', 0)} "
        f"targets={status.get('n_targets', 0)} fleet_epochs={fleet.get('epochs', 0)} "
        f"mass={fleet.get('mass', 0):.6g} applied={fleet.get('epochs_applied', 0)} "
        f"dup={fleet.get('duplicates', 0)} bytes={fleet.get('bytes', 0)}",
        "",
        f"{'NODE':<18} {'STATE':<8} {'EPOCHS':>7} {'DUP':>4} {'MASS':>10} "
        f"{'AGE(s)':>7} {'INC':>4}  TARGETS",
    ]
    for name, row in sorted((status.get("nodes") or {}).items()):
        age = row.get("last_push_age_s")
        lines.append(
            f"{name:<18.18} {row.get('state', '?'):<8} "
            f"{row.get('epochs_applied', 0):>7} {row.get('duplicates', 0):>4} "
            f"{row.get('mass', 0):>10.6g} "
            f"{age if age is not None else '--':>7} "
            f"{row.get('incarnations', 0):>4}  {','.join(row.get('targets') or []) or '--'}"
        )
    if not status.get("nodes"):
        lines.append("  (no nodes have pushed yet)")
    return "\n".join(lines)


def render_top(status: dict, base_url: str = "", k: int = 10) -> str:
    """One refresh of the hottest paths + verdicts, `top(1)`-style."""
    if status.get("aggregator"):
        state = "STALLED" if status.get("stalled") else ("done" if status.get("done") else "live")
        head = (
            f"profilerd top — {base_url}  [aggregator region={status.get('region', '?')}] "
            f"[{state}]\n" + render_fleet_rollup(status)
        )
        lines = [head, "", f"{'SHARE':>8}  HOTTEST PATHS (fleet)"]
        for hp in status.get("hot_paths", [])[:k]:
            lines.append(f"{hp['share']:8.2%}  {'/'.join(hp['path'])}")
        if not status.get("hot_paths"):
            lines.append("      --  (no samples yet)")
        events = status.get("events", [])
        if events:
            lines += ["", "FLEET EVENTS (newest last)"]
            for ev in events[-5:]:
                lines.append(
                    f"  {ev.get('kind', '?'):<18} node={ev.get('target', '-')}"
                )
        return "\n".join(lines)
    if status.get("offline"):
        head = (
            f"profilerd top — {base_url}  [offline profile {status.get('profile', '?')}]\n"
            f"samples={status.get('n_stacks', 0):.6g} call_sites={status.get('call_sites', 0)} "
            f"depth={status.get('depth', 0)}"
        )
    else:
        state = "STALLED" if status.get("stalled") else ("done" if status.get("done") else "live")
        tl = status.get("timeline") or {}
        who = (
            f"targets={status.get('n_targets', 1)}"
            if status.get("n_targets", 1) > 1 or status.get("watch")
            else f"pid={status.get('pid', '?')}"
        )
        head = (
            f"profilerd top — {base_url}  {who} [{state}] "
            f"wire=v{status.get('wire_version', '?')}\n"
            f"stacks={status.get('n_stacks', 0)} dropped={status.get('dropped_batches', 0)} "
            f"epochs={tl.get('epochs', 0)} call_sites={tl.get('call_sites', 0)} "
            f"windows={status.get('windows', 0)}"
        )
        if status.get("ingest"):
            from .pipeline import format_ingest_stats

            head += "\n" + format_ingest_stats(status["ingest"])
    lines = [head]
    targets = status.get("targets") or {}
    if len(targets) > 1 or status.get("watch"):
        lines += ["", f"{'TARGET':<18} {'STATE':<8} {'STACKS':>8} {'DROP':>5} "
                      f"{'BACKLOG':>8} {'RESTARTS':>8}  PID"]
        for name, row in sorted(targets.items()):
            tstate = (
                "STALLED" if row.get("stalled")
                else "done" if row.get("done")
                else "live" if row.get("alive")
                else "dead"
            )
            lines.append(
                f"{name:<18.18} {tstate:<8} {row.get('n_stacks', 0):>8} "
                f"{row.get('dropped_batches', 0):>5} {row.get('backlog_bytes', 0):>8} "
                f"{row.get('restarts', 0):>8}  {row.get('pid', '?')}"
            )
    for row in status.get("attach_failures") or []:
        if row.get("gave_up"):
            state = f"GAVE UP after {row.get('attempts', '?')} attempts"
        else:
            state = f"attach retry in {row.get('retry_in_s', '?')}s (attempt {row.get('attempts', '?')})"
        lines.append(f"  !! {row.get('path', '?')}: {state} — {row.get('error', '')}")
    lines += ["", f"{'SHARE':>8}  HOTTEST PATHS"]
    for hp in status.get("hot_paths", [])[:k]:
        lines.append(f"{hp['share']:8.2%}  {'/'.join(hp['path'])}")
    if not status.get("hot_paths"):
        lines.append("      --  (no samples yet)")
    events = status.get("events", [])
    if events:
        lines += ["", "DETECTOR VERDICTS (newest last)"]
        for ev in events[-5:]:
            where = "/".join(ev.get("path", [])) or "-"
            lines.append(f"  {ev.get('kind', '?'):<18} share={ev.get('share', 0):.2f}  {where}")
    return "\n".join(lines)


def top_loop(
    base_url: str,
    interval_s: float = 2.0,
    k: int = 10,
    once: bool = False,
    plane: str = "host",
) -> int:
    """Poll ``/status`` and redraw; returns an exit code (1 = unreachable,
    4 = the requested plane has no device artifact behind this server)."""
    while True:
        try:
            status = fetch_status(base_url)
        except OSError as e:
            print(f"[profilerd top] {base_url} unreachable: {e}")
            return 1
        frame = render_top(status, base_url, k=k)
        if plane != "host":
            code, body = fetch_plane_tree(base_url, plane)
            if code == 404:
                print(frame)
                print(f"\n[profilerd top] {body.strip()}")
                return 4
            if code != 200:
                print(f"[profilerd top] /tree?plane={plane} -> HTTP {code}: {body.strip()}")
                return 1
            frame += "\n\n" + render_plane_rows(CallTree.from_json(body), plane, k=k)
        if once:
            print(frame)
            return 0
        print("\x1b[2J\x1b[H" + frame + f"\n\n(refreshing every {interval_s:g}s — Ctrl-C to quit)")
        if status.get("done"):
            return 0
        time.sleep(interval_s)
