"""Binary codec for raw frame records (target -> daemon).

Design constraints (ISSUE / paper §III):

* the target side must do **no symbol resolution** — records carry raw
  ``(filename, func, lineno)`` triples; the daemon resolves and classifies;
* strings are interned: each unique string crosses the wire once as a
  ``STRDEF`` record and is referenced by id afterwards, so steady-state
  samples are a few bytes per frame;
* records are self-delimiting (``u32`` length prefix), so the same byte
  stream works over the mmap ring spool *or* length-prefixed frames on a
  Unix-domain socket — the transport can swap without touching the codec;
* a dropped batch must not poison the stream: the encoder interns strings
  *transactionally* (``encode_tick`` returns the newly-defined strings; the
  caller rolls them back if the transport rejected the batch), and the
  decoder maps unknown ids to ``"?"`` instead of failing.

Record layout (little-endian):

====== ========== ===========================================================
kind   name       payload
====== ========== ===========================================================
1      HELLO      u32 version, u32 pid, f64 period_s
2      STRDEF     u32 id, u16 len, utf-8 bytes
3      SAMPLE     f64 t, u64 tid, u32 thread_name_id, u16 nframes,
                  nframes * (u32 file_id, u32 func_id, u32 lineno);
                  frames ordered root -> leaf
4      RUSAGE     f64 t, f64 cpu_s, u64 rss_bytes
5      BYE        u64 n_ticks (publisher ticks over the whole session)
====== ========== ===========================================================
"""

from __future__ import annotations

import struct
from dataclasses import dataclass, field
from typing import Iterable, Iterator, Optional, Sequence, Union

WIRE_VERSION = 1

K_HELLO = 1
K_STRDEF = 2
K_SAMPLE = 3
K_RUSAGE = 4
K_BYE = 5

_LEN = struct.Struct("<I")
_KIND = struct.Struct("<B")
_HELLO = struct.Struct("<IId")
_STRDEF_HDR = struct.Struct("<IH")
_SAMPLE_HDR = struct.Struct("<dQIH")
_FRAME = struct.Struct("<III")
_RUSAGE = struct.Struct("<ddQ")
_BYE = struct.Struct("<Q")

UNKNOWN = "?"


@dataclass(frozen=True)
class RawFrame:
    """One unresolved frame, exactly what the target can read for free."""

    filename: str
    func: str
    lineno: int


@dataclass
class RawSample:
    """One thread's stack at one tick, root -> leaf."""

    t: float
    tid: int
    thread_name: str
    frames: list[RawFrame] = field(default_factory=list)


@dataclass
class Hello:
    version: int
    pid: int
    period_s: float


@dataclass
class Rusage:
    t: float
    cpu_s: float
    rss_bytes: int


@dataclass
class Bye:
    n_ticks: int


Event = Union[Hello, RawSample, Rusage, Bye]


def _record(kind: int, payload: bytes) -> bytes:
    body = _KIND.pack(kind) + payload
    return _LEN.pack(len(body)) + body


class Encoder:
    """Target-side encoder with a transactional string-intern table."""

    def __init__(self) -> None:
        self._ids: dict[str, int] = {}
        self._next_id = 0

    def _intern(self, s: str, out: list[bytes], fresh: list[str]) -> int:
        sid = self._ids.get(s)
        if sid is None:
            sid = self._next_id
            self._next_id += 1
            self._ids[s] = sid
            raw = s.encode("utf-8", "replace")[: 0xFFFF]
            out.append(_record(K_STRDEF, _STRDEF_HDR.pack(sid, len(raw)) + raw))
            fresh.append(s)
        return sid

    def rollback(self, fresh: Iterable[str]) -> None:
        """Forget strings interned by a batch the transport rejected.

        Ids are never reused (``_next_id`` keeps growing), so a later
        re-definition of the same string cannot collide with the dropped one.
        """
        for s in fresh:
            self._ids.pop(s, None)

    def encode_hello(self, pid: int, period_s: float) -> bytes:
        return _record(K_HELLO, _HELLO.pack(WIRE_VERSION, pid, period_s))

    def encode_tick(
        self, samples: Sequence[RawSample], rusage: Optional[Rusage] = None
    ) -> tuple[bytes, list[str]]:
        """Encode one tick's samples as a single batch.

        Returns ``(payload, fresh_strings)``; the caller must either commit
        the whole payload to the transport or call :meth:`rollback` with
        ``fresh_strings``.
        """
        out: list[bytes] = []
        fresh: list[str] = []
        for s in samples:
            name_id = self._intern(s.thread_name, out, fresh)
            body = [_SAMPLE_HDR.pack(s.t, s.tid, name_id, len(s.frames))]
            for f in s.frames:
                body.append(
                    _FRAME.pack(
                        self._intern(f.filename, out, fresh),
                        self._intern(f.func, out, fresh),
                        f.lineno,
                    )
                )
            out.append(_record(K_SAMPLE, b"".join(body)))
        if rusage is not None:
            out.append(_record(K_RUSAGE, _RUSAGE.pack(rusage.t, rusage.cpu_s, rusage.rss_bytes)))
        return b"".join(out), fresh

    def encode_bye(self, n_ticks: int) -> bytes:
        return _record(K_BYE, _BYE.pack(n_ticks))


class Decoder:
    """Streaming decoder: feed arbitrary byte chunks, get events out."""

    def __init__(self) -> None:
        self._buf = bytearray()
        self._strings: dict[int, str] = {}

    def _string(self, sid: int) -> str:
        return self._strings.get(sid, UNKNOWN)

    def feed(self, data: bytes) -> Iterator[Event]:
        self._buf.extend(data)
        # Walk an offset and trim once at the end: draining a multi-MiB spool
        # backlog arrives as one chunk, and a per-record front-trim would make
        # that O(n^2) in buffer size.
        off = 0
        try:
            while True:
                if len(self._buf) - off < _LEN.size:
                    return
                (n,) = _LEN.unpack_from(self._buf, off)
                if len(self._buf) - off < _LEN.size + n:
                    return
                start = off + _LEN.size
                body = bytes(self._buf[start : start + n])
                off = start + n
                ev = self._decode(body[0], body[1:])
                if ev is not None:
                    yield ev
        finally:
            del self._buf[:off]

    def _decode(self, kind: int, payload: bytes) -> Optional[Event]:
        if kind == K_STRDEF:
            sid, n = _STRDEF_HDR.unpack_from(payload, 0)
            off = _STRDEF_HDR.size
            self._strings[sid] = payload[off : off + n].decode("utf-8", "replace")
            return None
        if kind == K_SAMPLE:
            t, tid, name_id, nframes = _SAMPLE_HDR.unpack_from(payload, 0)
            off = _SAMPLE_HDR.size
            frames = []
            for _ in range(nframes):
                fid, qid, lineno = _FRAME.unpack_from(payload, off)
                off += _FRAME.size
                frames.append(RawFrame(self._string(fid), self._string(qid), lineno))
            return RawSample(t, tid, self._string(name_id), frames)
        if kind == K_HELLO:
            version, pid, period_s = _HELLO.unpack(payload)
            return Hello(version, pid, period_s)
        if kind == K_RUSAGE:
            t, cpu_s, rss = _RUSAGE.unpack(payload)
            return Rusage(t, cpu_s, rss)
        if kind == K_BYE:
            (n_ticks,) = _BYE.unpack(payload)
            return Bye(n_ticks)
        return None  # unknown kinds are skipped, forward-compatibly
