"""Binary codec for raw frame records (target -> daemon).

Design constraints (ISSUE / paper §III):

* the target side must do **no symbol resolution** — records carry raw
  ``(filename, func, lineno)`` triples; the daemon resolves and classifies;
* strings are interned: each unique string crosses the wire once as a
  ``STRDEF`` record and is referenced by id afterwards;
* **stacks are interned** (wire v2): each unique stack crosses the wire once
  as a ``STACKDEF`` record (string-id triples, prefix-delta encoded against
  the previously defined stack), after which a steady-state sample is a
  fixed-size ``SAMPLE2`` record (``t, tid, name_id, stack_id``) instead of
  12 bytes *per frame* — the dominance pattern the paper exploits (steady
  simulator stacks repeat almost verbatim tick after tick) makes the
  amortized cost per sample independent of stack depth;
* records are self-delimiting (``u32`` length prefix), so the same byte
  stream works over the mmap ring spool *or* length-prefixed frames on a
  Unix-domain socket — the transport can swap without touching the codec;
* a dropped batch must not poison the stream: the encoder interns strings
  *and stacks* transactionally (``encode_tick`` returns the newly-defined
  keys; the caller rolls them back if the transport rejected the batch), and
  the decoder maps unknown string ids to ``"?"`` and unknown stack ids to a
  counted ``"?"`` placeholder frame instead of failing.

Record layout (little-endian):

====== ========== ===========================================================
kind   name       payload
====== ========== ===========================================================
1      HELLO      u32 version, u32 pid, f64 period_s
2      STRDEF     u32 id, u16 len, utf-8 bytes (truncated on a codepoint
                  boundary at 0xFFFF bytes)
3      SAMPLE     (wire v1) f64 t, u64 tid, u32 thread_name_id, u16 nframes,
                  nframes * (u32 file_id, u32 func_id, u32 lineno);
                  frames ordered root -> leaf
4      RUSAGE     f64 t, f64 cpu_s, u64 rss_bytes
5      BYE        u64 n_ticks (publisher ticks over the whole session)
6      STACKDEF   (wire v2) u32 stack_id, u16 n_prefix, u16 n_new,
                  n_new * (u32 file_id, u32 func_id, u32 lineno).
                  The full stack is the first ``n_prefix`` frames of the
                  *previously defined* stack followed by the ``n_new``
                  frames, root -> leaf (prefix-delta encoding: consecutive
                  definitions usually share a long root prefix).  Stacks are
                  interned on their ``(filename, func)`` frame sequence —
                  symbol resolution is line-agnostic, so line numbers (which
                  jitter on an actively-executing leaf frame) never split a
                  stack; the encoded linenos are the first occurrence's.
7      SAMPLE2    (wire v2) f64 t, u64 tid, u32 thread_name_id, u32 stack_id
====== ========== ===========================================================

Version negotiation rides on ``HELLO``: a v2 agent announces ``version=2``
and emits ``STACKDEF``/``SAMPLE2``; the decoder dispatches on record kind, so
it decodes v1 and v2 streams (and old v1 spool files) with no mode switch.
``Encoder(version=1)`` keeps producing pure-v1 streams for old consumers.

Batch decode (vectorized ingest)
--------------------------------

``SAMPLE2`` records are a fixed 29 bytes on the wire precisely so a run of
them can be decoded as *one* ``np.frombuffer`` structured-dtype view instead
of a per-record ``struct.unpack`` loop.  :meth:`Decoder.feed_batch` does
that: contiguous ``SAMPLE2`` runs come out as columnar :class:`SampleBatch`
objects (``t``/``tid``/``name_id``/``stack_id`` arrays), while every other
record kind — and a torn tail straddling the chunk boundary — goes through
the exact same scalar parse core as :meth:`Decoder.feed`.  numpy is an
optional dependency here: it is imported lazily on first use (the in-target
agent, which only encodes, never pays the import), and when it is absent
``feed_batch`` simply degrades to the scalar path.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass, field
from collections.abc import Iterable, Iterator, Sequence

WIRE_VERSION = 2

K_HELLO = 1
K_STRDEF = 2
K_SAMPLE = 3
K_RUSAGE = 4
K_BYE = 5
K_STACKDEF = 6
K_SAMPLE2 = 7

_LEN = struct.Struct("<I")
_KIND = struct.Struct("<B")
_HELLO = struct.Struct("<IId")
_STRDEF_HDR = struct.Struct("<IH")
_SAMPLE_HDR = struct.Struct("<dQIH")
_FRAME = struct.Struct("<III")
_RUSAGE = struct.Struct("<ddQ")
_BYE = struct.Struct("<Q")
_STACKDEF_HDR = struct.Struct("<IHH")
_SAMPLE2 = struct.Struct("<dQII")

# Whole-record size of a SAMPLE2 on the wire: u32 len + u8 kind + payload.
_S2_RECORD = _LEN.size + 1 + _SAMPLE2.size

UNKNOWN = "?"

# numpy is optional (vectorized batch decode only) and imported lazily: the
# attach path's import budget must not pay ~100 ms for a dependency the
# scalar fallback never touches.  The sentinel distinguishes "not probed yet"
# from "probed, absent".
_np_probed = False
_np = None
_sample2_dtype = None

# Predicate cost per vectorized probe is bounded to this many records, so a
# stream of non-SAMPLE2 records (cold-start STRDEF/STACKDEF bursts) costs
# O(records) total instead of O(records^2) per chunk, while genuine runs
# amortize the probe over thousands of samples.
_PROBE_MAX = 4096


def _numpy():
    """The numpy module, or None when unavailable (scalar fallback)."""
    global _np_probed, _np, _sample2_dtype
    if not _np_probed:
        _np_probed = True
        try:
            import numpy
        except ImportError:  # pragma: no cover - exercised via monkeypatch
            numpy = None
        _np = numpy
        if numpy is not None:
            # One structured view per SAMPLE2 run: field offsets address the
            # raw record bytes in place (len prefix and kind byte included,
            # so the same view validates framing and extracts columns).
            _sample2_dtype = numpy.dtype(
                {
                    "names": ["len", "kind", "t", "tid", "name_id", "stack_id"],
                    "formats": ["<u4", "u1", "<f8", "<u8", "<u4", "<u4"],
                    "offsets": [0, 4, 5, 13, 21, 25],
                    "itemsize": _S2_RECORD,
                }
            )
    return _np


def numpy_available() -> bool:
    return _numpy() is not None

_MAX_STR_BYTES = 0xFFFF  # STRDEF length field is u16

# Safety valve for pathological stack diversity (deep recursion sampled at
# varying depths, exec'd code minting unique filenames): once the encoder's
# stack table is full, *new* stacks fall back to v1 per-frame SAMPLE records
# — the decoder dispatches per record kind, so mixed streams are legal — and
# the table (hence agent memory inside the target) stays bounded.
DEFAULT_MAX_STACKS = 1 << 16

# Every Nth STACKDEF is a full (n_prefix=0) definition even when a shorter
# delta exists — a keyframe.  A decoder that attached mid-stream (its delta
# context degraded) recovers delta decoding within N *new* definitions
# instead of never: real stacks share root frames, so organic n_prefix==0
# definitions effectively don't occur after warm-up.  Stacks interned before
# the attach are not re-emitted — their samples stay counted placeholders
# (``unknown_stack_refs``), same as v1's "?" symbols for consumed STRDEFs.
FULL_DEF_INTERVAL = 16


@dataclass(frozen=True, slots=True)
class RawFrame:
    """One unresolved frame, exactly what the target can read for free."""

    filename: str
    func: str
    lineno: int


@dataclass(slots=True)
class RawSample:
    """One thread's stack at one tick, root -> leaf.

    ``stack_id`` is set when the sample arrived as a v2 ``SAMPLE2`` record:
    it identifies the interned stack, and consumers may key a resolution
    cache on it (see :class:`repro.profilerd.ingest.TreeIngestor`).  For a
    cache hit the ``frames`` list need not be touched at all — the decoder
    shares one list object between every sample of the same stack, so the
    fast path allocates nothing per frame.
    """

    t: float
    tid: int
    thread_name: str
    frames: list[RawFrame] = field(default_factory=list)
    stack_id: int | None = None


@dataclass(slots=True)
class Hello:
    version: int
    pid: int
    period_s: float


@dataclass(slots=True)
class Rusage:
    t: float
    cpu_s: float
    rss_bytes: int


@dataclass(slots=True)
class Bye:
    n_ticks: int


class SampleBatch:
    """A columnar run of ``SAMPLE2`` records, in stream order.

    Produced by :meth:`Decoder.feed_batch`: ``t`` (f8), ``tid`` (u8),
    ``name_id`` (u4) and ``stack_id`` (u4) are equal-length numpy arrays —
    field views of one packed structured copy (the decoder's receive buffer
    is trimmed after the batch is emitted, so the columns must not alias
    it).  The ``decoder`` reference
    resolves the id columns against the live intern tables —
    :meth:`Decoder.thread_name` and :meth:`Decoder.batch_stack` — which is
    safe because ids are append-only and the batch is consumed before any
    later chunk can redefine the tables (only a re-attach replaces them, and
    that replaces the whole decoder).
    """

    __slots__ = ("t", "tid", "name_id", "stack_id", "decoder")

    def __init__(self, t, tid, name_id, stack_id, decoder: "Decoder"):
        self.t = t
        self.tid = tid
        self.name_id = name_id
        self.stack_id = stack_id
        self.decoder = decoder

    def __len__(self) -> int:
        return len(self.t)


Event = Hello | RawSample | Rusage | Bye

# Keys handed back by encode_tick for transactional rollback: interned
# strings are ``str``; interned stacks are tuples of (filename, func) pairs
# (line numbers are deliberately not part of a stack's identity — see
# Encoder._intern_stack).
InternKey = str | tuple


def _record(kind: int, payload: bytes) -> bytes:
    body = _KIND.pack(kind) + payload
    return _LEN.pack(len(body)) + body


def _truncate_utf8(s: str) -> bytes:
    """Encode with a 0xFFFF-byte cap, never splitting a multi-byte sequence."""
    raw = s.encode("utf-8", "replace")
    if len(raw) <= _MAX_STR_BYTES:
        return raw
    cut = _MAX_STR_BYTES
    # Back off past UTF-8 continuation bytes (0b10xxxxxx) so the cut lands
    # on a codepoint boundary; at most 3 steps.
    while cut > 0 and (raw[cut] & 0xC0) == 0x80:
        cut -= 1
    return raw[:cut]


class Encoder:
    """Target-side encoder with transactional string + stack intern tables."""

    def __init__(self, version: int = WIRE_VERSION, max_stacks: int = DEFAULT_MAX_STACKS) -> None:
        if version not in (1, 2):
            raise ValueError(f"unsupported wire version {version}")
        self.version = version
        self.max_stacks = max_stacks
        self._ids: dict[str, int] = {}
        self._next_id = 0
        self._stack_ids: dict[tuple, int] = {}
        self._next_stack_id = 0
        # Id-triples of the last committed STACKDEF — the prefix-delta
        # context.  Reset on rollback: the decoder never saw the dropped
        # definition, so the next STACKDEF must not delta against it.
        self._def_tail: tuple[tuple[int, int, int], ...] = ()
        self._defs_until_full = 0  # 0 -> next STACKDEF is a keyframe

    def _intern(self, s: str, out: list[bytes], fresh: list[InternKey]) -> int:
        sid = self._ids.get(s)
        if sid is None:
            sid = self._next_id
            self._next_id += 1
            self._ids[s] = sid
            raw = _truncate_utf8(s)
            out.append(_record(K_STRDEF, _STRDEF_HDR.pack(sid, len(raw)) + raw))
            fresh.append(s)
        return sid

    def _intern_stack(
        self, frames: Sequence[RawFrame], out: list[bytes], fresh: list[InternKey]
    ) -> int | None:
        """Intern one stack; returns its id, or None when the table is full
        (the caller then encodes a v1 per-frame SAMPLE for this sample)."""
        # Keyed on the (filename, func) sequence only: symbol resolution is
        # line-agnostic, and a busy thread's *leaf* line number changes nearly
        # every tick — including it would mint a new STACKDEF per sample and
        # grow the intern tables without bound.  The STACKDEF carries the
        # first-seen line numbers as representative values.
        key = tuple((f.filename, f.func) for f in frames)
        sid = self._stack_ids.get(key)
        if sid is None:
            if len(self._stack_ids) >= self.max_stacks:
                return None
            triples = tuple(
                (
                    self._intern(f.filename, out, fresh),
                    self._intern(f.func, out, fresh),
                    f.lineno,
                )
                for f in frames
            )
            sid = self._next_stack_id
            self._next_stack_id += 1
            self._stack_ids[key] = sid
            fresh.append(key)
            n_prefix = 0
            if self._defs_until_full == 0:
                self._defs_until_full = FULL_DEF_INTERVAL - 1  # keyframe
            else:
                self._defs_until_full -= 1
                for a, b in zip(self._def_tail, triples, strict=False):
                    if a != b:
                        break
                    n_prefix += 1
            body = [_STACKDEF_HDR.pack(sid, n_prefix, len(triples) - n_prefix)]
            for t in triples[n_prefix:]:
                body.append(_FRAME.pack(*t))
            out.append(_record(K_STACKDEF, b"".join(body)))
            self._def_tail = triples
        return sid

    def rollback(self, fresh: Iterable[InternKey]) -> None:
        """Forget strings/stacks interned by a batch the transport rejected.

        Ids are never reused (the counters keep growing), so a later
        re-definition of the same string or stack cannot collide with the
        dropped one.  The prefix-delta context is reset whenever a STACKDEF
        was dropped: the next definition encodes from scratch.
        """
        dropped_stack = False
        for k in fresh:
            if isinstance(k, tuple):
                self._stack_ids.pop(k, None)
                dropped_stack = True
            else:
                self._ids.pop(k, None)
        if dropped_stack:
            self._def_tail = ()

    def encode_hello(self, pid: int, period_s: float) -> bytes:
        return _record(K_HELLO, _HELLO.pack(self.version, pid, period_s))

    def encode_tick(
        self, samples: Sequence[RawSample], rusage: Rusage | None = None
    ) -> tuple[bytes, list[InternKey]]:
        """Encode one tick's samples as a single batch.

        Returns ``(payload, fresh_keys)``; the caller must either commit
        the whole payload to the transport or call :meth:`rollback` with
        ``fresh_keys``.
        """
        out: list[bytes] = []
        fresh: list[InternKey] = []
        v2 = self.version >= 2
        for s in samples:
            name_id = self._intern(s.thread_name, out, fresh)
            sid = self._intern_stack(s.frames, out, fresh) if v2 else None
            if sid is not None:
                out.append(_record(K_SAMPLE2, _SAMPLE2.pack(s.t, s.tid, name_id, sid)))
            else:
                body = [_SAMPLE_HDR.pack(s.t, s.tid, name_id, len(s.frames))]
                for f in s.frames:
                    body.append(
                        _FRAME.pack(
                            self._intern(f.filename, out, fresh),
                            self._intern(f.func, out, fresh),
                            f.lineno,
                        )
                    )
                out.append(_record(K_SAMPLE, b"".join(body)))
        if rusage is not None:
            out.append(_record(K_RUSAGE, _RUSAGE.pack(rusage.t, rusage.cpu_s, rusage.rss_bytes)))
        return b"".join(out), fresh

    def encode_bye(self, n_ticks: int) -> bytes:
        return _record(K_BYE, _BYE.pack(n_ticks))


class Decoder:
    """Streaming decoder: feed arbitrary byte chunks, get events out.

    Dispatches on record kind, so v1 (``SAMPLE``) and v2
    (``STACKDEF``/``SAMPLE2``) streams — and mixed ones — decode without a
    mode switch.  Samples of the same interned stack share one frames list
    object (never mutated), which is what makes the daemon's cached-path
    ingestion allocation-free per repeated sample.
    """

    def __init__(self) -> None:
        self._buf = bytearray()
        self._strings: dict[int, str] = {}
        self._stacks: dict[int, list[RawFrame]] = {}
        self._def_tail: list[RawFrame] = []
        # A SAMPLE2 whose STACKDEF this decoder never saw (e.g. re-attaching
        # to a live spool after a previous reader consumed the definitions)
        # degrades to one "?" placeholder frame — like v1's unknown-string
        # "?" symbols — and is counted so the loss is visible upstream.
        self._unknown_stack = [RawFrame(UNKNOWN, UNKNOWN, 0)]
        self.unknown_stack_refs = 0
        # A STACKDEF whose prefix-delta references a context we never saw
        # (same re-attach scenario) would silently mis-root the stack if
        # applied; it degrades to the placeholder instead, and the context
        # stays distrusted until a full (n_prefix == 0) definition arrives.
        self._def_valid = True
        self.degraded_stackdefs = 0

    def _string(self, sid: int) -> str:
        return self._strings.get(sid, UNKNOWN)

    def feed(self, data: bytes) -> Iterator[Event]:
        self._buf.extend(data)
        # Walk an offset and trim once at the end: draining a multi-MiB spool
        # backlog arrives as one chunk, and a per-record front-trim would make
        # that O(n^2) in buffer size.  Records are parsed in place with
        # unpack_from (no per-record body copy) — at steady state a sample is
        # one struct unpack, one dict hit, and one RawSample.
        buf = self._buf
        off = 0
        try:
            while True:
                if len(buf) - off < _LEN.size:
                    return
                (n,) = _LEN.unpack_from(buf, off)
                if len(buf) - off < _LEN.size + n:
                    return
                start = off + _LEN.size
                off = start + n
                ev = self._decode(buf[start], buf, start + 1, off)
                if ev is not None:
                    yield ev
        finally:
            del buf[:off]

    def feed_batch(self, data: bytes) -> Iterator[Event | SampleBatch]:
        """Like :meth:`feed`, but contiguous ``SAMPLE2`` runs come out as
        columnar :class:`SampleBatch` objects instead of per-record
        :class:`RawSample` events.

        A run is detected with one ``np.frombuffer`` structured view over the
        buffered bytes: from a known record boundary, every 29-byte stride
        whose ``len``/``kind`` fields read ``(25, SAMPLE2)`` is — by framing
        induction — a genuine record, and the first stride that does not ends
        the run.  Everything else (defs, hello/rusage/bye, v1 samples,
        corrupt records, a torn tail) goes through the scalar parse core,
        byte-for-byte identical to :meth:`feed`.  Runs are coalesced across
        non-yielding records (``STRDEF``/``STACKDEF``/unknown kinds): moving
        a definition ahead of the samples *preceding* it is safe because ids
        are append-only and a sample can only reference an id defined before
        it.  The pending batch is flushed before any yielded event, so the
        consumer observes samples and events in stream order.

        Without numpy this degrades to the scalar path (same yields as
        :meth:`feed`).
        """
        np = _numpy()
        if np is None:
            yield from self.feed(data)
            return
        self._buf.extend(data)
        buf = self._buf
        off = 0
        pending: list = []  # structured-run copies awaiting one flush

        def flush() -> SampleBatch | None:
            if not pending:
                return None
            arr = pending[0] if len(pending) == 1 else np.concatenate(pending)
            pending.clear()
            # Field views of one packed structured array: zero extra copies
            # per flush, and consumers (`bincount` grouping, `tolist` for the
            # timeline) take strided views as-is.
            return SampleBatch(arr["t"], arr["tid"], arr["name_id"], arr["stack_id"], self)

        try:
            while True:
                remaining = len(buf) - off
                if remaining < _LEN.size:
                    break
                (n,) = _LEN.unpack_from(buf, off)
                if n == _SAMPLE2.size + 1 and remaining >= _S2_RECORD and buf[off + _LEN.size] == K_SAMPLE2:
                    # Front record is a SAMPLE2: probe the run vectorized.
                    kmax = min(remaining // _S2_RECORD, _PROBE_MAX)
                    arr = np.frombuffer(buf, dtype=_sample2_dtype, count=kmax, offset=off)
                    ok = (arr["len"] == _SAMPLE2.size + 1) & (arr["kind"] == K_SAMPLE2)
                    end_at = np.flatnonzero(~ok)
                    k = int(end_at[0]) if end_at.size else kmax
                    # One structured copy materializes the run; every view
                    # into the bytearray is dropped before the finally-trim
                    # (a live export would make `del buf[:off]` raise
                    # BufferError).
                    pending.append(arr[:k].copy())
                    arr = ok = end_at = None  # noqa: F841
                    off += k * _S2_RECORD
                    continue
                if remaining < _LEN.size + n:
                    break
                start = off + _LEN.size
                off = start + n
                ev = self._decode(buf[start], buf, start + 1, off)
                if ev is not None:
                    batch = flush()
                    if batch is not None:
                        yield batch
                    yield ev
        finally:
            del buf[:off]
        batch = flush()
        if batch is not None:
            yield batch

    def thread_name(self, name_id: int) -> str:
        """Resolve a ``SampleBatch.name_id`` against the string table."""
        return self._strings.get(name_id, UNKNOWN)

    def batch_stack(self, stack_id: int, n: int = 1) -> list[RawFrame]:
        """Frames for a ``SampleBatch.stack_id`` covering ``n`` samples.

        Mirrors the scalar SAMPLE2 decode's degraded-mode accounting: an
        unknown or degraded stack id resolves to the shared ``"?"``
        placeholder and bumps ``unknown_stack_refs`` once per *sample*, so
        batch and scalar ingestion report identical loss counters.
        """
        frames = self._stacks.get(stack_id)
        if frames is None:
            self.unknown_stack_refs += n
            return self._unknown_stack
        if frames is self._unknown_stack:
            self.unknown_stack_refs += n
        return frames

    def _decode(self, kind: int, buf: bytearray, off: int, end: int) -> Event | None:
        """Decode one record whose payload spans ``buf[off:end]``.

        Parsing is in place, so every variable-length count and every
        fixed-size payload is validated against ``end`` — a corrupt record
        (torn write, declared count exceeding its length prefix) raises
        instead of silently consuming the following records' bytes.
        """
        if kind == K_SAMPLE2:
            if end - off != _SAMPLE2.size:
                raise ValueError(f"corrupt SAMPLE2 record: {end - off} byte payload")
            t, tid, name_id, sid = _SAMPLE2.unpack_from(buf, off)
            frames = self._stacks.get(sid)
            if frames is None:
                frames = self._unknown_stack
                self.unknown_stack_refs += 1
            elif frames is self._unknown_stack:
                # Reference to a degraded STACKDEF (delta against an unseen
                # context): count every affected sample, not just the def.
                self.unknown_stack_refs += 1
            return RawSample(t, tid, self._strings.get(name_id, UNKNOWN), frames, sid)
        if kind == K_STACKDEF:
            sid, n_prefix, n_new = _STACKDEF_HDR.unpack_from(buf, off)
            if end - off != _STACKDEF_HDR.size + n_new * _FRAME.size:
                raise ValueError(f"corrupt STACKDEF record: n_new={n_new}")
            if n_prefix == 0:
                self._def_valid = True
            elif not self._def_valid or n_prefix > len(self._def_tail):
                self.degraded_stackdefs += 1
                self._def_valid = False
                self._stacks[sid] = self._unknown_stack
                return None
            off += _STACKDEF_HDR.size
            frames = self._def_tail[:n_prefix]
            for _ in range(n_new):
                fid, qid, lineno = _FRAME.unpack_from(buf, off)
                off += _FRAME.size
                frames.append(RawFrame(self._string(fid), self._string(qid), lineno))
            self._stacks[sid] = frames
            self._def_tail = frames
            return None
        if kind == K_STRDEF:
            sid, n = _STRDEF_HDR.unpack_from(buf, off)
            off += _STRDEF_HDR.size
            if off + n > end:
                raise ValueError(f"corrupt STRDEF record: len={n}")
            self._strings[sid] = buf[off : off + n].decode("utf-8", "replace")
            return None
        if kind == K_SAMPLE:
            t, tid, name_id, nframes = _SAMPLE_HDR.unpack_from(buf, off)
            off += _SAMPLE_HDR.size
            if off + nframes * _FRAME.size > end:
                raise ValueError(f"corrupt SAMPLE record: nframes={nframes}")
            frames = []
            for _ in range(nframes):
                fid, qid, lineno = _FRAME.unpack_from(buf, off)
                off += _FRAME.size
                frames.append(RawFrame(self._string(fid), self._string(qid), lineno))
            return RawSample(t, tid, self._string(name_id), frames)
        if kind == K_HELLO:
            if end - off != _HELLO.size:
                raise ValueError("corrupt HELLO record")
            version, pid, period_s = _HELLO.unpack_from(buf, off)
            return Hello(version, pid, period_s)
        if kind == K_RUSAGE:
            if end - off != _RUSAGE.size:
                raise ValueError("corrupt RUSAGE record")
            t, cpu_s, rss = _RUSAGE.unpack_from(buf, off)
            return Rusage(t, cpu_s, rss)
        if kind == K_BYE:
            if end - off != _BYE.size:
                raise ValueError("corrupt BYE record")
            (n_ticks,) = _BYE.unpack_from(buf, off)
            return Bye(n_ticks)
        return None  # unknown kinds are skipped, forward-compatibly
