"""Daemon-side symbol resolution with an interned-symbol cache.

Turns raw ``(filename, func)`` pairs into the same ``origin::name`` symbols
the in-process thread backend produces (:func:`repro.core.sampler.frame_symbol`),
then applies the same ``collapse_origins`` folding
(:func:`repro.core.sampler.collapse_stack`).  Parity with the thread backend
is a tested invariant: the two backends must build identical trees from
identical frames.

Two cache tiers:

* per-frame — interns on the *(filename, func)* pair; classification runs
  once per unique pair and resolved symbol strings are shared between all
  stacks that reference them, so v1 steady-state resolution is two dict hits
  per frame;
* per-stack (wire v2) — :meth:`SymbolResolver.resolve_stack_interned` memoizes
  the whole collapsed stack on the agent-assigned ``stack_id``, so a stack
  seen again (e.g. under a different thread name) resolves with a single
  dict hit and no per-frame work at all.
"""

from __future__ import annotations

import sys
from collections.abc import Iterable, Sequence

from repro.core.sampler import classify_frame, collapse_stack

from .wire import RawFrame


class SymbolResolver:
    def __init__(self, collapse_origins: Sequence[str] = ()):
        self.collapse_origins = tuple(collapse_origins)
        self._cache: dict[tuple[str, str], str] = {}
        self._stack_cache: dict[int, list[str]] = {}
        self.hits = 0
        self.misses = 0

    def symbol(self, filename: str, func: str) -> str:
        key = (filename, func)
        sym = self._cache.get(key)
        if sym is None:
            self.misses += 1
            sym = sys.intern(f"{classify_frame(filename)}::{func}")
            self._cache[key] = sym
        else:
            self.hits += 1
        return sym

    def resolve_stack(self, frames: Iterable[RawFrame]) -> list[str]:
        """Raw frames (root -> leaf) to collapsed symbol stack (root -> leaf)."""
        syms = [self.symbol(f.filename, f.func) for f in frames]
        return collapse_stack(syms, self.collapse_origins)

    def resolve_stack_interned(self, stack_id: int, frames: Iterable[RawFrame]) -> list[str]:
        """Like :meth:`resolve_stack`, memoized on the wire-v2 ``stack_id``.

        Safe because stack ids are assigned transactionally by the agent and
        never reused, so one id always names one ``(filename, func)`` frame
        sequence — exactly the inputs resolution consumes.
        """
        stack = self._stack_cache.get(stack_id)
        if stack is None:
            stack = self.resolve_stack(frames)
            self._stack_cache[stack_id] = stack
        return stack

    def reset_interned(self) -> None:
        """Forget the per-``stack_id`` memo (NOT the per-frame cache).

        A restarted writer re-assigns stack ids from 0 for what may be
        entirely different stacks, so the id-keyed tier must not survive a
        re-attach; the ``(filename, func)`` tier is content-keyed and stays.
        """
        self._stack_cache.clear()
