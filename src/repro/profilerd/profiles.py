"""Loading profile artifacts, in every shape this repo produces them.

One loader serves the CLI (``timeline``/``diff``/``check``/``export``) and the
HTTP server's offline mode.  A "profile" is any of:

* a daemon ``--out`` dir (uses its ``timeline/`` ring, falling back to
  ``tree.json``);
* a timeline ring dir (``seg-*.tl`` segments);
* a ``tree.json`` dump (``CallTree.to_json`` schema);
* a binary ``.snap`` snapshot (``repro.core.snapshot.save_snapshot``).
"""

from __future__ import annotations

import json
import os

TIMELINE_DIRNAME = "timeline"
TARGETS_DIRNAME = "targets"  # multi-target daemon: per-target artifact dirs
DEVICE_TREE_FILENAME = "device_tree.json"  # device-plane artifact beside a profile
STATIC_TREE_FILENAME = "static_tree.json"  # static call-graph artifact beside a profile
REGION_FILENAME = "region.json"  # aggregator out dir: region -> node -> target map


class ProfileLoadError(RuntimeError):
    pass


def load_profile(path: str):
    """Load a CallTree from any profile artifact shape (see module docstring)."""
    from repro.core.calltree import CallTree
    from repro.core.snapshot import SnapshotError, TimelineReader, is_timeline_dir, load_snapshot

    if os.path.isdir(path):
        tdir = os.path.join(path, TIMELINE_DIRNAME)
        tree_json = _tree_json_inside(path)
        ring = path if is_timeline_dir(path) else tdir if is_timeline_dir(tdir) else None
        if ring is not None:
            try:
                last = TimelineReader(ring).last()
            except SnapshotError as e:  # e.g. version skew from a newer build
                raise ProfileLoadError(f"{ring}: {e}") from None
            if last is not None:
                return last[1]
            # A ring that never got a decodable epoch (e.g. daemon killed
            # mid-keyframe) must not mask a valid tree.json beside it.
            if tree_json is None:
                raise ProfileLoadError(f"{ring}: timeline ring holds no decodable epochs")
        if tree_json is not None:
            return load_profile(tree_json)
        raise ProfileLoadError(f"{path}: no timeline ring or tree.json inside")
    if not os.path.exists(path):
        raise ProfileLoadError(f"{path}: no such profile")
    if path.endswith(".json"):
        try:
            with open(path) as f:
                return CallTree.from_json(f.read())
        except (OSError, ValueError, KeyError) as e:
            raise ProfileLoadError(f"{path}: unreadable tree.json: {e}") from None
    try:
        return load_snapshot(path)[1]
    except (OSError, SnapshotError) as e:
        raise ProfileLoadError(f"{path}: unreadable snapshot: {e}") from None


def _tree_json_inside(dir_path: str):
    """A dir's tree dump: ``tree.json`` or the launcher's ``merged_tree.json``."""
    for name in ("tree.json", "merged_tree.json"):
        p = os.path.join(dir_path, name)
        if os.path.exists(p):
            return p
    return None


def profile_mtime(path: str) -> float:
    """Newest mtime across the artifacts ``load_profile`` would read.

    The server's offline source caches the loaded tree and re-reads only when
    this changes, so serving a directory a daemon is *still writing into*
    stays fresh without re-decoding the ring on every request.
    """
    from repro.core.snapshot import list_segments

    candidates = [path]
    if os.path.isdir(path):
        tj = _tree_json_inside(path)
        if tj:
            candidates.append(tj)
        for d in (path, os.path.join(path, TIMELINE_DIRNAME)):
            candidates.extend(list_segments(d))
    newest = 0.0
    for p in candidates:
        try:
            newest = max(newest, os.path.getmtime(p))
        except OSError:
            pass
    return newest


def list_profile_targets(path: str) -> list[str]:
    """Target names under a multi-target daemon out dir (sorted; [] if none).

    A target is any ``targets/<name>/`` subdir holding a ``tree.json`` or a
    timeline ring — the shapes :func:`load_profile` can read.
    """
    from repro.core.snapshot import is_timeline_dir

    d = os.path.join(path, TARGETS_DIRNAME)
    if not os.path.isdir(d):
        return []
    out = []
    for name in sorted(os.listdir(d)):
        sub = os.path.join(d, name)
        if not os.path.isdir(sub):
            continue
        if os.path.exists(os.path.join(sub, "tree.json")) or is_timeline_dir(
            os.path.join(sub, TIMELINE_DIRNAME)
        ):
            out.append(name)
    return out


def target_profile_dir(path: str, name: str):
    """The per-target profile dir behind a fleet out dir, or None."""
    sub = os.path.join(path, TARGETS_DIRNAME, name)
    return sub if name in list_profile_targets(path) else None


def device_tree_path(path: str, target: str | None = None):
    """Resolve the ``device_tree.json`` artifact beside a profile, or None.

    A profile dir holds it directly; a per-target dir under ``targets/<name>/``
    holds a target-specific one, falling back to the fleet-level artifact (all
    co-located targets run the same compiled program); a ``tree.json``/
    ``.snap`` file has it as a sibling.
    """
    if os.path.isdir(path):
        if target:
            p = os.path.join(path, TARGETS_DIRNAME, target, DEVICE_TREE_FILENAME)
            if os.path.exists(p):
                return p
        p = os.path.join(path, DEVICE_TREE_FILENAME)
        return p if os.path.exists(p) else None
    p = os.path.join(os.path.dirname(path) or ".", DEVICE_TREE_FILENAME)
    return p if os.path.exists(p) else None


def load_device_plane(path: str, target: str | None = None):
    """The device-plane CallTree beside a profile: None when absent, raises
    :class:`ProfileLoadError` when present but unreadable (never a vacuous
    empty tree — the plane contract mirrors the no-match marker contract)."""
    from repro.core.hlo_tree import load_device_tree

    p = device_tree_path(path, target)
    if p is None:
        return None
    try:
        return load_device_tree(p)
    except (OSError, ValueError, KeyError) as e:
        raise ProfileLoadError(f"{p}: unreadable device tree: {e}") from None


def static_tree_path(path: str, target: str | None = None):
    """Resolve the ``static_tree.json`` artifact beside a profile, or None.

    Same resolution as :func:`device_tree_path`: a profile dir holds it
    directly, a per-target dir may hold a target-specific one falling back
    to the fleet-level artifact (all targets run the same source tree), and
    a ``tree.json``/``.snap`` file has it as a sibling.
    """
    if os.path.isdir(path):
        if target:
            p = os.path.join(path, TARGETS_DIRNAME, target, STATIC_TREE_FILENAME)
            if os.path.exists(p):
                return p
        p = os.path.join(path, STATIC_TREE_FILENAME)
        return p if os.path.exists(p) else None
    p = os.path.join(os.path.dirname(path) or ".", STATIC_TREE_FILENAME)
    return p if os.path.exists(p) else None


def load_static_plane(path: str, target: str | None = None):
    """The static call-graph CallTree beside a profile: None when absent,
    raises :class:`ProfileLoadError` when present but unreadable (never a
    vacuous empty tree — same contract as the device plane)."""
    from repro.analysis.static_tree import load_static_tree

    p = static_tree_path(path, target)
    if p is None:
        return None
    try:
        return load_static_tree(p)
    except (OSError, ValueError, KeyError) as e:
        raise ProfileLoadError(f"{p}: unreadable static tree: {e}") from None


def load_region(path: str):
    """The aggregator's ``region.json`` hierarchy beside a profile, or None.

    Shape: ``{"region": <name>, "nodes": [{"name": ..., "targets": [...]},
    ...]}`` — written by ``profilerd aggregate`` every publish window so the
    offline query plane can serve hierarchical ``/targets`` from the same
    artifact dir.
    """
    if not os.path.isdir(path):
        return None
    p = os.path.join(path, REGION_FILENAME)
    try:
        with open(p) as f:
            data = json.load(f)
    except OSError:
        return None
    except ValueError as e:
        raise ProfileLoadError(f"{p}: unreadable region map: {e}") from None
    return data if isinstance(data, dict) else None


def timeline_dir_of(path: str):
    """The timeline ring dir behind a profile path, if it has one."""
    from repro.core.snapshot import is_timeline_dir

    if not os.path.isdir(path):
        return None
    if is_timeline_dir(path):
        return path
    for name in (TIMELINE_DIRNAME, "merged_timeline"):
        tdir = os.path.join(path, name)
        if is_timeline_dir(tdir):
            return tdir
    return None
