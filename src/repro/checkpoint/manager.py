"""Checkpointing: atomic, async, anomaly-triggered.

Layout: ``<dir>/step_<n>/`` with one ``.npy`` per leaf (flattened key path)
plus ``manifest.json`` (tree structure, dtypes, extra state like the data
pipeline position). Writes go to ``step_<n>.tmp`` and are renamed only when
complete — a crash mid-save can never corrupt the restore point.

* ``save`` — asynchronous by default (background writer thread; ``wait()``
  blocks), so the train loop overlaps checkpoint I/O with compute.
* ``save_emergency`` — the detector callback (paper §V-D: threshold violation
  -> checkpoint + warning). Tagged in the manifest with the triggering event.
* ``restore_latest`` — used by the launcher's restart policy; tolerant of a
  trailing ``.tmp`` from a crashed save.

Arrays are written host-local (this container is single-process). The
manifest records the logical axes of every leaf, so a real multi-host restore
re-shards by logical name onto whatever mesh the restarted job has — restore
is elastic by construction.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from collections.abc import Callable
from typing import Any

import numpy as np

try:  # jax is only needed to materialize device arrays on host; a pure-numpy
    import jax  # state tree (tests, fault scenarios) checkpoints without it.
except ImportError:  # pragma: no cover - exercised in jax-free environments
    jax = None

_SEP = "."


def _sync_path(path: str) -> None:
    """fsync one written file to stable storage.

    Module-level indirection on purpose: durability is where checkpoint
    writes wedge in production (hung NFS/fuse mounts), so the fault corpus
    (``repro.faults``) shims this symbol to reproduce a blocked-fsync save.
    """
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _flatten(tree, prefix=()) -> dict[tuple, Any]:
    if isinstance(tree, dict):
        out = {}
        for k, v in tree.items():
            out.update(_flatten(v, prefix + (str(k),)))
        return out
    return {prefix: tree}


def _unflatten(flat: dict[tuple, Any]) -> Any:
    if list(flat.keys()) == [()]:
        return flat[()]
    root: dict = {}
    for path, v in flat.items():
        node = root
        for k in path[:-1]:
            node = node.setdefault(k, {})
        node[path[-1]] = v
    return root


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, fsync: bool = False):
        self.directory = directory
        self.keep = keep
        # fsync=True forces every leaf + manifest to stable storage before
        # the rename — the durable mode whose blocking failure profile the
        # fault corpus injects (see _sync_path).
        self.fsync = fsync
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()
        self._pending: threading.Thread | None = None
        self.saved_steps: list[int] = []

    # -- save --------------------------------------------------------------------

    def save(self, step: int, tree: Any, *, extra: dict | None = None, blocking: bool = False, tag: str = "periodic") -> None:
        # Materialize on host *before* handing to the writer thread so the
        # train loop can donate/overwrite device buffers immediately.
        host_tree = jax.device_get(tree) if jax is not None else tree
        flat = {k: np.asarray(v) for k, v in _flatten(host_tree).items()}
        manifest = {
            "step": int(step),
            "tag": tag,
            "extra": extra or {},
            "leaves": {_SEP.join(k): {"dtype": str(v.dtype), "shape": list(v.shape)} for k, v in flat.items()},
        }

        def write():
            final = os.path.join(self.directory, f"step_{step:010d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            for k, v in flat.items():
                leaf = os.path.join(tmp, _SEP.join(k) + ".npy")
                np.save(leaf, v)
                if self.fsync:
                    _sync_path(leaf)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if self.fsync:
                _sync_path(os.path.join(tmp, "manifest.json"))
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            with self._lock:
                self.saved_steps.append(step)
                self._gc()

        self.wait()
        if blocking:
            write()
        else:
            t = threading.Thread(target=write, name="repro-ckpt-writer", daemon=True)
            t.start()
            self._pending = t

    def save_emergency(self, step_fn: Callable[[], tuple[int, Any]], event) -> str:
        """Detector hook: checkpoint NOW, tagged with the anomaly."""
        step, tree = step_fn()
        self.save(
            step,
            tree,
            extra={"anomaly": {"kind": event.kind, "path": list(event.path), "share": event.share}},
            blocking=True,
            tag="emergency",
        )
        return os.path.join(self.directory, f"step_{step:010d}")

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        while len(self.saved_steps) > self.keep:
            victim = self.saved_steps.pop(0)
            path = os.path.join(self.directory, f"step_{victim:010d}")
            if os.path.exists(path):
                shutil.rmtree(path)

    # -- restore --------------------------------------------------------------------

    def list_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def restore(self, step: int) -> tuple[Any, dict]:
        path = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat = {}
        for key in manifest["leaves"]:
            flat[tuple(key.split(_SEP))] = np.load(os.path.join(path, key + ".npy"))
        return _unflatten(flat), manifest

    def restore_latest(self) -> tuple[int, Any, dict] | None:
        steps = self.list_steps()
        if not steps:
            return None
        step = steps[-1]
        tree, manifest = self.restore(step)
        return step, tree, manifest
