"""Sharded AdamW with global-norm clipping.

Optimizer state (m, v) mirrors the parameter pytree, so the FSDP parameter
shardings apply verbatim — ZeRO-style sharded optimizer state for free. All
arithmetic is fp32 regardless of parameter dtype.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params, *, moment_dtype=jnp.float32) -> dict:
    """``moment_dtype=bfloat16`` halves optimizer HBM (8-bit-Adam-style
    quantized moments, the coarse version) — update math stays fp32."""
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(
    grads,
    opt_state: dict,
    params,
    *,
    lr: float | jax.Array,
    cfg: AdamWConfig = AdamWConfig(),
):
    """-> (new_params, new_opt_state, metrics). Pure; jit/scan-friendly."""
    with jax.named_scope("optimizer"):
        step = opt_state["step"] + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            mdt = m.dtype
            g = g.astype(jnp.float32) * scale
            m32 = cfg.b1 * m.astype(jnp.float32) + (1.0 - cfg.b1) * g
            v32 = cfg.b2 * v.astype(jnp.float32) + (1.0 - cfg.b2) * jnp.square(g)
            mhat = m32 / bc1
            vhat = v32 / bc2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(opt_state["m"])
        flat_v = treedef.flatten_up_to(opt_state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v, strict=True)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        metrics = {"grad_norm": gnorm, "clip_scale": scale}
        return new_p, {"step": step, "m": new_m, "v": new_v}, metrics
