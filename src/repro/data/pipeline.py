"""Deterministic, sharded, resumable synthetic LM data pipeline.

Counter-based determinism: batch ``step`` is a pure function of
``(seed, step, host_id)`` — no incremental RNG state — so

* **resume** after restart is exact (checkpoint stores only ``next_step``);
* **sharding** is by construction (host h draws rows [h*B/H, (h+1)*B/H));
* **elastic re-sharding** works: a restart with a different host count
  re-partitions the same global batch.

The token stream has learnable structure (a noisy affine bigram process over
the vocab) so example runs show a genuinely decreasing loss, plus a fixed
"syntax" token every 8 positions that models latch onto quickly.

``Pipeline`` adds a background prefetch thread (bounded queue). Its frames
appear in the host-plane profile under ``repro::_prefetch_worker`` — input
starvation shows up exactly like the paper's Ruby busy-wait.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from collections.abc import Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    noise: float = 0.1  # fraction of uniform-random tokens

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class SyntheticLM:
    """Stateless batch generator: batch(step) is pure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_id, 0xDA7A])
        )
        B, S, V = cfg.host_batch, cfg.seq_len, cfg.vocab
        x = np.empty((B, S + 1), np.int64)
        x[:, 0] = rng.integers(0, V, B)
        mult = 31 if V > 31 else 3
        noise = rng.random((B, S)) < cfg.noise
        rand_tok = rng.integers(0, V, (B, S))
        for t in range(1, S + 1):
            nxt = (x[:, t - 1] * mult + 7) % V
            x[:, t] = np.where(noise[:, t - 1], rand_tok[:, t - 1], nxt)
        x[:, ::8] = 1 % V  # periodic "syntax" anchor token
        tokens = x[:, :-1].astype(np.int32)
        labels = x[:, 1:].astype(np.int32)
        return {
            "tokens": tokens,
            "labels": labels,
            "loss_mask": np.ones((B, S), np.float32),
        }


class Pipeline:
    """Prefetching iterator with checkpointable position."""

    def __init__(self, dataset: SyntheticLM, *, prefetch: int = 2, start_step: int = 0):
        self.dataset = dataset
        self.next_step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._produce_step = start_step
        self._thread = threading.Thread(target=self._prefetch_worker, name="repro-data-prefetch", daemon=True)
        self._thread.start()

    def _prefetch_worker(self) -> None:
        while not self._stop.is_set():
            batch = self.dataset.batch(self._produce_step)
            item = (self._produce_step, batch)
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.2)
                    break
                except queue.Full:
                    continue
            self._produce_step += 1

    def __next__(self) -> dict[str, np.ndarray]:
        step, batch = self._q.get()
        # A restart may have rewound next_step; regenerate if out of sync.
        if step != self.next_step:
            batch = self.dataset.batch(self.next_step)
        self.next_step += 1
        return batch

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return self

    # -- checkpoint interface ---------------------------------------------------

    def state_dict(self) -> dict:
        return {"next_step": self.next_step}

    def load_state_dict(self, state: dict) -> None:
        self.next_step = int(state["next_step"])

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
