from .pipeline import DataConfig, Pipeline, SyntheticLM

__all__ = ["DataConfig", "Pipeline", "SyntheticLM"]
