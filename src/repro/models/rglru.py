"""Griffin / RecurrentGemma recurrent block: causal conv1d + RG-LRU.

RG-LRU recurrence (arXiv:2402.19427):

    r_t = sigmoid(W_a x_t + b_a)            (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)            (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)  (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training runs the scan in parallel via ``jax.lax.associative_scan`` on the
affine pairs (a, b) — the same blocked formulation the Pallas kernel tiles
into VMEM (``repro.kernels.rglru_scan``). Decode is an O(1) state update.

Block structure (Griffin):  x -> [linear_x -> conv1d -> RG-LRU] * gelu(linear_gate) -> linear_out
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .modules import ArraySpec

_C = 8.0


def rglru_spec(width: int) -> dict:
    return {
        "lam": ArraySpec((width,), ("state",), jnp.float32, "normal", 0.8),
        "wa": ArraySpec((width, width), ("state", "state_out")),
        "ba": ArraySpec((width,), ("state",), jnp.float32, "zeros"),
        "wx": ArraySpec((width, width), ("state", "state_out")),
        "bx": ArraySpec((width,), ("state",), jnp.float32, "zeros"),
    }


def recurrent_block_spec(cfg) -> dict:
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    return {
        "in_x": {"w": ArraySpec((d, w), ("embed", "state"))},
        "in_gate": {"w": ArraySpec((d, w), ("embed", "state"))},
        "conv_w": ArraySpec((cfg.conv_width, w), ("conv", "state")),
        "conv_b": ArraySpec((w,), ("state",), jnp.float32, "zeros"),
        "lru": rglru_spec(w),
        "out": {"w": ArraySpec((w, d), ("state", "embed"))},
    }


def _gates(params, x):
    """a_t (log-space) and gated input for the recurrence. x: (B,S,W)."""
    with jax.named_scope("gates"):
        xf = x.astype(jnp.float32)
        r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xf, params["wa"]) + params["ba"])
        i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xf, params["wx"]) + params["bx"])
        log_a = -_C * jax.nn.softplus(params["lam"]) * r  # <= 0
        a = jnp.exp(log_a)
        # sqrt(1-a^2) in a numerically safe form
        beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
        return a, beta * (i * xf)


def rglru(params, x, *, h0=None, scope: str = "rg_lru", impl: str = "xla", chunk: int = 256):
    """Parallel RG-LRU over sequence. x: (B,S,W) -> (B,S,W), final state.

    XLA path: **blocked** scan — ``lax.scan`` over sequence chunks carrying h,
    with an in-chunk ``associative_scan``, body checkpointed. A monolithic
    associative_scan over S=4096 keeps O(S log S) fp32 residuals for the
    backward pass, which the device-plane profiler flagged as the dominant
    memory term of recurrentgemma train_4k (§Perf). This mirrors exactly how
    the Pallas kernel tiles the recurrence into VMEM.
    """
    with jax.named_scope(scope):
        a, b = _gates(params, x)
        if impl in ("pallas", "pallas_interpret"):
            from repro.kernels import ops as kops

            h = kops.rglru_scan(a, b, interpret=(impl == "pallas_interpret"))
            return h.astype(x.dtype), h[:, -1].astype(jnp.float32)
        if h0 is not None:
            b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
        B, S, W = a.shape
        L = min(chunk, S)

        def combine(p, q):
            a1, b1 = p
            a2, b2 = q
            return a1 * a2, a2 * b1 + b2

        if S % L != 0 or S == L:
            with jax.named_scope("assoc_scan"):
                _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
            return h.astype(x.dtype), h[:, -1]

        n = S // L
        ac = jnp.moveaxis(a.reshape(B, n, L, W), 1, 0)
        bc = jnp.moveaxis(b.reshape(B, n, L, W), 1, 0)

        def body(h_in, ab):
            ach, bch = ab  # (B, L, W)
            with jax.named_scope("chunk_assoc_scan"):
                acc_a, acc_b = jax.lax.associative_scan(combine, (ach, bch), axis=1)
            h = acc_a * h_in[:, None] + acc_b  # carry-in folded per position
            return h[:, -1], h

        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable, prevent_cse=False)
        with jax.named_scope("chunk_scan"):
            h_last, hs = jax.lax.scan(body, jnp.zeros((B, W), jnp.float32), (ac, bc))
        h = jnp.moveaxis(hs, 0, 1).reshape(B, S, W)
        return h.astype(x.dtype), h_last


def rglru_step(params, x_t, h_prev):
    """One decode step. x_t: (B,1,W); h_prev: (B,W)."""
    with jax.named_scope("rg_lru"):
        a, b = _gates(params, x_t)
        h = a[:, 0] * h_prev.astype(jnp.float32) + b[:, 0]
        return h[:, None].astype(x_t.dtype), h


def causal_conv1d(params, x, *, scope: str = "conv1d"):
    """Depthwise causal conv, width W_c. x: (B,S,W)."""
    with jax.named_scope(scope):
        w = params["conv_w"].astype(x.dtype)  # (Wc, W)
        Wc = w.shape[0]
        pad = jnp.pad(x, ((0, 0), (Wc - 1, 0), (0, 0)))
        y = sum(pad[:, i : i + x.shape[1]] * w[i] for i in range(Wc))
        return y + params["conv_b"].astype(x.dtype)


def causal_conv1d_step(params, x_t, conv_state):
    """Decode: conv_state holds the last Wc-1 inputs. x_t: (B,1,W)."""
    with jax.named_scope("conv1d"):
        w = params["conv_w"].astype(x_t.dtype)
        Wc = w.shape[0]
        window = jnp.concatenate([conv_state, x_t], axis=1)  # (B, Wc, W)
        y = jnp.einsum("bcw,cw->bw", window, w)[:, None] + params["conv_b"].astype(x_t.dtype)
        return y, window[:, 1:]


def recurrent_block(params, x, cfg, *, scope: str = "recurrent_block"):
    """Full Griffin temporal-mixing block (training/prefill). x: (B,S,D)."""
    with jax.named_scope(scope):
        with jax.named_scope("in_proj"):
            xb = jnp.einsum("bsd,dw->bsw", x, params["in_x"]["w"].astype(x.dtype))
            gb = jnp.einsum("bsd,dw->bsw", x, params["in_gate"]["w"].astype(x.dtype))
        xb = causal_conv1d(params, xb)
        h, _ = rglru(
            params["lru"], xb, chunk=cfg.chunk,
            impl=cfg.attention_impl if cfg.attention_impl != "xla" else "xla",
        )
        with jax.named_scope("gate"):
            y = h * jax.nn.gelu(gb, approximate=True)
        with jax.named_scope("out_proj"):
            return jnp.einsum("bsw,wd->bsd", y, params["out"]["w"].astype(x.dtype))


def init_recurrent_state(cfg, batch: int, dtype=jnp.bfloat16) -> dict:
    w = cfg.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }


def abstract_recurrent_state(cfg, batch: int, dtype=jnp.bfloat16) -> dict:
    w = cfg.lru_width or cfg.d_model
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, w), dtype),
        "h": jax.ShapeDtypeStruct((batch, w), jnp.float32),
    }


def recurrent_block_step(params, x_t, state: dict, cfg, *, scope: str = "recurrent_block"):
    """Decode step: O(1) in sequence length. x_t: (B,1,D)."""
    with jax.named_scope(scope):
        with jax.named_scope("in_proj"):
            xb = jnp.einsum("bsd,dw->bsw", x_t, params["in_x"]["w"].astype(x_t.dtype))
            gb = jnp.einsum("bsd,dw->bsw", x_t, params["in_gate"]["w"].astype(x_t.dtype))
        xb, conv_state = causal_conv1d_step(params, xb, state["conv"])
        h_seq, h = rglru_step(params["lru"], xb, state["h"])
        with jax.named_scope("gate"):
            y = h_seq * jax.nn.gelu(gb, approximate=True)
        with jax.named_scope("out_proj"):
            out = jnp.einsum("bsw,wd->bsd", y, params["out"]["w"].astype(x_t.dtype))
        return out, {"conv": conv_state, "h": h}
