"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable) and
sLSTM (scalar memory, sequential) with exponential gating.

mLSTM cell (per head, head dims dk = dv = d):

    C_t = f_t * C_{t-1} + i_t * v_t k_t^T        (matrix memory)
    n_t = f_t * n_{t-1} + i_t * k_t              (normalizer)
    h_t = (C_t q_t) / max(|n_t . q_t|, 1)

Training uses a **chunkwise-parallel** formulation: within a chunk the
contribution is an attention-like masked product with gate-decay weights; the
chunk boundary state (C, n) carries across chunks via ``lax.scan``. Gate
exponents run in fp32 with log-sigmoid forget gates (log f <= 0) and a
soft cap on the input-gate exponent instead of the paper's running-max
stabilizer — equivalent at smoke scale, simpler to tile (documented in
DESIGN.md). Decode is the O(1) recurrence above.

sLSTM is inherently sequential (h feeds back into the gates), so training
runs ``lax.scan`` over time — the compiled while-loop's trip count is
attributed by the device-plane tree exactly like Ruby's event loop in the
paper.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .modules import ArraySpec, rms_norm, rms_norm_spec

_ICAP = 15.0  # soft cap on input-gate exponent (fp32-safe)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_spec(cfg) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    return {
        "wq": ArraySpec((d, H, hd), ("embed", "q_heads", "head")),
        "wk": ArraySpec((d, H, hd), ("embed", "q_heads", "head")),
        "wv": ArraySpec((d, H, hd), ("embed", "q_heads", "head")),
        "wi": ArraySpec((d, H), ("embed", "q_heads")),
        "wf": ArraySpec((d, H), ("embed", "q_heads")),
        "wo_gate": ArraySpec((d, d), ("embed", "embed_out")),
        "out_norm": rms_norm_spec(d),
        "wo": ArraySpec((d, d), ("embed", "embed_out")),
    }


def _mlstm_gates(params, x):
    xf = x.astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(jnp.einsum("bsd,dh->bsh", xf, params["wf"]) + 1.0)
    log_i = jnp.minimum(jnp.einsum("bsd,dh->bsh", xf, params["wi"]), _ICAP)
    return log_i, log_f


def mlstm(params, x, cfg, *, state=None, scope: str = "mlstm"):
    """Chunkwise-parallel mLSTM. x: (B,S,D) -> (B,S,D), new state."""
    B, S, D = x.shape
    H = cfg.n_heads
    hd = D // H
    L = min(cfg.chunk, S)
    n_chunks = (S + L - 1) // L
    assert S % L == 0, f"seq {S} must be divisible by chunk {L}"
    scale = 1.0 / math.sqrt(hd)
    with jax.named_scope(scope):
        with jax.named_scope("qkv_proj"):
            q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype)) * scale
            k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
            v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
        log_i, log_f = _mlstm_gates(params, x)

        if state is None:
            C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
            n0 = jnp.zeros((B, H, hd), jnp.float32)
        else:
            C0, n0 = state["C"], state["n"]

        # (n_chunks, B, L, ...) for scan
        def chunked(t):
            return jnp.moveaxis(t.reshape(B, n_chunks, L, *t.shape[2:]), 1, 0)

        qc, kc, vc = chunked(q.astype(jnp.float32)), chunked(k.astype(jnp.float32)), chunked(v.astype(jnp.float32))
        lic, lfc = chunked(log_i), chunked(log_f)

        def body(carry, args):
            C, n = carry
            qb, kb, vb, li, lf = args  # (B,L,H,k) / gates (B,L,H)
            cumf = jnp.cumsum(lf, axis=1)  # (B,L,H)
            with jax.named_scope("intra"):
                # w_ij = exp(cumf_i - cumf_j + li_j) for j <= i
                Eij = cumf[:, :, None] - cumf[:, None, :] + li[:, None, :]  # (B,L,L,H)
                mask = jnp.tril(jnp.ones((L, L), bool))
                w = jnp.where(mask[None, :, :, None], jnp.exp(Eij), 0.0)
                s = jnp.einsum("blhk,bmhk->blmh", qb, kb) * w
                num_intra = jnp.einsum("blmh,bmhk->blhk", s, vb)
                den_vec = jnp.einsum("blmh,bmhk->blhk", w, kb)
                den_intra = jnp.einsum("blhk,blhk->blh", qb, den_vec)
            with jax.named_scope("inter"):
                decay = jnp.exp(cumf)  # (B,L,H)
                num_inter = jnp.einsum("blhk,bhkv->blhv", qb, C) * decay[..., None]
                den_inter = jnp.einsum("blhk,bhk->blh", qb, n) * decay
            with jax.named_scope("normalize"):
                den = jnp.abs(den_intra + den_inter)
                h = (num_intra + num_inter) / jnp.maximum(den, 1.0)[..., None]
            with jax.named_scope("state_update"):
                decay_end = jnp.exp(cumf[:, -1])  # (B,H)
                wj = jnp.exp(cumf[:, -1:, :] - cumf + li)  # (B,L,H)
                C_new = decay_end[..., None, None] * C + jnp.einsum("blh,blhk,blhv->bhkv", wj, kb, vb)
                n_new = decay_end[..., None] * n + jnp.einsum("blh,blhk->bhk", wj, kb)
            return (C_new, n_new), h

        # checkpoint: the (B,L,L,H) intra-chunk weights must not be saved per
        # chunk for backward (profiler-identified memory term, §Perf).
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable, prevent_cse=False)
        with jax.named_scope("chunk_scan"):
            (C_f, n_f), h = jax.lax.scan(body, (C0, n0), (qc, kc, vc, lic, lfc))
        h = jnp.moveaxis(h, 0, 1).reshape(B, S, D).astype(x.dtype)
        with jax.named_scope("out"):
            og = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, params["wo_gate"].astype(x.dtype)))
            h = rms_norm(params["out_norm"], h, scope="out_norm") * og
            y = jnp.einsum("bsd,de->bse", h, params["wo"].astype(x.dtype))
        return y, {"C": C_f, "n": n_f}


def mlstm_step(params, x_t, state, cfg, *, scope: str = "mlstm"):
    """O(1) decode step. x_t: (B,1,D)."""
    B, _, D = x_t.shape
    H = cfg.n_heads
    hd = D // H
    scale = 1.0 / math.sqrt(hd)
    with jax.named_scope(scope):
        q = jnp.einsum("bsd,dhk->bshk", x_t, params["wq"].astype(x_t.dtype))[:, 0].astype(jnp.float32) * scale
        k = jnp.einsum("bsd,dhk->bshk", x_t, params["wk"].astype(x_t.dtype))[:, 0].astype(jnp.float32)
        v = jnp.einsum("bsd,dhk->bshk", x_t, params["wv"].astype(x_t.dtype))[:, 0].astype(jnp.float32)
        log_i, log_f = _mlstm_gates(params, x_t)
        i_t, f_t = jnp.exp(log_i[:, 0]), jnp.exp(log_f[:, 0])  # (B,H)
        C = f_t[..., None, None] * state["C"] + i_t[..., None, None] * jnp.einsum("bhk,bhv->bhkv", k, v)
        n = f_t[..., None] * state["n"] + i_t[..., None] * k
        num = jnp.einsum("bhkv,bhk->bhv", C, q)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, q))
        h = (num / jnp.maximum(den, 1.0)[..., None]).reshape(B, 1, D).astype(x_t.dtype)
        og = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x_t, params["wo_gate"].astype(x_t.dtype)))
        h = rms_norm(params["out_norm"], h, scope="out_norm") * og
        y = jnp.einsum("bsd,de->bse", h, params["wo"].astype(x_t.dtype))
        return y, {"C": C, "n": n}


def init_mlstm_state(cfg, batch: int) -> dict:
    hd = cfg.d_model // cfg.n_heads
    return {
        "C": jnp.zeros((batch, cfg.n_heads, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, cfg.n_heads, hd), jnp.float32),
    }


def abstract_mlstm_state(cfg, batch: int) -> dict:
    hd = cfg.d_model // cfg.n_heads
    return {
        "C": jax.ShapeDtypeStruct((batch, cfg.n_heads, hd, hd), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, cfg.n_heads, hd), jnp.float32),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_spec(cfg) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    return {
        # input projections for 4 gates (i, f, z, o)
        "wx": ArraySpec((d, 4, H, hd), ("embed", None, "q_heads", "head")),
        # per-head recurrent (block-diagonal) projections
        "r": ArraySpec((4, H, hd, hd), (None, "q_heads", "head", "head_out"), jnp.float32, "normal", 0.02),
        "b": ArraySpec((4, H, hd), (None, "q_heads", "head"), jnp.float32, "zeros"),
        "out_norm": rms_norm_spec(d),
        "wo": ArraySpec((d, d), ("embed", "embed_out")),
    }


def slstm(params, x, cfg, *, state=None, scope: str = "slstm"):
    """Sequential sLSTM over time (lax.scan). x: (B,S,D)."""
    B, S, D = x.shape
    H = cfg.n_heads
    hd = D // H
    with jax.named_scope(scope):
        with jax.named_scope("in_proj"):
            gx = jnp.einsum("bsd,dghk->bsghk", x.astype(jnp.float32), params["wx"].astype(jnp.float32))
        if state is None:
            state = init_slstm_state_arrays(B, H, hd)
        h0, c0, n0, m0 = state["h"], state["c"], state["n"], state["m"]
        gx_t = jnp.moveaxis(gx, 1, 0)  # (S,B,4,H,hd)

        def step(carry, g_t):
            h, c, n, m = carry
            rec = jnp.einsum("bhk,ghkl->bghl", h, params["r"]) + params["b"]
            gi, gf, gz, go = [(g_t[:, j] + rec[:, j]) for j in range(4)]
            log_f = jax.nn.log_sigmoid(gf)
            m_new = jnp.maximum(log_f + m, jnp.minimum(gi, _ICAP))
            i_p = jnp.exp(jnp.minimum(gi, _ICAP) - m_new)
            f_p = jnp.exp(log_f + m - m_new)
            z = jnp.tanh(gz)
            o = jax.nn.sigmoid(go)
            c_new = f_p * c + i_p * z
            n_new = f_p * n + i_p
            h_new = o * c_new / jnp.maximum(n_new, 1.0)
            return (h_new, c_new, n_new, m_new), h_new

        with jax.named_scope("time_scan"):
            (h_f, c_f, n_f, m_f), hs = jax.lax.scan(step, (h0, c0, n0, m0), gx_t)
        y = jnp.moveaxis(hs, 0, 1).reshape(B, S, D).astype(x.dtype)
        with jax.named_scope("out"):
            y = rms_norm(params["out_norm"], y, scope="out_norm")
            y = jnp.einsum("bsd,de->bse", y, params["wo"].astype(x.dtype))
        return y, {"h": h_f, "c": c_f, "n": n_f, "m": m_f}


def slstm_step(params, x_t, state, cfg, *, scope: str = "slstm"):
    y, new_state = slstm(params, x_t, cfg, state=state, scope=scope)
    return y, new_state


def init_slstm_state_arrays(batch: int, H: int, hd: int) -> dict:
    z = lambda: jnp.zeros((batch, H, hd), jnp.float32)
    return {"h": z(), "c": z(), "n": z(), "m": z()}


def init_slstm_state(cfg, batch: int) -> dict:
    return init_slstm_state_arrays(batch, cfg.n_heads, cfg.d_model // cfg.n_heads)


def abstract_slstm_state(cfg, batch: int) -> dict:
    hd = cfg.d_model // cfg.n_heads
    sh = (batch, cfg.n_heads, hd)
    return {k: jax.ShapeDtypeStruct(sh, jnp.float32) for k in ("h", "c", "n", "m")}
