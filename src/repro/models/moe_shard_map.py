"""Expert-parallel MoE via shard_map + explicit all-to-all (§Perf cell A).

The pjit dense-dispatch MoE (``moe.py``) is correct but GSPMD lowers its
indexed scatter/gather across a model-sharded buffer as *full-tensor
all-reduces* — the device-plane profiler measured 94% of qwen3-moe's
collective bytes there. This implementation is the classic GShard/Switch
layout, written explicitly:

  per data-shard (pure batch parallelism), per model-rank (E_loc experts):
    1. route the local T_loc tokens (router weights replicated — they are
       D x E, trivially small);
    2. scatter tokens into a *local* (E, C_s, D) dispatch buffer
       (C_s = per-source-shard capacity) — no collective;
    3. reshape to (n_model, E_loc, C_s, D) and ``all_to_all`` over the model
       axis — each rank receives exactly the tokens bound for ITS experts:
       moved bytes = T_loc * k * cf * D, the information-theoretic minimum;
    4. run the expert FFN on (E_loc, n_model * C_s, D) with local weights;
    5. reverse all_to_all; gather + weighted scatter-add back to tokens —
       again local.

Same parameters, same routing math, same capacity/dropping semantics as the
dense path (cross-checked by tests on a multi-device CPU mesh).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from .modules import ACTIVATIONS
from .mlp import mlp


def _local_capacity(t_loc: int, cfg) -> int:
    c = int(t_loc * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return max(4, (c + 3) // 4 * 4)


def moe_shard_map(params, x, cfg, *, mesh, data_axes: tuple[str, ...], scope: str = "moe_ep"):
    """x: (B, S, D) batch-sharded over ``data_axes``; experts over 'model'."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    n_model = mesh.shape["model"]
    E_loc = E // n_model
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    T_loc = T // n_data
    C_s = _local_capacity(T_loc, cfg)
    f = ACTIVATIONS[cfg.act]

    def local_moe(xt, router_w, wi, wg, wo):
        # xt: (T_loc, D) f32/bf16; router_w: (D, E); wi/wg: (E_loc, D, F); wo: (E_loc, F, D)
        axis = "model"
        with jax.named_scope("router"):
            logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router_w)
            probs = jax.nn.softmax(logits, axis=-1)
            gate_w, gate_ids = jax.lax.top_k(probs, K)
            gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
        with jax.named_scope("local_dispatch"):
            flat_ids = gate_ids.reshape(-1)
            order = jnp.argsort(flat_ids)
            sorted_ids = flat_ids[order]
            starts = jnp.searchsorted(sorted_ids, jnp.arange(E), side="left")
            rank = jnp.arange(T_loc * K) - starts[sorted_ids]
            valid = rank < C_s
            slot = jnp.where(valid, sorted_ids * C_s + rank, E * C_s)
            token_of_slot = order // K
            buf = jnp.zeros((E * C_s, D), xt.dtype)
            buf = buf.at[slot].add(xt[token_of_slot], mode="drop")
            buf = buf.reshape(n_model, E_loc, C_s, D)
        with jax.named_scope("a2a_dispatch"):
            # send axis-0 block g to model-rank g; receive my experts' tokens.
            # recv[j] = source j's block for MY experts: (n_src, E_loc, C_s, D)
            recv = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=0, tiled=True)
            recv = jnp.moveaxis(recv, 0, 1).reshape(E_loc, n_model * C_s, D)
        with jax.named_scope("experts"):
            h = jnp.einsum("ecd,edf->ecf", recv, wi.astype(xt.dtype))
            g = jnp.einsum("ecd,edf->ecf", recv, wg.astype(xt.dtype))
            y_e = jnp.einsum("ecf,efd->ecd", f(g) * h, wo.astype(xt.dtype))
        with jax.named_scope("a2a_combine"):
            back = jnp.moveaxis(y_e.reshape(E_loc, n_model, C_s, D), 1, 0)
            back = jax.lax.all_to_all(back, axis, split_axis=0, concat_axis=0, tiled=True)
            y_slots = back.reshape(E * C_s, D)
        with jax.named_scope("local_combine"):
            gathered = jnp.where(valid[:, None], y_slots[jnp.clip(slot, 0, E * C_s - 1)], 0.0)
            w_sorted = gate_w.reshape(-1)[order]
            y = jnp.zeros((T_loc, D), xt.dtype).at[token_of_slot].add(
                gathered * w_sorted[:, None].astype(xt.dtype)
            )
        with jax.named_scope("aux_loss"):
            counts = jnp.zeros((E,), jnp.float32).at[flat_ids].add(1.0)
            counts = jax.lax.psum(counts, data_axes)
            frac = counts / (T * K)
            mean_prob = jax.lax.pmean(probs.mean(0), data_axes)
            lb_loss = E * jnp.sum(frac * mean_prob)
            dropped = 1.0 - jax.lax.psum(valid.sum(), data_axes) / (T * K)
        return y, lb_loss, dropped, frac

    with jax.named_scope(scope):
        xt = x.reshape(T, D)
        specs_in = (
            P(data_axes, None),        # xt
            P(),                       # router (replicated)
            P("model", None, None),    # wi
            P("model", None, None),    # wg
            P("model", None, None),    # wo
        )
        specs_out = (P(data_axes, None), P(), P(), P())
        y, lb, dropped, frac = shard_map(
            local_moe,
            mesh=mesh,
            in_specs=specs_in,
            out_specs=specs_out,
            check_rep=False,
        )(xt, params["router"]["w"], params["wi"], params["wg"], params["wo"])
        if cfg.n_shared_experts:
            y = y + mlp(params["shared"], xt, act=cfg.act, scope="shared_experts")
        aux = {"lb_loss": lb, "dropped_frac": dropped, "expert_frac": frac}
        return y.reshape(B, S, D), aux
