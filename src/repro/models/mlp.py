"""Gated MLP (SwiGLU / GeGLU) and plain FFN."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .modules import ACTIVATIONS, ArraySpec


def mlp_spec(d_model: int, d_ff: int, *, gated: bool = True) -> dict:
    spec = {
        "wi": ArraySpec((d_model, d_ff), ("embed", "mlp")),
        "wo": ArraySpec((d_ff, d_model), ("mlp", "embed")),
    }
    if gated:
        spec["wg"] = ArraySpec((d_model, d_ff), ("embed", "mlp"))
    return spec


def mlp(params, x, *, act: str = "silu", scope: str = "mlp"):
    """x: (..., d_model) -> (..., d_model). Gated when 'wg' is present."""
    with jax.named_scope(scope):
        f = ACTIVATIONS[act]
        with jax.named_scope("up_proj"):
            h = jnp.einsum("...d,df->...f", x, params["wi"].astype(x.dtype))
        if "wg" in params:
            with jax.named_scope("gate_proj"):
                g = jnp.einsum("...d,df->...f", x, params["wg"].astype(x.dtype))
            h = f(g) * h
        else:
            h = f(h)
        with jax.named_scope("down_proj"):
            return jnp.einsum("...f,fd->...d", h, params["wo"].astype(x.dtype))
