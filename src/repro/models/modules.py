"""Parameter system + primitive modules.

Models are pure functions over a params pytree (nested dicts of arrays). Each
parameter is declared by an :class:`ArraySpec` carrying **logical axis names**
(``"embed"``, ``"mlp"``, ``"q_heads"``, ``"expert"``, ...). The sharding layer
(``repro.sharding.rules``) maps logical axes onto mesh axes per parallelism
strategy, so re-sharding never touches model code — that is what §Perf
iterates on.

Every module body runs under ``jax.named_scope`` so the compiled HLO carries
the module call-path in ``op_name`` metadata — the device-plane "call-stack"
that ``repro.core.hlo_tree`` attributes cost to.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from collections.abc import Callable
from typing import Any

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArraySpec:
    """Declarative parameter: shape + logical axes + initializer."""

    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    dtype: Any = jnp.float32
    init: str = "normal"  # normal | zeros | ones | embed
    scale: float | None = None  # overrides fan-in scaling

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)

    def initializer(self, key: jax.Array) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "embed":
            s = self.scale if self.scale is not None else 1.0
            return (jax.random.normal(key, self.shape) * s).astype(self.dtype)
        # fan-in scaled normal (truncation unnecessary for smoke-scale runs)
        fan_in = self.shape[0] if len(self.shape) > 1 else max(self.shape[-1], 1)
        if len(self.shape) >= 2:
            fan_in = int(math.prod(self.shape[:-1])) if self.init == "normal_fan_full" else self.shape[0]
        s = self.scale if self.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, self.shape) * s).astype(self.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ArraySpec)


def init_params(spec_tree, key: jax.Array):
    """Materialize concrete parameters from a spec tree (smoke tests/training)."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [leaf.initializer(k) for leaf, k in zip(leaves, keys, strict=True)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(spec_tree):
    """ShapeDtypeStruct stand-ins (dry-run: no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        spec_tree,
        is_leaf=is_spec,
    )


def param_count(spec_tree) -> int:
    return sum(int(math.prod(s.shape)) for s in jax.tree.leaves(spec_tree, is_leaf=is_spec))


def stack_specs(spec_tree, n: int, axis_name: str = "layers"):
    """Stack a per-layer spec ``n`` times along a leading 'layers' axis (scan)."""
    return jax.tree.map(
        lambda s: ArraySpec((n,) + s.shape, (axis_name,) + s.logical, s.dtype, s.init, s.scale),
        spec_tree,
        is_leaf=is_spec,
    )


# ---------------------------------------------------------------------------
# Numerics helpers
# ---------------------------------------------------------------------------


def rms_norm(params, x, *, eps: float = 1e-6, scope: str = "rms_norm"):
    with jax.named_scope(scope):
        xf = x.astype(jnp.float32)
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


def rms_norm_spec(dim: int, logical: str = "embed") -> dict:
    return {"scale": ArraySpec((dim,), (logical,), jnp.float32, "zeros")}


def dense(params, x, spec: str, *, scope: str = "dense"):
    """einsum-based projection; ``spec`` is the einsum equation."""
    with jax.named_scope(scope):
        w = params["w"]
        y = jnp.einsum(spec, x, w.astype(x.dtype))
        if "b" in params:
            y = y + params["b"].astype(x.dtype)
        return y


def dense_spec(
    shape: tuple[int, ...],
    logical: tuple[str | None, ...],
    *,
    bias: bool = False,
    bias_axes: tuple | None = None,
    dtype=jnp.float32,
    scale: float | None = None,
) -> dict:
    out = {"w": ArraySpec(shape, logical, dtype, "normal", scale)}
    if bias:
        bshape = shape[-1:] if bias_axes is None else None
        blog = logical[-1:] if bias_axes is None else bias_axes
        out["b"] = ArraySpec(bshape or shape[-1:], blog, dtype, "zeros")
    return out


ACTIVATIONS: dict[str, Callable] = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
}


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    freqs = rope_freqs(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]  # head axis
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float, sections: tuple[int, int, int] = None) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): the head dim splits into 3 sections rotated
    by (temporal, height, width) position streams. positions: (..., S, 3)."""
    d2 = x.shape[-1] // 2
    if sections is None:
        t = d2 - 2 * (d2 // 4)
        sections = (t, d2 // 4, d2 // 4)
    freqs = rope_freqs(x.shape[-1], theta)  # (d2,)
    parts = []
    start = 0
    for i, sec in enumerate(sections):
        pos_i = positions[..., i]  # (..., S)
        ang = pos_i[..., None].astype(jnp.float32) * freqs[start : start + sec]
        parts.append(ang)
        start += sec
    angles = jnp.concatenate(parts, axis=-1)[..., None, :]  # (..., S, 1, d2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embedding_spec(vocab: int, d_model: int) -> dict:
    return {"table": ArraySpec((vocab, d_model), ("vocab", "embed"), jnp.float32, "embed", 0.02)}


def embed(params, tokens, *, scope: str = "embed"):
    with jax.named_scope(scope):
        return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x, *, scope: str = "lm_head"):
    with jax.named_scope(scope):
        return jnp.einsum("...d,vd->...v", x, params["table"].astype(x.dtype))


def lm_head_spec(vocab: int, d_model: int) -> dict:
    return {"w": ArraySpec((d_model, vocab), ("embed", "vocab"), jnp.float32, "normal")}


def lm_head(params, x, *, scope: str = "lm_head"):
    with jax.named_scope(scope):
        return jnp.einsum("...d,dv->...v", x, params["w"].astype(x.dtype))
