"""Top-level Model API: spec/init/forward/loss/decode/input-specs.

``Model`` is the single entry point the launcher, dry-run, trainer, server,
benchmarks and tests all share. The forward pass runs entirely under
``jax.named_scope`` tags, giving the device-plane profiler a stable component
vocabulary across all ten architectures.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.sharding.ctx import shard_activation

from . import transformer as tfm
from .modules import (
    ArraySpec,
    abstract_params,
    embed,
    embedding_spec,
    init_params,
    is_spec,
    lm_head,
    lm_head_spec,
    param_count,
    rms_norm,
    rms_norm_spec,
    unembed,
)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- parameters -----------------------------------------------------------

    def spec(self) -> dict:
        cfg = self.cfg
        spec: dict[str, Any] = {}
        if cfg.input_mode == "tokens":
            spec["embed"] = embedding_spec(cfg.vocab, cfg.d_model)
        else:
            # Modality frontend is a STUB: inputs arrive as precomputed
            # frame/patch embeddings (assignment note for [audio]/[vlm]).
            spec["embed_proj"] = {"w": ArraySpec((cfg.d_model, cfg.d_model), ("embed", "embed_out"))}
        spec["layers"] = tfm.stack_spec(cfg)
        spec["final_norm"] = rms_norm_spec(cfg.d_model)
        if not cfg.tied_embeddings:
            spec["lm_head"] = lm_head_spec(cfg.vocab, cfg.d_model)
        return spec

    def init(self, key: jax.Array) -> dict:
        return init_params(self.spec(), key)

    def abstract_params(self) -> dict:
        return abstract_params(self.spec())

    @property
    def n_params(self) -> int:
        return param_count(self.spec())

    @property
    def n_active_params(self) -> int:
        """Per-token active parameters (MoE: routed experts count k/E)."""
        cfg = self.cfg
        total = self.n_params
        if not cfg.n_experts:
            return total
        spec = self.spec()
        routed = 0
        def count_routed(path, s):
            nonlocal routed
            if "moe" in path and any(ax == "expert" for ax in s.logical) and "router" not in path:
                routed += int(math.prod(s.shape))
        _walk_spec(spec, (), count_routed)
        active_routed = routed * cfg.top_k / cfg.n_experts
        return int(total - routed + active_routed)

    # -- forward / loss ------------------------------------------------------------

    def _trunk(self, params, batch) -> tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        if cfg.input_mode == "tokens":
            x = embed(params["embed"], batch["tokens"]).astype(jnp.bfloat16)
        else:
            x = jnp.einsum(
                "bsd,de->bse", batch["embeds"].astype(jnp.bfloat16),
                params["embed_proj"]["w"].astype(jnp.bfloat16),
            )
        if cfg.input_mode == "tokens" and not cfg.tied_embeddings:
            pass
        if cfg.tied_embeddings:
            x = x * math.sqrt(cfg.d_model)  # gemma convention
        x = shard_activation(x, ("batch", None, None))
        positions = batch.get("positions")
        if positions is None:
            S = x.shape[1]
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), x.shape[:2])
            if cfg.mrope:
                positions = jnp.broadcast_to(positions[..., None], (*x.shape[:2], 3))
        x, lb = tfm.stack_apply(params["layers"], x, cfg, positions)
        x = rms_norm(params["final_norm"], x, scope="final_norm")
        return x, lb

    def logits_fn(self, params, x):
        cfg = self.cfg
        if cfg.tied_embeddings:
            logits = unembed(params["embed"], x)
        else:
            logits = lm_head(params["lm_head"], x)
        if cfg.logit_softcap:
            logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
        return logits

    def forward(self, params, batch) -> tuple[jax.Array, jax.Array]:
        """-> (logits (B,S,V), moe load-balance loss)."""
        with jax.named_scope("model"):
            x, lb = self._trunk(params, batch)
            return self.logits_fn(params, x), lb

    def loss(self, params, batch) -> tuple[jax.Array, dict]:
        """Causal-LM cross entropy (+ z-loss + MoE aux)."""
        with jax.named_scope("loss"):
            logits, lb = self.forward(params, batch)
            labels = batch["labels"]
            mask = batch.get("loss_mask")
            lsm = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            nll = -jnp.take_along_axis(lsm, labels[..., None], axis=-1)[..., 0]
            if mask is None:
                mask = jnp.ones_like(nll)
            denom = jnp.maximum(mask.sum(), 1.0)
            ce = (nll * mask).sum() / denom
            zl = 1e-4 * ((jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1) ** 2) * mask).sum() / denom
            total = ce + zl + 1e-2 * lb
            return total, {"ce": ce, "z_loss": zl, "lb_loss": lb}

    # -- decode -----------------------------------------------------------------------

    def init_decode_state(self, batch: int, max_len: int) -> dict:
        return tfm.stack_state(self.cfg, batch, max_len, abstract=False)

    def abstract_decode_state(self, batch: int, max_len: int) -> dict:
        return tfm.stack_state(self.cfg, batch, max_len, abstract=True)

    def decode_step(self, params, batch, state: dict, pos) -> tuple[jax.Array, dict]:
        """One new token for every sequence. batch: {'tokens': (B,1)} or
        {'embeds': (B,1,D)}; pos: () int32. -> (logits (B,V), new state)."""
        cfg = self.cfg
        with jax.named_scope("decode"):
            if cfg.input_mode == "tokens":
                x = embed(params["embed"], batch["tokens"]).astype(jnp.bfloat16)
            else:
                x = jnp.einsum(
                    "bsd,de->bse", batch["embeds"].astype(jnp.bfloat16),
                    params["embed_proj"]["w"].astype(jnp.bfloat16),
                )
            if cfg.tied_embeddings:
                x = x * math.sqrt(cfg.d_model)
            x, new_state = tfm.stack_decode(params["layers"], x, state, pos, cfg)
            x = rms_norm(params["final_norm"], x, scope="final_norm")
            logits = self.logits_fn(params, x)
            return logits[:, 0], new_state

    # -- dry-run input specs ------------------------------------------------------------

    def input_specs(self, shape: ShapeSpec) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of this workload."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        f32, i32, bf16 = jnp.float32, jnp.int32, jnp.bfloat16
        if shape.kind in ("train", "prefill"):
            batch: dict[str, Any] = {}
            if cfg.input_mode == "tokens":
                batch["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
            else:
                batch["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), bf16)
            if cfg.mrope:
                batch["positions"] = jax.ShapeDtypeStruct((B, S, 3), i32)
            if shape.kind == "train":
                batch["labels"] = jax.ShapeDtypeStruct((B, S), i32)
                batch["loss_mask"] = jax.ShapeDtypeStruct((B, S), f32)
            return batch
        # decode: one new token against a state of length S
        batch = {}
        if cfg.input_mode == "tokens":
            batch["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
        else:
            batch["embeds"] = jax.ShapeDtypeStruct((B, 1, cfg.d_model), bf16)
        return batch

    # -- cost model ---------------------------------------------------------------------

    def model_flops(self, shape: ShapeSpec) -> float:
        """MODEL_FLOPS napkin: 6*N*D (dense) / 6*N_active*D (MoE); decode uses
        D = new tokens (global_batch) and 2*N_active (no backward)."""
        n = self.n_active_params
        if shape.kind == "train":
            return 6.0 * n * shape.tokens
        if shape.kind == "prefill":
            return 2.0 * n * shape.tokens
        return 2.0 * n * shape.global_batch


def _walk_spec(tree, path, fn):
    if is_spec(tree):
        fn(path, tree)
        return
    if isinstance(tree, dict):
        for k, v in tree.items():
            _walk_spec(v, path + (k,), fn)
