"""Attention: GQA/MQA with RoPE/M-RoPE, qk-norm, sliding windows, KV cache.

Three execution paths, selected by ``cfg.attention_impl``:

* ``xla``              — pure-jnp math (reference; what the dry-run lowers,
                         since TPU Pallas cannot be compiled by the CPU backend);
* ``pallas``           — Pallas flash kernel (TPU target);
* ``pallas_interpret`` — same kernel, interpret mode (CPU correctness tests).

The xla path switches to a **chunked** (online-softmax over query blocks)
variant above ``cfg.chunk_threshold`` so 32k-token prefill never materializes
the full S×S score matrix — same math as the flash kernel, scan-based.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.sharding.ctx import shard_activation

from .modules import ArraySpec, apply_mrope, apply_rope, rms_norm, rms_norm_spec

NEG_INF = -2.0e38


def attention_spec(cfg) -> dict:
    hd = cfg.head_dim
    spec = {
        "wq": ArraySpec((cfg.d_model, cfg.n_heads, hd), ("embed", "q_heads", "head")),
        "wk": ArraySpec((cfg.d_model, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head")),
        "wv": ArraySpec((cfg.d_model, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head")),
        "wo": ArraySpec((cfg.n_heads, hd, cfg.d_model), ("q_heads", "head", "embed")),
    }
    if cfg.qk_norm:
        spec["q_norm"] = rms_norm_spec(hd, "head")
        spec["k_norm"] = rms_norm_spec(hd, "head")
    return spec


def _project_qkv(params, x, cfg, positions):
    with jax.named_scope("qkv_proj"):
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
        k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_norm(params["q_norm"], q, scope="q_norm")
        k = rms_norm(params["k_norm"], k, scope="k_norm")
    with jax.named_scope("rope"):
        if cfg.mrope:
            q = apply_mrope(q, positions, cfg.rope_theta)
            k = apply_mrope(k, positions, cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask(q_idx, k_idx, window: int | None):
    m = k_idx[None, :] <= q_idx[:, None]
    if window is not None:
        m &= (q_idx[:, None] - k_idx[None, :]) < window
    return m


def _attend_full(q, k, v, cfg, *, q_offset: int = 0, window: int | None = None):
    """q: (B,S,Hq,D); k,v: (B,T,Hkv,D). Materializes (B,Hkv,G,S,T)."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    scale = 1.0 / math.sqrt(D)
    with jax.named_scope("scores"):
        s = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
        mask = _mask(jnp.arange(S) + q_offset, jnp.arange(T), window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    with jax.named_scope("pv"):
        o = jnp.einsum("bkgst,btkd->bskgd", p, v)
    return o.reshape(B, S, Hq, D)


def _attend_chunked(q, k, v, cfg, *, window: int | None = None):
    """Online-softmax over query chunks: memory O(chunk * T), same math as
    the flash kernel (the Pallas kernel additionally tiles T into VMEM).

    The chunk body is ``jax.checkpoint``-ed: without it, differentiating the
    scan saves every chunk's (Bq, T) score/probability residuals — i.e. the
    full S x T matrix again — which the device-plane profiler exposed as the
    dominant train_4k memory term (§Perf iteration 1)."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    C = min(cfg.chunk, S)
    n_chunks = (S + C - 1) // C
    pad = n_chunks * C - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qg = q.reshape(B, n_chunks, C, Hkv, G, D)
    qg = jnp.moveaxis(qg, 1, 0)  # (n_chunks, B, C, Hkv, G, D)
    scale = 1.0 / math.sqrt(D)
    k_idx = jnp.arange(T)

    # Sliding-window: each q-chunk only attends to the last `window` keys, so
    # slice a (window + C)-long KV strip per chunk instead of streaming all T
    # keys — 32k-prefill score traffic drops by T/(window+C) (§Perf cell C).
    use_strip = window is not None and (window + C) < T
    Lk = min(window + C, T) if window is not None else T

    def body(_, args):
        i, qc = args
        if getattr(cfg, "attn_cp", False):
            # Context parallelism: when heads don't divide the TP axis the
            # attention math replicates across 'model'; sharding the q-chunk
            # rows instead splits score/pv compute 16-ways (§Perf cell B).
            qc = shard_activation(qc, (None, "ctx_chunk", None, None, None))
        if use_strip:
            kstart = jnp.clip(i * C + C - Lk, 0, T - Lk)
            kc = jax.lax.dynamic_slice(k, (0, kstart, 0, 0), (k.shape[0], Lk, Hkv, D))
            vc = jax.lax.dynamic_slice(v, (0, kstart, 0, 0), (v.shape[0], Lk, Hkv, D))
            kidx = kstart + jnp.arange(Lk)
        else:
            kc, vc, kidx = k, v, k_idx
        with jax.named_scope("chunk_scores"):
            s = jnp.einsum("bckgd,btkd->bkgct", qc, kc).astype(jnp.float32) * scale
            q_idx = i * C + jnp.arange(C)
            m = kidx[None, :] <= q_idx[:, None]
            if window is not None:
                m &= (q_idx[:, None] - kidx[None, :]) < window
            s = jnp.where(m[None, None, None], s, NEG_INF)
            p = jax.nn.softmax(s, axis=-1).astype(qc.dtype)
        with jax.named_scope("chunk_pv"):
            o = jnp.einsum("bkgct,btkd->bckgd", p, vc)
        return None, o

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable, prevent_cse=False)
    with jax.named_scope("q_chunk_scan"):
        _, o = jax.lax.scan(body, None, (jnp.arange(n_chunks), qg))
    o = jnp.moveaxis(o, 0, 1).reshape(B, n_chunks * C, Hkv, G, D)
    if pad:
        o = o[:, :S]
    return o.reshape(B, S, Hq, D)


def attention(params, x, cfg, positions, *, window: int | None = None, scope: str = "attention"):
    """Training/prefill self-attention. x: (B,S,D) -> (B,S,D)."""
    with jax.named_scope(scope):
        q, k, v = _project_qkv(params, x, cfg, positions)
        impl = cfg.attention_impl
        S = x.shape[1]
        if impl in ("pallas", "pallas_interpret"):
            from repro.kernels import ops as kops

            o = kops.flash_attention(
                q, k, v, causal=True, window=window, interpret=(impl == "pallas_interpret")
            )
        elif S > cfg.chunk_threshold:
            o = _attend_chunked(q, k, v, cfg, window=window)
        else:
            o = _attend_full(q, k, v, cfg, window=window)
        with jax.named_scope("out_proj"):
            return jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Decode path (one new token against a KV cache)
# ---------------------------------------------------------------------------


def init_kv_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    # Hybrid archs only cache their attention window (sub-quadratic decode).
    L = min(max_len, cfg.window) if cfg.window else max_len
    shape = (batch, L, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def abstract_kv_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    L = min(max_len, cfg.window) if cfg.window else max_len
    shape = (batch, L, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jax.ShapeDtypeStruct(shape, dtype),
        "v": jax.ShapeDtypeStruct(shape, dtype),
    }


def decode_attention(params, x, cache: dict, pos, cfg, *, window: int | None = None, scope: str = "attention"):
    """One-token decode. x: (B,1,D); pos: () int32 current position.

    Returns (y, new_cache). The cache ring-buffers over the window for
    windowed (hybrid) attention; for full attention it is max_len long.
    """
    with jax.named_scope(scope):
        B = x.shape[0]
        L = cache["k"].shape[1]
        positions = jnp.full((B, 1), pos, jnp.int32) if not cfg.mrope else jnp.full((B, 1, 3), pos, jnp.int32)
        q, k_new, v_new = _project_qkv(params, x, cfg, positions)
        slot = jnp.mod(pos, L) if window else jnp.minimum(pos, L - 1)
        with jax.named_scope("cache_update"):
            k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
            v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
        Hq, D = q.shape[2], q.shape[3]
        Hkv = k.shape[2]
        G = Hq // Hkv
        qg = q.reshape(B, Hkv, G, D)
        with jax.named_scope("scores"):
            s = jnp.einsum("bkgd,btkd->bkgt", qg, k.astype(q.dtype)).astype(jnp.float32)
            s *= 1.0 / math.sqrt(D)
            t_idx = jnp.arange(L)
            if window:
                # Ring buffer: valid slots are the last `window` positions.
                age = jnp.mod(pos - t_idx, L)
                valid = (age >= 0) & (age < jnp.minimum(pos + 1, L))
            else:
                valid = t_idx <= pos
            s = jnp.where(valid[None, None, None], s, NEG_INF)
            p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        with jax.named_scope("pv"):
            o = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(q.dtype)).reshape(B, 1, Hq, D)
        with jax.named_scope("out_proj"):
            y = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))
        return y, {"k": k, "v": v}
