from .model import Model
from .modules import ArraySpec, abstract_params, init_params, param_count

__all__ = ["Model", "ArraySpec", "abstract_params", "init_params", "param_count"]
