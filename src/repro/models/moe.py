"""Mixture-of-Experts: shared + routed experts, top-k, sort-based dispatch.

DeepSeekMoE-style fine-grained experts: ``n_shared`` always-on experts plus
``n_experts`` routed experts with top-k gating (softmax -> top-k -> renorm).

Dispatch is **sort-based with static capacity** (TPU-friendly: all shapes
static, no ragged ops):

1. flatten tokens, route, take top-k -> (T*k) slots tagged with expert ids;
2. ``argsort`` slots by expert id; rank-within-expert = position - first
   occurrence of that expert in the sorted order (O(T*k log) total);
3. slots with rank >= capacity are *dropped* (capacity_factor controls how
   many); survivors scatter into a dense (E, C, D) buffer;
4. one batched einsum per projection runs all experts: (E,C,D)x(E,D,F) —
   this is the tensor the **EP** sharding rule shards over the 'model' axis;
5. results scale by router weights and segment-add back to tokens.

The (E,C,D) buffer is annotated with a sharding constraint so GSPMD places
the token->expert exchange (all-to-all / gather) explicitly — visible in the
device-plane tree and a first-class §Perf hillclimb target.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.ctx import shard_activation

from .modules import ACTIVATIONS, ArraySpec
from .mlp import mlp, mlp_spec


def moe_spec(cfg) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    spec = {
        "router": {"w": ArraySpec((d, e), ("embed", "expert"), jnp.float32)},
        "wi": ArraySpec((e, d, f), ("expert", "embed", "mlp")),
        "wg": ArraySpec((e, d, f), ("expert", "embed", "mlp")),
        "wo": ArraySpec((e, f, d), ("expert", "mlp", "embed")),
    }
    if cfg.n_shared_experts:
        spec["shared"] = mlp_spec(d, cfg.n_shared_experts * cfg.moe_d_ff)
    return spec


def _capacity(n_tokens: int, cfg) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    # round up to a multiple of 8 for lane-friendly layouts
    return max(8, (c + 7) // 8 * 8)


def moe(params, x, cfg, *, ep_constraint=None, scope: str = "moe"):
    """x: (B, S, D) -> (B, S, D), aux dict with load-balance stats/loss.

    ``ep_constraint`` (optional callable) applies a sharding constraint to the
    (E, C, D) expert buffers — installed by the sharding layer.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    C = _capacity(T, cfg)
    f = ACTIVATIONS[cfg.act]
    with jax.named_scope(scope):
        xt = x.reshape(T, D)
        with jax.named_scope("router"):
            logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"]["w"])
            probs = jax.nn.softmax(logits, axis=-1)
            with jax.named_scope("top_k"):
                gate_w, gate_ids = jax.lax.top_k(probs, K)  # (T,K)
                gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)  # renorm over selected
        with jax.named_scope("dispatch"):
            flat_ids = gate_ids.reshape(-1)  # (T*K,)
            order = jnp.argsort(flat_ids)  # stable
            sorted_ids = flat_ids[order]
            starts = jnp.searchsorted(sorted_ids, jnp.arange(E), side="left")
            rank = jnp.arange(T * K) - starts[sorted_ids]
            valid = rank < C
            slot = jnp.where(valid, sorted_ids * C + rank, E * C)  # E*C == drop bucket
            token_of_slot = order // K
            # Keep the (T*K, D) slot tensor sharded over the data axis: without
            # this constraint GSPMD replicates the gather output per device
            # (profiler-identified memory term on qwen3-moe train, §Perf A.3).
            slot_vals = shard_activation(xt[token_of_slot], ("batch", None))
            buf = jnp.zeros((E * C, D), x.dtype)
            buf = buf.at[slot].add(slot_vals, mode="drop")
            buf = buf.reshape(E, C, D)
            # EP: pin the expert buffer to the expert-parallel axis so the
            # token->expert exchange is an explicit collective at this seam.
            buf = shard_activation(buf, ("expert_buf", None, None))
            if ep_constraint is not None:
                buf = ep_constraint(buf)
        with jax.named_scope("experts"):
            h = jnp.einsum("ecd,edf->ecf", buf, params["wi"].astype(x.dtype))
            g = jnp.einsum("ecd,edf->ecf", buf, params["wg"].astype(x.dtype))
            h = f(g) * h
            y_e = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(x.dtype))
            y_e = shard_activation(y_e, ("expert_buf", None, None))
            if ep_constraint is not None:
                y_e = ep_constraint(y_e)
        with jax.named_scope("combine"):
            y_slots = y_e.reshape(E * C, D)
            gathered = jnp.where(valid[:, None], y_slots[jnp.clip(slot, 0, E * C - 1)], 0.0)
            gathered = shard_activation(gathered, ("batch", None))
            w_sorted = gate_w.reshape(-1)[order]
            contrib = gathered * w_sorted[:, None].astype(x.dtype)
            y = jnp.zeros((T, D), x.dtype).at[token_of_slot].add(contrib)
            y = shard_activation(y, ("batch", None))
        if cfg.n_shared_experts:
            y = y + mlp(params["shared"], xt, act=cfg.act, scope="shared_experts")
        with jax.named_scope("aux_loss"):
            # Switch-style load balancing: E * sum_e fraction_e * prob_e
            counts = jnp.zeros((E,), jnp.float32).at[flat_ids].add(1.0)
            frac = counts / (T * K)
            mean_prob = probs.mean(0)
            lb_loss = E * jnp.sum(frac * mean_prob)
            dropped = 1.0 - valid.sum() / (T * K)
        aux = {"lb_loss": lb_loss, "dropped_frac": dropped, "expert_frac": frac}
        return y.reshape(B, S, D), aux
