"""Block assembly: pattern-cycled layers under ``lax.scan`` + decode path.

Layer stacks run as ``lax.scan`` over **pattern units** so heterogeneous
architectures (Griffin's rec,rec,attn; xLSTM's slstm,mlstm,... cycles) stay
scan-compatible: one unit = one full pattern repetition, its parameters
stacked along a leading 'layers' axis. Layers that do not fit whole units
(``first_dense`` prefix layers, pattern remainders) are applied unrolled.

Remat policy applies to the scan body (one unit), the standard
compile-time/memory trade at 90+ layers.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import rglru as rec_mod
from . import xlstm as xlstm_mod
from .mlp import mlp, mlp_spec
from .moe import moe, moe_spec
from .modules import rms_norm, rms_norm_spec, stack_specs
from repro.sharding.ctx import shard_activation


# ---------------------------------------------------------------------------
# Per-layer spec / apply
# ---------------------------------------------------------------------------


def _ffn_kind(cfg, layer_idx: int) -> str:
    if layer_idx < cfg.first_dense:
        return "dense_mlp"
    if cfg.n_experts:
        return "moe"
    if cfg.d_ff == 0:
        return "none"
    return "mlp"


def layer_kind(cfg, layer_idx: int) -> str:
    return cfg.pattern[layer_idx % len(cfg.pattern)]


def block_spec(cfg, kind: str, ffn: str) -> dict:
    d = cfg.d_model
    spec: dict[str, Any] = {"norm1": rms_norm_spec(d)}
    if kind == "attn":
        spec["attn"] = attn_mod.attention_spec(cfg)
    elif kind == "rec":
        spec["rec"] = rec_mod.recurrent_block_spec(cfg)
    elif kind == "slstm":
        spec["slstm"] = xlstm_mod.slstm_spec(cfg)
    elif kind == "mlstm":
        spec["mlstm"] = xlstm_mod.mlstm_spec(cfg)
    else:
        raise ValueError(f"unknown layer kind {kind}")
    if ffn == "mlp":
        spec["norm2"] = rms_norm_spec(d)
        spec["mlp"] = mlp_spec(d, cfg.d_ff)
    elif ffn == "dense_mlp":
        spec["norm2"] = rms_norm_spec(d)
        spec["mlp"] = mlp_spec(d, cfg.dense_d_ff or 4 * d)
    elif ffn == "moe":
        spec["norm2"] = rms_norm_spec(d)
        spec["moe"] = moe_spec(cfg)
    return spec


def block_apply(params, x, cfg, kind: str, ffn: str, positions, *, scope: str):
    """One residual block (training/prefill). Returns (x, lb_loss)."""
    lb = jnp.zeros((), jnp.float32)
    with jax.named_scope(scope):
        h = rms_norm(params["norm1"], x, scope="pre_norm")
        if kind == "attn":
            y = attn_mod.attention(params["attn"], h, cfg, positions, window=cfg.window)
        elif kind == "rec":
            y = rec_mod.recurrent_block(params["rec"], h, cfg)
        elif kind == "slstm":
            y, _ = xlstm_mod.slstm(params["slstm"], h, cfg)
        elif kind == "mlstm":
            y, _ = xlstm_mod.mlstm(params["mlstm"], h, cfg)
        else:
            raise ValueError(kind)
        x = x + y
        x = shard_activation(x, ("batch", None, None))
        if ffn in ("mlp", "dense_mlp"):
            h2 = rms_norm(params["norm2"], x, scope="pre_mlp_norm")
            x = x + mlp(params["mlp"], h2, act=cfg.act)
        elif ffn == "moe":
            h2 = rms_norm(params["norm2"], x, scope="pre_moe_norm")
            y2, aux = _apply_moe(params["moe"], h2, cfg)
            x = x + y2
            lb = aux["lb_loss"]
        x = shard_activation(x, ("batch", None, None))
    return x, lb


def _apply_moe(params, h, cfg):
    """Dense-dispatch (pjit) or explicit shard_map EP, per cfg.moe_impl."""
    if cfg.moe_impl == "shard_map":
        from repro.sharding.ctx import current_sharding_ctx

        mesh, rules = current_sharding_ctx()
        if mesh is not None and "model" in mesh.shape and cfg.n_experts % mesh.shape["model"] == 0:
            from .moe_shard_map import moe_shard_map

            batch = rules.get("batch", ("data",))
            data_axes = (batch,) if isinstance(batch, str) else tuple(batch)
            return moe_shard_map(params, h, cfg, mesh=mesh, data_axes=data_axes)
    return moe(params, h, cfg)


def block_decode(params, x, state, pos, cfg, kind: str, ffn: str, *, scope: str):
    """One residual block, single-token decode. Returns (x, new_state)."""
    with jax.named_scope(scope):
        h = rms_norm(params["norm1"], x, scope="pre_norm")
        if kind == "attn":
            y, new_state = attn_mod.decode_attention(params["attn"], h, state, pos, cfg, window=cfg.window)
        elif kind == "rec":
            y, new_state = rec_mod.recurrent_block_step(params["rec"], h, state, cfg)
        elif kind == "slstm":
            y, new_state = xlstm_mod.slstm_step(params["slstm"], h, state, cfg)
        elif kind == "mlstm":
            y, new_state = xlstm_mod.mlstm_step(params["mlstm"], h, state, cfg)
        else:
            raise ValueError(kind)
        x = x + y
        if ffn in ("mlp", "dense_mlp"):
            h2 = rms_norm(params["norm2"], x, scope="pre_mlp_norm")
            x = x + mlp(params["mlp"], h2, act=cfg.act)
        elif ffn == "moe":
            h2 = rms_norm(params["norm2"], x, scope="pre_moe_norm")
            y2, _ = moe(params["moe"], h2, cfg)
            x = x + y2
    return x, new_state


def layer_state_init(cfg, kind: str, batch: int, max_len: int, abstract: bool = False):
    if kind == "attn":
        fn = attn_mod.abstract_kv_cache if abstract else attn_mod.init_kv_cache
        return fn(cfg, batch, max_len)
    if kind == "rec":
        fn = rec_mod.abstract_recurrent_state if abstract else rec_mod.init_recurrent_state
        return fn(cfg, batch)
    if kind == "mlstm":
        fn = xlstm_mod.abstract_mlstm_state if abstract else xlstm_mod.init_mlstm_state
        return fn(cfg, batch)
    if kind == "slstm":
        fn = xlstm_mod.abstract_slstm_state if abstract else xlstm_mod.init_slstm_state
        return fn(cfg, batch)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Stack layout: prefix (unrolled) + scan units + remainder (unrolled)
# ---------------------------------------------------------------------------


class StackLayout:
    """Partition of n_layers into [prefix | n_units x pattern | remainder]."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.prefix = list(range(cfg.first_dense))
        body = cfg.n_layers - cfg.first_dense
        p = len(cfg.pattern)
        self.n_units = body // p
        self.unit_kinds = tuple(cfg.pattern)
        rem = body % p
        self.remainder = [cfg.first_dense + self.n_units * p + i for i in range(rem)]
        self.rem_kinds = tuple(cfg.pattern[i] for i in range(rem))

    def describe(self) -> str:
        return (
            f"prefix={len(self.prefix)} scan={self.n_units}x{self.unit_kinds} "
            f"remainder={self.rem_kinds}"
        )


def stack_spec(cfg) -> dict:
    lay = StackLayout(cfg)
    spec: dict[str, Any] = {}
    if lay.prefix:
        spec["prefix"] = {
            f"layer{i}": block_spec(cfg, layer_kind(cfg, i), _ffn_kind(cfg, i)) for i in lay.prefix
        }
    if lay.n_units:
        unit = {
            f"block{j}": block_spec(cfg, k, _ffn_kind(cfg, cfg.first_dense + j))
            for j, k in enumerate(lay.unit_kinds)
        }
        spec["scan"] = stack_specs(unit, lay.n_units)
    if lay.remainder:
        spec["remainder"] = {
            f"layer{i}": block_spec(cfg, layer_kind(cfg, i), _ffn_kind(cfg, i)) for i in lay.remainder
        }
    return spec


def stack_apply(params, x, cfg, positions):
    """Full layer stack forward. Returns (x, total_lb_loss)."""
    lay = StackLayout(cfg)
    lb_total = jnp.zeros((), jnp.float32)
    for i in lay.prefix:
        x, lb = block_apply(
            params["prefix"][f"layer{i}"], x, cfg, layer_kind(cfg, i), _ffn_kind(cfg, i),
            positions, scope=f"layer{i}",
        )
        lb_total += lb

    if lay.n_units:
        def unit_body(carry, unit_params):
            h, lb_acc = carry
            for j, kind in enumerate(lay.unit_kinds):
                with jax.named_scope(f"unit_block{j}_{kind}"):
                    h, lb = block_apply(
                        unit_params[f"block{j}"], h, cfg, kind,
                        _ffn_kind(cfg, cfg.first_dense + j), positions, scope=f"block{j}",
                    )
                    lb_acc += lb
            return (h, lb_acc), None

        body = unit_body
        if cfg.remat != "none":
            policy = {
                "full": jax.checkpoint_policies.nothing_saveable,
                "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            }[cfg.remat]
            body = jax.checkpoint(unit_body, policy=policy, prevent_cse=False)
        # Cast matrix weights to bf16 BEFORE the scan: FSDP all-gathers inside
        # the loop then move bf16, not fp32 — halves weight traffic (§Perf A).
        scan_params = jax.tree.map(
            lambda a: a.astype(jnp.bfloat16) if (a.dtype == jnp.float32 and a.ndim >= 3) else a,
            params["scan"],
        )
        with jax.named_scope("layers"):
            (x, lb_total), _ = jax.lax.scan(body, (x, lb_total), scan_params)

    for i in lay.remainder:
        x, lb = block_apply(
            params["remainder"][f"layer{i}"], x, cfg, layer_kind(cfg, i), _ffn_kind(cfg, i),
            positions, scope=f"layer{i}",
        )
        lb_total += lb
    return x, lb_total


def stack_decode(params, x, states, pos, cfg):
    """Single-token decode through the stack. Returns (x, new_states)."""
    lay = StackLayout(cfg)
    new_states: dict[str, Any] = {}
    if lay.prefix:
        new_states["prefix"] = {}
        for i in lay.prefix:
            key = f"layer{i}"
            x, s = block_decode(
                params["prefix"][key], x, states["prefix"][key], pos, cfg,
                layer_kind(cfg, i), _ffn_kind(cfg, i), scope=key,
            )
            new_states["prefix"][key] = s

    if lay.n_units:
        def unit_body(h, scan_in):
            unit_params, unit_state = scan_in
            out_states = {}
            for j, kind in enumerate(lay.unit_kinds):
                key = f"block{j}"
                with jax.named_scope(f"unit_block{j}_{kind}"):
                    h, s = block_decode(
                        unit_params[key], h, unit_state[key], pos, cfg, kind,
                        _ffn_kind(cfg, cfg.first_dense + j), scope=key,
                    )
                out_states[key] = s
            return h, out_states

        # identical bf16 weight cast as stack_apply (prefill/decode consistency)
        scan_params = jax.tree.map(
            lambda a: a.astype(jnp.bfloat16) if (a.dtype == jnp.float32 and a.ndim >= 3) else a,
            params["scan"],
        )
        with jax.named_scope("layers"):
            x, scan_states = jax.lax.scan(unit_body, x, (scan_params, states["scan"]))
        new_states["scan"] = scan_states

    if lay.remainder:
        new_states["remainder"] = {}
        for i in lay.remainder:
            key = f"layer{i}"
            x, s = block_decode(
                params["remainder"][key], x, states["remainder"][key], pos, cfg,
                layer_kind(cfg, i), _ffn_kind(cfg, i), scope=key,
            )
            new_states["remainder"][key] = s
    return x, new_states


def stack_state(cfg, batch: int, max_len: int, abstract: bool = False):
    """Decode-state pytree matching the params layout."""
    lay = StackLayout(cfg)
    states: dict[str, Any] = {}
    if lay.prefix:
        states["prefix"] = {
            f"layer{i}": layer_state_init(cfg, layer_kind(cfg, i), batch, max_len, abstract)
            for i in lay.prefix
        }
    if lay.n_units:
        def stack_one(j_kind):
            j, kind = j_kind
            one = layer_state_init(cfg, kind, batch, max_len, abstract)
            if abstract:
                return jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct((lay.n_units,) + s.shape, s.dtype), one
                )
            return jax.tree.map(lambda a: jnp.broadcast_to(a, (lay.n_units,) + a.shape).copy(), one)

        states["scan"] = {f"block{j}": stack_one((j, k)) for j, k in enumerate(lay.unit_kinds)}
    if lay.remainder:
        states["remainder"] = {
            f"layer{i}": layer_state_init(cfg, layer_kind(cfg, i), batch, max_len, abstract)
            for i in lay.remainder
        }
    return states
