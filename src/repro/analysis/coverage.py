"""Profile coverage: cross-join the static call graph with a sampled profile.

Two asymmetric questions, one report:

* **Cold defs** — functions the extractor can see but the profiler never
  sampled (zero dynamic mass).  Blind spot or dead weight; either way the
  flamegraph silently says nothing about them.
* **Symbolization drift** — sampled ``repro::`` frames whose ``co_name``
  maps to no known def.  A def was renamed/deleted after the profile (or
  the static artifact) was taken: the sample did NOT vanish, it just no
  longer joins, and this report is where that surfaces.

The join key is the resolver's own symbol scheme: a sampled repo frame is
``repro::<co_name>`` and the extractor names def nodes identically, so a
flatten-view name match needs no heuristics.  Interpreter-synthetic names
(``<module>``, ``<lambda>``, ...) and origin-collapse stars are excluded
from drift — they are real samples but never defs.
"""

from __future__ import annotations

from typing import Any

from repro.core.calltree import CallTree

from .extract import DEFS, SYNTHETIC_NAMES, StaticGraph

COVERAGE_SCHEMA = "repro-coverage-report/v1"

_REPRO = "repro::"


def _static_def_masses(static: CallTree) -> dict[str, float]:
    """name -> def count, from the static plane's flatten view (call-edge
    child nodes flatten to 0.0 defs and are dropped)."""
    return {
        name[len(_REPRO):]: v
        for name, v in static.flatten(DEFS).items()
        if name.startswith(_REPRO) and v > 0.0
    }


def _dynamic_repro_masses(dynamic: CallTree, metric: str) -> dict[str, float]:
    out: dict[str, float] = {}
    for name, v in dynamic.flatten(metric).items():
        if not name.startswith(_REPRO) or v <= 0.0:
            continue
        short = name[len(_REPRO):]
        if short == "*" or short in SYNTHETIC_NAMES:
            continue
        out[short] = out.get(short, 0.0) + v
    return out


def coverage_report(
    static: StaticGraph | CallTree,
    dynamic: CallTree,
    *,
    metric: str = "samples",
) -> dict[str, Any]:
    """Build the cross-join report (JSON-serializable).

    ``static`` may be a live :class:`StaticGraph` (cold entries then carry
    def sites) or a bare static-plane tree loaded from ``static_tree.json``.
    """
    graph = static if isinstance(static, StaticGraph) else None
    tree = static.tree if graph is not None else static
    def_masses = _static_def_masses(tree)
    dyn = _dynamic_repro_masses(dynamic, metric)

    sites: dict[str, Any] = {}
    if graph is not None:
        for d in graph.defs:
            sites.setdefault(d.name, {"qualname": d.qualname, "path": d.relpath, "line": d.line})

    cold = []
    covered = []
    for name in sorted(def_masses):
        entry: dict[str, Any] = {"name": name, "defs": def_masses[name]}
        if name in sites:
            entry.update(sites[name])
        if dyn.get(name, 0.0) > 0.0:
            entry["mass"] = dyn[name]
            covered.append(entry)
        else:
            cold.append(entry)
    drift = [
        {"name": name, "mass": mass}
        for name, mass in sorted(dyn.items(), key=lambda kv: (-kv[1], kv[0]))
        if name not in def_masses
    ]
    n_defs = len(def_masses)
    return {
        "schema": COVERAGE_SCHEMA,
        "metric": metric,
        "defs": n_defs,
        "covered": len(covered),
        "cold": cold,
        "drift": drift,
        "coverage": (len(covered) / n_defs) if n_defs else 0.0,
        "hot": sorted(covered, key=lambda e: (-e["mass"], e["name"]))[:10],
    }


def coverage_tree(report: dict[str, Any]) -> CallTree:
    """Fold the report into a CallTree so it round-trips through every
    export format (folded, html, speedscope) like any other profile."""
    tree = CallTree()
    for entry in report.get("cold", []):
        tree.add_stack(["coverage::cold", f"repro::{entry['name']}"], {"samples": 1.0, DEFS: entry.get("defs", 1.0)})
    for entry in report.get("drift", []):
        tree.add_stack(["coverage::drift", f"repro::{entry['name']}"], {"samples": entry["mass"]})
    for entry in report.get("hot", []):
        tree.add_stack(["coverage::covered", f"repro::{entry['name']}"], {"samples": entry["mass"], DEFS: entry.get("defs", 1.0)})
    return tree


def render_coverage(report: dict[str, Any], *, limit: int = 20) -> str:
    """Terminal rendering (what ``python -m repro.analysis coverage`` prints)."""
    lines = [
        f"profile coverage: {report['covered']}/{report['defs']} defs sampled "
        f"({report['coverage']:.1%}, metric={report['metric']})"
    ]
    cold = report["cold"]
    lines.append(f"cold defs (statically reachable, zero dynamic mass): {len(cold)}")
    for entry in cold[:limit]:
        where = f"  {entry['qualname']} ({entry['path']}:{entry['line']})" if "qualname" in entry else f"  {entry['name']}"
        lines.append(where)
    if len(cold) > limit:
        lines.append(f"  ... {len(cold) - limit} more")
    drift = report["drift"]
    lines.append(f"symbolization drift (sampled frames with no known def): {len(drift)}")
    for entry in drift[:limit]:
        lines.append(f"  repro::{entry['name']}  mass={entry['mass']:g}")
    if len(drift) > limit:
        lines.append(f"  ... {len(drift) - limit} more")
    return "\n".join(lines)


__all__ = [
    "COVERAGE_SCHEMA",
    "coverage_report",
    "coverage_tree",
    "render_coverage",
]
