"""AST-based static call-graph extractor — the profile's static dual.

The sampled planes answer "where did time go"; this module answers "what
code exists to go to".  It parses every module under a package root (no
imports, pure ``ast``) and folds the result into an ordinary
:class:`~repro.core.calltree.CallTree` so the whole existing toolchain —
snapshot codec, exports, ``/tree`` query plane, ``top`` — works on the
static plane with zero special cases.

Tree shape (root -> leaf)::

    <root>
      mod::repro.profilerd.agent        defs = #defs in module
        cls::Agent
          repro::tick                   defs = 1, self defs = 1
            repro::_raw_stack           calls = #call sites tick -> _raw_stack

* ``mod::`` / ``cls::`` frames carry the containment hierarchy (they are
  origin-prefixed so plane name-matching strips them like ``thread::``).
* Function defs are named ``repro::<name>`` — exactly the symbol the
  resolver mints for a sampled frame in repo code — so a flatten-view
  cross-join against a dynamic profile lines up name-for-name
  (:mod:`repro.analysis.coverage`).
* A call edge resolved to a repo def appears as a child of the caller with
  the ``calls`` metric; unresolved (external/stdlib) call sites are counted
  on the caller via ``ext_calls``.

Resolution is deliberately coarse (last-attribute-segment, repo-wide name
set): it is a reachability map for coverage analysis, not a type-checked
call graph, and it must stay pure stdlib so CI can run it without jax.
"""

from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from collections.abc import Iterator

from repro.core.calltree import CallTree

from .static_tree import STATIC_TREE_SCHEMA, save_static_tree

# Metric keys on the static plane.  "samples" mirrors defs/calls so the
# default flamegraph/export pipelines render without a --metric override.
DEFS = "defs"
CALLS = "calls"
EXT_CALLS = "ext_calls"

# Synthetic code-object names the interpreter mints (module bodies, lambdas,
# comprehensions).  They appear in *dynamic* profiles of repo code but are
# not defs, so coverage's drift check must never flag them.
SYNTHETIC_NAMES = frozenset(
    {"<module>", "<lambda>", "<listcomp>", "<setcomp>", "<dictcomp>", "<genexpr>", "<string>"}
)


def iter_py_files(root: str) -> Iterator[str]:
    """Yield repo-relative paths of every ``.py`` under ``root``, sorted so
    extraction (and therefore the serialized artifact) is deterministic."""
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames if d != "__pycache__" and not d.startswith("."))
        for fn in filenames:
            if fn.endswith(".py"):
                out.append(os.path.relpath(os.path.join(dirpath, fn), root))
    return iter(sorted(out))


def module_name(relpath: str, package: str) -> str:
    parts = relpath[: -len(".py")].replace(os.sep, "/").split("/")
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join([package] + parts) if parts else package


@dataclass
class DefSite:
    """One function/method definition found by the extractor."""

    qualname: str  # repro.profilerd.agent.Agent.tick
    name: str  # tick (== the sampled frame's co_name)
    relpath: str
    line: int
    frames: list[str] = field(default_factory=list)  # tree path, root -> leaf


@dataclass
class StaticGraph:
    """Extractor output: the plane tree plus the coverage cross-join inputs."""

    tree: CallTree
    defs: list[DefSite]
    n_modules: int
    n_edges: int
    root: str

    @property
    def def_names(self) -> frozenset[str]:
        """Every defined ``co_name`` — the resolver's symbolization universe."""
        return frozenset(d.name for d in self.defs)

    def meta(self) -> dict:
        return {
            "generator": "repro.analysis.extract",
            "root": os.path.basename(os.path.abspath(self.root)),
            "modules": self.n_modules,
            "defs": len(self.defs),
            "edges": self.n_edges,
        }


def _call_targets(body: list[ast.stmt]) -> dict[str, int]:
    """Count call targets in ``body`` without descending into nested defs
    (those own their call sites).  Target = bare name or last attribute
    segment (``self._raw_stack()`` -> ``_raw_stack``)."""
    counts: dict[str, int] = {}
    stack: list[ast.AST] = list(body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            continue
        if isinstance(node, ast.Call):
            fn = node.func
            name = None
            if isinstance(fn, ast.Name):
                name = fn.id
            elif isinstance(fn, ast.Attribute):
                name = fn.attr
            if name:
                counts[name] = counts.get(name, 0) + 1
        stack.extend(ast.iter_child_nodes(node))
    return counts


def _walk_defs(
    module_ast: ast.Module, modname: str, relpath: str
) -> Iterator[tuple[DefSite, dict[str, int]]]:
    """Yield every def in the module with its call-target counts, in source
    order, carrying the containment frames the tree uses."""

    def visit(body: list[ast.stmt], frames: list[str], qual: list[str]) -> Iterator:
        for node in body:
            if isinstance(node, ast.ClassDef):
                yield from visit(node.body, frames + [f"cls::{node.name}"], qual + [node.name])
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                site = DefSite(
                    qualname=".".join([modname] + qual + [node.name]),
                    name=node.name,
                    relpath=relpath,
                    line=node.lineno,
                    frames=frames + [f"repro::{node.name}"],
                )
                yield site, _call_targets(node.body)
                yield from visit(node.body, site.frames, qual + [node.name])

    yield from visit(module_ast.body, [f"mod::{modname}"], [])


def extract_static_graph(root: str, *, package: str = "repro") -> StaticGraph:
    """Parse every module under ``root`` into the static call-graph plane.

    Raises ``SyntaxError`` (annotated with the file) if a module does not
    parse — an unparsable tree is "unreadable", never a silently smaller one.
    """
    per_module: list[tuple[str, str, list[tuple[DefSite, dict[str, int]]]]] = []
    for relpath in iter_py_files(root):
        with open(os.path.join(root, relpath), encoding="utf-8") as f:
            src = f.read()
        try:
            mod = ast.parse(src)
        except SyntaxError as exc:
            raise SyntaxError(f"{os.path.join(root, relpath)}: {exc}") from exc
        modname = module_name(relpath, package)
        per_module.append((modname, relpath, list(_walk_defs(mod, modname, relpath))))

    all_names = frozenset(site.name for _, _, pairs in per_module for site, _ in pairs)
    tree = CallTree()
    defs: list[DefSite] = []
    n_edges = 0
    for _modname, _relpath, pairs in per_module:
        for site, targets in pairs:
            defs.append(site)
            tree.add_stack(site.frames, {DEFS: 1.0, "samples": 1.0})
            ext = 0
            for callee in sorted(targets):
                n = targets[callee]
                if callee in all_names and callee != site.name:
                    tree.add_stack(site.frames + [f"repro::{callee}"], {CALLS: float(n), "samples": float(n)})
                    n_edges += 1
                else:
                    ext += n
            if ext:
                tree.add_stack(site.frames, {EXT_CALLS: float(ext)})
    return StaticGraph(tree=tree, defs=defs, n_modules=len(per_module), n_edges=n_edges, root=root)


def default_package_root() -> str:
    """The installed ``repro`` package directory (what CI extracts)."""
    import repro

    paths = list(getattr(repro, "__path__", []))
    if paths:  # namespace package: no __init__.py, no __file__
        return os.path.abspath(paths[0])
    return os.path.dirname(os.path.abspath(repro.__file__))


def extract_to_file(out_path: str, *, root: str | None = None, package: str = "repro") -> StaticGraph:
    """Extract and save the versioned artifact; returns the graph."""
    root = root or default_package_root()
    graph = extract_static_graph(root, package=package)
    save_static_tree(graph.tree, out_path, meta=graph.meta())
    return graph


__all__ = [
    "CALLS",
    "DEFS",
    "EXT_CALLS",
    "STATIC_TREE_SCHEMA",
    "SYNTHETIC_NAMES",
    "DefSite",
    "StaticGraph",
    "default_package_root",
    "extract_static_graph",
    "extract_to_file",
    "iter_py_files",
    "module_name",
]
