"""repro-lint: pluggable AST passes encoding the repo's hard-won invariants.

Each pass checks one contract that an earlier PR established by measurement
and that ordinary tests cannot cheaply guard (the violation compiles, runs,
and only shows up as a regression in a benchmark or a subtly wrong
profile).  The passes are pure ``ast`` — no imports of the checked code, no
third-party linters — so CI runs them on a bare stdlib interpreter.

Passes (id — contract):

* ``agent-hot-path`` — the target-side per-sample path (``Agent.tick`` /
  ``Agent._raw_stack``) stays free of blocking/hashing/serialization calls;
  the target pays only for frame capture (PR 1's non-intrusiveness budget).
* ``wire-slots`` — every ``@dataclass`` wire record carries ``slots``
  (decoder allocates one per record at MHz rates; ``__dict__`` per record
  was the PR 2 ingest regression).
* ``numpy-module-scope`` — ``wire``/``ingest``/``pipeline``/``agent``
  import without touching numpy (PR 8's lazy ``_numpy()`` contract keeps
  ``profilerd attach`` at milliseconds).
* ``lock-io`` — no blocking I/O while holding the ``SharedProfileState``
  lock (it guards attribute swaps only; a handler stalled under it would
  stall the daemon's publish path).
* ``lock-order`` — nested lock acquisitions across the daemon/server/
  aggregator threads must agree on one global order (static inversion
  detection over ``with <lock>`` nesting).
* ``event-kinds`` — every literally-emitted event ``kind`` is registered in
  the canonical table (:mod:`repro.profilerd.events`); an unregistered kind
  is invisible to the scoreboard's detector mapping.
* ``scope-coverage`` — kernel jit wrappers and model forwards that accept a
  ``scope`` parameter actually open ``jax.named_scope``; a missing scope
  silently breaks ``core/planes.py`` host<->device name matching.
"""

from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from collections.abc import Callable, Iterator


@dataclass(frozen=True)
class Finding:
    """One invariant violation at one site."""

    pass_id: str
    path: str  # index-relative, "/"-separated
    line: int
    symbol: str  # the def/class/kind the finding is about
    message: str

    def key(self) -> str:
        """Baseline identity: stable across line-number churn."""
        return f"{self.pass_id}:{self.path}:{self.symbol}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.pass_id}] {self.symbol}: {self.message}"


@dataclass
class LintPass:
    id: str
    description: str
    run: Callable[["RepoIndex"], list[Finding]]


class RepoIndex:
    """Parsed ASTs of every ``.py`` under a root, keyed by relative path."""

    def __init__(self, root: str, files: dict[str, ast.Module]):
        self.root = root
        self.files = files

    @classmethod
    def load(cls, root: str) -> "RepoIndex":
        files: dict[str, ast.Module] = {}
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = sorted(d for d in dirnames if d != "__pycache__" and not d.startswith("."))
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                full = os.path.join(dirpath, fn)
                rel = os.path.relpath(full, root).replace(os.sep, "/")
                with open(full, encoding="utf-8") as f:
                    src = f.read()
                try:
                    files[rel] = ast.parse(src)
                except SyntaxError as exc:
                    raise SyntaxError(f"{full}: {exc}") from exc
        return cls(root, files)

    def matching(self, suffix: str) -> list[tuple[str, ast.Module]]:
        return [(p, t) for p, t in sorted(self.files.items()) if p.endswith(suffix)]


# -- shared AST helpers ------------------------------------------------------


def call_name(node: ast.Call) -> str | None:
    """Bare callee name or last attribute segment (``self.x.f()`` -> ``f``)."""
    fn = node.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return None


def iter_calls(node: ast.AST, *, into_defs: bool = True) -> Iterator[ast.Call]:
    stack = list(ast.iter_child_nodes(node))
    while stack:
        n = stack.pop()
        if not into_defs and isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            continue
        if isinstance(n, ast.Call):
            yield n
        stack.extend(ast.iter_child_nodes(n))


def _top_level_functions(tree: ast.Module) -> Iterator[ast.FunctionDef]:
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _classes(tree: ast.Module) -> Iterator[ast.ClassDef]:
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            yield node


def _methods(cls: ast.ClassDef) -> dict[str, ast.FunctionDef]:
    return {
        n.name: n for n in cls.body if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
    }


# -- pass: agent-hot-path ----------------------------------------------------

# Call names that allocate, hash, serialize, or block.  The per-sample path
# is allowed exactly: frame walking, list append/reverse, monotonic clocks,
# the wire encoder, and the (non-blocking) spool write.
HOT_PATH_BANNED = frozenset(
    {
        # blocking / syscalls
        "open", "print", "connect", "recv", "send", "sendall", "accept",
        "select", "sleep", "join", "fsync", "urlopen", "wait_for",
        # hashing
        "md5", "sha1", "sha256", "sha512", "blake2b", "blake2s", "crc32",
        # (de)serialization — per-sample JSON/pickle is the classic regression
        "dumps", "loads", "dump", "load", "deepcopy",
        # filesystem
        "makedirs", "listdir", "stat", "remove", "unlink", "replace",
    }
)

HOT_PATH_FUNCTIONS = ("tick", "_raw_stack")


def _run_agent_hot_path(index: RepoIndex) -> list[Finding]:
    out: list[Finding] = []
    for path, tree in index.matching("profilerd/agent.py"):
        for cls in _classes(tree):
            if cls.name != "Agent":
                continue
            methods = _methods(cls)
            for name in HOT_PATH_FUNCTIONS:
                fn = methods.get(name)
                if fn is None:
                    continue
                for call in iter_calls(fn):
                    cn = call_name(call)
                    if cn in HOT_PATH_BANNED:
                        out.append(
                            Finding(
                                "agent-hot-path", path, call.lineno, f"Agent.{name}:{cn}",
                                f"banned call {cn}() in the per-sample path — the target pays "
                                "for every tick; keep capture allocation/hash/block free",
                            )
                        )
    return out


# -- pass: wire-slots --------------------------------------------------------


def _dataclass_decorator(cls: ast.ClassDef) -> ast.expr | None:
    for dec in cls.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = target.attr if isinstance(target, ast.Attribute) else getattr(target, "id", None)
        if name == "dataclass":
            return dec
    return None


def _has_slots(cls: ast.ClassDef, dec: ast.expr) -> bool:
    if isinstance(dec, ast.Call):
        for kw in dec.keywords:
            if kw.arg == "slots" and isinstance(kw.value, ast.Constant) and kw.value.value is True:
                return True
    for node in cls.body:
        targets = node.targets if isinstance(node, ast.Assign) else []
        for t in targets:
            if isinstance(t, ast.Name) and t.id == "__slots__":
                return True
    return False


def _run_wire_slots(index: RepoIndex) -> list[Finding]:
    out: list[Finding] = []
    for path, tree in index.matching("profilerd/wire.py"):
        for cls in _classes(tree):
            dec = _dataclass_decorator(cls)
            if dec is None:
                continue
            if not _has_slots(cls, dec):
                out.append(
                    Finding(
                        "wire-slots", path, cls.lineno, cls.name,
                        "wire record dataclass without slots=True — decoder allocates one per "
                        "record; __dict__ per record regresses batch ingest",
                    )
                )
    return out


# -- pass: numpy-module-scope ------------------------------------------------

NUMPY_OPTIONAL_MODULES = (
    "profilerd/wire.py",
    "profilerd/ingest.py",
    "profilerd/pipeline.py",
    "profilerd/agent.py",
)


def _module_scope_nodes(tree: ast.Module) -> Iterator[ast.stmt]:
    """Statements executed at import time: module body, descending into
    module-level If/Try/With — but not into function or class bodies, and
    not into ``if TYPE_CHECKING:`` blocks (those never execute)."""
    stack: list[ast.stmt] = list(tree.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            continue
        if isinstance(node, ast.If):
            test = node.test
            tname = test.attr if isinstance(test, ast.Attribute) else getattr(test, "id", None)
            if tname == "TYPE_CHECKING":
                stack.extend(node.orelse)
                continue
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.stmt):
                stack.append(child)


def _run_numpy_module_scope(index: RepoIndex) -> list[Finding]:
    out: list[Finding] = []
    for suffix in NUMPY_OPTIONAL_MODULES:
        for path, tree in index.matching(suffix):
            for node in _module_scope_nodes(tree):
                bad = None
                if isinstance(node, ast.Import):
                    bad = next((a.name for a in node.names if a.name.split(".")[0] == "numpy"), None)
                elif isinstance(node, ast.ImportFrom):
                    if node.module and node.module.split(".")[0] == "numpy":
                        bad = node.module
                if bad:
                    out.append(
                        Finding(
                            "numpy-module-scope", path, node.lineno, bad,
                            "module-scope numpy import in a numpy-optional module — use the "
                            "lazy _numpy() probe; attach must import in milliseconds without numpy",
                        )
                    )
    return out


# -- pass: lock-io -----------------------------------------------------------

LOCK_IO_BANNED = frozenset(
    {
        "open", "read", "write", "recv", "send", "sendall", "sleep", "urlopen",
        "dump", "dumps", "load", "loads", "fsync", "flush", "connect",
        "makedirs", "listdir", "stat", "remove", "unlink", "replace", "wait",
    }
)


def _lock_withs(fn: ast.AST) -> Iterator[tuple[str, ast.With]]:
    """Yield (lock attribute name, with-node) for each ``with <..lock..>:``."""
    for node in ast.walk(fn):
        if not isinstance(node, ast.With):
            continue
        for item in node.items:
            expr = item.context_expr
            if isinstance(expr, ast.Call):
                expr = expr.func
            name = expr.attr if isinstance(expr, ast.Attribute) else getattr(expr, "id", None)
            if name and "lock" in name.lower():
                yield name, node


def _run_lock_io(index: RepoIndex) -> list[Finding]:
    out: list[Finding] = []
    for path, tree in index.matching("profilerd/server.py"):
        for cls in _classes(tree):
            if cls.name != "SharedProfileState":
                continue
            for fn in _methods(cls).values():
                for _lock, w in _lock_withs(fn):
                    for stmt in w.body:
                        for call in iter_calls(stmt):
                            cn = call_name(call)
                            if cn in LOCK_IO_BANNED:
                                out.append(
                                    Finding(
                                        "lock-io", path, call.lineno,
                                        f"SharedProfileState.{fn.name}:{cn}",
                                        f"blocking call {cn}() while holding the publish lock — "
                                        "it guards attribute swaps only",
                                    )
                                )
    return out


# -- pass: lock-order --------------------------------------------------------

LOCK_ORDER_MODULES = (
    "profilerd/daemon.py",
    "profilerd/server.py",
    "profilerd/aggregator.py",
    "profilerd/sources.py",
)


def _nested_lock_pairs(tree: ast.Module, path: str) -> Iterator[tuple[str, str, int]]:
    """Yield (outer, inner, line) for every lexically nested acquisition.

    Lock identity is ``<Class>.<attr>`` (or ``<module>.<name>`` at module
    scope) so two classes' unrelated ``self._lock`` attributes don't alias.
    """
    mod = os.path.basename(path)[: -len(".py")]

    def visit(node: ast.AST, owner: str, held: tuple[str, ...]) -> Iterator[tuple[str, str, int]]:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                yield from visit(child, child.name, held)
                continue
            now = held
            if isinstance(child, ast.With):
                acquired = []
                for item in child.items:
                    expr = item.context_expr
                    if isinstance(expr, ast.Call):
                        expr = expr.func
                    if isinstance(expr, ast.Attribute) and "lock" in expr.attr.lower():
                        base = expr.value
                        scope = owner
                        # self.agg._lock names the *other* object's lock
                        if isinstance(base, ast.Attribute):
                            scope = base.attr
                        acquired.append(f"{scope}.{expr.attr}")
                    elif isinstance(expr, ast.Name) and "lock" in expr.id.lower():
                        acquired.append(f"{mod}.{expr.id}")
                for name in acquired:
                    for outer in now:
                        if outer != name:
                            yield outer, name, child.lineno
                    now = now + (name,)
            yield from visit(child, owner, now)

    yield from visit(tree, mod, ())


def _run_lock_order(index: RepoIndex) -> list[Finding]:
    pairs: dict[tuple[str, str], tuple[str, int]] = {}
    for suffix in LOCK_ORDER_MODULES:
        for path, tree in index.matching(suffix):
            for outer, inner, line in _nested_lock_pairs(tree, path):
                pairs.setdefault((outer, inner), (path, line))
    out: list[Finding] = []
    for (a, b), (path, line) in sorted(pairs.items()):
        if a < b and (b, a) in pairs:
            other_path, other_line = pairs[(b, a)]
            out.append(
                Finding(
                    "lock-order", path, line, f"{a}<->{b}",
                    f"lock order inversion: {a} -> {b} here but {b} -> {a} at "
                    f"{other_path}:{other_line} — pick one global order or deadlock",
                )
            )
    return out


# -- pass: event-kinds -------------------------------------------------------

EVENT_SCAN_PREFIXES = ("profilerd/", "faults/", "launch/")
EVENT_SCAN_SUFFIXES = ("core/detector.py",)
EVENTS_TABLE = "profilerd/events.py"


def _emitted_kinds(tree: ast.Module) -> Iterator[tuple[str, int]]:
    for node in ast.walk(tree):
        if isinstance(node, ast.Dict):
            for k, v in zip(node.keys, node.values, strict=True):
                if (
                    isinstance(k, ast.Constant) and k.value == "kind"
                    and isinstance(v, ast.Constant) and isinstance(v.value, str)
                ):
                    yield v.value, v.lineno
        elif isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg == "kind" and isinstance(kw.value, ast.Constant) and isinstance(kw.value.value, str):
                    yield kw.value.value, kw.value.lineno
        elif isinstance(node, ast.AnnAssign):
            if (
                isinstance(node.target, ast.Name) and node.target.id == "kind"
                and isinstance(node.value, ast.Constant) and isinstance(node.value.value, str)
            ):
                yield node.value.value, node.lineno


def _registered_kinds(index: RepoIndex) -> frozenset[str] | None:
    tables = index.matching(EVENTS_TABLE)
    if not tables:
        return None
    kinds: set[str] = set()
    for _path, tree in tables:
        for node in tree.body:
            if isinstance(node, ast.Assign):
                value = node.value
                if isinstance(value, ast.Constant) and isinstance(value.value, str):
                    kinds.add(value.value)
                elif isinstance(value, (ast.Tuple, ast.List, ast.Set)):
                    for elt in value.elts:
                        if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                            kinds.add(elt.value)
                elif isinstance(value, ast.Call):
                    for arg in value.args:
                        if isinstance(arg, (ast.Tuple, ast.List, ast.Set)):
                            for elt in arg.elts:
                                if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                                    kinds.add(elt.value)
    return frozenset(kinds)


def _run_event_kinds(index: RepoIndex) -> list[Finding]:
    registered = _registered_kinds(index)
    out: list[Finding] = []
    for path, tree in sorted(index.files.items()):
        if path.endswith(EVENTS_TABLE):
            continue
        if not (path.startswith(EVENT_SCAN_PREFIXES) or path.endswith(EVENT_SCAN_SUFFIXES)):
            continue
        seen: set[str] = set()
        for kind, line in _emitted_kinds(tree):
            if kind in seen:
                continue
            seen.add(kind)
            if registered is None:
                out.append(
                    Finding(
                        "event-kinds", path, line, kind,
                        "event kind emitted but no canonical table (profilerd/events.py) exists",
                    )
                )
            elif kind not in registered:
                out.append(
                    Finding(
                        "event-kinds", path, line, kind,
                        f"event kind {kind!r} not registered in repro.profilerd.events — "
                        "unregistered kinds are invisible to the scoreboard mapping",
                    )
                )
    return out


# -- pass: scope-coverage ----------------------------------------------------


def _contains_named_scope(fn: ast.AST) -> bool:
    for call in iter_calls(fn):
        if call_name(call) == "named_scope":
            return True
    return False


def _forwards_scope(fn: ast.AST) -> bool:
    """A pure delegation like ``slstm_step`` forwarding ``scope=scope`` to a
    covered callee counts as coverage — the callee opens the scope."""
    for call in iter_calls(fn):
        if call_name(call) == "named_scope":
            continue
        for kw in call.keywords:
            if kw.arg == "scope" and isinstance(kw.value, ast.Name) and kw.value.id == "scope":
                return True
    return False


def _run_scope_coverage(index: RepoIndex) -> list[Finding]:
    out: list[Finding] = []
    for path, tree in index.matching("kernels/ops.py"):
        for fn in _top_level_functions(tree):
            if fn.name.startswith("_"):
                continue
            if not _contains_named_scope(fn):
                out.append(
                    Finding(
                        "scope-coverage", path, fn.lineno, fn.name,
                        "public kernel wrapper without jax.named_scope — the device plane "
                        "loses this op's call path and planes.py name-matching goes dark",
                    )
                )
    for path, tree in sorted(index.files.items()):
        if "models/" not in path or path.endswith("__init__.py"):
            continue
        for fn in _top_level_functions(tree):
            params = {a.arg for a in fn.args.args + fn.args.kwonlyargs}
            if "scope" not in params:
                continue
            if not _contains_named_scope(fn) and not _forwards_scope(fn):
                out.append(
                    Finding(
                        "scope-coverage", path, fn.lineno, fn.name,
                        "forward accepts scope= but never opens jax.named_scope(scope) — "
                        "the HLO loses the tag planes.py matches on",
                    )
                )
    return out


# -- registry ----------------------------------------------------------------

PASSES: tuple[LintPass, ...] = (
    LintPass("agent-hot-path", "per-sample path free of alloc/hash/blocking calls", _run_agent_hot_path),
    LintPass("wire-slots", "wire record dataclasses carry __slots__", _run_wire_slots),
    LintPass("numpy-module-scope", "numpy-optional modules never import numpy at module scope", _run_numpy_module_scope),
    LintPass("lock-io", "no blocking I/O under the SharedProfileState lock", _run_lock_io),
    LintPass("lock-order", "one global lock-acquisition order across daemon threads", _run_lock_order),
    LintPass("event-kinds", "every emitted event kind registered in the canonical table", _run_event_kinds),
    LintPass("scope-coverage", "kernel wrappers and scoped forwards open jax.named_scope", _run_scope_coverage),
)

PASS_IDS = tuple(p.id for p in PASSES)


def run_passes(
    index: RepoIndex, *, only: str | None = None
) -> list[Finding]:
    """Run all (or one) passes; findings sorted for stable baselines."""
    if only is not None and only not in PASS_IDS:
        raise ValueError(f"unknown pass {only!r} (expected one of {', '.join(PASS_IDS)})")
    out: list[Finding] = []
    for p in PASSES:
        if only is not None and p.id != only:
            continue
        out.extend(p.run(index))
    return sorted(out, key=lambda f: (f.pass_id, f.path, f.line, f.symbol))


__all__ = [
    "Finding",
    "HOT_PATH_BANNED",
    "LOCK_IO_BANNED",
    "LintPass",
    "NUMPY_OPTIONAL_MODULES",
    "PASSES",
    "PASS_IDS",
    "RepoIndex",
    "run_passes",
]
