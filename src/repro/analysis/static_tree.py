"""Versioned on-disk envelope for the static call-graph plane.

Mirrors the device-plane artifact (:mod:`repro.core.hlo_tree`): a
``static_tree.json`` file carrying a schema tag and a serialized
:class:`~repro.core.calltree.CallTree` root, written atomically so a reader
polling the profile dir never sees a torn document.  The profiler's loaders
(:func:`repro.profilerd.profiles.load_static_plane`) and the query plane's
``/tree?plane=static`` both consume this format.
"""

from __future__ import annotations

import json
import os
from collections.abc import Mapping
from typing import Any

from repro.core.calltree import CallNode, CallTree

STATIC_TREE_SCHEMA = "repro-static-tree/v1"

# Canonical artifact filename — a static tree saved under this name beside a
# profile's tree.json is discovered by the daemon, the offline server, and
# the CLI --plane static paths, exactly like device_tree.json.
STATIC_TREE_FILENAME = "static_tree.json"


def save_static_tree(tree: CallTree, path: str, *, meta: Mapping[str, Any] | None = None) -> None:
    """Write ``tree`` as a versioned static-plane artifact (atomic rename)."""
    doc: dict[str, Any] = {"schema": STATIC_TREE_SCHEMA, "root": tree.root.to_dict()}
    if meta:
        doc["meta"] = dict(meta)
    tmp = f"{path}.tmp.{id(doc)}"
    with open(tmp, "w") as f:
        json.dump(doc, f)
    os.replace(tmp, path)


def load_static_tree(path: str) -> CallTree:
    """Load a static-plane artifact; raises ``ValueError`` on a bad document.

    Accepts the versioned envelope or a legacy bare serialized root (the
    same tolerance the device-plane loader extends), so a tree dumped with
    ``CallTree.to_json`` still loads.
    """
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict):
        raise ValueError(f"static tree {path}: expected a JSON object")
    if "schema" in doc:
        if doc["schema"] != STATIC_TREE_SCHEMA:
            raise ValueError(
                f"static tree {path}: unknown schema {doc['schema']!r} (expected {STATIC_TREE_SCHEMA!r})"
            )
        root = doc.get("root")
    else:
        root = doc  # legacy bare root
    if not isinstance(root, dict) or "name" not in root:
        raise ValueError(f"static tree {path}: missing root node")
    return CallTree(CallNode.from_dict(root))


def static_meta(path: str) -> dict[str, Any]:
    """Return the envelope's ``meta`` block ({} for legacy documents)."""
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, dict) and isinstance(doc.get("meta"), dict):
        return doc["meta"]
    return {}
