"""CLI for the static-analysis plane: ``python -m repro.analysis <cmd>``.

Commands::

    extract   build static_tree.json (the /tree?plane=static artifact)
    lint      run the repro-lint passes and print findings
    check     gate findings against a committed baseline (CI entrypoint)
    coverage  cross-join a static tree with a sampled profile
    fixtures  score every pass against its seeded-violation fixture

Exit codes follow the ``profilerd check`` contract: 0 pass, 2 regression /
findings, 3 unreadable input.  Everything here is pure stdlib (plus the
repo's own core modules) so CI runs it without jax or numpy.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from .baseline import EXIT_PASS, EXIT_REGRESSION, EXIT_UNREADABLE, check
from .coverage import coverage_report, coverage_tree, render_coverage
from .extract import default_package_root, extract_to_file
from .lint import PASS_IDS, RepoIndex, run_passes
from .score import render_score, score_fixtures
from .static_tree import STATIC_TREE_FILENAME


def _root_arg(parser: argparse.ArgumentParser) -> None:
    parser.add_argument(
        "--root",
        default=None,
        help="package source root to analyze (default: the installed repro package)",
    )


def cmd_extract(args) -> int:
    out = args.out
    if os.path.isdir(out):
        out = os.path.join(out, STATIC_TREE_FILENAME)
    try:
        graph = extract_to_file(out, root=args.root, package=args.package)
    except (OSError, SyntaxError) as e:
        print(f"UNREADABLE: {e}", file=sys.stderr)
        return EXIT_UNREADABLE
    print(
        f"static tree: {graph.n_modules} modules, {len(graph.defs)} defs, "
        f"{graph.n_edges} resolved call edges -> {out}"
    )
    return EXIT_PASS


def cmd_lint(args) -> int:
    root = args.root or default_package_root()
    try:
        index = RepoIndex.load(root)
        if not index.files:
            raise OSError(f"{root}: no python files to analyze")
        findings = run_passes(index, only=getattr(args, "pass_id", None))
    except (OSError, SyntaxError, ValueError) as e:
        print(f"UNREADABLE: {e}", file=sys.stderr)
        return EXIT_UNREADABLE
    if args.json:
        print(json.dumps([f.__dict__ for f in findings], indent=2))
    else:
        for f in findings:
            print(f.render())
        print(f"{len(findings)} finding(s) across {len(index.files)} files")
    return EXIT_REGRESSION if findings else EXIT_PASS


def cmd_check(args) -> int:
    root = args.root or default_package_root()
    code, report = check(root, args.baseline, update=args.update)
    print(report)
    return code


def cmd_coverage(args) -> int:
    from repro.profilerd.profiles import ProfileLoadError, load_profile, load_static_plane

    try:
        dynamic = load_profile(args.profile)
    except ProfileLoadError as e:
        print(f"UNREADABLE: {e}", file=sys.stderr)
        return EXIT_UNREADABLE
    static = None
    if args.static:
        from .static_tree import load_static_tree

        try:
            static = load_static_tree(args.static)
        except (OSError, ValueError) as e:
            print(f"UNREADABLE: {e}", file=sys.stderr)
            return EXIT_UNREADABLE
    else:
        try:
            static = load_static_plane(args.profile)
        except ProfileLoadError as e:
            print(f"UNREADABLE: {e}", file=sys.stderr)
            return EXIT_UNREADABLE
        if static is None:
            # No artifact beside the profile: extract the installed package
            # live so `coverage` works out of the box on any profile.
            from .extract import extract_static_graph

            static = extract_static_graph(default_package_root())
    report = coverage_report(static, dynamic, metric=args.metric)
    if args.tree:
        out = args.tree
        if os.path.isdir(out):
            out = os.path.join(out, "coverage_tree.json")
        with open(out, "w") as f:
            f.write(coverage_tree(report).to_json())
        print(f"coverage tree -> {out}", file=sys.stderr)
    if args.json:
        print(json.dumps(report, indent=2))
    else:
        print(render_coverage(report, limit=args.limit))
    return EXIT_PASS


def cmd_fixtures(args) -> int:
    clean_root = args.root or default_package_root()
    try:
        score = score_fixtures(args.dir, clean_root)
    except (OSError, SyntaxError) as e:
        print(f"UNREADABLE: {e}", file=sys.stderr)
        return EXIT_UNREADABLE
    if args.json:
        print(json.dumps(score, indent=2))
    else:
        print(render_score(score))
    return EXIT_PASS if score["ok"] else EXIT_REGRESSION


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static call-graph plane + repro-lint invariant checks",
    )
    sub = parser.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("extract", help="emit the static_tree.json plane artifact")
    _root_arg(p)
    p.add_argument("--package", default="repro", help="package name prefix for module nodes")
    p.add_argument("--out", required=True, help="output file, or a profile dir to drop the artifact into")
    p.set_defaults(fn=cmd_extract)

    p = sub.add_parser("lint", help="run the invariant passes and print findings")
    _root_arg(p)
    p.add_argument("--pass", dest="pass_id", choices=list(PASS_IDS), help="run a single pass")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_lint)

    p = sub.add_parser("check", help="gate findings against a committed baseline")
    _root_arg(p)
    p.add_argument("--baseline", required=True, help="baseline JSON path")
    p.add_argument("--update", action="store_true", help="accept current findings as the new baseline")
    p.set_defaults(fn=cmd_check)

    p = sub.add_parser("coverage", help="cross-join static defs with a sampled profile")
    p.add_argument("--profile", required=True, help="profile artifact (dir, tree.json, or .snap)")
    p.add_argument("--static", default=None, help="static_tree.json (default: beside the profile, else live extract)")
    p.add_argument("--metric", default="samples")
    p.add_argument("--limit", type=int, default=20)
    p.add_argument("--json", action="store_true")
    p.add_argument("--tree", default=None, help="also write the report as a tree.json for the exporters")
    p.set_defaults(fn=cmd_coverage)

    p = sub.add_parser("fixtures", help="score each pass against its seeded-violation fixture")
    _root_arg(p)
    p.add_argument("--dir", required=True, help="fixtures dir (one subdir per pass id)")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_fixtures)

    args = parser.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
