"""Committed-baseline gating for repro-lint — the ``profilerd check`` of
static analysis.

The baseline is a JSON document listing the finding keys the repo has
accepted (for a clean tree: none).  ``check`` re-runs the passes and fails
only on findings *not* in the baseline, so adopting the gate on a tree with
known debt is possible without ratcheting noise — and fixing debt shows up
as "fixed" keys the next ``--update`` drops.

Exit-code contract (shared with ``profilerd check`` so CI wiring is
uniform): 0 pass, 2 regression (new findings), 3 unreadable (missing or
malformed baseline, unparsable tree — never a vacuous pass).
"""

from __future__ import annotations

import json
import os
from typing import Any

from .lint import Finding, RepoIndex, run_passes

BASELINE_SCHEMA = "repro-analysis-baseline/v1"

EXIT_PASS = 0
EXIT_REGRESSION = 2
EXIT_UNREADABLE = 3


class BaselineError(RuntimeError):
    pass


def save_baseline(findings: list[Finding], path: str, *, root_label: str = "repro") -> None:
    doc: dict[str, Any] = {
        "schema": BASELINE_SCHEMA,
        "root": root_label,
        "keys": sorted({f.key() for f in findings}),
    }
    tmp = f"{path}.tmp.{id(doc)}"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    os.replace(tmp, path)


def load_baseline(path: str) -> frozenset[str]:
    try:
        with open(path) as f:
            doc = json.load(f)
    except OSError as e:
        raise BaselineError(f"{path}: unreadable baseline: {e}") from None
    except ValueError as e:
        raise BaselineError(f"{path}: malformed baseline: {e}") from None
    if not isinstance(doc, dict) or doc.get("schema") != BASELINE_SCHEMA:
        raise BaselineError(
            f"{path}: not an analysis baseline (expected schema {BASELINE_SCHEMA!r})"
        )
    keys = doc.get("keys")
    if not isinstance(keys, list) or not all(isinstance(k, str) for k in keys):
        raise BaselineError(f"{path}: malformed baseline: 'keys' must be a list of strings")
    return frozenset(keys)


def diff_baseline(
    findings: list[Finding], allowed: frozenset[str]
) -> tuple[list[Finding], list[str]]:
    """(new findings not in the baseline, baseline keys no longer found)."""
    seen = {f.key() for f in findings}
    new = [f for f in findings if f.key() not in allowed]
    fixed = sorted(allowed - seen)
    return new, fixed


def check(
    root: str, baseline_path: str, *, update: bool = False, only: str | None = None
) -> tuple[int, str]:
    """Run the passes against ``root`` and gate on the committed baseline.

    Returns (exit code, report text).  An empty or unparsable tree is
    "unreadable" (3), never a pass — the gate must not succeed vacuously
    because ``--root`` pointed somewhere empty.
    """
    try:
        index = RepoIndex.load(root)
    except (OSError, SyntaxError) as e:
        return EXIT_UNREADABLE, f"UNREADABLE: {e}"
    if not index.files:
        return EXIT_UNREADABLE, f"UNREADABLE: {root}: no python files to analyze"
    try:
        findings = run_passes(index, only=only)
    except ValueError as e:
        return EXIT_UNREADABLE, f"UNREADABLE: {e}"

    if update:
        save_baseline(findings, baseline_path)
        return EXIT_PASS, (
            f"baseline updated: {baseline_path} ({len(findings)} accepted finding(s))"
        )

    try:
        allowed = load_baseline(baseline_path)
    except BaselineError as e:
        return EXIT_UNREADABLE, f"UNREADABLE: {e}"
    new, fixed = diff_baseline(findings, allowed)
    lines = [
        f"repro-lint: {len(index.files)} files, {len(findings)} finding(s), "
        f"{len(allowed)} baselined, {len(new)} new, {len(fixed)} fixed"
    ]
    for f in new:
        lines.append(f"NEW: {f.render()}")
    for k in fixed:
        lines.append(f"FIXED (run check --update to drop from baseline): {k}")
    if new:
        lines.append("FAIL: new static-analysis findings vs baseline")
        return EXIT_REGRESSION, "\n".join(lines)
    lines.append("PASS")
    return EXIT_PASS, "\n".join(lines)


__all__ = [
    "BASELINE_SCHEMA",
    "BaselineError",
    "EXIT_PASS",
    "EXIT_REGRESSION",
    "EXIT_UNREADABLE",
    "check",
    "diff_baseline",
    "load_baseline",
    "save_baseline",
]
