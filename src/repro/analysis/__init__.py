"""repro.analysis — the static dual of the sampled profile.

AST-based static call-graph extraction (``/tree?plane=static``), the
repro-lint invariant passes, profile-coverage cross-joins, and the
``check --baseline`` CI gate.  Pure stdlib on top of ``repro.core``.

Exports are lazy (PEP 562), mirroring ``repro.core``: importing the
package costs nothing until a symbol is touched, so the profiling plane's
millisecond-import budget is unaffected.
"""

from __future__ import annotations

_EXPORTS = {
    "STATIC_TREE_SCHEMA": ".static_tree",
    "STATIC_TREE_FILENAME": ".static_tree",
    "save_static_tree": ".static_tree",
    "load_static_tree": ".static_tree",
    "static_meta": ".static_tree",
    "StaticGraph": ".extract",
    "DefSite": ".extract",
    "extract_static_graph": ".extract",
    "extract_to_file": ".extract",
    "default_package_root": ".extract",
    "SYNTHETIC_NAMES": ".extract",
    "COVERAGE_SCHEMA": ".coverage",
    "coverage_report": ".coverage",
    "coverage_tree": ".coverage",
    "render_coverage": ".coverage",
    "Finding": ".lint",
    "LintPass": ".lint",
    "PASSES": ".lint",
    "PASS_IDS": ".lint",
    "RepoIndex": ".lint",
    "run_passes": ".lint",
    "BASELINE_SCHEMA": ".baseline",
    "check": ".baseline",
    "load_baseline": ".baseline",
    "save_baseline": ".baseline",
    "score_fixtures": ".score",
    "render_score": ".score",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    from importlib import import_module

    return getattr(import_module(mod, __name__), name)


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
