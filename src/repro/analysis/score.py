"""Precision/recall scoring of the lint passes against seeded fixtures.

Every pass ships a fixture tree under ``tests/data/analysis_fixtures/<pass>/``
containing exactly the violation the pass exists to catch.  The scorer runs
each pass over its own fixture (recall: the seeded violation must be found)
and the full pass set over the clean repo (precision: a clean tree yields
zero findings).  CI runs this nightly; a pass that stops catching its own
fixture — or starts flagging healthy code — fails the matrix.
"""

from __future__ import annotations

import os
from typing import Any

from .lint import PASSES, RepoIndex, run_passes

SCORE_SCHEMA = "repro-analysis-score/v1"


def score_fixtures(fixtures_dir: str, clean_root: str) -> dict[str, Any]:
    """Build the matrix; ``ok`` is the CI gate verdict."""
    clean_index = RepoIndex.load(clean_root)
    clean_findings = run_passes(clean_index)
    clean_by_pass: dict[str, int] = {}
    for f in clean_findings:
        clean_by_pass[f.pass_id] = clean_by_pass.get(f.pass_id, 0) + 1

    matrix: dict[str, Any] = {}
    ok = True
    for p in PASSES:
        fdir = os.path.join(fixtures_dir, p.id)
        row: dict[str, Any] = {
            "description": p.description,
            "clean_findings": clean_by_pass.get(p.id, 0),
            "precision": 1.0 if clean_by_pass.get(p.id, 0) == 0 else 0.0,
        }
        if not os.path.isdir(fdir):
            row.update({"fixture": False, "seeded_found": 0, "recall": 0.0})
            ok = False
        else:
            index = RepoIndex.load(fdir)
            own = run_passes(index, only=p.id)
            row.update(
                {
                    "fixture": True,
                    "seeded_found": len(own),
                    "recall": 1.0 if own else 0.0,
                    "findings": [f.render() for f in own],
                }
            )
            if not own:
                ok = False
        if row["precision"] < 1.0:
            ok = False
        matrix[p.id] = row
    return {
        "schema": SCORE_SCHEMA,
        "fixtures_dir": fixtures_dir,
        "clean_root": os.path.basename(os.path.abspath(clean_root)),
        "passes": matrix,
        "clean_total": len(clean_findings),
        "ok": ok,
    }


def render_score(score: dict[str, Any]) -> str:
    lines = [f"{'pass':<20} {'recall':>6} {'precision':>9}  seeded/clean"]
    for pid, row in score["passes"].items():
        lines.append(
            f"{pid:<20} {row['recall']:>6.1f} {row['precision']:>9.1f}  "
            f"{row['seeded_found']}/{row['clean_findings']}"
            + ("" if row.get("fixture") else "  (MISSING FIXTURE)")
        )
    lines.append("OK" if score["ok"] else "FAIL: recall or precision below 1.0")
    return "\n".join(lines)


__all__ = ["SCORE_SCHEMA", "render_score", "score_fixtures"]
