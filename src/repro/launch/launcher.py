"""Process-level launcher with restart policy (fault tolerance at job scope).

The in-process watchdog (sampler + dominance detector) handles anomalies the
process can see; the launcher handles the ones it cannot — a hung or killed
trainer. Mechanism (the paper's external-observer stance, one level up):

* the trainer touches a **heartbeat file** every step;
* the launcher polls it; a stale heartbeat (or a dead process) triggers
  kill -> restart from the latest checkpoint (restore is exact: params,
  optimizer, data position);
* restarts are budgeted (``max_restarts``) with exponential backoff;
* **elastic**: each restart re-reads the host inventory (``n_hosts``) so a
  shrunk fleet resumes with re-partitioned data shards — checkpoints store
  logical state only, never device layouts.

**Shared per-node profiling daemon** (``profile_dir``): the launcher starts
ONE ``python -m repro.profilerd attach --watch <profile_dir>`` per node — the
children only publish raw frames to per-attempt spools (they pick the daemon
backend up from ``REPRO_PROFILERD_SPOOL``, no config change needed), and the
single daemon discovers each spool as it appears, aggregates every target
out-of-process into per-target trees plus a continuously merged fleet tree
(``fleet.d/tree.json``), and re-attaches across child restarts.

**Multi-node merge** goes through the regional aggregator: the shared daemon
is spawned with ``--push`` at an aggregator URL (``aggregator_url`` for an
external ``profilerd aggregate``, or ``aggregate=True`` to run one in-process
under ``profile_dir/region.d``), every sealed epoch streams there as a
CRC-framed delta, and rendezvous just collects the aggregator's continuously
merged fleet tree — no file copying between nodes.  The legacy file-copy
rendezvous (``CallTree.merge`` across ``*.d`` dirs under a shared
``profile_dir``) remains as the documented fallback when no aggregator is
configured, and as the recovery path when an external aggregator is
unreachable at rendezvous.  This is the paper's single-external-observer
design at node scope, with zero profiling work inside any trainer.

On a real multi-pod deployment this wraps the per-host ``jax.distributed``
bring-up; in this container it supervises local subprocesses, and the tests
exercise hang-detection + restart with a deliberately stalling child.
"""

from __future__ import annotations

import json
import os
import subprocess
import time
from dataclasses import dataclass, field


@dataclass
class LaunchConfig:
    cmd: list[str]
    workdir: str
    heartbeat_path: str
    heartbeat_timeout_s: float = 30.0
    poll_s: float = 0.5
    max_restarts: int = 3
    backoff_s: float = 1.0
    env: dict = field(default_factory=dict)
    # When set, one shared repro.profilerd daemon per node watches this dir;
    # per-attempt spools land here and the fleet tree merges at rendezvous.
    profile_dir: str | None = None
    profile_period_s: float = 0.2
    # Push every sealed epoch to this regional aggregator (an external
    # ``profilerd aggregate`` endpoint); rendezvous collects the merged
    # fleet tree from it instead of copying files between nodes.
    aggregator_url: str | None = None
    # Run the regional aggregator in-process (under profile_dir/region.d)
    # when no external URL is given — single-supervisor deployments get the
    # push plane without operating a second service.
    aggregate: bool = False
    # Node name reported to the aggregator (defaults to the short hostname).
    node_name: str | None = None
    region: str = "region"
    # When set (with profile_dir), serve the rendezvous-merged fleet tree
    # over the profilerd HTTP query plane on this port (0 = ephemeral) once
    # the job ends; the server runs on a daemon thread (see Launcher.server).
    serve_port: int | None = None


@dataclass
class LaunchReport:
    restarts: int = 0
    exit_code: int | None = None
    events: list[str] = field(default_factory=list)

    def log(self, msg: str) -> None:
        self.events.append(msg)
        print(f"[launcher] {msg}")


class Launcher:
    def __init__(self, cfg: LaunchConfig):
        self.cfg = cfg
        self.report = LaunchReport()
        self.server = None  # ProfileServer over the merged profile (serve_port)
        self.aggregator = None  # in-process regional Aggregator (aggregate=True)
        self._agg_url: str | None = None  # effective push endpoint
        self._daemons: list[subprocess.Popen] = []
        if cfg.profile_dir and not os.path.isabs(cfg.profile_dir):
            # The launcher, the daemon (cwd=workdir), and the child all touch
            # this path; resolve it once, against the job's workdir.
            cfg.profile_dir = os.path.abspath(os.path.join(cfg.workdir, cfg.profile_dir))

    def _heartbeat_age(self) -> float:
        try:
            return time.time() - os.path.getmtime(self.cfg.heartbeat_path)
        except OSError:
            return float("inf")

    def _spawn(self, attempt: int = 0) -> subprocess.Popen:
        env = {**os.environ, **self.cfg.env}
        if self.cfg.profile_dir:
            spool = os.path.join(self.cfg.profile_dir, f"attempt{attempt}.spool")
            env["REPRO_PROFILERD_SPOOL"] = spool
            env["REPRO_PROFILERD_PERIOD"] = str(self.cfg.profile_period_s)
            # The shared daemon publishes this attempt's artifacts under its
            # per-target dir, not <spool>.d — point the child's DaemonBackend
            # (snapshot()/depth_trace()/wait-for-done) at the right place.
            env["REPRO_PROFILERD_OUT"] = os.path.join(
                self.cfg.profile_dir, "fleet.d", "targets", f"attempt{attempt}"
            )
            self._ensure_shared_daemon()
        return subprocess.Popen(
            self.cfg.cmd, cwd=self.cfg.workdir, env=env,
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        )

    # -- shared per-node profiling daemon ------------------------------------

    def _ensure_shared_daemon(self) -> None:
        """Start the node's ONE profilerd daemon (idempotent).

        It watches ``profile_dir`` and attaches every ``attempt*.spool`` as
        the supervised processes create them — restarts included, without
        multiplying daemon processes or resolver/ingest state.
        """
        if self._daemons:
            return
        from repro.profilerd.daemon import spawn_attached_daemon

        os.makedirs(self.cfg.profile_dir, exist_ok=True)
        self._ensure_aggregator()
        proc = spawn_attached_daemon(
            watch_dir=self.cfg.profile_dir,
            out_dir=os.path.join(self.cfg.profile_dir, "fleet.d"),
            stall_timeout_s=self.cfg.heartbeat_timeout_s,
            # Die with the launcher: a crashed supervisor must not leak a
            # watch daemon that has no BYE to exit on.
            exit_with_pid=os.getpid(),
            cwd=self.cfg.workdir,
            push=self._agg_url,
            push_node=self.cfg.node_name,
        )
        self._daemons.append(proc)
        self.report.log(f"profilerd daemon watching {self.cfg.profile_dir} (one per node)")
        if self._agg_url:
            self.report.log(f"daemon pushes sealed epochs to {self._agg_url}")

    def _ensure_aggregator(self) -> None:
        """Resolve the push endpoint: external URL, or an in-process one.

        ``aggregate=True`` without an ``aggregator_url`` starts the regional
        aggregator inside the launcher (ephemeral port, artifacts under
        ``profile_dir/region.d``) so a single supervisor gets the push plane
        without running ``profilerd aggregate`` as a separate service.
        """
        cfg = self.cfg
        if self._agg_url is not None or (not cfg.aggregator_url and not cfg.aggregate):
            return
        if cfg.aggregator_url:
            self._agg_url = cfg.aggregator_url
            return
        from repro.profilerd.aggregator import Aggregator, AggregatorConfig

        try:
            self.aggregator = Aggregator(
                AggregatorConfig(
                    out_dir=os.path.join(cfg.profile_dir, "region.d"),
                    region=cfg.region,
                    stall_floor_s=max(cfg.heartbeat_timeout_s, 1.0),
                )
            )
            self._agg_url = self.aggregator.enable_serving().url
        except OSError as e:  # no listening socket: fall back to file copy
            self.report.log(f"in-process aggregator failed ({e}); file-copy rendezvous")
            self.aggregator = None
            return
        self.report.log(f"in-process aggregator ({cfg.region}) at {self._agg_url}")

    def _rendezvous_merge(self) -> str | None:
        """Collect the fleet tree at job end.

        With an aggregator configured (external or in-process) the merged
        tree is *already there* — every node's daemon pushed its sealed
        epochs — so rendezvous is one collect call.  Without one, fall back
        to the legacy file-copy merge across ``*.d`` dirs under the shared
        ``profile_dir`` (and use the same fallback if an external aggregator
        is unreachable: the job result must still land).
        """
        if not self.cfg.profile_dir:
            return None
        for d in self._daemons:
            # A --watch daemon has no BYE to exit on: SIGTERM asks it for a
            # clean final drain + seal + publish (and, with --push, a forced
            # final flush of the spill queue to the aggregator).
            d.terminate()
            try:
                d.wait(timeout=15.0)
            except subprocess.TimeoutExpired:
                d.kill()
                d.wait()
        out = self._collect_from_aggregator() if self._agg_url else None
        if out is None:
            out = self._merge_host_trees()
        if out is None:
            return None
        self._surface_device_tree()
        self._merge_timelines()
        self._serve_merged()
        return out

    def _collect_from_aggregator(self) -> str | None:
        """The aggregator's continuously merged fleet tree -> merged_tree.json.

        In-process: seal + publish + read directly.  External: one GET of
        ``/tree?fmt=json`` (the export schema round-trips through
        ``CallTree.from_json``).  Returns None on failure so the caller can
        fall back to the file-copy merge.
        """
        from repro.core.calltree import CallTree

        merged = None
        if self.aggregator is not None:
            self.aggregator.seal_fleet_epoch(force=True)
            self.aggregator.publish()
            merged = self.aggregator.fleet_tree()
            self.aggregator.close()
            src = "in-process aggregator"
        else:
            import urllib.request

            url = self._agg_url.rstrip("/") + "/tree?fmt=json"
            try:
                with urllib.request.urlopen(url, timeout=10.0) as resp:
                    merged = CallTree.from_json(resp.read().decode())
            except (OSError, ValueError, KeyError) as e:
                self.report.log(f"rendezvous: aggregator fetch failed ({e}); file-copy fallback")
                return None
            src = self._agg_url
        if merged is None or not merged.root.children:
            self.report.log("rendezvous: aggregator holds no epochs; file-copy fallback")
            return None
        out = os.path.join(self.cfg.profile_dir, "merged_tree.json")
        with open(out, "w") as f:
            f.write(merged.to_json())
        self.report.log(f"rendezvous: fleet tree from {src} -> {out}")
        return out

    def _merge_host_trees(self) -> str | None:
        """Legacy file-copy rendezvous: merge ``*.d/tree.json`` dumps.

        The documented fallback for deployments without an aggregator — all
        nodes' daemons must share (or rsync into) ``profile_dir``.  With one
        node this loop is a pass-through of ``fleet.d/tree.json``.
        """
        from repro.core.calltree import CallNode, CallTree

        merged = CallTree()
        n = 0
        for entry in sorted(os.listdir(self.cfg.profile_dir)):
            path = os.path.join(self.cfg.profile_dir, entry, "tree.json")
            if not entry.endswith(".d") or entry == "region.d" or not os.path.exists(path):
                continue
            try:
                with open(path) as f:
                    merged.merge(CallTree(CallNode.from_dict(json.load(f))))
                n += 1
            except (OSError, ValueError) as e:
                self.report.log(f"skipping unreadable tree {path}: {e}")
        if n == 0:
            return None
        out = os.path.join(self.cfg.profile_dir, "merged_tree.json")
        with open(out, "w") as f:
            f.write(merged.to_json())
        self.report.log(f"rendezvous: merged {n} host tree(s) -> {out}")
        return out

    def _surface_device_tree(self) -> None:
        """Copy a target-dropped ``device_tree.json`` beside the merged tree.

        Trainers drop the artifact into their daemon target dir (all
        co-located attempts run the same compiled program, so any one copy
        serves the fleet); surfacing it at the profile-dir root lets the
        rendezvous server answer ``/tree?plane=device|merged`` and
        ``profilerd check --plane`` gate the merged profile.
        """
        import glob

        dst = os.path.join(self.cfg.profile_dir, "device_tree.json")
        if os.path.exists(dst):
            return
        candidates = sorted(
            glob.glob(os.path.join(self.cfg.profile_dir, "*.d", "device_tree.json"))
            + glob.glob(os.path.join(self.cfg.profile_dir, "*.d", "targets", "*", "device_tree.json"))
        )
        if not candidates:
            return
        try:
            with open(candidates[0]) as f:
                payload = f.read()
            tmp = f"{dst}.tmp.{os.getpid()}"
            with open(tmp, "w") as f:
                f.write(payload)
            os.replace(tmp, dst)
            self.report.log(f"rendezvous: device plane {candidates[0]} -> {dst}")
        except OSError as e:
            self.report.log(f"rendezvous: device plane copy failed: {e}")

    def _serve_merged(self) -> None:
        """Expose the fleet-merged profile over the HTTP query plane.

        The paper's cross-host aggregation becomes queryable the moment the
        job ends: ``/tree?fmt=html`` is the fleet flamegraph, ``/timeline``
        replays the merged epoch ring, ``/diff?baseline=`` compares against
        any earlier run.  The server thread is a daemon thread — callers that
        want it to outlive ``run()`` keep the process alive (or use
        ``python -m repro.profilerd serve --profile <profile_dir>``).
        """
        if self.cfg.serve_port is None or self.server is not None:
            return
        from repro.profilerd.server import OfflineSource, ProfileServer

        try:
            self.server = ProfileServer(
                OfflineSource(self.cfg.profile_dir, label="fleet-merged"),
                port=self.cfg.serve_port,
            ).start()
        except OSError as e:  # port taken: the job result must still land
            self.report.log(f"rendezvous: serve failed ({e})")
            return
        self.report.log(f"rendezvous: merged profile served at {self.server.url}")

    def _merge_timelines(self) -> str | None:
        """Merge per-host timeline rings epoch-by-epoch at rendezvous.

        Epochs join on their sealed epoch *number*, not list position — ring
        retention may have dropped a long-running host's oldest segments, so
        its first retained epoch can be far from 0.  At each merged epoch a
        host contributes its latest cumulative tree at-or-before that epoch
        (a host that stopped early keeps contributing its final tree), so the
        fleet total never dips.  The merged ring lives beside
        ``merged_tree.json`` and feeds ``profilerd timeline``/``diff``/
        ``check`` at fleet scope.
        """
        from repro.core.calltree import CallTree
        from repro.core.snapshot import EpochMeta, TimelineReader, TimelineWriter, is_timeline_dir

        # Streamed lock-step merge: each host holds one retained cumulative
        # copy, never its whole epoch history (a long ring can span 1000+
        # epochs of 10k-node trees — materializing every cumulative per host
        # would OOM the launcher at rendezvous).
        hosts = []  # per host: {"it": epoch iterator, "peek", "meta", "cum"}
        for entry in sorted(os.listdir(self.cfg.profile_dir)):
            tdir = os.path.join(self.cfg.profile_dir, entry, "timeline")
            # region.d is the aggregator's out dir: its ring already IS the
            # fleet sum, so folding it in would double-count every node.
            if entry.endswith(".d") and entry != "region.d" and is_timeline_dir(tdir):
                it = TimelineReader(tdir).epochs()
                peek = next(it, None)
                if peek is not None:
                    hosts.append({"it": it, "peek": peek, "meta": None, "cum": None})
        if not hosts:
            return None
        out_dir = os.path.join(self.cfg.profile_dir, "merged_timeline")
        writer = TimelineWriter(out_dir)
        prev = CallTree()
        n_merged = 0
        while any(h["peek"] is not None for h in hosts):
            epoch = min(h["peek"][0].epoch for h in hosts if h["peek"] is not None)
            fleet = CallTree()
            wall = 0.0
            progress = 0.0
            for h in hosts:
                while h["peek"] is not None and h["peek"][0].epoch <= epoch:
                    meta, _window, cum = h["peek"]
                    # Copy before advancing: the reader mutates `cum` in place.
                    h["meta"], h["cum"] = meta, cum.copy()
                    h["peek"] = next(h["it"], None)
                if h["cum"] is None:
                    continue  # host's retained history starts later
                fleet.merge(h["cum"])
                wall = max(wall, h["meta"].wall_time)
                progress += h["meta"].progress
            meta_out = EpochMeta(epoch, wall, progress)
            if writer.needs_keyframe():
                writer.append_full(fleet, meta_out)
            else:
                writer.append_delta(fleet.diff(prev), meta_out)
            prev = fleet
            n_merged += 1
        writer.close()
        self.report.log(
            f"rendezvous: merged {len(hosts)} host timeline(s) x {n_merged} epoch(s) -> {out_dir}"
        )
        return out_dir

    def run(self) -> LaunchReport:
        cfg, rep = self.cfg, self.report
        attempt = 0
        while True:
            start = time.time()
            proc = self._spawn(attempt)
            rep.log(f"spawned attempt {attempt} pid={proc.pid}")
            hung = False
            while True:
                rc = proc.poll()
                if rc is not None:
                    break
                age = self._heartbeat_age()
                alive_for = time.time() - start
                if alive_for > cfg.heartbeat_timeout_s and age > cfg.heartbeat_timeout_s:
                    hung = True
                    rep.log(f"heartbeat stale ({age:.1f}s) -> SIGKILL pid={proc.pid}")
                    proc.kill()
                    proc.wait()
                    break
                time.sleep(cfg.poll_s)
            out = proc.stdout.read() if proc.stdout else ""
            if not hung and proc.returncode == 0:
                rep.exit_code = 0
                rep.log("job completed")
                self._rendezvous_merge()
                return rep
            reason = "hang" if hung else f"exit={proc.returncode}"
            attempt += 1
            rep.restarts = attempt
            if attempt > cfg.max_restarts:
                rep.exit_code = proc.returncode if not hung else -9
                rep.log(f"giving up after {attempt - 1} restarts ({reason}); last output tail:\n"
                        + "\n".join(out.splitlines()[-5:]))
                self._rendezvous_merge()
                return rep
            rep.log(f"restarting ({reason}); resume comes from the latest checkpoint")
            time.sleep(cfg.backoff_s * attempt)
