"""Process-level launcher with restart policy (fault tolerance at job scope).

The in-process watchdog (sampler + dominance detector) handles anomalies the
process can see; the launcher handles the ones it cannot — a hung or killed
trainer. Mechanism (the paper's external-observer stance, one level up):

* the trainer touches a **heartbeat file** every step;
* the launcher polls it; a stale heartbeat (or a dead process) triggers
  kill -> restart from the latest checkpoint (restore is exact: params,
  optimizer, data position);
* restarts are budgeted (``max_restarts``) with exponential backoff;
* **elastic**: each restart re-reads the host inventory (``n_hosts``) so a
  shrunk fleet resumes with re-partitioned data shards — checkpoints store
  logical state only, never device layouts.

On a real multi-pod deployment this wraps the per-host ``jax.distributed``
bring-up; in this container it supervises local subprocesses, and the tests
exercise hang-detection + restart with a deliberately stalling child.
"""

from __future__ import annotations

import os
import signal
import subprocess
import sys
import time
from dataclasses import dataclass, field
from typing import Optional


@dataclass
class LaunchConfig:
    cmd: list[str]
    workdir: str
    heartbeat_path: str
    heartbeat_timeout_s: float = 30.0
    poll_s: float = 0.5
    max_restarts: int = 3
    backoff_s: float = 1.0
    env: dict = field(default_factory=dict)


@dataclass
class LaunchReport:
    restarts: int = 0
    exit_code: Optional[int] = None
    events: list[str] = field(default_factory=list)

    def log(self, msg: str) -> None:
        self.events.append(msg)
        print(f"[launcher] {msg}")


class Launcher:
    def __init__(self, cfg: LaunchConfig):
        self.cfg = cfg
        self.report = LaunchReport()

    def _heartbeat_age(self) -> float:
        try:
            return time.time() - os.path.getmtime(self.cfg.heartbeat_path)
        except OSError:
            return float("inf")

    def _spawn(self) -> subprocess.Popen:
        env = {**os.environ, **self.cfg.env}
        return subprocess.Popen(
            self.cfg.cmd, cwd=self.cfg.workdir, env=env,
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        )

    def run(self) -> LaunchReport:
        cfg, rep = self.cfg, self.report
        attempt = 0
        while True:
            start = time.time()
            proc = self._spawn()
            rep.log(f"spawned attempt {attempt} pid={proc.pid}")
            hung = False
            while True:
                rc = proc.poll()
                if rc is not None:
                    break
                age = self._heartbeat_age()
                alive_for = time.time() - start
                if alive_for > cfg.heartbeat_timeout_s and age > cfg.heartbeat_timeout_s:
                    hung = True
                    rep.log(f"heartbeat stale ({age:.1f}s) -> SIGKILL pid={proc.pid}")
                    proc.kill()
                    proc.wait()
                    break
                time.sleep(cfg.poll_s)
            out = proc.stdout.read() if proc.stdout else ""
            if not hung and proc.returncode == 0:
                rep.exit_code = 0
                rep.log("job completed")
                return rep
            reason = "hang" if hung else f"exit={proc.returncode}"
            attempt += 1
            rep.restarts = attempt
            if attempt > cfg.max_restarts:
                rep.exit_code = proc.returncode if not hung else -9
                rep.log(f"giving up after {attempt - 1} restarts ({reason}); last output tail:\n"
                        + "\n".join(out.splitlines()[-5:]))
                return rep
            rep.log(f"restarting ({reason}); resume comes from the latest checkpoint")
            time.sleep(cfg.backoff_s * attempt)
