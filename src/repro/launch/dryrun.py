import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:

* the sharding config is coherent (lower/compile succeeds — sharding
  mismatches, unsupported collectives, and compile-time OOM all fail here);
* the memory plan fits (``compiled.memory_analysis()`` per-device bytes);
* the cost model for §Roofline (``cost_analysis()`` FLOPs/bytes +
  collective bytes parsed from the compiled HLO via the device-plane tree).

NOTE the first two lines of this file: jax locks the device count at first
initialization, so XLA_FLAGS must be set before ANY other import — including
``from repro...``. Do not set this flag globally (tests/benches must see the
real single device).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, get_config, list_archs, shape_applicable  # noqa: E402
from repro.core.hlo_tree import build_device_tree, collective_summary, save_device_tree  # noqa: E402
from repro.core.roofline import report_from_artifacts  # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_chips  # noqa: E402
from repro.launch.steps import make_serve_step, make_train_step  # noqa: E402
from repro.models import Model  # noqa: E402
from repro.models.modules import abstract_params  # noqa: E402
from repro.optim import AdamWConfig, cosine_schedule  # noqa: E402
from repro.sharding import make_strategy, params_shardings, sharding_ctx  # noqa: E402


def batch_shardings(batch_abs, mesh, batch_axes):
    """Inputs: shard dim 0 (batch) over the data axes; rest replicated."""

    def one(leaf):
        if leaf.shape and leaf.shape[0] % _axes_size(mesh, batch_axes) == 0:
            return NamedSharding(mesh, P(batch_axes, *([None] * (len(leaf.shape) - 1))))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, batch_abs)


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return n


def state_shardings(state_abs, mesh, batch_axes):
    """Decode-state shardings: batch dim over data axes; one wide dim (heads
    preferred, else feature) over 'model'. 'scan'-stacked leaves carry a
    leading layer axis which stays unsharded."""
    model_n = mesh.shape["model"]
    batch_n = _axes_size(mesh, batch_axes)

    def one(path, leaf):
        keys = [getattr(p, "key", "") for p in path]
        dims: list = [None] * len(leaf.shape)
        off = 1 if "scan" in keys else 0  # leading layer-stack axis
        bdim = off
        if len(leaf.shape) > bdim and leaf.shape[bdim] % batch_n == 0:
            dims[bdim] = batch_axes
        # prefer the head axis (rank-4 kv / mlstm-C), else the last wide axis
        prefer = [bdim + 2, bdim + 3, bdim + 1]
        for d in prefer:
            if d < len(leaf.shape) and dims[d] is None and leaf.shape[d] % model_n == 0 and leaf.shape[d] >= model_n:
                dims[d] = "model"
                break
        return NamedSharding(mesh, P(*dims))

    return jax.tree_util.tree_map_with_path(one, state_abs)


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    *,
    strategy_name: str = "tp_fsdp",
    grad_accum: int = 1,
    remat: str = None,
    chunk_threshold: int = None,
    chunk: int = None,
    moe_impl: str = None,
    attn_cp: bool = False,
    opt_dtype: str = "float32",
    donate: bool = True,
    verbose: bool = True,
    dump_tree: str = None,
) -> dict:
    t0 = time.time()
    cfg = get_config(arch)
    overrides = {}
    if remat is not None:
        overrides["remat"] = remat
    if chunk_threshold is not None:
        overrides["chunk_threshold"] = chunk_threshold
    if chunk is not None:
        overrides["chunk"] = chunk
    if moe_impl is not None:
        overrides["moe_impl"] = moe_impl
    if attn_cp:
        overrides["attn_cp"] = True
    if overrides:
        from dataclasses import replace

        cfg = replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "strategy": strategy_name,
        "grad_accum": grad_accum,
        "overrides": overrides,
    }
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        cell.update(status="skip", reason=why)
        return cell
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = mesh_chips(mesh)
        model = Model(cfg)
        strategy = make_strategy(strategy_name, multi_pod=multi_pod)
        batch_axes = tuple(strategy.act_rules["batch"])
        spec_tree = model.spec()
        params_abs = abstract_params(spec_tree)
        p_sh = params_shardings(spec_tree, strategy, mesh)
        batch_abs = model.input_specs(shape)
        b_sh = batch_shardings(batch_abs, mesh, batch_axes)

        with mesh, sharding_ctx(mesh, strategy.act_rules):
            if shape.kind == "train":
                mdt = jnp.dtype(opt_dtype)
                opt_abs = jax.eval_shape(lambda p: _opt_abstract(p, mdt), params_abs)
                o_sh = {
                    "step": NamedSharding(mesh, P()),
                    "m": p_sh,
                    "v": p_sh,
                }
                step = make_train_step(model, cosine_schedule(3e-4), AdamWConfig(), grad_accum=grad_accum)
                jitted = jax.jit(
                    step,
                    in_shardings=(p_sh, o_sh, b_sh),
                    donate_argnums=(0, 1) if donate else (),
                )
                lowered = jitted.lower(params_abs, opt_abs, batch_abs)
            else:
                if shape.kind == "prefill":
                    def prefill(params, batch):
                        logits, _ = model.forward(params, batch)
                        return jnp.argmax(logits[:, -1], axis=-1)

                    jitted = jax.jit(prefill, in_shardings=(p_sh, b_sh))
                    lowered = jitted.lower(params_abs, batch_abs)
                else:  # decode
                    state_abs = model.abstract_decode_state(shape.global_batch, shape.seq_len)
                    s_sh = state_shardings(state_abs, mesh, batch_axes)
                    step = make_serve_step(model)
                    jitted = jax.jit(
                        step,
                        in_shardings=(p_sh, b_sh, s_sh, NamedSharding(mesh, P())),
                        donate_argnums=(2,) if donate else (),
                    )
                    lowered = jitted.lower(
                        params_abs, batch_abs, state_abs, jax.ShapeDtypeStruct((), jnp.int32)
                    )
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # older jax returns [per-device dict]
            ca = ca[0] if ca else {}
        tree = build_device_tree(compiled.as_text(), step_name=f"{arch}:{shape_name}")
        colls = collective_summary(tree)
        if dump_tree:
            os.makedirs(os.path.dirname(dump_tree) or ".", exist_ok=True)
            save_device_tree(tree, dump_tree, meta={"arch": arch, "shape": shape_name, "mesh": mesh_name})
        from repro.core.report import breakdown

        component_breakdown = {
            metric: breakdown(tree, level=8, metric=metric, min_share=0.03)[:40]
            for metric in ("flops", "bytes", "coll_bytes")
        }
        rep = report_from_artifacts(
            arch=arch,
            shape=shape_name,
            mesh=mesh_name,
            chips=chips,
            cost_analysis=ca,
            device_tree=tree,
            memory_analysis=ma,
            model_flops_global=model.model_flops(shape),
        )
        cell.update(
            status="ok",
            chips=chips,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory_analysis={
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_bytes_per_device": rep.per_device_hbm_peak,
                "fits_hbm_16g": rep.fits_hbm(),
            },
            cost_analysis={"flops": ca.get("flops", 0.0), "bytes_accessed": ca.get("bytes accessed", 0.0)},
            tree_metrics={"flops": tree.total("flops"), "bytes": tree.total("bytes"), "ops": tree.total("ops")},
            collectives=colls,
            roofline=rep.row(),
            breakdown=component_breakdown,
            n_params=model.n_params,
            n_active_params=model.n_active_params,
        )
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
                  f"(compile {t_compile:.0f}s, dominant={rep.dominant}, "
                  f"t_step={rep.t_step*1e3:.2f}ms, peak={rep.per_device_hbm_peak/2**30:.2f}GiB)")
            print(f"  memory_analysis: {ma}")
            print(f"  cost_analysis: flops={ca.get('flops', 0.0):.3e} bytes={ca.get('bytes accessed', 0.0):.3e}")
            print(f"  collectives: { {k: f'{v:.3e}' for k, v in colls.items()} }")
    except Exception as e:  # noqa: BLE001 — cell failures are data, not crashes
        cell.update(status="fail", error=f"{type(e).__name__}: {e}", trace=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: FAIL {type(e).__name__}: {e}")
    return cell


def _opt_abstract(params_abs, moment_dtype=jnp.float32):
    import jax.numpy as jnp

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype), params_abs),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype), params_abs),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--strategy", default="tp_fsdp")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--chunk-threshold", type=int, default=None)
    ap.add_argument("--chunk", type=int, default=None)
    ap.add_argument("--dump-tree", default=None, help="write full device-tree JSON here")
    ap.add_argument("--moe-impl", default=None, choices=["dense", "shard_map"])
    ap.add_argument("--opt-dtype", default="float32", choices=["float32", "bfloat16"])
    ap.add_argument("--attn-cp", action="store_true", help="context-parallel attention q-chunks")
    ap.add_argument("--all", action="store_true", help="run every (arch, shape) cell")
    ap.add_argument("--out", default=None, help="output dir for per-cell JSON")
    args = ap.parse_args()

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cell = run_cell(
                    arch, shape, mp,
                    strategy_name=args.strategy,
                    grad_accum=args.grad_accum,
                    remat=args.remat,
                    chunk_threshold=args.chunk_threshold,
                    chunk=args.chunk,
                    moe_impl=args.moe_impl,
                    attn_cp=args.attn_cp,
                    opt_dtype=args.opt_dtype,
                    dump_tree=args.dump_tree,
                )
                results.append(cell)
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    mesh_tag = "2x16x16" if mp else "16x16"
                    fn = f"{arch}__{shape}__{mesh_tag}__{args.strategy}"
                    if args.grad_accum > 1:
                        fn += f"__ga{args.grad_accum}"
                    if args.remat:
                        fn += f"__remat-{args.remat}"
                    if args.chunk_threshold is not None:
                        fn += f"__ct{args.chunk_threshold}"
                    if args.moe_impl:
                        fn += f"__moe-{args.moe_impl}"
                    if args.opt_dtype != "float32":
                        fn += f"__opt-{args.opt_dtype}"
                    if args.attn_cp:
                        fn += "__cp"
                    with open(os.path.join(args.out, fn + ".json"), "w") as f:
                        json.dump(cell, f, indent=1)
    n_ok = sum(1 for c in results if c["status"] == "ok")
    n_skip = sum(1 for c in results if c["status"] == "skip")
    n_fail = sum(1 for c in results if c["status"] == "fail")
    print(f"\n[dryrun] done: {n_ok} ok, {n_skip} skip(by-rule), {n_fail} fail")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
