"""Step-function builders shared by the trainer, server, dry-run and benches."""

from __future__ import annotations

from collections.abc import Callable

import jax
import jax.numpy as jnp

from repro.models import Model
from repro.optim import AdamWConfig, adamw_update


def make_train_step(
    model: Model,
    lr_fn: Callable,
    opt_cfg: AdamWConfig = AdamWConfig(),
    *,
    grad_accum: int = 1,
):
    """-> train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``grad_accum > 1`` scans over microbatches (leading batch dim must divide),
    accumulating fp32 gradients — the standard memory/throughput knob.
    """

    def loss_fn(p, b):
        return model.loss(p, b)

    def train_step(params, opt_state, batch):
        with jax.named_scope("train_step"):
            if grad_accum == 1:
                with jax.named_scope("fwd_bwd"):
                    (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            else:
                def micro(b):
                    return jax.tree.map(
                        lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum, *x.shape[1:]), b
                    )

                mb = micro(batch)

                def body(carry, b):
                    acc, loss_acc = carry
                    (l, aux_i), g = jax.value_and_grad(loss_fn, has_aux=True)(params, b)
                    acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32), acc, g)
                    return (acc, loss_acc + l), aux_i

                zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
                with jax.named_scope("fwd_bwd"):
                    (gsum, lsum), auxs = jax.lax.scan(body, (zero, jnp.zeros((), jnp.float32)), mb)
                grads = jax.tree.map(lambda g: g / grad_accum, gsum)
                loss = lsum / grad_accum
                aux = jax.tree.map(lambda x: x[-1], auxs)
            lr = lr_fn(opt_state["step"])
            new_params, new_opt, om = adamw_update(grads, opt_state, params, lr=lr, cfg=opt_cfg)
            metrics = {"loss": loss, "lr": lr, **aux, **om}
            return new_params, new_opt, metrics

    return train_step


def make_serve_step(model: Model, *, greedy: bool = True, temperature: float = 1.0):
    """-> serve_step(params, batch, state, pos) -> (next_tokens|logits, state)."""

    def serve_step(params, batch, state, pos):
        with jax.named_scope("serve_step"):
            logits, new_state = model.decode_step(params, batch, state, pos)
            if greedy:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_state
            return logits / temperature, new_state

    return serve_step


def make_eval_step(model: Model):
    def eval_step(params, batch):
        with jax.named_scope("eval_step"):
            loss, aux = model.loss(params, batch)
            return {"loss": loss, **aux}

    return eval_step
