"""Batched serving driver: prefill + decode with continuous batching (lite).

A fixed-size decode batch is kept full from a request queue: finished
sequences are replaced by queued prompts (their prefill runs as masked decode
steps of the shared batch, which keeps one compiled step function — the
approach used by TPU serving stacks when prefill traffic is light). The
host-plane sampler + dominance detector watch the loop exactly like training:
a stuck decode (e.g. a dead host in a multi-pod serving cell) trips the
watchdog's hang rule.

CLI:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --requests 16

Out-of-process profiling (attach `python -m repro.profilerd` from another
terminal — the serving loop only publishes raw frames):
  PYTHONPATH=src python -m repro.launch.serve --profile --backend daemon \\
      --spool /tmp/serve.spool
"""

from __future__ import annotations

import argparse
import json
import threading
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import DominanceDetector, Rule, SamplerConfig, WatchdogLoop, make_sampler
from repro.launch.steps import make_serve_step
from repro.models import Model


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (P,) int32
    max_new: int = 16
    out: list[int] = field(default_factory=list)
    done: bool = False


class ServeMetrics:
    """Serving counters shared between the decode loop and scrapers.

    One lock guards the counters: the decode loop takes it once per step
    (`record_step`), dashboards/scrapers take it to read (`snapshot`).  That
    makes this the serving loop's lock-convoy seam — a scraper that holds the
    lock too long parks the decode thread in ``record_step``, which is
    exactly the contention profile the fault corpus injects and the
    profiler's dominance rules are scored on.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self.steps = 0
        self.requests_done = 0
        self.step_wall_s = 0.0

    def record_step(self, *, done_now: int, wall_s: float) -> None:
        with self._lock:
            self.steps += 1
            self.requests_done += done_now
            self.step_wall_s += wall_s

    def snapshot(self) -> dict:
        with self._lock:
            mean = self.step_wall_s / self.steps if self.steps else 0.0
            return {
                "steps": self.steps,
                "requests_done": self.requests_done,
                "mean_step_s": mean,
            }


class BatchedServer:
    def __init__(self, model: Model, *, batch: int = 4, max_len: int = 128, seed: int = 0):
        self.model = model
        self.batch = batch
        self.max_len = max_len
        self.params = model.init(jax.random.key(seed))
        self.state = model.init_decode_state(batch, max_len)
        self.step_fn = jax.jit(make_serve_step(model), donate_argnums=(2,))
        self.slots: list[Request | None] = [None] * batch
        # per-slot progress: how many prompt tokens already consumed
        self.consumed = [0] * batch
        self.pos = 0
        self.steps = 0
        self.metrics = ServeMetrics()

    def _admit(self, queue: list[Request]) -> None:
        for i in range(self.batch):
            if self.slots[i] is None and queue:
                self.slots[i] = queue.pop(0)
                self.consumed[i] = 0

    def run(self, requests: list[Request]) -> dict:
        queue = list(requests)
        t0 = time.time()
        self._admit(queue)
        vocab = self.model.cfg.vocab
        while any(s is not None for s in self.slots) or queue:
            t_step = time.time()
            tokens = np.zeros((self.batch, 1), np.int32)
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                if self.consumed[i] < len(req.prompt):
                    tokens[i, 0] = req.prompt[self.consumed[i]]  # prefill-as-decode
                else:
                    tokens[i, 0] = req.out[-1] if req.out else req.prompt[-1]
            next_tok, self.state = self.step_fn(
                self.params, {"tokens": jnp.asarray(tokens)}, self.state, jnp.int32(self.pos)
            )
            next_tok = np.asarray(next_tok)
            self.pos += 1
            self.steps += 1
            done_before = sum(1 for r in requests if r.done)
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                if self.consumed[i] < len(req.prompt):
                    self.consumed[i] += 1
                    continue
                req.out.append(int(next_tok[i]) % vocab)
                if len(req.out) >= req.max_new:
                    req.done = True
                    self.slots[i] = None
                    self._admit(queue)
            self.metrics.record_step(
                done_now=sum(1 for r in requests if r.done) - done_before,
                wall_s=time.time() - t_step,
            )
            if self.pos >= self.max_len - 1:
                break  # context exhausted for this demo server
        wall = time.time() - t0
        done = [r for r in requests if r.done]
        return {
            "requests_done": len(done),
            "decode_steps": self.steps,
            "wall_s": wall,
            "steps_per_s": self.steps / max(wall, 1e-9),
            "batch": self.batch,
            "metrics": self.metrics.snapshot(),
        }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--profile", action="store_true")
    ap.add_argument("--backend", default="thread", choices=("thread", "daemon"),
                    help="profiler backend (daemon = out-of-process repro.profilerd)")
    ap.add_argument("--spool", default=None,
                    help="daemon backend: spool path for an externally-attached profilerd")
    ap.add_argument("--push", default=None, metavar="URL",
                    help="daemon backend: regional aggregator the spawned "
                         "profilerd pushes sealed epochs to (profilerd aggregate)")
    ap.add_argument("--push-node", default=None,
                    help="node name reported to the aggregator (default: hostname)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=not args.full)
    model = Model(cfg)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, rng.integers(3, 10)).astype(np.int32),
                max_new=args.max_new)
        for i in range(args.requests)
    ]
    sampler = (
        make_sampler(
            SamplerConfig(period_s=0.1, backend=args.backend, spool_path=args.spool,
                          push_url=args.push, push_node=args.push_node)
        )
        if args.profile
        else None
    )
    wd = None
    if sampler:
        det = DominanceDetector([Rule(threshold=0.95, consecutive=3, min_window_total=8)])
        wd = WatchdogLoop(sampler, det, interval_s=1.0)
        sampler.start()
        wd.start()
    server = BatchedServer(model, batch=args.batch, max_len=128)
    stats = server.run(reqs)
    if sampler:
        wd.stop()
        tree = sampler.stop()
        stats["profile_samples"] = tree.total()
    print(json.dumps(stats, indent=1))


if __name__ == "__main__":
    main()
