"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run must set
``XLA_FLAGS`` before the first jax initialization.
"""

from __future__ import annotations

import jax


def axis_types_kw(n: int) -> dict:
    """``axis_types=`` kwarg when this jax has explicit axis types (>=0.5);
    older versions (0.4.x) predate ``jax.sharding.AxisType`` and default to
    auto sharding anyway."""
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n} if at is not None else {}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **axis_types_kw(len(axes)))


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh over whatever devices the current process actually has
    (CPU smoke tests / single-host debugging)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh(
        (n // model_axis, model_axis),
        ("data", "model"),
        **axis_types_kw(2),
    )


def mesh_chips(mesh) -> int:
    n = 1
    for s in mesh.shape.values():
        n *= s
    return n
