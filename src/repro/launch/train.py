"""Training driver: profiling-first train loop with fault tolerance.

Wires every subsystem together the way a production job would:

* data pipeline (prefetch thread) -> jit'd train_step (donated buffers);
* **host-plane sampler** running for the whole job (the paper's external
  profiler — zero instrumentation of the step function);
* **watchdog**: dominance detector over sampler windows; an anomaly triggers
  warn -> emergency checkpoint (paper §V-D flow) -> optional abort so the
  launcher can restart from the checkpoint;
* periodic async checkpoints + exact resume (params, optimizer, data
  position, step);
* heartbeat file per step — the launcher's process-level hang detector.

CLI (CPU-scale by default — full configs are exercised via the dry-run):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --steps 30
"""

from __future__ import annotations

import argparse
import json
import os
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import (
    DominanceDetector,
    Rule,
    SamplerConfig,
    WatchdogLoop,
    make_sampler,
    write_report,
)
from repro.data import DataConfig, Pipeline, SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init, cosine_schedule


@dataclass
class TrainJobConfig:
    arch: str = "xlstm-125m"
    smoke: bool = True
    steps: int = 30
    global_batch: int = 8
    seq_len: int = 64
    lr: float = 3e-3
    warmup: int = 10
    grad_accum: int = 1
    seed: int = 0
    out_dir: str = "/tmp/repro_train"
    ckpt_every: int = 20
    profile: bool = True
    # "thread" = in-process StackSampler; "daemon" = raw-frame agent + external
    # repro.profilerd process (see src/repro/profilerd/).
    profile_backend: str = "thread"
    # Daemon backend: explicit spool path means an external
    # `python -m repro.profilerd attach --spool ...` drains it; when None a
    # daemon subprocess is spawned automatically.
    spool_path: str | None = None
    # Daemon backend: regional aggregator URL the spawned profilerd pushes
    # sealed epochs to (`profilerd aggregate`); node name defaults to hostname.
    push_url: str | None = None
    push_node: str | None = None
    sample_period_s: float = 0.2
    watchdog_threshold: float = 0.95
    # Extra detector rules appended to the defaults (e.g. a pattern-scoped
    # rule for a known livelock signature — far more robust than tuning the
    # generic threshold).
    extra_rules: list | None = None
    heartbeat_timeout_s: float = 600.0
    resume: bool = True


class Trainer:
    def __init__(self, job: TrainJobConfig):
        self.job = job
        self.cfg = get_config(job.arch, smoke=job.smoke)
        self.model = Model(self.cfg)
        os.makedirs(job.out_dir, exist_ok=True)
        self.ckpt = CheckpointManager(os.path.join(job.out_dir, "ckpt"))
        self.data = Pipeline(
            SyntheticLM(
                DataConfig(
                    vocab=self.cfg.vocab, seq_len=job.seq_len,
                    global_batch=job.global_batch, seed=job.seed,
                )
            )
        )
        self.metrics_log: list[dict] = []
        self.step = 0
        self.params = None
        self.opt_state = None
        self._heartbeat_path = os.path.join(job.out_dir, "heartbeat")

        lr_fn = cosine_schedule(job.lr, warmup_steps=job.warmup, total_steps=max(job.steps, 2))
        self._train_step = jax.jit(
            make_train_step(self.model, lr_fn, AdamWConfig(), grad_accum=job.grad_accum),
            donate_argnums=(0, 1),
        )

        # -- profiling plane (the paper's toolchain, always on) -------------
        self.sampler = (
            make_sampler(
                SamplerConfig(
                    period_s=job.sample_period_s,
                    backend=job.profile_backend,
                    spool_path=job.spool_path,
                    push_url=job.push_url,
                    push_node=job.push_node,
                )
            )
            if job.profile
            else None
        )
        self.detector = DominanceDetector(
            [
                # generic livelock/hang rule (paper's 90%-class threshold)
                Rule(threshold=job.watchdog_threshold, consecutive=2, min_window_total=8),
                # input starvation: the prefetch worker should never dominate
                Rule(pattern="_prefetch_worker", threshold=0.6, consecutive=2,
                     min_window_total=8, self_only=False, kind="INPUT_STARVATION"),
            ]
            + list(job.extra_rules or []),
        )
        self.detector.add_callback(self._on_anomaly)
        self.watchdog = WatchdogLoop(self.sampler, self.detector, interval_s=1.0) if self.sampler else None
        self.anomalies: list = []
        self._device_tree_dumped = not job.profile  # device plane rides the profiling plane

    # -- fault-tolerance hooks ---------------------------------------------------

    def _on_anomaly(self, event) -> None:
        self.anomalies.append(event)
        print(f"[watchdog] {event.describe()} -> emergency checkpoint")
        self.ckpt.save_emergency(lambda: (self.step, self._state_tree()), event)

    def _touch_heartbeat(self) -> None:
        with open(self._heartbeat_path, "w") as f:
            f.write(f"{self.step} {time.time()}")

    def _dump_device_tree(self, batch: dict) -> None:
        """Drop the device-plane artifact beside the host profile (once).

        AOT lower+compile of the same train step the loop runs, costed into a
        CallTree by ``op_name`` path — the daemon/server merge it onto the
        sampled host tree (``?plane=merged``).  Also lands in the launcher's
        per-target daemon dir (``REPRO_PROFILERD_OUT``) where the shared
        daemon's lazy discovery picks it up.  Best-effort: the device plane
        must never cost the training run.
        """
        self._device_tree_dumped = True
        try:
            from repro.core.hlo_tree import save_device_tree, tree_from_compiled

            compiled = self._train_step.lower(self.params, self.opt_state, batch).compile()
            tree = tree_from_compiled(compiled)
            dests = [os.path.join(self.job.out_dir, "device_tree.json")]
            env_out = os.environ.get("REPRO_PROFILERD_OUT")
            if env_out:
                dests.append(os.path.join(env_out, "device_tree.json"))
            for p in dests:
                os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
                save_device_tree(tree, p, meta={"arch": self.cfg.name, "source": "train"})
            print(f"[train] device plane: {dests[0]} ({tree.node_count()} call sites)")
        except Exception as e:  # noqa: BLE001 - any failure here is non-fatal
            print(f"[train] device-tree dump skipped: {e}")

    def _state_tree(self) -> dict:
        return {
            "params": self.params,
            "opt": self.opt_state,
            "data": {"next_step": np.asarray(self.data.next_step)},
        }

    # -- init / resume -------------------------------------------------------------

    def initialize(self) -> None:
        restored = self.ckpt.restore_latest() if self.job.resume else None
        if restored is not None:
            step, tree, manifest = restored
            self.step = step
            self.params = jax.tree.map(jnp.asarray, tree["params"])
            self.opt_state = jax.tree.map(jnp.asarray, tree["opt"])
            self.data.load_state_dict({"next_step": int(tree["data"]["next_step"])})
            print(f"[train] resumed from step {step} (tag={manifest['tag']})")
        else:
            self.params = self.model.init(jax.random.key(self.job.seed))
            self.opt_state = adamw_init(self.params)

    # -- loop --------------------------------------------------------------------------

    def run(self) -> dict:
        self.initialize()
        if self.sampler:
            self.sampler.start()
        if self.watchdog:
            self.watchdog.start()
        t0 = time.time()
        try:
            while self.step < self.job.steps:
                batch = {k: jnp.asarray(v) for k, v in next(self.data).items()}
                if not self._device_tree_dumped:
                    # Before the step call: donation invalidates the argument
                    # buffers, and lowering only needs their avals anyway.
                    self._dump_device_tree(batch)
                self.params, self.opt_state, metrics = self._train_step(
                    self.params, self.opt_state, batch
                )
                self.step += 1
                self._touch_heartbeat()
                if self.step % self.job.ckpt_every == 0 or self.step == self.job.steps:
                    self.ckpt.save(self.step, self._state_tree())
                m = {k: float(v) for k, v in metrics.items() if jnp.ndim(v) == 0}
                m["step"] = self.step
                self.metrics_log.append(m)
                if self.step % 5 == 0 or self.step == 1:
                    print(f"[train] step {self.step}: loss={m['loss']:.4f} lr={m['lr']:.2e}")
        finally:
            if self.watchdog:
                self.watchdog.stop()
            host_tree = self.sampler.stop() if self.sampler else None
            self.ckpt.wait()
            self.data.close()
        wall = time.time() - t0
        tokens = self.step * self.job.global_batch * self.job.seq_len
        summary = {
            "arch": self.cfg.name,
            "steps": self.step,
            "wall_s": wall,
            "tokens_per_s": tokens / max(wall, 1e-9),
            "final_loss": self.metrics_log[-1]["loss"] if self.metrics_log else None,
            "first_loss": self.metrics_log[0]["loss"] if self.metrics_log else None,
            "anomalies": [e.describe() for e in self.anomalies],
        }
        with open(os.path.join(self.job.out_dir, "metrics.json"), "w") as f:
            json.dump({"summary": summary, "steps": self.metrics_log}, f, indent=1)
        if host_tree is not None and host_tree.total() > 0:
            write_report(host_tree, self.job.out_dir, "host_profile")
            summary["host_profile"] = os.path.join(self.job.out_dir, "host_profile.html")
        return summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--full", action="store_true", help="full config (default: smoke)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--out", default="/tmp/repro_train")
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--backend", default="thread", choices=("thread", "daemon"),
                    help="profiler backend (daemon = out-of-process repro.profilerd)")
    ap.add_argument("--spool", default=None,
                    help="daemon backend: spool path for an externally-attached profilerd")
    ap.add_argument("--push", default=None, metavar="URL",
                    help="daemon backend: regional aggregator the spawned "
                         "profilerd pushes sealed epochs to (profilerd aggregate)")
    ap.add_argument("--push-node", default=None,
                    help="node name reported to the aggregator (default: hostname)")
    args = ap.parse_args()
    job = TrainJobConfig(
        arch=args.arch,
        smoke=not args.full,
        steps=args.steps,
        global_batch=args.batch,
        seq_len=args.seq,
        lr=args.lr,
        grad_accum=args.grad_accum,
        out_dir=args.out,
        resume=not args.no_resume,
        profile_backend=args.backend,
        spool_path=args.spool,
        push_url=args.push,
        push_node=args.push_node,
    )
    summary = Trainer(job).run()
    print(json.dumps(summary, indent=1))


if __name__ == "__main__":
    main()
