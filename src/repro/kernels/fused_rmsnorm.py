"""Fused RMSNorm Pallas TPU kernel.

One HBM read + one HBM write per element: the row-wise mean-square reduction,
rsqrt and scale multiply all happen in VMEM on a (Br, D) tile. XLA emits this
as reduce + broadcast-multiply which it usually fuses anyway; the kernel
exists because the *fp32-upcast* variant (bf16 in, fp32 statistics, bf16 out)
otherwise materializes an fp32 copy of the activation in HBM at long sequence
lengths. Grid is 1-D over row blocks; D stays whole on the lane axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (Br, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + s_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


def fused_rmsnorm_pallas(
    x: jax.Array,  # (..., D)
    scale: jax.Array,  # (D,)
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    orig_shape = x.shape
    D = x.shape[-1]
    rows = 1
    for d in x.shape[:-1]:
        rows *= d
    x2 = x.reshape(rows, D)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = ((rows + pad) // block_rows,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pad, D), x.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",),
        ) if not interpret else None,
        interpret=interpret,
    )(x2, scale.reshape(1, D))
    return out[:rows].reshape(orig_shape)
