"""jit'd public wrappers around the Pallas kernels.

Layout conventions match the model code: attention takes (B, S, H, D) and
returns the same; the kernel works in (B, H, S, D). ``interpret=True`` runs
the kernel body on CPU (tests); on TPU ``interpret=False`` compiles via
Mosaic. The XLA reference path used by the dry-run lives in the model code.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_bhsd
from .fused_rmsnorm import fused_rmsnorm_pallas
from .rglru_scan import rglru_scan_pallas


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,  # (B, S, Hq, D)
    k: jax.Array,  # (B, T, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    with jax.named_scope("flash_attention"):
        qh = jnp.swapaxes(q, 1, 2)
        kh = jnp.swapaxes(k, 1, 2)
        vh = jnp.swapaxes(v, 1, 2)
        o = flash_attention_bhsd(
            qh, kh, vh, causal=causal, window=window, block_q=block_q, block_k=block_k,
            interpret=interpret,
        )
        return jnp.swapaxes(o, 1, 2)


@functools.partial(jax.jit, static_argnames=("block_s", "block_w", "interpret"))
def rglru_scan(
    a: jax.Array,  # (B, S, W)
    b: jax.Array,
    *,
    block_s: int = 128,
    block_w: int = 512,
    interpret: bool = False,
) -> jax.Array:
    with jax.named_scope("rglru_scan"):
        return rglru_scan_pallas(a, b, block_s=block_s, block_w=block_w, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def fused_rmsnorm(
    x: jax.Array,
    scale: jax.Array,
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    with jax.named_scope("fused_rmsnorm"):
        return fused_rmsnorm_pallas(x, scale, eps=eps, block_rows=block_rows, interpret=interpret)
