"""RG-LRU linear-scan Pallas TPU kernel.

Computes h_t = a_t * h_{t-1} + b_t over the sequence axis, the core
recurrence of the Griffin RG-LRU (gates are dense einsums handled by XLA;
the kernel owns the scan, which XLA cannot fuse well).

TPU adaptation: the recurrence is elementwise over the feature axis, so the
kernel tiles **features into VMEM lanes** and streams **sequence blocks**
from HBM:

  grid = (batch, n_feature_blocks, n_seq_blocks)   (seq innermost)

The hidden state h (1, Bw) persists in VMEM scratch across sequence blocks of
a fixed (batch, feature-block); inside a block the scan is an unrolled
vector recurrence over Bs rows (VPU work, no MXU). The roofline is
memory-bound: 3 streams (a, b in; h out) at HBM bandwidth — matching the
§Roofline memory term, which is exactly why this op deserves a kernel rather
than a materialized ``associative_scan`` (which moves O(S log S) HBM bytes).

Validated in interpret mode against ``ref.rglru_ref`` (sequential lax.scan).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, o_ref, h_ref, *, block_s: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)  # (Bs, Bw)
    b = b_ref[0].astype(jnp.float32)
    h = h_ref[...]  # (1, Bw)

    rows = []
    for t in range(block_s):  # unrolled vector recurrence within the block
        h = a[t : t + 1, :] * h + b[t : t + 1, :]
        rows.append(h)
    o_ref[0] = jnp.concatenate(rows, axis=0).astype(o_ref.dtype)
    h_ref[...] = h


def rglru_scan_pallas(
    a: jax.Array,  # (B, S, W) decay in (0,1]
    b: jax.Array,  # (B, S, W) gated input
    *,
    block_s: int = 128,
    block_w: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, S, W = a.shape
    block_s = min(block_s, S)
    block_w = min(block_w, W)
    pad_s = (-S) % block_s
    pad_w = (-W) % block_w
    if pad_s or pad_w:
        # pad a with 1s would corrupt state; pad sequence with a=0,b=0 (keeps h)
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, pad_w)))
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, pad_w)))
    Sp, Wp = S + pad_s, W + pad_w
    grid = (B, Wp // block_w, Sp // block_s)
    out = pl.pallas_call(
        functools.partial(_rglru_kernel, block_s=block_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_w), lambda b_, w, s: (b_, s, w)),
            pl.BlockSpec((1, block_s, block_w), lambda b_, w, s: (b_, s, w)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_w), lambda b_, w, s: (b_, s, w)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, Wp), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(a, b)
    return out[:, :S, :W]
