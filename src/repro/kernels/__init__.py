"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ships as <name>.py (pl.pallas_call + BlockSpec tiling), a jit'd
wrapper in ops.py, and a pure-jnp oracle in ref.py. TPU is the target; CPU
validation runs the kernel bodies under interpret=True.
"""

from . import ops, ref

__all__ = ["ops", "ref"]
