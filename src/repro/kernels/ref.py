"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (B, Hq, S, D)
    k: jax.Array,  # (B, Hkv, T, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    B, Hq, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, S, D).astype(jnp.float32)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    s = jnp.einsum("bkgsd,bktd->bkgst", qg, kf) / math.sqrt(D)
    q_idx = jnp.arange(S)[:, None]
    k_idx = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= k_idx <= q_idx
    if window is not None:
        mask &= (q_idx - k_idx) < window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bktd->bkgsd", p, vf)
    return o.reshape(B, Hq, S, D).astype(q.dtype)


def rglru_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Sequential scan: h_t = a_t h_{t-1} + b_t. a, b: (B, S, W)."""

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    af, bf = a.astype(jnp.float32), b.astype(jnp.float32)
    h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
    _, hs = jax.lax.scan(step, h0, (jnp.moveaxis(af, 1, 0), jnp.moveaxis(bf, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).astype(a.dtype)


def rmsnorm_ref(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)
