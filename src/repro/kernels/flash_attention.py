"""Flash attention Pallas TPU kernel: online-softmax, VMEM-tiled.

Grid: (batch, q_head, n_q_blocks, n_kv_blocks), kv innermost. For a fixed
(b, h, iq) the kernel visits every kv block consecutively, carrying the
running max ``m``, normalizer ``l`` and accumulator ``acc`` in VMEM scratch —
the classic flash recurrence, adapted to the TPU memory hierarchy:

* HBM -> VMEM movement is declared by BlockSpecs: q block (1,1,Bq,D),
  kv blocks (1,1,Bk,D); the MXU sees (Bq,D)x(D,Bk)^T and (Bq,Bk)x(Bk,D)
  matmuls with Bq/Bk multiples of 128 and D on the lane dimension;
* accumulators are f32 VMEM scratch; inputs stay bf16;
* GQA maps q-head h to kv-head h // (Hq // Hkv) inside the kv index_map —
  KV is never materialized per q-head;
* causal + sliding-window masks are applied in-block. (§Perf TODO: skip
  fully-masked kv blocks by shrinking the grid; masking keeps the kernel
  shape-generic for the sweep tests.)

Validated in interpret mode against ``ref.attention_ref`` over a
shape/dtype/mask sweep (tests/test_kernels.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _flash_kernel(
    q_ref, k_ref, v_ref,  # (1,1,Bq,D), (1,1,Bk,D), (1,1,Bk,D)
    o_ref,  # (1,1,Bq,D)
    m_ref, l_ref, acc_ref,  # scratch: (Bq,1), (Bq,1), (Bq,D) f32
    *,
    causal: bool,
    window: int | None,
    block_q: int,
    block_k: int,
    kv_len: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    n_k = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (Bq, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (Bk, D)
    v = v_ref[0, 0].astype(jnp.float32)
    d = q.shape[-1]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * (1.0 / math.sqrt(d))  # (Bq, Bk)

    q_idx = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_idx = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_idx < kv_len  # kv padding
    if causal:
        mask &= k_idx <= q_idx
    if window is not None:
        mask &= (q_idx - k_idx) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]  # (Bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array,  # (B, Hq, S, D)
    k: jax.Array,  # (B, Hkv, T, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, S, D = q.shape
    _, Hkv, T, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    pad_q = (-S) % block_q
    pad_k = (-T) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sp, Tp = S + pad_q, T + pad_k
    grid = (B, Hq, Sp // block_q, Tp // block_k)

    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        kv_len=T,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S, :]
