"""Compact binary snapshots + on-disk timeline ring for :class:`CallTree`.

The daemon's live tree answers "where is time going *now*", but the paper's
headline case studies (coherence livelock forensics, CPU-model comparisons)
need the *time evolution* of the tree and *differences* across runs.  This
module provides the storage layer for both:

* **Snapshot codec** — a versioned, CRC-framed binary encoding of a
  ``CallTree``.  Strings (frame names *and* metric keys) are interned per
  segment, integers are LEB128 varints, and an epoch record encodes only the
  **delta against the previous epoch** (changed nodes, changed metric keys),
  so steady-state epochs cost bytes proportional to the window's activity,
  not to the accumulated tree.

* **Timeline ring** (:class:`TimelineWriter` / :class:`TimelineReader`) — a
  directory of segment files, each opening with a *keyframe* (a full
  snapshot) followed by delta epochs.  Retention is bounded by dropping whole
  segments (oldest first); because every segment is self-contained
  (keyframe + per-segment string table), dropped history never breaks decode.
  Appends are crash-safe: every record carries a length + CRC32 header, a
  torn tail is detected and ignored on read, and the next segment's keyframe
  resynchronizes the cumulative state.

* **Epoch sealer** (:class:`EpochSealer`) — the daemon-side producer.  It
  keeps a per-node "last sealed" shadow value and, fed the set of node chains
  the ingestor touched during the epoch (see
  :meth:`repro.profilerd.ingest.TreeIngestor.drain_epoch`), builds the delta
  in O(touched paths) — the live tree is never walked in full on the epoch
  cadence, which is what keeps sealing under the <5 % ingest-overhead budget
  (``benchmarks/timeline_overhead.py``).

Single snapshots (CI baselines, ``profilerd check``) use the same format via
:func:`save_snapshot` / :func:`load_snapshot` — one keyframe record in a file.
"""

from __future__ import annotations

import os
import struct
import zlib
from dataclasses import dataclass
from collections.abc import Iterator, Sequence

from .calltree import CallNode, CallTree

MAGIC = b"RTL1"
FORMAT_VERSION = 1

K_FULL = 1  # absolute snapshot (keyframe)
K_DELTA = 2  # generic tree delta vs the previous epoch record
K_COUNTS = 3  # samples-plane delta: (interned root->leaf path, count) pairs

_HDR = struct.Struct("<4sHH")  # magic, format version, reserved
_REC = struct.Struct("<II")  # payload length, crc32(payload)
_F64 = struct.Struct("<d")

SEGMENT_SUFFIX = ".tl"
SNAPSHOT_SUFFIX = ".snap"


class SnapshotError(RuntimeError):
    pass


class SnapshotCorrupt(SnapshotError):
    """Bad magic, CRC mismatch, or a payload that does not parse."""


class SnapshotVersionError(SnapshotError):
    """The file announces a format version newer than this reader."""


@dataclass
class EpochMeta:
    """Per-epoch header: when it was sealed and how far the target had got.

    ``progress`` is a monotonically non-decreasing counter whose *stall*
    distinguishes a livelock from plain dominance (the daemon uses the number
    of distinct call-sites ever sealed; see ``core.detector.TrendDetector``).
    """

    epoch: int
    wall_time: float = 0.0
    progress: float = 0.0
    kind: int = K_DELTA


# -- varints ----------------------------------------------------------------


def _wv(out: bytearray, v: int) -> None:
    """Append one unsigned LEB128 varint (fast path: single byte)."""
    if v < 0x80:
        out.append(v)
        return
    while True:
        b = v & 0x7F
        v >>= 7
        out.append(b | (0x80 if v else 0))
        if not v:
            return


def _rv(buf: bytes, off: int) -> tuple[int, int]:
    v = 0
    shift = 0
    while True:
        b = buf[off]
        off += 1
        v |= (b & 0x7F) << shift
        if not b & 0x80:
            return v, off
        shift += 7


class _StringTable:
    """Encoder-side intern table; fresh strings ride in the record payload."""

    def __init__(self) -> None:
        self._ids: dict[str, int] = {}
        self._fresh: list[str] = []

    def id(self, s: str) -> int:
        sid = self._ids.get(s)
        if sid is None:
            sid = len(self._ids)
            self._ids[s] = sid
            self._fresh.append(s)
        return sid

    def drain_fresh(self) -> list[str]:
        fresh, self._fresh = self._fresh, []
        return fresh


# -- payload codec ----------------------------------------------------------


def _enc_node(node: CallNode, tab: _StringTable, out: bytearray) -> None:
    # Keyframe hot path: one call per tree node, so the string-table lookup
    # and the (almost always single-byte) varints are inlined — a 15k-node
    # keyframe has ~90k of each, and call overhead would dominate otherwise.
    ids = tab._ids
    fresh = tab._fresh
    pack = _F64.pack
    append = out.append

    def enc(node: CallNode) -> None:
        v = ids.get(node.name)
        if v is None:
            v = len(ids)
            ids[node.name] = v
            fresh.append(node.name)
        if v < 0x80:
            append(v)
        else:
            _wv(out, v)
        for metrics in (node.metrics, node.self_metrics):
            n = len(metrics)
            if n < 0x80:
                append(n)
            else:
                _wv(out, n)
            for k, val in metrics.items():
                kid = ids.get(k)
                if kid is None:
                    kid = len(ids)
                    ids[k] = kid
                    fresh.append(k)
                if kid < 0x80:
                    append(kid)
                else:
                    _wv(out, kid)
                out.extend(pack(val))
        kids = node.children
        n = len(kids)
        if n < 0x80:
            append(n)
        else:
            _wv(out, n)
        for c in kids.values():
            enc(c)

    enc(node)


def _payload_head(kind: int, meta: EpochMeta, tab: _StringTable, body: bytes) -> bytes:
    """Assemble kind + meta + the string defs the body freshly interned."""
    head = bytearray()
    head.append(kind)
    _wv(head, meta.epoch)
    head += _F64.pack(meta.wall_time)
    head += _F64.pack(meta.progress)
    fresh = tab.drain_fresh()
    _wv(head, len(fresh))
    for s in fresh:
        raw = s.encode()
        _wv(head, len(raw))
        head += raw
    return bytes(head) + body


def _encode_payload(kind: int, meta: EpochMeta, tree: CallTree, tab: _StringTable) -> bytes:
    nodes = bytearray()
    _enc_node(tree.root, tab, nodes)  # interns names/metric keys, may add fresh
    return _payload_head(kind, meta, tab, bytes(nodes))


def _encode_counts_payload(
    meta: EpochMeta,
    items,  # iterable of (chain, count); chain = [root, ...nodes] CallNode refs
    tab: _StringTable,
    path_tab: dict[int, int],
    metric: str,
) -> bytes:
    """Encode one samples-plane epoch as interned path counts.

    This is the daemon's sealing fast path: one table lookup and two varints
    per *touched chain* (not per node), so a dense steady-state epoch seals in
    O(chains).  A chain's root->leaf name path crosses the wire once per
    segment (``path_tab`` maps ``id(chain)`` -> path id); the caller must keep
    the chain objects alive for the lifetime of the table (the ingestor's
    chain cache does).
    """
    defs = bytearray()
    counts = bytearray()
    n_defs = 0
    n_counts = 0
    ids = tab._ids
    fresh = tab._fresh
    dappend = defs.append
    for chain, count in items:
        if count <= 0:
            continue
        pid = path_tab.get(id(chain))
        if pid is None:
            pid = len(path_tab)
            path_tab[id(chain)] = pid
            _wv(defs, len(chain) - 1)
            for node in chain[1:]:
                nid = ids.get(node.name)
                if nid is None:
                    nid = len(ids)
                    ids[node.name] = nid
                    fresh.append(node.name)
                if nid < 0x80:
                    dappend(nid)
                else:
                    _wv(defs, nid)
            n_defs += 1
        _wv(counts, pid)
        _wv(counts, int(count))
        n_counts += 1
    body = bytearray()
    _wv(body, tab.id(metric))
    _wv(body, n_defs)
    body += defs
    _wv(body, n_counts)
    body += counts
    return _payload_head(K_COUNTS, meta, tab, bytes(body))


def _apply_node(buf: bytes, off: int, strings: list[str], parent: CallNode | None, tree: CallTree) -> int:
    nid, off = _rv(buf, off)
    if parent is None:
        node = tree.root  # the encoded root name is canonical; keep ours
    else:
        node = parent.child(strings[nid])
    nm, off = _rv(buf, off)
    m = node.metrics
    for _ in range(nm):
        kid, off = _rv(buf, off)
        (v,) = _F64.unpack_from(buf, off)
        off += _F64.size
        k = strings[kid]
        m[k] = m.get(k, 0.0) + v
    ns, off = _rv(buf, off)
    s = node.self_metrics
    for _ in range(ns):
        kid, off = _rv(buf, off)
        (v,) = _F64.unpack_from(buf, off)
        off += _F64.size
        k = strings[kid]
        s[k] = s.get(k, 0.0) + v
    nc, off = _rv(buf, off)
    for _ in range(nc):
        off = _apply_node(buf, off, strings, node, tree)
    return off


def _decode_payload(
    payload: bytes, strings: list[str], paths: list[list[str]] | None = None
) -> tuple[EpochMeta, CallTree]:
    if paths is None:
        paths = []
    try:
        kind = payload[0]
        if kind not in (K_FULL, K_DELTA, K_COUNTS):
            raise SnapshotCorrupt(f"unknown record kind {kind}")
        epoch, off = _rv(payload, 1)
        (wall_time,) = _F64.unpack_from(payload, off)
        off += _F64.size
        (progress,) = _F64.unpack_from(payload, off)
        off += _F64.size
        n_fresh, off = _rv(payload, off)
        for _ in range(n_fresh):
            ln, off = _rv(payload, off)
            strings.append(payload[off : off + ln].decode("utf-8", "replace"))
            off += ln
        tree = CallTree()
        if kind == K_COUNTS:
            mid, off = _rv(payload, off)
            metric = strings[mid]
            n_defs, off = _rv(payload, off)
            for _ in range(n_defs):
                n_names, off = _rv(payload, off)
                path = []
                for _ in range(n_names):
                    nid, off = _rv(payload, off)
                    path.append(strings[nid])
                paths.append(path)
            n_counts, off = _rv(payload, off)
            for _ in range(n_counts):
                pid, off = _rv(payload, off)
                count, off = _rv(payload, off)
                tree.add_stack(paths[pid], {metric: float(count)})
        else:
            off = _apply_node(payload, off, strings, None, tree)
        if off != len(payload):
            raise SnapshotCorrupt(f"{len(payload) - off} trailing bytes in record")
    except (IndexError, struct.error) as e:
        raise SnapshotCorrupt(f"truncated record payload: {e}") from None
    return EpochMeta(epoch, wall_time, progress, kind), tree


def _frame(payload: bytes) -> bytes:
    return _REC.pack(len(payload), zlib.crc32(payload) & 0xFFFFFFFF) + payload


def _check_header(data: bytes, path: str) -> None:
    if len(data) < _HDR.size:
        raise SnapshotCorrupt(f"{path}: truncated header")
    magic, version, _ = _HDR.unpack_from(data, 0)
    if magic != MAGIC:
        raise SnapshotCorrupt(f"{path}: bad magic {magic!r}")
    if version > FORMAT_VERSION:
        raise SnapshotVersionError(
            f"{path}: format version {version} > supported {FORMAT_VERSION}"
        )


def _parse_segment(data: bytes, path: str) -> tuple[list[tuple[EpochMeta, CallTree]], bool]:
    """Decode a segment's records; ``clean`` is False at a torn/corrupt tail.

    Corruption never raises here (crash-safe append contract): a torn or
    bad header yields no records, and decoding stops at the first bad record
    — everything after it is untrusted — with the next segment's keyframe
    resynchronizing the cumulative state.  Version skew still raises: a
    newer-format segment is not corruption and must refuse loudly.
    """
    try:
        _check_header(data, path)
    except SnapshotVersionError:
        raise
    except SnapshotCorrupt:
        return [], False  # e.g. crash between segment open() and header write
    strings: list[str] = []
    paths: list[list[str]] = []
    out: list[tuple[EpochMeta, CallTree]] = []
    off = _HDR.size
    while off < len(data):
        if len(data) - off < _REC.size:
            return out, False  # torn length header: crash mid-append
        n, crc = _REC.unpack_from(data, off)
        start = off + _REC.size
        if start + n > len(data):
            return out, False  # torn payload
        payload = data[start : start + n]
        if zlib.crc32(payload) & 0xFFFFFFFF != crc:
            return out, False
        try:
            out.append(_decode_payload(payload, strings, paths))
        except SnapshotCorrupt:
            return out, False
        off = start + n
    return out, True


# -- single-snapshot files --------------------------------------------------


def save_snapshot(tree: CallTree, path: str, meta: EpochMeta | None = None) -> str:
    """Write one full snapshot (CI baselines, ``profilerd check`` refs).

    Defaults are deterministic (no wall clock) so a committed baseline file is
    byte-reproducible from the same tree.
    """
    meta = meta or EpochMeta(0)
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    payload = _encode_payload(K_FULL, meta, tree, _StringTable())
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(_HDR.pack(MAGIC, FORMAT_VERSION, 0))
        f.write(_frame(payload))
    os.replace(tmp, path)
    return path


def load_snapshot(path: str) -> tuple[EpochMeta, CallTree]:
    with open(path, "rb") as f:
        data = f.read()
    _check_header(data, path)
    if len(data) < _HDR.size + _REC.size:
        raise SnapshotCorrupt(f"{path}: no record")
    n, crc = _REC.unpack_from(data, _HDR.size)
    start = _HDR.size + _REC.size
    payload = data[start : start + n]
    if len(payload) < n or zlib.crc32(payload) & 0xFFFFFFFF != crc:
        raise SnapshotCorrupt(f"{path}: record CRC mismatch")
    meta, tree = _decode_payload(payload, [])
    return meta, tree


# -- timeline ring ----------------------------------------------------------


class TimelineWriter:
    """Append epochs into a bounded ring of self-contained segment files.

    Every segment starts with a keyframe (full snapshot) and a fresh string
    table, so retention can unlink whole old segments without breaking the
    survivors.  A write failure poisons only the current segment: the next
    append opens a new one with a keyframe.

    A writer owns its directory for one run: any segments left by a previous
    run are removed before the first segment is written (epoch numbering
    restarts, so stale segments would otherwise shadow or extend the new
    ring and a reader could silently reconstruct the *old* run's tree).
    The purge is deferred to the first write so that merely constructing a
    writer — e.g. a daemon whose attach then times out — cannot destroy the
    previous run's history.

    ``preserve=True`` opts out of the purge for writers that *continue* a
    ring across process restarts (the regional aggregator recovers its state
    from the ring and keeps epoch numbering monotonic, so the old segments
    stay valid history); retention still unlinks the oldest segments past
    ``max_segments``.
    """

    def __init__(
        self,
        dir_path: str,
        epochs_per_segment: int = 16,
        max_segments: int = 64,
        fsync: bool = False,
        preserve: bool = False,
    ):
        if epochs_per_segment < 1 or max_segments < 1:
            raise ValueError("epochs_per_segment and max_segments must be >= 1")
        self.dir = dir_path
        self.epochs_per_segment = epochs_per_segment
        self.max_segments = max_segments
        self.fsync = fsync
        os.makedirs(dir_path, exist_ok=True)
        self._purged = preserve
        self._f = None
        self._tab = _StringTable()
        self._path_tab: dict[int, int] = {}  # id(chain) -> per-segment path id
        self._records = 0
        self.epochs_written = 0

    def needs_keyframe(self) -> bool:
        return self._f is None or self._records >= self.epochs_per_segment

    def append_full(self, tree: CallTree, meta: EpochMeta) -> None:
        """Rotate to a new segment and write ``tree`` as its keyframe."""
        self._rotate(meta.epoch)
        self._write(_encode_payload(K_FULL, meta, tree, self._tab))

    def append_delta(self, delta: CallTree, meta: EpochMeta) -> None:
        """Append one delta epoch to the open segment (keyframe must exist)."""
        if self._f is None:
            raise SnapshotError("no open segment: write a keyframe first")
        self._write(_encode_payload(K_DELTA, meta, delta, self._tab))

    def append_counts(self, items, meta: EpochMeta, metric: str = "samples") -> None:
        """Append one epoch of ``(chain, count)`` pairs (the sealing fast path).

        Chains must stay alive while the segment is open (path ids key on
        ``id(chain)``); the ingestor's chain cache guarantees that.
        """
        if self._f is None:
            raise SnapshotError("no open segment: write a keyframe first")
        self._write(_encode_counts_payload(meta, items, self._tab, self._path_tab, metric))

    def _rotate(self, epoch: int) -> None:
        self.close()
        if not self._purged:
            for stale in list_segments(self.dir):
                try:
                    os.unlink(stale)
                except OSError:
                    pass
            self._purged = True
        path = os.path.join(self.dir, f"seg-{epoch:010d}{SEGMENT_SUFFIX}")
        self._f = open(path, "wb")
        self._f.write(_HDR.pack(MAGIC, FORMAT_VERSION, 0))
        self._f.flush()
        self._tab = _StringTable()
        self._path_tab = {}
        self._records = 0
        segs = list_segments(self.dir)
        for old in segs[: max(0, len(segs) - self.max_segments)]:
            try:
                os.unlink(old)
            except OSError:
                pass

    def _write(self, payload: bytes) -> None:
        try:
            self._f.write(_frame(payload))
            self._f.flush()
            if self.fsync:
                os.fsync(self._f.fileno())
        except OSError:
            # Poisoned segment: drop it from the writer; the next append
            # keyframes into a fresh file and the reader's CRC check skips
            # whatever half-record landed here.
            self.close()
            raise
        self._records += 1
        self.epochs_written += 1

    def close(self) -> None:
        if self._f is not None:
            try:
                self._f.close()
            finally:
                self._f = None


def list_segments(dir_path: str) -> list[str]:
    try:
        names = os.listdir(dir_path)
    except OSError:
        return []
    return [
        os.path.join(dir_path, n)
        for n in sorted(names)
        if n.startswith("seg-") and n.endswith(SEGMENT_SUFFIX)
    ]


def is_timeline_dir(path: str) -> bool:
    return os.path.isdir(path) and bool(list_segments(path))


class TimelineReader:
    """Replay a timeline ring: per-epoch windows plus the running cumulative.

    ``epochs()`` yields ``(meta, window, cumulative)``; ``cumulative`` is the
    reader's live accumulator (copy it to retain across iterations).  A torn
    or corrupt record ends its segment (``truncated`` is set); the next
    segment's keyframe resynchronizes the cumulative state.
    """

    def __init__(self, dir_path: str):
        self.dir = dir_path
        self.truncated = False

    def epochs(self) -> Iterator[tuple[EpochMeta, CallTree, CallTree]]:
        cum = CallTree()
        seen_any = False
        for seg in list_segments(self.dir):
            try:
                with open(seg, "rb") as f:
                    data = f.read()
            except OSError:
                continue
            records, clean = _parse_segment(data, seg)
            if not clean:
                self.truncated = True
            for meta, tree in records:
                if meta.kind == K_FULL:
                    window = tree.diff(cum) if seen_any else tree.copy()
                    cum = tree
                else:
                    window = tree
                    cum.merge(tree)
                seen_any = True
                yield meta, window, cum

    def last(self) -> tuple[EpochMeta, CallTree] | None:
        """Final ``(meta, cumulative)`` without replaying the whole ring.

        Every segment opens with a keyframe, so the final cumulative depends
        only on the newest segment holding decodable records — scan segments
        from the end instead of decoding up to ``max_segments`` of history.
        """
        for seg in reversed(list_segments(self.dir)):
            try:
                with open(seg, "rb") as f:
                    data = f.read()
            except OSError:
                continue
            records, clean = _parse_segment(data, seg)
            if not clean:
                self.truncated = True
            if not records:
                continue
            if records[0][0].kind != K_FULL:
                break  # non-keyframe segment start: fall back to a full replay
            cum: CallTree | None = None
            for meta, tree in records:
                if meta.kind == K_FULL:
                    cum = tree
                else:
                    cum.merge(tree)
            return records[-1][0], cum
        out = None
        for meta, _window, cum in self.epochs():
            out = (meta, cum)
        return out  # cum is final: safe to hand out without a copy


def read_epochs(dir_path: str, copy_cumulative: bool = False) -> list[tuple[EpochMeta, CallTree, CallTree]]:
    """Materialize a timeline (small histories; prefer the iterator for big ones)."""
    reader = TimelineReader(dir_path)
    out = []
    for meta, window, cum in reader.epochs():
        out.append((meta, window, cum.copy() if copy_cumulative else cum))
    return out


# -- epoch sealing ----------------------------------------------------------


class EpochSealer:
    """Seal the live tree's epoch windows into a :class:`TimelineWriter`.

    Keeps a per-node shadow of the last sealed metric values; the delta for
    an epoch is computed only over the node chains the ingestor touched
    (O(touched paths), not O(tree)).  Keyframes (segment rotation) and the
    untracked fallback (legacy v1 samples mutate the tree outside the chain
    cache) do a full-tree resync.
    """

    def __init__(self, tree: CallTree, writer: TimelineWriter | None = None):
        self.tree = tree
        self.writer = writer
        self.epoch = 0
        # id(node) -> (node ref, sealed metrics, sealed self-metrics).  The
        # node ref pins the object so ids can never be recycled under us.
        self._sealed: dict[int, tuple[CallNode, dict, dict]] = {}

    @property
    def node_count(self) -> int:
        """Distinct call-sites ever sealed — the default progress metric."""
        return len(self._sealed)

    def _delta_vs_sealed(self, real: CallNode) -> tuple[dict, dict]:
        cur_m = dict(real.metrics)
        cur_s = dict(real.self_metrics)
        ent = self._sealed.get(id(real))
        self._sealed[id(real)] = (real, cur_m, cur_s)
        if ent is None:
            return dict(cur_m), dict(cur_s)
        _, pm, ps = ent
        dm = {k: v - pm.get(k, 0.0) for k, v in cur_m.items() if v != pm.get(k, 0.0)}
        ds = {k: v - ps.get(k, 0.0) for k, v in cur_s.items() if v != ps.get(k, 0.0)}
        return dm, ds

    def _delta_from_chains(self, chains: Sequence[Sequence[CallNode]]) -> CallTree:
        root_real = self.tree.root
        mirror_root = CallNode(root_real.name)
        mirrors: dict[int, CallNode] = {id(root_real): mirror_root}
        order: list[CallNode] = [root_real]
        for chain in chains:
            parent = mirror_root
            for node in chain[1:]:
                m = mirrors.get(id(node))
                if m is None:
                    m = CallNode(node.name)
                    parent.children[node.name] = m
                    mirrors[id(node)] = m
                    order.append(node)
                parent = m
        for real in order:
            dm, ds = self._delta_vs_sealed(real)
            mirror = mirrors[id(real)]
            mirror.metrics = dm
            mirror.self_metrics = ds
        return CallTree(mirror_root)

    def _delta_full_walk(self) -> CallTree:
        def rec(real: CallNode) -> CallNode | None:
            dm, ds = self._delta_vs_sealed(real)
            kids = {}
            for name, c in real.children.items():
                mc = rec(c)
                if mc is not None:
                    kids[name] = mc
            if not dm and not ds and not kids:
                return None
            node = CallNode(real.name, dm, ds)
            node.children = kids
            return node

        node = rec(self.tree.root)
        return CallTree(node if node is not None else CallNode(CallTree.ROOT))

    def _resync_all(self) -> None:
        for _path, node in self.tree.root.walk():
            self._sealed[id(node)] = (node, dict(node.metrics), dict(node.self_metrics))

    def seal(
        self,
        chains: Sequence[Sequence[CallNode]] | None = None,
        *,
        wall_time: float = 0.0,
        progress: float | None = None,
        full_walk: bool = False,
    ) -> tuple[EpochMeta, CallTree]:
        """Seal one epoch; returns ``(meta, window_delta_tree)``.

        ``chains`` is the ingestor's dirty set for the epoch; ``full_walk``
        forces the O(tree) diff (required whenever mutations bypassed the
        chain cache).  The window delta is returned even when the record
        written is a keyframe, so detectors always see per-epoch activity.
        """
        if chains is None or full_walk:
            delta = self._delta_full_walk()
        else:
            delta = self._delta_from_chains(chains)
        meta = EpochMeta(
            self.epoch,
            wall_time,
            float(len(self._sealed)) if progress is None else progress,
        )
        if self.writer is not None:
            if self.writer.needs_keyframe():
                meta.kind = K_FULL
                self.writer.append_full(self.tree, meta)
                self._resync_all()
            else:
                self.writer.append_delta(delta, meta)
        self.epoch += 1
        return meta, delta


class CountSealer:
    """Samples-plane epoch sealer: O(touched chains) per epoch, no tree walk.

    The generic :class:`EpochSealer` diffs *nodes*, which a dense steady-state
    epoch turns into an O(tree) walk with per-node dict copies — two orders of
    magnitude over the <5 % ingest-overhead budget.  The daemon's host plane
    only ever bumps whole-sample counts along cached chains, so its epoch
    delta is fully described by ``(chain, hit count)`` pairs, which the
    ingestor already maintains — one integer add per sample on the scalar
    path, one *aggregated* add per ``(thread, stack)`` group on the
    vectorized batch path (``TreeIngestor.ingest_batch``): either way the
    entries arrive here pre-summed, there is no per-hit work at seal time.
    Sealing then writes a :data:`K_COUNTS` record: two varints per touched
    chain, with counts coerced through ``int()`` so numpy integers from the
    batch lane encode identically to Python ints.

    Keyframes (segment rotation) still write a full snapshot; mutations that
    bypass the chain cache (legacy v1 samples, cache overflow) force an early
    keyframe, because a counts record could not describe them.
    """

    def __init__(self, tree: CallTree, writer: TimelineWriter, metric: str = "samples"):
        self.tree = tree
        self.writer = writer
        self.metric = metric
        self.epoch = 0
        # Every chain ever sealed, pinned so path-table ids(chain) stay valid
        # and to serve as the progress counter (distinct stacks ever seen —
        # a livelocked target stops minting new ones).
        self._seen: dict[int, object] = {}

    @property
    def node_count(self) -> int:
        """Distinct stacks ever sealed — the default progress metric."""
        return len(self._seen)

    def seal(
        self,
        entries,  # ingestor epoch entries: [chain, depth, stamp, count]
        *,
        wall_time: float = 0.0,
        progress: float | None = None,
        untracked: bool = False,
    ) -> EpochMeta:
        seen = self._seen
        for e in entries:
            chain = e[0]
            if id(chain) not in seen:
                seen[id(chain)] = chain
        meta = EpochMeta(
            self.epoch,
            wall_time,
            float(len(seen)) if progress is None else progress,
        )
        if untracked or self.writer.needs_keyframe():
            # The keyframe snapshots the live tree, which already contains
            # every count drained into ``entries`` — they must not be
            # re-applied, so they are consumed here.
            meta.kind = K_FULL
            self.writer.append_full(self.tree, meta)
        else:
            self.writer.append_counts(((e[0], e[3]) for e in entries), meta, self.metric)
        self.epoch += 1
        return meta
