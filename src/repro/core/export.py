"""Universal call-tree exporters: folded stacks, speedscope, flamegraph HTML.

Every profile artifact in this repo is a :class:`~repro.core.calltree.CallTree`
— the live daemon tree, sealed epoch windows, cross-run diffs, the launcher's
fleet merge — so one exporter layer makes all of them consumable by standard
tooling:

* **folded** (:func:`to_folded`) — Brendan Gregg collapsed-stack lines
  (``a;b;c 42``), the interchange format every flamegraph tool reads.  Values
  are *residual self* values (a node's inclusive metric minus its children's),
  so :func:`from_folded` re-ingests a folded dump into a tree with identical
  inclusive metrics at every node — the exporter round-trips.
* **speedscope** (:func:`to_speedscope`) — the `speedscope file-format schema
  <speedscope.app>`-shaped JSON (``shared.frames`` + one ``sampled`` profile),
  loadable by drag-and-drop.
* **flamegraph HTML** (:func:`flamegraph_html`) — a single self-contained
  page (no CDN, no external URL): rect layout, click-to-zoom, hover details.
  Diff trees built by :func:`build_diff_tree` render with share-delta
  coloring — red where the candidate gained share over the baseline, blue
  where it lost — the visual form of ``profilerd diff``.

:func:`export_tree` routes any ``(tree, format)`` pair through an optional
:class:`~repro.core.report.ViewConfig`, so all the library views in
:mod:`repro.core.views_library` export uniformly; the ``profilerd`` HTTP
server and the ``export`` subcommand are thin wrappers over it.
"""

from __future__ import annotations

import html as _html
import json
from collections.abc import Iterator

from .calltree import SAMPLES, CallNode, CallTree

EXPORT_FORMATS = ("csv", "folded", "speedscope", "html", "json")

#: metric keys a diff tree carries beside the compared metric
DIFF_BASELINE = "baseline"
DIFF_SHARE_DELTA = "share_delta"

CONTENT_TYPES = {
    "csv": "text/csv; charset=utf-8",
    "folded": "text/plain; charset=utf-8",
    "speedscope": "application/json",
    "json": "application/json",
    "html": "text/html; charset=utf-8",
}


# -- folded (collapsed) stacks ----------------------------------------------


def iter_folded(tree: CallTree, metric: str = SAMPLES) -> Iterator[tuple[tuple[str, ...], float]]:
    """Yield ``(path, residual)`` per node, children sorted by name.

    ``residual`` is the node's inclusive value minus its children's inclusive
    sum — the value attributable to *exactly* this stack.  For trees built
    from stack samples it equals the self value; defining it structurally
    makes the fold → re-ingest roundtrip exact for any tree (including
    device-plane metrics and windowed deltas, where negatives can appear).
    A nonzero residual on the synthetic root (samples ingested with an empty
    stack) is yielded with the empty path ``()`` so no mass is ever dropped.
    """

    def rec(node: CallNode, path: tuple[str, ...]) -> Iterator[tuple[tuple[str, ...], float]]:
        kids = sorted(node.children.values(), key=lambda c: c.name)
        residual = node.metrics.get(metric, 0.0) - sum(c.metrics.get(metric, 0.0) for c in kids)
        if residual:
            yield path, residual
        for c in kids:
            yield from rec(c, path + (c.name,))

    yield from rec(tree.root, ())


def _escape_frame(name: str) -> str:
    # ';' is the folded-format separator and '\n' the record separator; a
    # frame (e.g. an arbitrary HLO op_name path) may contain either.
    return name.replace("\\", "\\\\").replace(";", "\\;").replace("\n", "\\n")


def _split_frames(stack: str) -> list[str]:
    frames: list[str] = []
    cur: list[str] = []
    it = iter(stack)
    for ch in it:
        if ch == "\\":
            nxt = next(it, "")
            cur.append("\n" if nxt == "n" else nxt)
        elif ch == ";":
            frames.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    frames.append("".join(cur))
    return frames


def to_folded(tree: CallTree, metric: str = SAMPLES) -> str:
    """FlameGraph-compatible collapsed stacks, one ``a;b;c value`` per line.

    Values use ``repr`` (shortest exact float roundtrip) and frame names
    escape ``;``/``\\``/newlines, so the text layer never loses information.
    Re-ingestion is bit-exact whenever the residuals themselves are (always
    true for count-like metrics; a parent mixing a tiny self value into a
    huge child total is subject to ordinary float subtraction error, text
    format regardless).
    """
    lines = []
    for path, v in iter_folded(tree, metric):
        if not path:
            # Root residual (empty-stack samples): the root token is the only
            # way folded text can carry it; from_folded maps it back to [].
            stack = CallTree.ROOT
        else:
            stack = ";".join(_escape_frame(f) for f in path)
            if stack == CallTree.ROOT:  # a real frame named "<root>": disambiguate
                stack = "\\" + stack
            if stack.startswith("#"):  # would read back as a comment line
                stack = "\\" + stack
        lines.append(f"{stack} {v!r}")
    return "\n".join(lines)


def from_folded(text: str, metric: str = SAMPLES) -> CallTree:
    """Re-ingest a folded dump (inverse of :func:`to_folded`)."""
    tree = CallTree()
    # Split on '\n' only (not splitlines): '\r', '\x0b', ' ' etc. are
    # legal inside frame names and must not break records.  The rstrip below
    # still swallows a '\r\n' ending from externally-produced files.
    for line in text.split("\n"):
        if not line.strip() or (line.startswith("#") and not line.startswith("\\#")):
            continue
        # No lstrip: leading whitespace belongs to the first frame's name.
        stack, sep, value = line.rstrip().rpartition(" ")
        if not sep:
            continue  # no value field: malformed/foreign line
        # stack == "" is a legitimate single frame whose name is empty.
        if stack == CallTree.ROOT:
            tree.add_stack([], {metric: float(value)})  # root residual
        else:
            tree.add_stack(_split_frames(stack), {metric: float(value)})
    return tree


# -- speedscope --------------------------------------------------------------


def to_speedscope(tree: CallTree, metric: str = SAMPLES, name: str = "profile") -> dict:
    """Speedscope file-format dict (``shared.frames`` + one sampled profile).

    Each unique stack becomes one sample whose weight is the stack's residual
    value; non-positive residuals are skipped (speedscope weights must be
    positive — diff trees belong in the HTML diff view instead), as is any
    root residual (a weight needs at least one frame to attach to).
    """
    frames: list[dict] = []
    index: dict[str, int] = {}
    samples: list[list[int]] = []
    weights: list[float] = []
    for path, v in iter_folded(tree, metric):
        if v <= 0 or not path:
            continue
        stack = []
        for frame in path:
            i = index.get(frame)
            if i is None:
                i = index[frame] = len(frames)
                frames.append({"name": frame})
            stack.append(i)
        samples.append(stack)
        weights.append(v)
    return {
        "$schema": "https://www.speedscope.app/file-format-schema.json",
        "name": name,
        "exporter": "repro.core.export",
        "activeProfileIndex": 0,
        "shared": {"frames": frames},
        "profiles": [
            {
                "type": "sampled",
                "name": name,
                "unit": "none",
                "startValue": 0.0,
                "endValue": sum(weights),
                "samples": samples,
                "weights": weights,
            }
        ],
    }


def to_speedscope_json(tree: CallTree, metric: str = SAMPLES, name: str = "profile") -> str:
    return json.dumps(to_speedscope(tree, metric, name))


def prune_min_share(tree: CallTree, metric: str = SAMPLES, min_share: float = 0.0) -> CallTree:
    """Drop subtrees whose inclusive share of the root total is below
    ``min_share`` (the non-CSV formats' counterpart of ``ViewConfig.min_share``).
    """
    total = tree.total(metric)
    if min_share <= 0 or total <= 0:
        return tree
    cutoff = min_share * total

    def keep(node: CallNode) -> CallNode:
        out = CallNode(node.name, dict(node.metrics), dict(node.self_metrics))
        for name, c in node.children.items():
            if abs(c.metrics.get(metric, 0.0)) >= cutoff:
                out.children[name] = keep(c)
        return out

    return CallTree(keep(tree.root))


# -- cross-run diff trees ----------------------------------------------------


def build_diff_tree(baseline: CallTree, candidate: CallTree, metric: str = SAMPLES) -> CallTree:
    """Union tree annotating every call-site with its cross-run share delta.

    Each node's metrics carry the candidate value under ``metric``, the
    baseline value under ``"baseline"`` and ``"share_delta"`` = candidate
    share minus baseline share (each tree normalized to its own total, so run
    length cancels out).  Sign convention: **positive = the candidate grew**
    (regression red), negative = it shrank (improvement blue).
    """
    btot = baseline.total(metric) or 1.0
    ctot = candidate.total(metric) or 1.0

    def rec(bnode: CallNode | None, cnode: CallNode | None, name: str) -> CallNode:
        bv = bnode.metrics.get(metric, 0.0) if bnode is not None else 0.0
        cv = cnode.metrics.get(metric, 0.0) if cnode is not None else 0.0
        bs = bnode.self_metrics.get(metric, 0.0) if bnode is not None else 0.0
        cs = cnode.self_metrics.get(metric, 0.0) if cnode is not None else 0.0
        out = CallNode(
            name,
            {metric: cv, DIFF_BASELINE: bv, DIFF_SHARE_DELTA: cv / ctot - bv / btot},
            {metric: cs, DIFF_BASELINE: bs, DIFF_SHARE_DELTA: cs / ctot - bs / btot},
        )
        names: dict[str, None] = {}
        if bnode is not None:
            names.update(dict.fromkeys(bnode.children))
        if cnode is not None:
            names.update(dict.fromkeys(cnode.children))
        for n in names:
            out.children[n] = rec(
                bnode.children.get(n) if bnode is not None else None,
                cnode.children.get(n) if cnode is not None else None,
                n,
            )
        return out

    return CallTree(rec(baseline.root, candidate.root, CallTree.ROOT))


# -- self-contained flamegraph HTML ------------------------------------------

_FLAME_PAGE = """<!DOCTYPE html>
<html><head><meta charset="utf-8"><title>{title}</title>
<style>
 body {{ font: 12px ui-monospace, Menlo, monospace; background:#101014; color:#ddd; margin:0; }}
 #hdr {{ padding:8px 12px; }} #hdr b {{ color:#fff; }}
 #crumb span {{ color:#8cf; cursor:pointer; margin-left:.6em; }}
 #fg {{ position:relative; margin:0 12px 12px; }}
 .f {{ position:absolute; height:16px; line-height:15px; overflow:hidden; white-space:nowrap;
      font-size:11px; padding:0 3px; box-sizing:border-box; cursor:pointer; color:#15151a;
      border-right:1px solid #101014; border-bottom:1px solid #101014; border-radius:2px; }}
 .f:hover {{ filter: brightness(1.25); }}
 #legend {{ color:#888; padding:0 12px 10px; }}
</style></head>
<body>
<div id="hdr"><b>{title}</b> &mdash; metric <b>{metric}</b>, total <b>{total:.6g}</b>
 <span id="crumb"></span></div>
<div id="fg"></div>
<div id="legend">{legend}</div>
<script id="fgdata" type="application/json">{data}</script>
<script>
(function () {{
 "use strict";
 var root = JSON.parse(document.getElementById('fgdata').textContent);
 var DIFF = !!root.diff;
 var ROOF = !!root.roofline;
 var el = document.getElementById('fg'), crumb = document.getElementById('crumb');
 (function link(n) {{ n.c.forEach(function (k) {{ k.p = n; link(k); }}); }})(root);
 var zoomed = root;
 function pct(x) {{ return (100 * x).toFixed(2) + '%'; }}
 function hue(s) {{
   var h = 0;
   for (var i = 0; i < s.length; i++) h = (h * 31 + s.charCodeAt(i)) >>> 0;
   return h;
 }}
 function color(n) {{
   if (DIFF) {{
     var d = Math.max(-1, Math.min(1, (n.d || 0) * 4));
     if (d >= 0) return 'rgb(255,' + Math.round(225 - 150 * d) + ',' + Math.round(160 - 120 * d) + ')';
     return 'rgb(' + Math.round(160 + 120 * d) + ',' + Math.round(205 + 40 * d) + ',255)';
   }}
   if (ROOF) {{
     var o = Math.max(0, Math.min(1, n.o || 0));
     var l = (74 - 28 * o).toFixed(0) + '%';
     if (n.t === 'compute') return 'hsl(28,90%,' + l + ')';
     if (n.t === 'memory') return 'hsl(210,85%,' + l + ')';
     if (n.t === 'collective') return 'hsl(130,55%,' + l + ')';
     return 'hsl(240,3%,62%)';
   }}
   var h = hue(n.n);
   return 'hsl(' + (h % 55) + ',' + (55 + h % 25) + '%,' + (52 + h % 12) + '%)';
 }}
 function depth(n) {{
   var d = 1;
   n.c.forEach(function (k) {{ d = Math.max(d, 1 + depth(k)); }});
   return d;
 }}
 function title(n) {{
   var t = n.n + '\\nvalue=' + n.v;
   if (DIFF) t += '\\nbaseline=' + n.b + '\\n\\u0394share=' + pct(n.d || 0);
   else if (root.v) t += '  (' + pct(n.v / root.v) + ' of total)';
   if (ROOF && n.t) t += '\\ndominant=' + n.t + '\\noccupancy=' + pct(n.o || 0);
   return t;
 }}
 function render() {{
   el.innerHTML = '';
   var W = el.clientWidth || 1200;
   el.style.height = (depth(zoomed) * 16 + 2) + 'px';
   (function rec(n, x, width, lvl) {{
     if (width < 0.4) return;
     var d = document.createElement('div');
     d.className = 'f';
     d.style.left = x.toFixed(1) + 'px';
     d.style.top = (lvl * 16) + 'px';
     d.style.width = Math.max(1, width - 1).toFixed(1) + 'px';
     d.style.background = color(n);
     d.textContent = width > 34 ? n.n : '';
     d.title = title(n);
     d.onclick = function (ev) {{ ev.stopPropagation(); zoom(n); }};
     el.appendChild(d);
     var sumc = 0;
     n.c.forEach(function (k) {{ sumc += k.w; }});
     if (!sumc) return;
     var unit = width / Math.max(n.w, sumc);
     var cx = x;
     n.c.forEach(function (k) {{ rec(k, cx, k.w * unit, lvl + 1); cx += k.w * unit; }});
   }})(zoomed, 0, W, 0);
   var trail = [], n = zoomed;
   while (n) {{ trail.unshift(n); n = n.p; }}
   crumb.innerHTML = '';
   trail.forEach(function (t) {{
     var s = document.createElement('span');
     s.textContent = t === root ? '[reset zoom]' : t.n;
     s.onclick = function () {{ zoom(t); }};
     crumb.appendChild(s);
   }});
 }}
 function zoom(n) {{ zoomed = n; render(); }}
 window.onresize = render;
 render();
}})();
</script>
</body></html>
"""


def _fg_data(node: CallNode, metric: str, diff: bool, roofline: bool = False) -> dict:
    v = node.metrics.get(metric, 0.0)
    d: dict = {"n": node.name, "v": v, "w": abs(v), "c": []}
    if diff:
        b = node.metrics.get(DIFF_BASELINE, 0.0)
        d["b"] = b
        d["d"] = node.metrics.get(DIFF_SHARE_DELTA, 0.0)
        d["w"] = abs(v) + abs(b)
    if roofline:
        from .planes import OCCUPANCY, dominant_term

        term = dominant_term(node.metrics)
        if term is not None:
            d["t"] = term
            d["o"] = node.metrics.get(OCCUPANCY, 0.0)
    for c in sorted(node.children.values(), key=lambda c: -abs(c.metrics.get(metric, 0.0))):
        d["c"].append(_fg_data(c, metric, diff, roofline))
    return d


def flamegraph_html(
    tree: CallTree,
    metric: str = SAMPLES,
    title: str = "flamegraph",
    *,
    diff: bool = False,
    roofline: bool = False,
) -> str:
    """One self-contained interactive flamegraph page (no external resources).

    ``diff=True`` expects a tree from :func:`build_diff_tree`: rect widths
    combine baseline+candidate mass and colors encode the share delta
    (red = candidate gained share, blue = lost).

    ``roofline=True`` expects a merged-plane tree from
    :func:`repro.core.planes.annotate_tree`: each frame is colored by its
    dominant roofline term (orange = compute, blue = memory, green =
    collective; gray = no device annotation), with the shade deepening as the
    node's roofline occupancy grows.
    """
    data = _fg_data(tree.root, metric, diff, roofline)
    data["diff"] = diff
    data["roofline"] = roofline
    if diff:
        legend = "color: share delta vs baseline &mdash; red grew, blue shrank; click a frame to zoom"
    elif roofline:
        legend = (
            "color: dominant roofline term &mdash; "
            '<span style="color:hsl(28,90%,55%)">compute</span>, '
            '<span style="color:hsl(210,85%,60%)">memory</span>, '
            '<span style="color:hsl(130,55%,50%)">collective</span>, '
            "gray = no device annotation; darker = higher roofline occupancy; "
            "click a frame to zoom"
        )
    else:
        legend = "click a frame to zoom; click [reset zoom] to return"
    # `</` must not appear verbatim inside the <script> data island (a frame
    # named "</script>" would terminate it); "<\/" is the same JSON string.
    blob = json.dumps(data).replace("</", "<\\/")
    return _FLAME_PAGE.format(
        title=_html.escape(title),
        metric=_html.escape(metric),
        total=tree.total(metric),
        legend=legend,
        data=blob,
    )


def diff_flamegraph_html(
    baseline: CallTree,
    candidate: CallTree,
    metric: str = SAMPLES,
    title: str = "diff flamegraph (red = candidate grew)",
) -> str:
    """Baseline-vs-candidate flamegraph with share-delta coloring."""
    return flamegraph_html(build_diff_tree(baseline, candidate, metric), metric, title, diff=True)


# -- the view-routed export front door ---------------------------------------


def resolve_view(view: str | object | None):
    """Normalize a view argument: name -> library ViewConfig, None passes."""
    from .report import ViewConfig

    if isinstance(view, str):
        from .views_library import VIEWS

        if view not in VIEWS:
            raise KeyError(f"unknown view {view!r} (see views_library.list_views())")
        return VIEWS[view]
    if view is not None and not isinstance(view, ViewConfig):
        raise TypeError(f"view must be a ViewConfig or view name, got {type(view).__name__}")
    return view


def prepare_view(
    tree: CallTree,
    view,
    metric: str | None = None,
    fmt: str | None = None,
) -> tuple[CallTree, str, str | None]:
    """Apply a view (zoom/filters/level **and** min_share pruning) exactly once.

    Returns ``(applied_tree, metric, marker)``: ``marker`` is non-None when a
    non-empty input tree came out empty — the no-match / filter-emptied /
    min_share-pruned-everything verdicts the CLI and server turn into exit
    code 4 / HTTP 404 so a vacuous export never ships silently.  Pass ``fmt``
    to also mark structural stacklessness (a level=0 fold leaves a root-only
    tree): CSV still carries the total in its header, but the stack-shaped
    formats (``folded``/``speedscope``) would render nothing at all.
    """
    view = resolve_view(view)
    if view is None:
        return tree, metric or SAMPLES, None
    metric = metric or view.metric
    applied = view.apply(tree)
    pruned = prune_min_share(applied, metric, view.min_share) if view.min_share > 0 else applied
    marker = None
    if not pruned.root.children and tree.root.children:
        from .report import min_share_marker

        marker = view.empty_marker(tree)
        if marker is None and applied.root.children:
            marker = min_share_marker(view.min_share)
        if marker is None and fmt in ("folded", "speedscope"):
            marker = f"# empty export: the view left no stacks for fmt={fmt} (level=0?)"
    return pruned, metric, marker


def export_tree(
    tree: CallTree,
    fmt: str = "csv",
    *,
    view: str | object | None = None,
    metric: str | None = None,
    title: str = "calltree",
    diff: bool = False,
    roofline: bool = False,
) -> str:
    """Render ``tree`` in any supported format, optionally through a view.

    ``view`` is a :class:`~repro.core.report.ViewConfig` or the name of one in
    :data:`repro.core.views_library.VIEWS`; its zoom/level/filters/min_share
    apply to every format (the paper's exploration configs, now export-format
    agnostic).  ``metric`` overrides the view's metric (default ``samples``).
    Callers that must fail loudly on vacuously-empty views use
    :func:`prepare_view` first and pass the applied tree here with no view.
    """
    if fmt not in EXPORT_FORMATS:
        raise ValueError(f"unknown format {fmt!r} (choose from {', '.join(EXPORT_FORMATS)})")
    view = resolve_view(view)
    if view is not None and fmt == "csv":
        from dataclasses import replace

        return replace(view, metric=metric or view.metric).to_csv(tree)
    applied, metric, _marker = prepare_view(tree, view, metric)
    if view is not None and view.name not in title:
        title = f"{title} [{view.name}]"
    if fmt == "csv":
        from .report import ViewConfig as _VC

        return _VC(name=title, metric=metric).to_csv(applied)
    if fmt == "folded":
        return to_folded(applied, metric)
    if fmt == "speedscope":
        return to_speedscope_json(applied, metric, name=title)
    if fmt == "json":
        return applied.to_json()
    return flamegraph_html(applied, metric, title=title, diff=diff, roofline=roofline)
