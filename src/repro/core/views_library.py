"""Predefined exploration views (artifact §G: the paper ships 125 parser
configs — root function, fold level, white/blacklists, plot knobs).

These are the curated equivalents for this framework's component vocabulary,
usable against either profiling plane:

    from repro.core.views_library import VIEWS, render_view
    print(render_view(tree, "attention_internals", metric="flops"))

Each view is a :class:`~repro.core.report.ViewConfig`; ``save_views`` writes
the whole library as CSVs next to a run's reports.
"""

from __future__ import annotations

from .calltree import CallTree
from .report import ViewConfig

VIEWS: dict[str, ViewConfig] = {
    v.name: v
    for v in [
        # ---- holistic (zoom-out) --------------------------------------------------
        ViewConfig(name="top_level", level=2),
        ViewConfig(name="train_step_phases", root="train_step", level=2),
        ViewConfig(name="serve_step_phases", root="serve_step", level=2),
        ViewConfig(name="model_components", root="model", level=3),
        # ---- per-component (zoom-in) ---------------------------------------------
        ViewConfig(name="attention_internals", root="attention", level=-1),
        ViewConfig(name="attention_scores_only", root="attention", whitelist=["scores", "chunk_scores"]),
        ViewConfig(name="moe_internals", root="moe", level=2),
        ViewConfig(name="moe_dispatch_combine", root="moe", whitelist=["dispatch", "combine", "a2a"]),
        ViewConfig(name="recurrent_internals", root="recurrent_block", level=-1),
        ViewConfig(name="rglru_scan", root="rg_lru", level=-1),
        ViewConfig(name="mlstm_internals", root="mlstm", level=2),
        ViewConfig(name="optimizer", root="optimizer", level=2),
        ViewConfig(name="lm_head_and_loss", root="loss", level=2),
        # ---- cost-specific -------------------------------------------------------
        ViewConfig(name="collectives_by_site", metric="coll_bytes", level=-1, min_share=0.01),
        ViewConfig(name="memory_traffic_hotspots", metric="bytes", level=6, min_share=0.02),
        ViewConfig(name="flops_by_layer_stage", metric="flops", level=5, min_share=0.02),
        # ---- host plane ----------------------------------------------------------
        ViewConfig(name="host_threads", level=1),
        ViewConfig(name="host_data_pipeline", root="_prefetch_worker", level=-1),
        ViewConfig(name="host_dispatch_noise", whitelist=["jax::"], level=-1),
        ViewConfig(name="host_checkpoint_writer", root="repro-ckpt", level=-1),
        # ---- anomaly forensics (what the detector saw) ----------------------------
        ViewConfig(name="dominant_leaves", level=-1, min_share=0.10),
    ]
}


def render_view(tree: CallTree, name: str, metric: str | None = None) -> str:
    cfg = VIEWS[name]
    if metric is not None:
        from dataclasses import replace

        cfg = replace(cfg, metric=metric)
    return cfg.to_csv(tree)


def list_views() -> list[str]:
    return sorted(VIEWS)
