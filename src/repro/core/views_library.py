"""Predefined exploration views (artifact §G: the paper ships 125 parser
configs — root function, fold level, white/blacklists, plot knobs).

These are the curated equivalents for this framework's component vocabulary,
usable against either profiling plane:

    from repro.core.views_library import VIEWS, render_view
    print(render_view(tree, "attention_internals", metric="flops"))

Each view is a :class:`~repro.core.report.ViewConfig`; ``save_views`` writes
the whole library as CSVs next to a run's reports.
"""

from __future__ import annotations

from .calltree import CallTree
from .report import ViewConfig

VIEWS: dict[str, ViewConfig] = {
    v.name: v
    for v in [
        # ---- holistic (zoom-out) --------------------------------------------------
        ViewConfig(name="top_level", level=2),
        ViewConfig(name="train_step_phases", root="train_step", level=2),
        ViewConfig(name="serve_step_phases", root="serve_step", level=2),
        ViewConfig(name="model_components", root="model", level=3),
        # ---- per-component (zoom-in) ---------------------------------------------
        ViewConfig(name="attention_internals", root="attention", level=-1),
        ViewConfig(name="attention_scores_only", root="attention", whitelist=["scores", "chunk_scores"]),
        ViewConfig(name="moe_internals", root="moe", level=2),
        ViewConfig(name="moe_dispatch_combine", root="moe", whitelist=["dispatch", "combine", "a2a"]),
        ViewConfig(name="recurrent_internals", root="recurrent_block", level=-1),
        ViewConfig(name="rglru_scan", root="rg_lru", level=-1),
        ViewConfig(name="mlstm_internals", root="mlstm", level=2),
        ViewConfig(name="optimizer", root="optimizer", level=2),
        ViewConfig(name="lm_head_and_loss", root="loss", level=2),
        # ---- cost-specific -------------------------------------------------------
        ViewConfig(name="collectives_by_site", metric="coll_bytes", level=-1, min_share=0.01),
        ViewConfig(name="memory_traffic_hotspots", metric="bytes", level=6, min_share=0.02),
        ViewConfig(name="flops_by_layer_stage", metric="flops", level=5, min_share=0.02),
        # ---- host plane ----------------------------------------------------------
        ViewConfig(name="host_threads", level=1),
        ViewConfig(name="host_data_pipeline", root="_prefetch_worker", level=-1),
        ViewConfig(name="host_dispatch_noise", whitelist=["jax::"], level=-1),
        ViewConfig(name="host_checkpoint_writer", root="repro-ckpt", level=-1),
        # ---- anomaly forensics (what the detector saw) ----------------------------
        ViewConfig(name="dominant_leaves", level=-1, min_share=0.10),
        # ---- timeline / differential -----------------------------------------------
        # Applied to one sealed epoch *window* (not the cumulative tree):
        ViewConfig(name="epoch_window_hotspots", level=-1, min_share=0.05),
        ViewConfig(name="epoch_window_phases", level=2),
        # Applied to a cross-run diff context before rendering share deltas:
        ViewConfig(name="diff_regression_context", level=4, min_share=0.01),
    ]
}


# -- timeline views (epoch sequences, not single trees) ----------------------


def epoch_share_vectors(epochs, metric: str = "samples") -> list[dict[str, float]]:
    """Flattened share vector per sealed epoch window (phase-segmentation input)."""
    from .detector import flat_shares

    return [flat_shares(window, metric) for _meta, window, _cum in epochs]


def timeline_table(epochs, metric: str = "samples", k: int = 1) -> str:
    """One line per sealed epoch: when, how much activity, where it went."""
    lines = [f"{'epoch':>5}  {'wall_time':>13}  {'window':>9}  {'progress':>8}  top self path"]
    for meta, window, _cum in epochs:
        tops = window.hot_paths(metric, k=k, self_only=True)
        top = "/".join(tops[0][0]) + f" ({tops[0][1]:.0%})" if tops else "-"
        lines.append(
            f"{meta.epoch:>5}  {meta.wall_time:>13.2f}  {window.total(metric):>9.6g}  "
            f"{meta.progress:>8.6g}  {top}"
        )
    return "\n".join(lines)


def phase_table(epochs, boundary: float = 0.25, metric: str = "samples", k: int = 3) -> str:
    """Phase segmentation over sealed epochs (the paper's time-evolution view).

    Splits the epoch sequence wherever the window share distribution jumps by
    more than ``boundary`` (TV distance) and summarizes each phase's top
    self-time functions — "when did the behavior change, and into what".
    """
    from .detector import segment_phases
    from .report import name_shares

    if not epochs:
        return "# empty timeline"
    vectors = epoch_share_vectors(epochs, metric)
    lines = [f"# {len(epochs)} epoch(s), boundary={boundary}"]
    for start, end in segment_phases(vectors, boundary):
        merged = CallTree()
        wall0 = epochs[start][0].wall_time
        wall1 = epochs[end][0].wall_time
        for _meta, window, _cum in epochs[start : end + 1]:
            merged.merge(window)  # merge only reads its argument
        top = sorted(name_shares(merged, metric).items(), key=lambda kv: -kv[1])[:k]
        summary = ", ".join(f"{name} {share:.0%}" for name, share in top) or "-"
        lines.append(
            f"phase epochs {epochs[start][0].epoch}..{epochs[end][0].epoch} "
            f"({max(0.0, wall1 - wall0):.1f}s, {merged.total(metric):.6g} {metric}): {summary}"
        )
    return "\n".join(lines)


def render_view(tree: CallTree, name: str, metric: str | None = None) -> str:
    cfg = VIEWS[name]
    if metric is not None:
        from dataclasses import replace

        cfg = replace(cfg, metric=metric)
    return cfg.to_csv(tree)


def export_view(tree: CallTree, name: str, fmt: str = "csv", metric: str | None = None) -> str:
    """Render a library view in any export format (folded/speedscope/html/...).

    The format-agnostic sibling of :func:`render_view`: the whole 20+ view
    library becomes flamegraph/speedscope material through one call.
    """
    from .export import export_tree

    return export_tree(tree, fmt, view=name, metric=metric, title=name)


def list_views() -> list[str]:
    return sorted(VIEWS)
