"""Sampling profiler backends — host plane (paper §III-D "profiler").

The paper attaches a stand-alone helper *process* to gem5 via Linux
``perf_event`` and periodically captures call-chains without instrumenting the
target.  Two backends implement that contract here, selected by
:attr:`SamplerConfig.backend` and constructed via :func:`make_sampler`:

* ``"thread"`` — :class:`StackSampler`, a dedicated in-process helper thread
  that every ``period`` seconds snapshots **every** Python thread's stack via
  ``sys._current_frames()``, resolves "symbols" from code objects, classifies
  each frame by origin (``repro``/``jax``/``numpy``/``py``), merges each
  sample into a :class:`~repro.core.calltree.CallTree` on the fly, records a
  ``(t, depth)`` timeline (paper Fig. 2), and optionally samples
  ``/proc/self`` cpu/rss.  Cheap to wire up, but resolution/classification/
  merging all burn target-process cycles.

* ``"daemon"`` — :class:`repro.profilerd.agent.DaemonBackend`, the paper's
  actual architecture: the target only publishes **raw, unresolved** frame
  records into a lock-free mmap ring spool; a separate daemon process
  (``python -m repro.profilerd``) resolves, classifies, merges, runs the
  dominance/stall detectors, and serves live status + reports.  See
  :mod:`repro.profilerd`.

Symbol resolution and origin-collapse (:func:`frame_symbol`,
:func:`collapse_stack`) are shared by both backends, so they produce
identical trees from identical frames — a tested invariant.  On a TPU pod
each host runs its own backend and the per-host trees are merged with
``CallTree.merge`` at rendezvous (see ``launch/launcher.py``).
"""

from __future__ import annotations

import os
import sys
import threading
import time
from dataclasses import dataclass
from collections.abc import Sequence
from typing import Protocol, runtime_checkable

from .calltree import CallTree

# Default matches the paper (§V-E): 0.5 s balances detail vs overhead.
DEFAULT_PERIOD_S = 0.5

# Ceiling on the thread backend's interned-ingest cache (one CallNode chain
# per unique (thread, stack)); pathological stack diversity degrades to the
# uncached path instead of growing target memory without bound.
PATH_CACHE_CAP = 1 << 16

# Environment seam used by the launcher's per-host daemons: when set, jobs
# built through make_sampler publish to this spool for an external
# `python -m repro.profilerd` to drain.
ENV_SPOOL = "REPRO_PROFILERD_SPOOL"
ENV_PERIOD = "REPRO_PROFILERD_PERIOD"
# Where the external daemon publishes this target's artifacts.  A shared
# multi-target daemon writes per-target trees under <out>/targets/<name>/,
# not <spool>.d/, so the launcher passes the per-target dir through this.
ENV_OUT = "REPRO_PROFILERD_OUT"


def classify_frame(filename: str) -> str:
    """Coarse symbol "origin" classification (paper: gem5 vs pybind vs libc)."""
    if "/repro/" in filename or filename.endswith("repro"):
        return "repro"
    if "/jax/" in filename or "/jaxlib/" in filename:
        return "jax"
    if "/numpy/" in filename:
        return "numpy"
    return "py"


def frame_symbol(frame) -> str:
    code = frame.f_code
    origin = classify_frame(code.co_filename)
    return f"{origin}::{code.co_name}"


# Threads whose names carry this prefix are profiler infrastructure (helper,
# watchdog, agent) and are excluded from every backend's capture — part of
# the "identical trees from identical frames" parity contract.  The prefix is
# deliberately narrower than the framework's ``repro-`` convention: workload
# threads like ``repro-data-prefetch`` and ``repro-ckpt-writer`` are part of
# the program under observation and must stay visible in profiles.
PROFILER_THREAD_PREFIX = "repro-prof"


def is_profiler_thread(name: str) -> bool:
    return name.startswith(PROFILER_THREAD_PREFIX)


def open_psutil_process():
    """The optional /proc rusage handle both backends sample, or None."""
    try:
        import psutil

        return psutil.Process(os.getpid())
    except Exception:  # pragma: no cover - psutil is optional
        return None


def collapse_stack(symbols: Sequence[str], collapse_origins: Sequence[str]) -> list[str]:
    """Fold runs of frames from ``collapse_origins`` into one ``origin::*`` node.

    The paper's answer to "20 pybind frames bury the interesting ones"; shared
    by the thread backend and the daemon's resolver so both produce identical
    stacks.
    """
    if not collapse_origins:
        return list(symbols)
    collapsed: list[str] = []
    for sym in symbols:
        origin = sym.split("::", 1)[0]
        if origin in collapse_origins:
            star = f"{origin}::*"
            if collapsed and collapsed[-1] == star:
                continue
            collapsed.append(star)
        else:
            collapsed.append(sym)
    return collapsed


@dataclass
class SamplerConfig:
    period_s: float = DEFAULT_PERIOD_S
    max_depth: int = 256
    # Collapse consecutive frames from these origins into one node.
    collapse_origins: tuple[str, ...] = ()
    record_timeline: bool = True
    record_rusage: bool = True
    # -- backend seam ------------------------------------------------------
    # "thread": in-process helper thread (StackSampler).
    # "daemon": raw-frame publisher + out-of-process repro.profilerd daemon.
    backend: str = "thread"
    # Daemon backend: spool file the agent publishes to (default: a temp path).
    spool_path: str | None = None
    spool_bytes: int = 4 << 20
    # Daemon backend: wire protocol the agent emits (2 = stack-interned
    # STACKDEF/SAMPLE2 records, 1 = legacy per-frame SAMPLE records).
    wire_version: int = 2
    # Daemon backend: where the daemon publishes status/tree/report files
    # (default: "<spool_path>.d").
    daemon_out: str | None = None
    # None -> auto: spawn `python -m repro.profilerd` iff no explicit spool
    # path was given (an explicit spool means an external daemon attaches).
    spawn_daemon: bool | None = None
    # Daemon backend: regional aggregator URL — the spawned daemon pushes
    # every sealed epoch there (`attach --push`); node name defaults to the
    # short hostname.  Ignored when an external daemon drains the spool
    # (configure --push on that daemon instead).
    push_url: str | None = None
    push_node: str | None = None


@runtime_checkable
class SamplerBackend(Protocol):
    """What the drivers (train/serve/watchdog/benchmarks) require of a backend."""

    def start(self) -> "SamplerBackend": ...

    def stop(self) -> CallTree: ...

    def snapshot(self) -> CallTree: ...

    def sample_now(self) -> None: ...

    def depth_trace(self) -> list[tuple[float, int]]: ...


def make_sampler(config: SamplerConfig | None = None) -> SamplerBackend:
    """Construct the backend selected by ``config.backend``.

    The ``REPRO_PROFILERD_SPOOL`` environment variable overrides the choice to
    the daemon backend with an externally-managed daemon — this is how the
    launcher attaches one profilerd per supervised host process without the
    job's own config knowing about it.
    """
    config = config or SamplerConfig()
    env_spool = os.environ.pop(ENV_SPOOL, None)
    if env_spool:
        from dataclasses import replace

        # The override is consumed (popped), not just read: a spool belongs to
        # exactly one publisher, and grandchild processes inheriting the
        # variable would recreate the file out from under the daemon's mmap.
        period = config.period_s
        env_period = os.environ.pop(ENV_PERIOD, None)
        if env_period:
            try:
                period = float(env_period)
            except ValueError:
                pass
        env_out = os.environ.pop(ENV_OUT, None)
        config = replace(
            config, backend="daemon", spool_path=env_spool, spawn_daemon=False,
            period_s=period, daemon_out=env_out or config.daemon_out,
        )
    if config.backend == "thread":
        return StackSampler(config)
    if config.backend == "daemon":
        from repro.profilerd.agent import DaemonBackend

        return DaemonBackend(config)
    raise ValueError(f"unknown sampler backend {config.backend!r} (expected 'thread' or 'daemon')")


@dataclass
class TimelinePoint:
    t: float
    depth: int
    thread: str


@dataclass
class RusagePoint:
    t: float
    cpu_s: float
    rss_bytes: int


class StackSampler:
    """The ``thread`` backend: sampling helper thread inside the target."""

    def __init__(self, config: SamplerConfig | None = None):
        self.config = config or SamplerConfig()
        self.tree = CallTree()
        # Interned-ingest cache mirroring the daemon's (profilerd.ingest):
        # (thread_name, *stack) -> prebuilt CallNode chain.  A repeated stack
        # costs one tuple hash plus an O(depth) float-add loop instead of
        # per-frame dict bumps in add_stack.
        self._path_cache: dict[tuple, list] = {}
        self.timeline: list[TimelinePoint] = []
        self.rusage: list[RusagePoint] = []
        self.n_samples = 0
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._t0 = time.monotonic()
        self._psutil_proc = open_psutil_process() if self.config.record_rusage else None

    # -- capture -----------------------------------------------------------------

    def _stack_of(self, frame) -> list[str]:
        rev: list[str] = []
        depth = 0
        while frame is not None and depth < self.config.max_depth:
            rev.append(frame_symbol(frame))
            frame = frame.f_back
            depth += 1
        rev.reverse()  # root -> leaf
        return collapse_stack(rev, self.config.collapse_origins)

    def _capture(self) -> None:
        helper = self._thread.ident if self._thread is not None else None
        names = {t.ident: t.name for t in threading.enumerate()}
        now = time.monotonic() - self._t0
        frames = sys._current_frames()
        with self._lock:
            for ident, frame in frames.items():
                # Profiler infrastructure lives "outside the cgroup": neither
                # the helper itself nor watchdog/report threads are profiled.
                # (A synchronous sample_now() caller *is* profiled — it is
                # target code asking for a sample of itself.)
                if ident == helper or is_profiler_thread(names.get(ident, "")):
                    continue
                stack = self._stack_of(frame)
                tname = names.get(ident, f"tid{ident}")
                key = (tname, *stack)
                chain = self._path_cache.get(key)
                if chain is None:
                    chain = self.tree.path_nodes([f"thread::{tname}"] + stack)
                    if len(self._path_cache) < PATH_CACHE_CAP:
                        self._path_cache[key] = chain
                CallTree.add_stack_nodes(chain)
                if self.config.record_timeline:
                    self.timeline.append(TimelinePoint(now, len(stack), tname))
            self.n_samples += 1
            if self._psutil_proc is not None:
                try:
                    cpu = self._psutil_proc.cpu_times()
                    rss = self._psutil_proc.memory_info().rss
                    self.rusage.append(RusagePoint(now, cpu.user + cpu.system, rss))
                except Exception:
                    pass

    def _run(self) -> None:
        while not self._stop.wait(self.config.period_s):
            try:
                self._capture()
            except Exception:
                # The profiler must never take down the run it observes.
                pass

    # -- lifecycle ------------------------------------------------------------------

    def start(self) -> "StackSampler":
        if self._thread is not None:
            raise RuntimeError("sampler already started")
        self._t0 = time.monotonic()
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, name="repro-prof-helper", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> CallTree:
        if self._thread is not None:
            self._stop.set()
            self._thread.join(timeout=5.0)
            self._thread = None
        return self.snapshot()

    def __enter__(self) -> "StackSampler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- access -----------------------------------------------------------------------

    def snapshot(self) -> CallTree:
        """Thread-safe copy of the merged tree (detector windows use this)."""
        with self._lock:
            return self.tree.copy()

    def sample_now(self) -> None:
        """Force one synchronous sample (used by tests and the detector loop)."""
        self._capture()

    def depth_trace(self) -> list[tuple[float, int]]:
        with self._lock:
            return [(p.t, p.depth) for p in self.timeline]
